"""Batched serving example: prefill a batch of prompts, decode with the
jit'd serve_step (the same function the decode-shape dry-run cells lower).

  PYTHONPATH=src python examples/serve_batch.py [--arch qwen2.5-32b]

Uses the reduced (smoke) config of the chosen assigned architecture so it
runs on CPU; the full config is exercised via the dry-run.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models import transformer as T
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-32b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=args.prompt_len + args.new_tokens,
                      temperature=args.temperature)

    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.encoder_layers:
        batch["frames"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    if cfg.n_image_tokens:
        batch["image_embeds"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.n_image_tokens, cfg.d_model)), jnp.float32)

    t0 = time.perf_counter()
    out = eng.generate(batch, max_new_tokens=args.new_tokens)
    dt = time.perf_counter() - t0
    toks = out.shape[0] * out.shape[1]
    print(f"arch={args.arch} (reduced) batch={args.batch}")
    for i in range(args.batch):
        print(f"  seq {i}: {np.asarray(out[i]).tolist()}")
    print(f"{toks} tokens in {dt:.2f}s ({toks / dt:.1f} tok/s incl. "
          "prefill+compile)")


if __name__ == "__main__":
    main()
