"""Serving example: dense fixed-batch or paged continuous batching.

  PYTHONPATH=src python examples/serve_batch.py [--arch qwen2.5-32b]
      [--engine paged|dense]

``--engine dense`` (any family): one prefill + jit'd decode steps over a
dense cache, in-trace sampling, eos early exit.

``--engine paged`` (attn / local / attn_moe families): the production
path (DESIGN.md §12, docs/serving.md) — two tenant sessions submit
staggered requests with different sampling params into a block-pool KV
cache; the continuous-batching scheduler admits and retires them
between jit'd flash-decode steps, one request streams token-by-token,
another is cancelled mid-flight, and the pool stats are printed at the
end. With ``--metrics`` the obs layer (DESIGN.md §13,
docs/observability.md) is enabled for the run: a per-request latency
table (queue wait / TTFT / mean ITL / E2E) is printed from the handle
timestamps and a Prometheus text-exposition snapshot plus a Chrome
trace are written under ``--metrics-dir``.

Uses the reduced (smoke) config of the chosen architecture so it runs
on CPU; the full config is exercised via the dry-run.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models import transformer as T
from repro.serve import (PagedServeEngine, SamplingParams, ServeEngine,
                         Session, paged_supported)


def _prompt_batch(cfg, rng, batch, prompt_len):
    batch_d = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)}
    if cfg.encoder_layers:
        batch_d["frames"] = jnp.asarray(rng.standard_normal(
            (batch, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    if cfg.n_image_tokens:
        batch_d["image_embeds"] = jnp.asarray(rng.standard_normal(
            (batch, cfg.n_image_tokens, cfg.d_model)), jnp.float32)
    return batch_d


def run_dense(cfg, params, args, rng):
    eng = ServeEngine(cfg, params,
                      max_len=args.prompt_len + args.new_tokens,
                      temperature=args.temperature)
    batch = _prompt_batch(cfg, rng, args.batch, args.prompt_len)
    t0 = time.perf_counter()
    out = eng.generate(batch, max_new_tokens=args.new_tokens)
    dt = time.perf_counter() - t0
    toks = out.shape[0] * out.shape[1]
    print(f"arch={args.arch} (reduced) dense batch={args.batch}")
    for i in range(args.batch):
        print(f"  seq {i}: {np.asarray(out[i]).tolist()}")
    print(f"{toks} tokens in {dt:.2f}s ({toks / dt:.1f} tok/s incl. "
          "prefill+compile)")


def run_paged(cfg, params, args, rng):
    eng = PagedServeEngine(
        cfg, params, block_size=8,
        num_blocks=args.batch * 2
        * -(-(args.prompt_len + args.new_tokens) // 8),
        num_slots=args.batch, max_prefill_len=args.prompt_len,
        prefill_chunk=8, num_splits=2)
    tenant_a = Session(eng, "tenant-a")
    tenant_b = Session(eng, "tenant-b", default_sampling=SamplingParams(
        temperature=max(args.temperature, 0.7), top_k=50, top_p=0.95,
        seed=1))

    def prompt(n):
        return rng.integers(0, cfg.vocab_size, (n,))

    t0 = time.perf_counter()
    # tenant A: greedy requests, one streamed token-by-token
    streamed = tenant_a.submit(prompt(args.prompt_len),
                               max_new_tokens=args.new_tokens)
    rest = [tenant_a.submit(prompt(args.prompt_len - 2),
                            max_new_tokens=args.new_tokens)]
    # tenant B: sampled requests admitted mid-flight, one cancelled
    eng.step()
    rest.append(tenant_b.submit(prompt(args.prompt_len),
                                max_new_tokens=args.new_tokens))
    doomed = tenant_b.submit(prompt(args.prompt_len),
                             max_new_tokens=4 * args.new_tokens)
    print(f"arch={args.arch} (reduced) paged slots={args.batch}")
    got = []
    for tok in streamed.stream():
        got.append(tok)
        if len(got) == 3:
            doomed.cancel()
    print(f"  {streamed.request.request_id} (streamed): {got}")
    eng.run()
    for h in rest:
        print(f"  {h.request.request_id} ({h.finish_reason}): {h.tokens}")
    print(f"  {doomed.request.request_id}: {doomed.finish_reason} after "
          f"{len(doomed.tokens)} tokens (blocks returned to pool)")
    dt = time.perf_counter() - t0
    stats = eng.stats()
    toks = sum(len(h.tokens) for h in (streamed, doomed, *rest))
    print(f"{toks} tokens in {dt:.2f}s ({toks / dt:.1f} tok/s incl. "
          "prefill+compile)")
    print(f"pool: {stats['used_blocks']}/{stats['num_blocks']} blocks used "
          f"after drain, paged {stats['cache_bytes'] / 1e6:.2f}MB vs "
          f"dense-equivalent {stats['dense_bytes_equivalent'] / 1e6:.2f}MB, "
          f"{stats['steps']} decode steps")
    if args.metrics:
        _report_metrics(args, (streamed, doomed, *rest))


def _report_metrics(args, handles):
    import os

    from repro import obs

    def fmt(v, spec):
        # a request cancelled before admission has no queue_wait/ttft
        return format(v, spec) if v is not None else "-"

    print("\nper-request latency (seconds; quantized to decode steps):")
    print(f"  {'request':<22} {'finish':<10} {'toks':>4} {'queue':>7} "
          f"{'ttft':>7} {'itl_mean':>8} {'e2e':>7}")
    for h in handles:
        s = h.latency_summary()
        print(f"  {s['request_id']:<22} {s['finish_reason']:<10} "
              f"{s['n_tokens']:>4} {fmt(s['queue_wait'], '.3f'):>7} "
              f"{fmt(s['ttft'], '.3f'):>7} {fmt(s['itl_mean'], '.4f'):>8} "
              f"{fmt(s['e2e'], '.3f'):>7}")
    r = obs.registry()
    ttft, itl = r.get("serve_ttft_seconds"), r.get("serve_itl_seconds")
    print(f"ttft p50/p99: {ttft.quantile(0.5):.3f}/{ttft.quantile(0.99):.3f}"
          f"  itl p50/p99: {itl.quantile(0.5):.4f}/{itl.quantile(0.99):.4f}")
    os.makedirs(args.metrics_dir, exist_ok=True)
    prom = obs.write_prometheus(
        os.path.join(args.metrics_dir, "metrics.prom"))
    trace = obs.write_chrome_trace(
        os.path.join(args.metrics_dir, "trace.json"))
    print(f"wrote {prom} and {trace}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-32b")
    ap.add_argument("--engine", choices=["dense", "paged"], default="dense")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--metrics", action="store_true",
                    help="enable the obs layer (paged engine only): "
                         "per-request latency table + Prometheus snapshot "
                         "+ Chrome trace under --metrics-dir")
    ap.add_argument("--metrics-dir", default="/tmp/serve_metrics")
    args = ap.parse_args()

    if args.metrics:
        if args.engine != "paged":
            raise SystemExit("--metrics instruments the paged engine; "
                             "use --engine paged")
        from repro import obs
        obs.enable()

    cfg = get_config(args.arch, smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    if args.engine == "paged":
        if not paged_supported(cfg):
            raise SystemExit(f"{args.arch} is not a paged family; "
                             "use --engine dense")
        run_paged(cfg, params, args, np.random.default_rng(0))
    else:
        run_dense(cfg, params, args, np.random.default_rng(0))


if __name__ == "__main__":
    main()
