"""Fault-tolerance demo: crash mid-run, restart, resume from checkpoint.

  PYTHONPATH=src python examples/fault_tolerance.py

Phase 1 trains 30 steps (checkpoint every 10), then "crashes".
Phase 2 constructs a fresh Trainer pointed at the same directory and
finishes to 60 — resuming from step 30, not from scratch. This is the
single-process version of what `--supervise` automates across real node
failures; checkpoints are mesh-agnostic so the restart may use a
different data-parallel width (elastic).
"""
import tempfile

import jax
import jax.numpy as jnp

from repro.data.synthetic import SyntheticLM
from repro.models.config import ModelConfig
from repro.optim.api import get_optimizer
from repro.train.loop import Trainer
from repro.train.steps import init_state, make_train_step

cfg = ModelConfig(
    name="tiny", family="dense", d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256, schedule=((("attn",), 2),),
    param_dtype="float32", compute_dtype="float32", remat=False)
opt = get_optimizer("dct_adamw", lr=1e-3, rank=16)
step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=0)
ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)

ckpt_dir = tempfile.mkdtemp(prefix="repro_ft_")


def make_trainer():
    return Trainer(
        train_step=step_fn,
        init_state_fn=lambda: init_state(cfg, opt, jax.random.PRNGKey(0)),
        batch_fn=lambda s: ds.batch(jnp.int32(s)),
        ckpt_dir=ckpt_dir, ckpt_every=10, log_every=10)


print("=== phase 1: train to step 30, then 'crash' ===")
state = make_trainer().run(total_steps=30)
print(f"crashed at step {int(state.step)} (checkpoints in {ckpt_dir})")

print("=== phase 2: new process restarts, resumes from checkpoint ===")
t2 = make_trainer()
state = t2.run(total_steps=60)
assert int(state.step) == 60
print(f"finished at step {int(state.step)} — resumed, not restarted.")
