"""Fault-tolerance demo: crash/resume, then survive NaNs + checkpoint rot.

  PYTHONPATH=src python examples/fault_tolerance.py

Phase 1 trains 30 steps (checkpoint every 10), then "crashes".
Phase 2 constructs a fresh Trainer pointed at the same directory and
finishes to 60 — resuming from step 30, not from scratch. This is the
single-process version of what `--supervise` automates across real node
failures; checkpoints are mesh-agnostic so the restart may use a
different data-parallel width (elastic).

Phase 3 turns on the resilience layer (docs/resilience.md) and drills it
with a chaos plan: a three-batch NaN window plus a bit-flipped
checkpoint behind its OK marker. The in-jit guard refuses the poisoned
steps, the ladder escalates skip → rollback, the rollback quarantines
the corrupted checkpoint and restores the older verified one, and the
run still reaches its target step with finite parameters — the
`--resilient --chaos plan.json` path of the CLI trainer, in-process.
"""
import os
import tempfile

import jax
import jax.numpy as jnp

from repro.data.synthetic import SyntheticLM
from repro.models.config import ModelConfig
from repro.optim.api import get_optimizer
from repro.train.chaos import ChaosPlan, Fault
from repro.train.loop import Trainer
from repro.train.resilience import (
    ResilienceConfig,
    ResilienceManager,
    all_finite_tree,
)
from repro.train.steps import init_state, make_train_step

cfg = ModelConfig(
    name="tiny", family="dense", d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256, schedule=((("attn",), 2),),
    param_dtype="float32", compute_dtype="float32", remat=False)
opt = get_optimizer("dct_adamw", lr=1e-3, rank=16)
step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=0)
ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)

ckpt_dir = tempfile.mkdtemp(prefix="repro_ft_")


def make_trainer():
    return Trainer(
        train_step=step_fn,
        init_state_fn=lambda: init_state(cfg, opt, jax.random.PRNGKey(0)),
        batch_fn=lambda s: ds.batch(jnp.int32(s)),
        ckpt_dir=ckpt_dir, ckpt_every=10, log_every=10)


print("=== phase 1: train to step 30, then 'crash' ===")
state = make_trainer().run(total_steps=30)
print(f"crashed at step {int(state.step)} (checkpoints in {ckpt_dir})")

print("=== phase 2: new process restarts, resumes from checkpoint ===")
t2 = make_trainer()
state = t2.run(total_steps=60)
assert int(state.step) == 60
print(f"finished at step {int(state.step)} — resumed, not restarted.")

print("=== phase 3: resilient run under chaos (NaNs + checkpoint rot) ===")
# lr_scale=True adds the inject_hyperparams seam rollbacks cut LR through
res_opt = get_optimizer("dct_adamw", lr=1e-3, rank=16, lr_scale=True)
plan = ChaosPlan([
    Fault(step=15, site="grads", mode="nan"),       # three-batch NaN window:
    Fault(step=16, site="grads", mode="nan"),       # two skips, then the
    Fault(step=17, site="grads", mode="nan"),       # ladder rolls back —
    Fault(step=15, site="checkpoint", mode="bitflip"),  # past the rotten
])                                                      # newest checkpoint
res_dir = tempfile.mkdtemp(prefix="repro_ft_chaos_")
resilience = ResilienceManager(ResilienceConfig(max_skips=2, max_rollbacks=3))
trainer = Trainer(
    train_step=jax.jit(make_train_step(cfg, res_opt, guard=True, chaos=plan),
                       donate_argnums=0),
    init_state_fn=lambda: init_state(cfg, res_opt, jax.random.PRNGKey(0)),
    batch_fn=plan.wrap_batch_fn(lambda s: ds.batch(jnp.int32(s))),
    ckpt_dir=res_dir, ckpt_every=5, log_every=10,
    resilience=resilience,
    ckpt_fault_hook=plan.bind_checkpoint_dir(res_dir))
state = trainer.run(total_steps=30)

assert int(state.step) == 30, int(state.step)
assert bool(all_finite_tree(state.params)), "params poisoned"
assert resilience.n_skips == 2 and resilience.n_rollbacks == 1
assert os.path.isdir(os.path.join(res_dir, "step_15.corrupt")), \
    "corrupt checkpoint was not quarantined"
print(f"finished at step {int(state.step)} with finite params after "
      f"{resilience.n_skips} skips and {resilience.n_rollbacks} rollback — "
      f"the bitflipped checkpoint was quarantined, the NaN window skipped.")
