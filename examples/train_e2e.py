"""End-to-end training driver example (brief deliverable b).

Trains the paper's Llama-30M for a few hundred steps with Trion through
the full production stack — config registry, data pipeline with prefetch,
checkpoint manager (atomic/keep-k/async), supervisor-compatible Trainer —
the same path `python -m repro.launch.train` uses on a cluster.

  PYTHONPATH=src python examples/train_e2e.py [--steps 200]

On the 1-core CPU container this uses seq 128 / batch 8 to finish in
minutes; pass --paper-scale for the paper's seq 512 / batch 64 (slow on
CPU, the real setting for a TPU slice).
"""
import argparse

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--paper-scale", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: reduced config, 20 steps")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    ap.add_argument("--telemetry", default="off",
                    choices=["off", "jsonl", "csv"],
                    help="stream subspace telemetry (switches the smoke "
                         "run to dct_adamw so the stats have a subject)")
    ap.add_argument("--telemetry-path", default=None)
    ap.add_argument("--basis", default=None,
                    choices=["dct", "dst", "hadamard", "randortho"],
                    help="predefined-basis backend (switches the run to "
                         "dct_adamw, the preset the basis plugs into)")
    ap.add_argument("--obs-dir", default=None,
                    help="enable the obs layer (DESIGN.md §13) and write "
                         "metrics.prom + trace.json artifacts there")
    args = ap.parse_args()
    steps = 20 if args.smoke else args.steps
    # telemetry/basis runs exercise the paper's optimizer (projected-Adam
    # family); the default run keeps the historic trion config
    optimizer = ("dct_adamw" if args.telemetry != "off" or args.basis
                 else "trion")
    argv = ["--arch", "llama-30m", "--optimizer", optimizer, "--rank", "64",
            "--steps", str(steps), "--ckpt-dir", args.ckpt_dir,
            "--ckpt-every", "50" if not args.smoke else "10",
            "--log-every", "10"]
    if args.telemetry != "off":
        argv += ["--telemetry", args.telemetry, "--telemetry-every", "5"]
        if args.telemetry_path:
            argv += ["--telemetry-path", args.telemetry_path]
    if args.basis:
        argv += ["--basis", args.basis]
    if args.obs_dir:
        # sampled honest full-state sync every 5 steps rides along so the
        # artifact carries train_full_sync_seconds too
        argv += ["--obs-dir", args.obs_dir, "--obs-sync-every", "5"]
    if args.smoke:
        # llama-30m is already the CPU-sized paper model; just shrink the run
        argv += ["--seq-len", "64", "--batch", "4"]
    elif args.paper_scale:
        argv += ["--seq-len", "512", "--batch", "64"]
    else:
        argv += ["--seq-len", "128", "--batch", "8"]
    raise SystemExit(train_main(argv))
