"""Quickstart: train a tiny Llama with the paper's Trion optimizer, built
from the composable gradient-transform API (DESIGN.md §4).

  PYTHONPATH=src python examples/quickstart.py

Every preset (``get_optimizer("trion", ...)``) is exactly a chain like the
one below: ``partition`` routes linear-layer matrices to the low-rank rule
and everything else (embeddings, norms, biases) to full-rank Adam, then
lr scaling and weight decay apply to the merged updates.
``inject_hyperparams`` turns the floats into state leaves — the printed
mid-run LR drop changes the step size *without retracing*.
"""
import jax
import jax.numpy as jnp

from repro.data.synthetic import SyntheticLM
from repro.models.config import ModelConfig
from repro.optim import transform as tx
from repro.optim.trion import TrionRule
from repro.train.steps import init_state, make_train_step

cfg = ModelConfig(
    name="llama-tiny", family="dense", d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=344, vocab_size=512, schedule=((("attn",), 4),),
    param_dtype="float32", compute_dtype="float32", remat=False)

# the paper's optimizer as an explicit chain (== get_optimizer("trion", ...))
trion_chain = tx.inject_hyperparams(lambda lr, weight_decay: tx.chain(
    tx.partition({"lowrank": tx.lowrank_project(TrionRule(rank=32)),
                  "full": tx.scale_by_adam()}),
    tx.scale_by_learning_rate(lr),
    tx.add_decayed_weights(weight_decay, schedule=lr),
))(lr=3e-3, weight_decay=0.01)
opt = tx.as_optimizer(trion_chain)

state = init_state(cfg, opt, jax.random.PRNGKey(0))
step = jax.jit(make_train_step(cfg, opt), donate_argnums=0)

data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
first = None
for i in range(60):
    if i == 40:  # mid-run LR surgery: edit the state leaf, no recompile
        hp = dict(state.opt_state.leaves.hyperparams)
        hp["lr"] = jnp.asarray(1e-3, jnp.float32)
        state = state._replace(opt_state=state.opt_state._replace(
            leaves=state.opt_state.leaves._replace(hyperparams=hp)))
        print("        (lr -> 1e-3, no retrace)")
    state, metrics = step(state, data.batch(jnp.int32(i)))
    loss = float(metrics["ce"])
    first = first if first is not None else loss
    if (i + 1) % 10 == 0:
        print(f"step {i + 1:3d}  ce {loss:.4f}")
print(f"\nloss {first:.4f} -> {loss:.4f} "
      f"({'OK: decreasing' if loss < first else 'NOT decreasing?!'})")
