"""Quickstart: train a tiny Llama with the paper's Trion optimizer.

  PYTHONPATH=src python examples/quickstart.py

Shows the whole public API in ~30 lines: config -> params -> optimizer ->
jit'd train step -> loss goes down.
"""
import jax
import jax.numpy as jnp

from repro.data.synthetic import SyntheticLM
from repro.models.config import ModelConfig
from repro.optim.api import get_optimizer
from repro.train.steps import init_state, make_train_step

cfg = ModelConfig(
    name="llama-tiny", family="dense", d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=344, vocab_size=512, schedule=((("attn",), 4),),
    param_dtype="float32", compute_dtype="float32", remat=False)

opt = get_optimizer("trion", lr=3e-3, rank=32)       # the paper's optimizer
state = init_state(cfg, opt, jax.random.PRNGKey(0))
step = jax.jit(make_train_step(cfg, opt), donate_argnums=0)

data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
first = None
for i in range(60):
    state, metrics = step(state, data.batch(jnp.int32(i)))
    loss = float(metrics["ce"])
    first = first if first is not None else loss
    if (i + 1) % 10 == 0:
        print(f"step {i + 1:3d}  ce {loss:.4f}")
print(f"\nloss {first:.4f} -> {loss:.4f} "
      f"({'OK: decreasing' if loss < first else 'NOT decreasing?!'})")
