"""Autotuned vs default kernel blocks -> BENCH_tuned_kernels.json.

Runs the roofline-seeded autotuner (repro.tune) over one spec per kernel
family, then records the tuned-vs-default wall-time ratio per entry. The
gate: the tuned block must be at least as fast as the kernel's hardcoded
default within a noise margin — the autotuner measures the default
alongside the survivors and breaks ties toward it, so a slower "winner"
can only mean the measurement harness itself regressed.

Off-TPU the kernels run in interpret mode; absolute times are Pallas
interpreter wall-clock and only the *ratio* is meaningful (the committed
record carries the ``platform`` block so TPU regeneration is
distinguishable). The CI ``tune`` job runs the reduced grid and also
asserts the cache JSON round-trip.
"""
from __future__ import annotations

from .common import write_bench_json

#: tuned_s may exceed default_s by this factor before the gate fails
#: (interpret-mode wall times on a shared CI box are noisy; the tuner's
#: tie-break toward the default bounds the true regret at ~measurement
#: noise)
NOISE_MARGIN = 1.25


def run(*, fast: bool = False, keep: int = 4, iters: int = 3,
        warmup: int = 1, arch: str | None = None,
        out_path: str | None = "BENCH_tuned_kernels.json",
        cache_path: str | None = None) -> dict:
    """Tune one entry per kernel family and persist the record.

    ``fast`` sweeps the reduced CI grid (small shapes); the default sweeps
    the production-shaped specs. ``cache_path`` additionally saves the
    winning blocks as a ``--tune-cache`` JSON for launch/train.py and
    benchmarks/run.py to load.
    """
    from repro.tune import FULL_SPECS, REDUCED_SPECS, TuningCache, tune_all

    specs = REDUCED_SPECS if fast else FULL_SPECS
    cache = TuningCache()   # fresh: the record reflects exactly this sweep
    records = tune_all(specs, keep=keep, iters=iters, warmup=warmup,
                       arch=arch, cache=cache, verbose=True)

    rows = []
    failures = []
    for rec in records:
        ratio = rec["best_s"] / max(rec["default_s"], 1e-12)
        row = {
            "kernel": rec["kernel"], "shape": rec["shape"],
            "rank": rec["rank"], "dtype": rec["dtype"],
            "bound": rec["bound"], "grid_size": rec["grid_size"],
            "survivors": rec["survivors"],
            "default_block": rec["default_block"],
            "default_s": rec["default_s"],
            "best_block": rec["best_block"], "best_s": rec["best_s"],
            "tuned_over_default": ratio,
            "speedup": rec["speedup"],
        }
        rows.append(row)
        if ratio > NOISE_MARGIN:
            failures.append(f"{rec['kernel']} {tuple(rec['shape'])}: tuned "
                            f"{rec['best_s']:.4g}s vs default "
                            f"{rec['default_s']:.4g}s (x{ratio:.2f} > "
                            f"{NOISE_MARGIN})")
        print(f"[tuned_kernels] {rec['kernel']:22s} {str(rec['shape']):16s}"
              f" tuned/default x{ratio:.2f} "
              f"({rec['best_block']} vs {rec['default_block']})")

    result = {
        "bench": "tuned_kernels",
        "specs": "reduced" if fast else "full",
        "noise_margin": NOISE_MARGIN,
        "entries": rows,
        "cache_entries": len(cache),
        "gate_ok": not failures,
    }
    if cache_path:
        cache.save(cache_path)
        print(f"[tuned_kernels] wrote tuning cache {cache_path} "
              f"({len(cache)} entries)")
    if out_path:
        write_bench_json(out_path, result)
        print(f"[tuned_kernels] wrote {out_path}")
    if failures:
        raise RuntimeError("tuned block slower than default beyond noise "
                           "margin:\n  " + "\n  ".join(failures))
    return result


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced CI grid (small shapes)")
    ap.add_argument("--keep", type=int, default=4)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--device-arch", default=None,
                    help="roofline arch table for pruning (repro.roofline."
                         "hw); default: REPRO_ARCH env or v5e")
    ap.add_argument("--out", default="BENCH_tuned_kernels.json")
    ap.add_argument("--tune-cache", default=None, metavar="PATH",
                    help="also save the winners as a loadable tuning cache")
    args = ap.parse_args()
    run(fast=args.fast, keep=args.keep, iters=args.iters,
        arch=args.device_arch, out_path=args.out,
        cache_path=args.tune_cache)
