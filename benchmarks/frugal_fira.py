"""Paper Appendix G Table 6: FRUGAL / FIRA with different projections.

Checks: DCT projection approximates SVD inside both optimizers (loss gap
small) and beats Random / RandPerm in FRUGAL; runtime of the DCT variant
is below SVD (no per-refresh SVD factorization).
"""
from __future__ import annotations

from .common import fmt_row, tiny_llama, train


def run(steps: int = 40, rank: int = 16, update_interval: int = 10
        ) -> list[dict]:
    cfg = tiny_llama()
    rows = []
    for opt, proj in (("frugal", "svd"), ("frugal", "dct"),
                      ("frugal", "random"), ("frugal", "randperm"),
                      ("fira", "svd"), ("fira", "dct")):
        r = train(cfg, opt, steps=steps, rank=rank, projector=proj,
                  update_interval=update_interval)
        r["label"] = f"{opt}[{proj}]"
        rows.append(r)
        print(fmt_row(r["label"], r))
    byl = {r["label"]: r for r in rows}
    for opt in ("frugal", "fira"):
        svd, dct = byl[f"{opt}[svd]"], byl[f"{opt}[dct]"]
        gap = dct["final_loss"] - svd["final_loss"]
        print(f"[check] {opt}: dct-svd loss gap = {gap:+.4f} "
              f"({'PASS' if gap < 0.15 else 'FAIL'} < 0.15)")
    fr = byl["frugal[dct]"]
    rnd = byl["frugal[random]"]
    print(f"[check] frugal: dct<=random*1.05: "
          f"{'PASS' if fr['final_loss'] <= rnd['final_loss'] * 1.05 else 'FAIL'}")
    return rows


if __name__ == "__main__":
    run()
