"""Paper Appendix H Tables 7-8: fine-tuning with low-rank optimizers.

CPU-scale proxy for GSM-8k fine-tuning: pre-train a tiny Llama on the
base synthetic distribution, then fine-tune on a shifted distribution
(different Markov seed) and compare final fine-tune loss / memory / time
across FRUGAL/FIRA/LDAdamW/DCT-AdamW at two ranks (the paper's 32/512
scaled to the tiny model).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.data.synthetic import SyntheticLM
from repro.models import transformer as T
from repro.optim.api import get_optimizer
from repro.train.steps import TrainState, make_train_step

from .common import fmt_row, state_bytes, lowrank_state_bytes, tiny_llama


def _run_ft(cfg, base_params, name, rank, steps, **kw):
    import time
    opt = get_optimizer(name, lr=1e-3, rank=rank, **kw)
    state = TrainState(jnp.zeros((), jnp.int32), base_params,
                       opt.init(base_params))
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8,
                     seed=99, markov_shift=13)     # shifted task
    step_fn = jax.jit(make_train_step(cfg, opt))
    losses, ts = [], []
    for i in range(steps):
        b = ds.batch(jnp.int32(i))
        t0 = time.perf_counter()
        state, m = step_fn(state, b)
        jax.block_until_ready(m["loss"])
        ts.append(time.perf_counter() - t0)
        losses.append(float(m["ce"]))
    return {
        "optimizer": name, "rank": rank,
        "final_loss": sum(losses[-5:]) / 5,
        "opt_state_bytes": state_bytes(state.opt_state),
        "lowrank_state_bytes": lowrank_state_bytes(state.opt_state),
        "shared_basis_bytes": 0,
        "s_per_step": sum(ts[2:]) / max(len(ts) - 2, 1),
    }


def run(pretrain_steps: int = 30, ft_steps: int = 25,
        ranks=(4, 32)) -> list[dict]:
    cfg = tiny_llama()
    # base pre-training with AdamW
    opt = get_optimizer("adamw", lr=3e-3)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    state = TrainState(jnp.zeros((), jnp.int32), params, opt.init(params))
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    step_fn = jax.jit(make_train_step(cfg, opt))
    for i in range(pretrain_steps):
        state, m = step_fn(state, ds.batch(jnp.int32(i)))
    base = state.params
    print(f"pretrained base: loss={float(m['ce']):.4f}")

    rows = []
    for rank in ranks:
        for name, kw in (("frugal", {"projector": "svd"}),
                         ("frugal", {"projector": "dct"}),
                         ("fira", {"projector": "svd"}),
                         ("fira", {"projector": "dct"}),
                         ("ldadamw", {}),
                         ("dct_adamw", {})):
            r = _run_ft(cfg, base, name, rank, ft_steps, **kw)
            label = f"{name}[{kw.get('projector', '-')},r={rank}]"
            r["shared_basis_bytes"] = 0
            rows.append(r)
            print(fmt_row(label, r))
    return rows


if __name__ == "__main__":
    run()
