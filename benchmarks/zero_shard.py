"""ZeRO-1 memory / step benchmark (DESIGN.md §9, acceptance gate).

Two measurements on an 8-way ('pod', 'data') host mesh:

1. **Per-device optimizer-state bytes** at the production leaf config —
   stacked ``(2, 4096, 4096)``, rank 256, q8 error feedback — replicated
   vs ZeRO-partitioned, from *real placed arrays* (summing the shard
   bytes resident on device 0). The partitionable state (moments + EF
   payload + per-row scales) is everything but the ``r`` int32 indices per
   layer, so the reduction must be at least ``(N_dp - 1) / N_dp`` minus
   the few replicated KB of indices. Asserted.

2. **Step wall time** at a configurable (CI-sized) leaf, replicated vs
   sharded step, both through the full chain API. On a CPU host the 8
   "devices" share the same cores, so sharding cannot beat replication on
   wall clock — the number is recorded to catch gross regressions (e.g. an
   accidental per-step all-gather of the EF buffer), not as a speedup
   claim.

  PYTHONPATH=src python -m benchmarks.zero_shard [--step-dim 1024] \\
      [--out BENCH_zero_shard.json]
"""
import os

# must precede the jax import: the device count locks at first init
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def _per_device_bytes(tree, dev) -> int:
    return sum(s.data.nbytes for x in jax.tree.leaves(tree)
               for s in x.addressable_shards if s.device == dev)


def measure_state_bytes(mesh, zcfg, *, layers=2, dim=4096, rank=256) -> dict:
    from repro.optim.api import get_optimizer
    from repro.parallel import sharding as sh
    from repro.parallel.compat import set_mesh

    n_dp = mesh.size
    params = {"w": jnp.zeros((layers, dim, dim), jnp.float32)}
    opt = get_optimizer("dct_adamw", lr=0.01, rank=rank, zero=zcfg)
    with set_mesh(mesh):
        state = opt.init(params)
        p_specs = sh.params_specs(params, mesh)
        o_specs = sh.opt_state_specs(state, params, p_specs, zero=zcfg,
                                     mesh=mesh)
        sharded = jax.device_put(state, sh.named_shardings(o_specs, mesh))

    d0 = jax.devices()[0]
    # per-leaf state only: the shared DCT basis is one-per-device by design
    # (the paper's memory win) and identical in both placements
    b_rep = _per_device_bytes(state.leaves, d0)
    b_sh = _per_device_bytes(sharded.leaves, d0)
    reduction = 1.0 - b_sh / b_rep
    target = (n_dp - 1) / n_dp
    # the r int32 indices per layer (a few KB) replicate by design; allow
    # exactly that much shortfall from the ideal (N-1)/N
    from jax.sharding import PartitionSpec as P
    idx_bytes = sum(
        int(np.prod(x.shape)) * x.dtype.itemsize
        for x, spec in zip(
            jax.tree.leaves(state.leaves),
            jax.tree.leaves(o_specs.leaves,
                            is_leaf=lambda s: isinstance(s, P)))
        if all(ax is None for ax in spec))
    assert reduction >= target - (idx_bytes / b_rep) - 1e-6, (
        f"per-device reduction {reduction:.5f} < (N-1)/N = {target:.5f} "
        f"beyond the replicated-index allowance")
    print(f"[zero_shard] state bytes/device: replicated {b_rep / 1e6:.2f}MB"
          f" -> zero {b_sh / 1e6:.2f}MB  "
          f"(reduction {reduction:.4f}, target {target:.4f}, "
          f"replicated idx {idx_bytes / 1e3:.1f}KB)")
    return {"leaf_shape": [layers, dim, dim], "rank": rank, "n_dp": n_dp,
            "bytes_per_device_replicated": int(b_rep),
            "bytes_per_device_zero": int(b_sh),
            "replicated_index_bytes": int(idx_bytes),
            "reduction": reduction, "target_reduction": target}


def measure_step_time(mesh, zcfg, *, layers=2, dim=1024, rank=64,
                      steps=3, warmup=1) -> dict:
    from repro.optim.api import get_optimizer
    from repro.parallel import sharding as sh
    from repro.parallel.compat import set_mesh

    params = {"w": jnp.zeros((layers, dim, dim), jnp.float32)}
    grads = {"w": jax.random.normal(jax.random.PRNGKey(0),
                                    (layers, dim, dim), jnp.float32)}
    rows = {}
    with set_mesh(mesh):
        for label, zero in (("replicated", None), ("zero1", zcfg)):
            opt = get_optimizer("dct_adamw", lr=0.01, rank=rank, fused="fft",
                                zero=zero)
            state = opt.init(params)
            if zero is not None:
                p_specs = sh.params_specs(params, mesh)
                o_specs = sh.opt_state_specs(state, params, p_specs,
                                             zero=zero, mesh=mesh)
                state = jax.device_put(state,
                                       sh.named_shardings(o_specs, mesh))
            fn = jax.jit(opt.update, donate_argnums=1)
            times = []
            for _ in range(warmup + steps):
                t0 = time.perf_counter()
                u, state = fn(grads, state, params)
                jax.block_until_ready(u)
                times.append(time.perf_counter() - t0)
            rows[label] = sum(times[warmup:]) / steps
            print(f"[zero_shard] step {label:10s} "
                  f"{rows[label] * 1e3:9.1f} ms/step "
                  f"(leaf {layers}x{dim}x{dim} r={rank}, fft)")
    return {"leaf_shape": [layers, dim, dim], "rank": rank,
            "s_per_step_replicated": rows["replicated"],
            "s_per_step_zero": rows["zero1"]}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--dim", type=int, default=4096,
                    help="leaf dim for the memory measurement")
    ap.add_argument("--rank", type=int, default=256)
    ap.add_argument("--step-dim", type=int, default=1024,
                    help="leaf dim for the wall-time measurement")
    ap.add_argument("--step-rank", type=int, default=64)
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--out", default="BENCH_zero_shard.json")
    args = ap.parse_args(argv)

    from repro.launch.mesh import make_mesh
    from repro.parallel.zero import ZeroConfig

    n = jax.device_count()
    assert n >= 2, "zero_shard bench needs >1 device (force host devices)"
    mesh = make_mesh((2, n // 2), ("pod", "data"))
    zcfg = ZeroConfig(mode="1")

    result = {
        "bench": "zero_shard",
        "backend": jax.default_backend(),
        "n_devices": n,
        "memory": measure_state_bytes(mesh, zcfg, layers=args.layers,
                                      dim=args.dim, rank=args.rank),
        "step": measure_step_time(mesh, zcfg, layers=args.layers,
                                  dim=args.step_dim, rank=args.step_rank,
                                  steps=args.steps),
    }
    if args.out:
        from benchmarks.common import write_bench_json
        write_bench_json(args.out, result)
        print(f"[zero_shard] wrote {args.out}")
    return result


if __name__ == "__main__":
    main()
