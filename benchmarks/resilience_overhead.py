"""Anomaly-guard overhead gate (DESIGN.md §11): the in-jit guard must be free.

Times the fused projected-Adam optimizer step on the production-shaped
stacked leaf — (2, 4096, 4096) rank 256, the same subject as
``BENCH_optimizer_step.json`` / ``BENCH_telemetry_overhead.json`` — with
and without the resilience guard tail appended (``all_finite_tree`` over
the produced updates + the ``select_tree`` commit/reject point on the
optimizer state, exactly the extra work ``make_train_step(...,
guard=True)`` adds per step).

The acceptance invariant is *"the HLO is unchanged except the
finite-flag select"*, gated at 1 %:

- **flops**: raw compiled flop count, ≤ ``threshold`` (the guard adds a
  handful of scalar ANDs — any real extra pass shows up here).
- **bytes beyond the select**: the select and the finite check cannot
  avoid reading their own operands (old + new value of every state leaf
  at the commit point; the updates tree for the check) — that traffic is
  the criterion's named exception. The gate subtracts an *analytic upper
  bound* on those operand bytes (computed from the abstract state /
  updates trees; ``select(p, x, x)`` on untouched leaves folds to zero,
  so the bound is slightly generous) and requires everything **else** to
  be ≤ ``threshold``: if the guard ever breaks a fusion of the main
  dataflow, duplicates projection work, or forces extra full-size
  copies, this trips.
- **wall**: min-estimator over interleaved samples, ≤ ``wall_threshold``
  (default 3 % — same noise floor the telemetry gate uses on shared CI
  boxes; in practice the select fuses and the wall delta is ~the operand
  reads, well under it).

Both variants are compiled up front and the timed steps *interleave* them
(off, on, off, on, ...), so slow drift in machine load hits both equally.
Raw overhead fractions are all reported in the JSON for transparency.
Fails (non-zero exit / raise) on any gate, or when the fused execution
layer stops being reached with the guard on (dispatch-spy regression).

  PYTHONPATH=src python -m benchmarks.resilience_overhead \
      [--dim 4096] [--rank 256] [--threshold 0.01] [--out ...]
"""
from __future__ import annotations

import time

import jax

from .common import compile_opt_step


def _tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)
               if hasattr(x, "size"))


def guard_operand_bytes(state, updates_like) -> dict:
    """Analytic upper bound on the guard's own unavoidable memory traffic.

    ``select_tree`` at the commit point reads the old and the new value of
    every optimizer-state leaf (untouched leaves are the same tensor in
    both trees and fold away — counting them anyway makes this a slightly
    generous bound, never an underestimate of what is allowed).
    ``all_finite_tree`` reads every inexact updates leaf once and its
    1-byte finiteness predicate once."""
    select_b = 2 * _tree_bytes(state)
    check_b = sum(x.size * x.dtype.itemsize + x.size
                  for x in jax.tree.leaves(updates_like)
                  if hasattr(x, "size")
                  and jax.numpy.issubdtype(x.dtype, jax.numpy.inexact))
    return {"select_bytes": int(select_b), "check_bytes": int(check_b),
            "total": int(select_b + check_b)}


def run(*, layers: int = 2, dim: int = 4096, rank: int = 256,
        steps: int = 9, warmup: int = 1, threshold: float = 0.01,
        wall_threshold: float = 0.03,
        out_path: str | None = "BENCH_resilience_overhead.json") -> dict:
    from repro.kernels import ops as kops
    from repro.optim.projected_adam import ProjectedAdamRule

    fused_mode = "on" if kops.ON_TPU else "fft"
    shape = (layers, dim, dim)
    rule = ProjectedAdamRule(rank=rank, projector="dct", residual="ef",
                             ef_dtype="q8", fused=fused_mode)
    result = {
        "bench": "resilience_overhead",
        "leaf_shape": list(shape),
        "rank": rank,
        "fused_mode": fused_mode,
        "steps_timed": steps,
        "threshold": threshold,
        "wall_threshold": wall_threshold,
        "backend": jax.default_backend(),
        "modes": {},
    }
    variants = {}
    for label, guard in (("guard_off", False), ("guard_on", True)):
        compiled, (grads, params), init, spy, peak = compile_opt_step(
            rule, shape, guard=guard)
        # the guard must not knock the step off the fused execution layer
        spy.check(fused_mode)
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else (ca or {})
        variants[label] = {"compiled": compiled, "grads": grads,
                           "params": params, "state": init(),
                           "peak": peak, "dispatch": dict(spy.counts),
                           "flops": float(ca.get("flops", 0.0)),
                           "bytes": float(ca.get("bytes accessed", 0.0)),
                           "times": []}
    # the guard's allowed traffic: select over this state, check over
    # updates shaped like the grads tree
    allowance = guard_operand_bytes(variants["guard_on"]["state"],
                                    variants["guard_on"]["grads"])
    result["guard_operand_bytes"] = allowance

    def one_step(v, record: bool):
        tic = time.perf_counter()
        out = v["compiled"](v["grads"], v["state"], v["params"])
        v["state"] = out[1]
        jax.block_until_ready(out[0])
        if record:
            v["times"].append(time.perf_counter() - tic)

    labels = list(variants)
    for k in range(warmup + steps):                 # interleaved, with the
        order = labels if k % 2 == 0 else labels[::-1]   # order alternating
        for label in order:                              # per round
            one_step(variants[label], record=k >= warmup)

    for label, v in variants.items():
        ts = sorted(v["times"])
        result["modes"][label] = {
            "s_per_step": sum(ts) / len(ts),
            "s_per_step_median": ts[len(ts) // 2],
            "s_per_step_min": ts[0],
            "flops": v["flops"],
            "bytes_accessed": v["bytes"],
            "peak_live_bytes": v["peak"],
            "dispatch": v["dispatch"],
        }
        row = result["modes"][label]
        print(f"[resilience_overhead] {label:9s} "
              f"median {row['s_per_step_median'] * 1e3:9.1f} ms/step "
              f"min {row['s_per_step_min'] * 1e3:9.1f} ms/step "
              f"flops {row['flops']:.3e} bytes {row['bytes_accessed']:.3e} "
              f"dispatch={row['dispatch']}")

    off, on = result["modes"]["guard_off"], result["modes"]["guard_on"]

    def frac(key):
        return (on[key] - off[key]) / max(off[key], 1e-30)

    # raw fractions (reported); the deterministic gates below subtract the
    # guard's own operand traffic from the bytes delta — the criterion's
    # named exception — and use the min estimator (classic noise-robust
    # choice) over interleaved samples for the wall gate
    result["overhead_frac"] = frac("s_per_step_median")
    result["overhead_frac_min"] = frac("s_per_step_min")
    result["overhead_frac_flops"] = frac("flops")
    result["overhead_frac_bytes"] = frac("bytes_accessed")
    extra_beyond = (on["bytes_accessed"] - off["bytes_accessed"]
                    - allowance["total"])
    result["overhead_frac_bytes_beyond_select"] = (
        extra_beyond / max(off["bytes_accessed"], 1e-30))
    print(f"[resilience_overhead] overhead: median "
          f"{result['overhead_frac'] * 100:+.2f}% "
          f"min {result['overhead_frac_min'] * 100:+.2f}% "
          f"flops {result['overhead_frac_flops'] * 100:+.2f}% "
          f"bytes {result['overhead_frac_bytes'] * 100:+.2f}% "
          f"(select operands {allowance['total'] / 1e6:.0f} MB -> beyond "
          f"{result['overhead_frac_bytes_beyond_select'] * 100:+.2f}%; "
          f"gates: {threshold * 100:.0f}% flops/bytes, "
          f"{wall_threshold * 100:.0f}% wall)")
    if out_path:
        from benchmarks.common import write_bench_json
        write_bench_json(out_path, result)
        print(f"[resilience_overhead] wrote {out_path}")
    failures = [k for k, gate in (
        ("overhead_frac_flops", threshold),
        ("overhead_frac_bytes_beyond_select", threshold),
        ("overhead_frac_min", wall_threshold),
    ) if result[k] > gate]
    if failures:
        raise RuntimeError(
            f"the in-jit anomaly guard regressed the fused step at {shape} "
            f"r={rank} beyond the gate: "
            + ", ".join(f"{k}={result[k] * 100:+.2f}%" for k in failures))
    return result


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--dim", type=int, default=4096)
    ap.add_argument("--rank", type=int, default=256)
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--threshold", type=float, default=0.01)
    ap.add_argument("--wall-threshold", type=float, default=0.03)
    ap.add_argument("--out", default="BENCH_resilience_overhead.json")
    args = ap.parse_args()
    run(layers=args.layers, dim=args.dim, rank=args.rank, steps=args.steps,
        warmup=args.warmup, threshold=args.threshold,
        wall_threshold=args.wall_threshold, out_path=args.out)
