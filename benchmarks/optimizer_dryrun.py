import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Optimizer-only dry-run: the paper's distributed claim at the HLO level.

Lowers ``optimizer.update(grads, state, params)`` alone (no fwd/bwd) for a
full-size architecture on the production mesh and reports per-device
flops/bytes/collective payloads. This isolates the cost of the paper's
subject — Trion's DCT projection + top-r selection + low-rank
Newton-Schulz vs Dion's power-iteration/QR vs (DCT-/LD-)AdamW — and checks
the headline distributed property: the update's collective payload is
low-rank (R x r), not full-size (R x C).

  PYTHONPATH=src python -m benchmarks.optimizer_dryrun [--arch qwen2.5-32b]
"""
import argparse
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


def adaptive_rank_dryrun(arch: str, rank: int, *, rounds: int = 6,
                         seed: int = 0):
    """Controller dry-run (DESIGN.md §8): drive the RankAllocator over the
    full-size arch's leaf set with seeded synthetic captured-energy
    profiles, then lower dct_adamw with the resulting per-leaf overrides
    on the production mesh.

    Checks the two closed-loop claims at scale without materializing
    weights: (1) the final allocation is non-uniform (ranks actually
    reallocate), (2) the weighted rank budget — and therefore total
    optimizer-state memory — stays within the uniform-rank footprint
    (asserted on eval_shape byte counts of the real optimizer state).
    """
    import numpy as np

    from repro.configs.registry import ARCHS
    from repro.launch.mesh import make_production_mesh
    from repro.models import transformer as T
    from repro.optim.api import get_optimizer
    from repro.parallel import compat
    from repro.parallel import sharding as sh
    from repro.telemetry.controllers import (RankAllocator,
                                             RankAllocatorConfig,
                                             leaf_inventory)

    cfg = ARCHS[arch]
    params_sds = jax.eval_shape(
        partial(T.init_params, cfg, jax.random.PRNGKey(0)))
    leaves = leaf_inventory(params_sds)
    allocator = RankAllocator(
        RankAllocatorConfig(base_rank=rank, decide_every=1), leaves)

    # synthetic but deterministic per-leaf energy profiles: wide matrices
    # (attention out / mlp down) concentrate energy, square ones spread it;
    # seeded jitter stands in for batch noise. The *controller* under test
    # is real — only the plant is simulated (this is a dry run).
    rng = np.random.default_rng(seed)
    base_ce = {p: float(np.clip(0.35 + 0.6 * (1.0 - li.cols /
                                              max(li.rows, li.cols)),
                                0.05, 0.98))
               for p, li in leaves.items()}
    jitter = {p: rng.uniform(-0.08, 0.08) for p in leaves}
    for rnd in range(1, rounds + 1):
        stats = {p: {"captured_energy": float(np.clip(
            base_ce[p] + jitter[p] + rng.normal(0, 0.01), 0.01, 1.0))}
            for p in leaves}
        for _ in range(5):                    # settle the EMA
            allocator.observe(rnd, stats)
        allocator.propose(rnd)

    alloc = allocator.alloc
    uniform = {p: min(rank, li.cols) for p, li in leaves.items()}
    distinct = sorted(set(alloc.values()))
    print(f"[adaptive-rank] {arch}: {len(leaves)} lowrank leaves, "
          f"{allocator.n_decisions} decisions, distinct ranks {distinct}")
    for p in sorted(alloc):
        mark = "  " if alloc[p] == uniform[p] else ("+ " if alloc[p] >
                                                    uniform[p] else "- ")
        print(f"  {mark}{p:40s} r={alloc[p]:4d} (uniform {uniform[p]})")
    assert len(distinct) > 1, "allocation stayed uniform — controller dead"

    # memory: eval_shape the REAL optimizer state, adaptive vs uniform
    def state_bytes(overrides):
        opt = get_optimizer("dct_adamw", lr=0.01, rank=rank,
                            overrides=overrides or None)
        sds = jax.eval_shape(opt.init, params_sds)
        return sum(int(np.prod(s.shape)) * s.dtype.itemsize
                   for s in jax.tree.leaves(sds))

    b_uniform = state_bytes(None)
    b_adaptive = state_bytes(allocator.overrides())
    print(f"[adaptive-rank] opt-state bytes: uniform {b_uniform / 1e9:.3f}GB"
          f" adaptive {b_adaptive / 1e9:.3f}GB "
          f"({(b_adaptive - b_uniform) / b_uniform * 100:+.2f}%)")
    assert b_adaptive <= b_uniform, \
        "adaptive allocation exceeded the fixed-rank memory budget"

    # and the sharding layer must derive specs for the non-uniform state
    mesh = make_production_mesh()
    with compat.set_mesh(mesh):
        opt = get_optimizer("dct_adamw", lr=0.01, rank=rank,
                            overrides=allocator.overrides())
        p_specs = sh.params_specs(params_sds, mesh)
        state_sds = jax.eval_shape(opt.init, params_sds)
        sh.opt_state_specs(state_sds, params_sds, p_specs)
    print("[adaptive-rank] opt_state_specs derived for non-uniform ranks OK")
    return alloc


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-32b")
    ap.add_argument("--rank", type=int, default=256)
    ap.add_argument("--optimizers", default="trion,dion,dct_adamw,adamw")
    ap.add_argument("--adaptive-rank", action="store_true",
                    help="run the rank-allocator controller dry-run instead "
                         "of the per-optimizer HLO table")
    ap.add_argument("--device-arch", default=None,
                    help="accelerator roofline table (repro.roofline.hw); "
                         "--arch is the model, this is the device")
    args = ap.parse_args(argv)

    if args.adaptive_rank:
        return adaptive_rank_dryrun(args.arch, args.rank)

    from repro.configs.registry import ARCHS
    from repro.launch.mesh import make_production_mesh
    from repro.models import transformer as T
    from repro.optim.api import get_optimizer
    from repro.parallel import compat
    from repro.parallel import sharding as sh
    from repro.roofline.analysis import analyze_compiled

    cfg = ARCHS[args.arch]
    mesh = make_production_mesh()
    rows = []
    for name in args.optimizers.split(","):
        kw = {} if name == "adamw" else {"rank": args.rank}
        opt = get_optimizer(name, lr=0.01, **kw)
        with compat.set_mesh(mesh):
            params_sds = jax.eval_shape(
                partial(T.init_params, cfg, jax.random.PRNGKey(0)))
            p_specs = sh.params_specs(params_sds, mesh)
            state_sds = jax.eval_shape(opt.init, params_sds)
            o_specs = sh.opt_state_specs(state_sds, params_sds, p_specs)

            def with_ns(tree, specs):
                return jax.tree.map(
                    lambda s, p: jax.ShapeDtypeStruct(
                        s.shape, s.dtype, sharding=NamedSharding(mesh, p)),
                    tree, specs,
                    is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

            params_in = with_ns(params_sds, p_specs)
            grads_in = params_in
            state_in = with_ns(state_sds, o_specs)
            compiled = jax.jit(opt.update, donate_argnums=1).lower(
                grads_in, state_in, params_in).compile()
        rep = analyze_compiled(compiled, arch=args.arch, shape="opt_only",
                               mesh_name="pod1x16x16", n_devices=mesh.size,
                               model_flops_total=0.0,
                               device_arch=args.device_arch)
        coll = rep.collectives.get("_total", {"bytes": 0, "count": 0})
        print(f"{name:12s} flops/dev={rep.flops_per_device:.3e} "
              f"bytes/dev={rep.bytes_per_device:.3e} "
              f"coll={coll['bytes'] / 1e9:8.3f}GB (n={coll['count']:.0f}) "
              f"compute={rep.compute_s * 1e3:7.2f}ms "
              f"mem={rep.memory_s * 1e3:7.2f}ms "
              f"collective={rep.collective_s * 1e3:7.2f}ms")
        rows.append((name, rep))
    return rows


if __name__ == "__main__":
    main()
