import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Optimizer-only dry-run: the paper's distributed claim at the HLO level.

Lowers ``optimizer.update(grads, state, params)`` alone (no fwd/bwd) for a
full-size architecture on the production mesh and reports per-device
flops/bytes/collective payloads. This isolates the cost of the paper's
subject — Trion's DCT projection + top-r selection + low-rank
Newton-Schulz vs Dion's power-iteration/QR vs (DCT-/LD-)AdamW — and checks
the headline distributed property: the update's collective payload is
low-rank (R x r), not full-size (R x C).

  PYTHONPATH=src python -m benchmarks.optimizer_dryrun [--arch qwen2.5-32b]
"""
import argparse
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-32b")
    ap.add_argument("--rank", type=int, default=256)
    ap.add_argument("--optimizers", default="trion,dion,dct_adamw,adamw")
    args = ap.parse_args(argv)

    from repro.configs.registry import ARCHS
    from repro.launch.mesh import make_production_mesh
    from repro.models import transformer as T
    from repro.optim.api import get_optimizer
    from repro.parallel import compat
    from repro.parallel import sharding as sh
    from repro.roofline.analysis import analyze_compiled

    cfg = ARCHS[args.arch]
    mesh = make_production_mesh()
    rows = []
    for name in args.optimizers.split(","):
        kw = {} if name == "adamw" else {"rank": args.rank}
        opt = get_optimizer(name, lr=0.01, **kw)
        with compat.set_mesh(mesh):
            params_sds = jax.eval_shape(
                partial(T.init_params, cfg, jax.random.PRNGKey(0)))
            p_specs = sh.params_specs(params_sds, mesh)
            state_sds = jax.eval_shape(opt.init, params_sds)
            o_specs = sh.opt_state_specs(state_sds, params_sds, p_specs)

            def with_ns(tree, specs):
                return jax.tree.map(
                    lambda s, p: jax.ShapeDtypeStruct(
                        s.shape, s.dtype, sharding=NamedSharding(mesh, p)),
                    tree, specs,
                    is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

            params_in = with_ns(params_sds, p_specs)
            grads_in = params_in
            state_in = with_ns(state_sds, o_specs)
            compiled = jax.jit(opt.update, donate_argnums=1).lower(
                grads_in, state_in, params_in).compile()
        rep = analyze_compiled(compiled, arch=args.arch, shape="opt_only",
                               mesh_name="pod1x16x16", n_devices=mesh.size,
                               model_flops_total=0.0)
        coll = rep.collectives.get("_total", {"bytes": 0, "count": 0})
        print(f"{name:12s} flops/dev={rep.flops_per_device:.3e} "
              f"bytes/dev={rep.bytes_per_device:.3e} "
              f"coll={coll['bytes'] / 1e9:8.3f}GB (n={coll['count']:.0f}) "
              f"compute={rep.compute_s * 1e3:7.2f}ms "
              f"mem={rep.memory_s * 1e3:7.2f}ms "
              f"collective={rep.collective_s * 1e3:7.2f}ms")
        rows.append((name, rep))
    return rows


if __name__ == "__main__":
    main()
