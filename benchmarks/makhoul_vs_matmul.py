"""Paper Appendix C/D Tables 4-5: fast transforms vs matmul timing.

On this container the backend is CPU, where the fast paths are the right
algorithm (the paper's GPU setting) — so the paper's qualitative claim
(Makhoul wins for large n, especially R < C) is reproducible here, while
DESIGN.md §2 explains why the TPU production path inverts the choice
(MXU matmul + fused Pallas kernel).

``run_transforms`` extends the comparison to every registered basis
backend (DESIGN.md §10): each kind's ``apply_fast`` against its own
matmul path, at the production width — the numbers behind
``BENCH_basis_transforms.json``. The committed record asserts the
Hadamard FHT butterfly beats its matmul at n=4096 (it is matmul-free and
twiddle-free, so it should win by more than Makhoul does).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import transforms as tr
from repro.core.dct import dct2_matrix, makhoul_dct2


def _time(fn, *args, warmup=3, iters=10):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(sizes=((1024, 1024), (4096, 1024), (1024, 4096))) -> list[dict]:
    rows = []
    for r, c in sizes:
        g = jnp.asarray(
            np.random.default_rng(0).standard_normal((r, c)), jnp.float32)
        q = dct2_matrix(c, jnp.float32)
        mm = jax.jit(lambda g, q: g @ q)
        fft = jax.jit(makhoul_dct2)
        t_mm = _time(mm, g, q)
        t_fft = _time(fft, g)
        ratio = t_mm / t_fft
        rows.append({"shape": (r, c), "matmul_s": t_mm, "makhoul_s": t_fft,
                     "ratio": ratio})
        print(f"({r:5d},{c:5d})  matmul={t_mm * 1e3:8.3f}ms  "
              f"makhoul={t_fft * 1e3:8.3f}ms  ratio={ratio:6.2f}x "
              f"({'makhoul wins' if ratio > 1 else 'matmul wins'})")
    return rows


def run_transforms(rows: int = 512, n: int = 4096,
                   out_path: str | None = "BENCH_basis_transforms.json"
                   ) -> dict:
    """Per-backend fast-vs-matmul timing at the production width.

    For each registered basis backend: time ``x @ Q`` (the TPU/MXU path)
    against ``backend.apply_fast(x)`` (Makhoul FFT for dct, FHT butterfly
    for hadamard; backends without a fast path are timed matmul-only).
    Asserts the committed acceptance claim: hadamard's FHT beats its own
    matmul path at the production n.
    """
    x = jnp.asarray(
        np.random.default_rng(0).standard_normal((rows, n)), jnp.float32)
    result = {"bench": "basis_transforms", "rows": rows, "n": n,
              "backend": jax.default_backend(), "kinds": {}}
    for kind in tr.backend_kinds():
        be = tr.get_backend(kind)
        q = tr.shared_basis(kind, n)           # build cost outside timing
        mm = jax.jit(lambda x, q: x @ q)
        t_mm = _time(mm, x, q)
        row = {"matmul_s": t_mm, "has_fast": be.has_fast}
        if be.has_fast:
            fast = jax.jit(be.apply_fast)
            t_fast = _time(fast, x)
            row["fast_s"] = t_fast
            row["speedup_fast_vs_matmul"] = t_mm / t_fast
            print(f"[basis_transforms] {kind:10s} matmul={t_mm * 1e3:8.3f}ms"
                  f"  fast={t_fast * 1e3:8.3f}ms  "
                  f"{t_mm / t_fast:6.2f}x")
        else:
            print(f"[basis_transforms] {kind:10s} matmul={t_mm * 1e3:8.3f}ms"
                  f"  (no fast path)")
        result["kinds"][kind] = row
    had = result["kinds"]["hadamard"]
    assert had["fast_s"] < had["matmul_s"], \
        f"hadamard FHT ({had['fast_s']:.4f}s) must beat its matmul " \
        f"({had['matmul_s']:.4f}s) at n={n}"
    if out_path:
        from benchmarks.common import write_bench_json
        write_bench_json(out_path, result)
        print(f"[basis_transforms] wrote {out_path}")
    return result


if __name__ == "__main__":
    run()
    run_transforms()
