"""Paper Appendix C/D Tables 4-5: Makhoul FFT-DCT vs matmul timing.

On this container the backend is CPU, where the FFT path is the right
algorithm (the paper's GPU setting) — so the paper's qualitative claim
(Makhoul wins for large n, especially R < C) is reproducible here, while
DESIGN.md §2 explains why the TPU production path inverts the choice
(MXU matmul + fused Pallas kernel).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dct import dct2_matrix, makhoul_dct2


def _time(fn, *args, warmup=3, iters=10):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(sizes=((1024, 1024), (4096, 1024), (1024, 4096))) -> list[dict]:
    rows = []
    for r, c in sizes:
        g = jnp.asarray(
            np.random.default_rng(0).standard_normal((r, c)), jnp.float32)
        q = dct2_matrix(c, jnp.float32)
        mm = jax.jit(lambda g, q: g @ q)
        fft = jax.jit(makhoul_dct2)
        t_mm = _time(mm, g, q)
        t_fft = _time(fft, g)
        ratio = t_mm / t_fft
        rows.append({"shape": (r, c), "matmul_s": t_mm, "makhoul_s": t_fft,
                     "ratio": ratio})
        print(f"({r:5d},{c:5d})  matmul={t_mm * 1e3:8.3f}ms  "
              f"makhoul={t_fft * 1e3:8.3f}ms  ratio={ratio:6.2f}x "
              f"({'makhoul wins' if ratio > 1 else 'matmul wins'})")
    return rows


if __name__ == "__main__":
    run()
