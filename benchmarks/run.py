"""Benchmark driver: one section per paper table/figure.

  python -m benchmarks.run [--fast] [--only trion_vs_dion,...]

Sections:
  trion_vs_dion        Table 1 / Fig 3   Trion vs Dion pre-training
  dct_adamw            Table 2 / Fig 2   AdamW vs LDAdamW vs DCT-AdamW
  makhoul              Tables 4-5        FFT-DCT vs matmul timing
  frugal_fira          Table 6           projection swap in FRUGAL/FIRA
  projection_errors    Fig 1 / App F     factorization error Trion vs Dion
  finetune             Tables 7-8        fine-tune proxy across optimizers
  optimizer_step       DESIGN.md §3      fused vs reference projected-Adam
                                         step -> BENCH_optimizer_step.json
  telemetry_overhead   DESIGN.md §8      stats-on vs stats-off fused step
                                         (≤3% gate) ->
                                         BENCH_telemetry_overhead.json
  basis_transforms     DESIGN.md §10     fast-vs-matmul per basis backend
                                         -> BENCH_basis_transforms.json
  basis_errors         DESIGN.md §10     per-basis selection error vs the
                                         rank-r SVD optimum
  serve_decode         DESIGN.md §12     paged continuous-batching decode:
                                         paged-vs-dense cache bytes, tok/s
                                         static vs churn, flash-decode
                                         dispatch gate -> BENCH_serve.json
  obs_overhead         DESIGN.md §13     obs-on vs obs-off serving tok/s
                                         (≤2% gate) and train-loop wall
                                         (≤1% gate) ->
                                         BENCH_obs_overhead.json
  tuned_kernels        DESIGN.md §15     roofline-pruned autotuner sweep:
                                         tuned-vs-default block ratio per
                                         kernel family (gate: tuned >=
                                         default within noise) ->
                                         BENCH_tuned_kernels.json
  lowp_errors          DESIGN.md §15     bf16/int8 projection-matmul error
                                         + selection overlap vs fp32 on
                                         the App. F gradient stream (gate:
                                         LOWP_ERROR_BOUNDS)

``--tune-cache PATH`` preloads autotuned block sizes into the process-wide
TuningCache before any section jits, so every kernel launched with
``block=None`` resolves its tuned block (repro.tune; docs/tuning.md).
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer steps (CI smoke)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--tune-cache", default=None, metavar="PATH",
                    help="autotuned block-size cache JSON to preload "
                         "(repro.tune; must load before the first jit)")
    args = ap.parse_args(argv)
    steps = 15 if args.fast else 40

    if args.tune_cache:
        from repro.tune import tuning_cache
        tuning_cache().load(args.tune_cache)
        print(f"[bench] loaded tuning cache {args.tune_cache} "
              f"({len(tuning_cache())} entries)")

    from . import (dct_adamw_vs_ldadamw, finetune, frugal_fira,
                   makhoul_vs_matmul, obs_overhead, projection_errors,
                   serve_decode, telemetry_overhead, trion_vs_dion,
                   tuned_kernels)

    sections = {
        "trion_vs_dion": lambda: trion_vs_dion.run(steps=steps),
        "dct_adamw": lambda: dct_adamw_vs_ldadamw.run(steps=steps),
        "makhoul": lambda: makhoul_vs_matmul.run(
            sizes=((512, 512), (2048, 512), (512, 2048)) if args.fast
            else ((1024, 1024), (4096, 1024), (1024, 4096))),
        "frugal_fira": lambda: frugal_fira.run(steps=steps),
        "projection_errors": lambda: projection_errors.run(
            steps=10 if args.fast else 30),
        "finetune": lambda: finetune.run(
            pretrain_steps=10 if args.fast else 30,
            ft_steps=10 if args.fast else 25),
        # fast mode writes to a scratch path so it never clobbers the
        # committed production-shape perf record
        "optimizer_step": lambda: dct_adamw_vs_ldadamw.run_step_bench(
            dim=1024 if args.fast else 4096,
            rank=64 if args.fast else 256,
            out_path=("BENCH_optimizer_step_fast.json" if args.fast
                      else "BENCH_optimizer_step.json")),
        # fast mode: tiny (~65ms) steps can't resolve a 3% wall gate on a
        # noisy box, so the scratch variant loosens the threshold; the
        # committed production-shape gate stays at 3% (CI runs that one)
        "telemetry_overhead": lambda: telemetry_overhead.run(
            dim=1024 if args.fast else 4096,
            rank=64 if args.fast else 256,
            threshold=0.15 if args.fast else 0.03,
            out_path=("BENCH_telemetry_overhead_fast.json" if args.fast
                      else "BENCH_telemetry_overhead.json")),
        # per-backend fast-vs-matmul (fast mode: scratch path + reduced
        # size so the committed production-shape record never gets
        # clobbered; n stays >= 2048 because the FHT-beats-matmul assert
        # needs a decisive margin on a noisy CI box)
        "basis_transforms": lambda: makhoul_vs_matmul.run_transforms(
            rows=128 if args.fast else 512,
            n=2048 if args.fast else 4096,
            out_path=("BENCH_basis_transforms_fast.json" if args.fast
                      else "BENCH_basis_transforms.json")),
        "basis_errors": lambda: projection_errors.run_basis_errors(
            steps=4 if args.fast else 10),
        # paged serving decode; the memory assert and the flash-decode
        # dispatch gate hard-fail in both modes (fast mode: fewer tokens,
        # scratch path so the committed record isn't clobbered)
        "serve_decode": lambda: serve_decode.run(
            new_tokens=8 if args.fast else 32,
            out_path=("BENCH_serve_fast.json" if args.fast
                      else "BENCH_serve.json")),
        # obs-on vs obs-off hot-path gates (fast mode: fewer/shorter waves
        # can't resolve a 1-2% wall gate on a noisy box, so the scratch
        # variant loosens the thresholds — same precedent as
        # telemetry_overhead; CI's obs job runs the full gates)
        "obs_overhead": lambda: obs_overhead.run(
            waves=2 if args.fast else 6,
            serve_new_tokens=8 if args.fast else 24,
            train_steps_per_wave=10 if args.fast else 25,
            serve_threshold=0.15 if args.fast else 0.02,
            train_threshold=0.10 if args.fast else 0.01,
            out_path=("BENCH_obs_overhead_fast.json" if args.fast
                      else "BENCH_obs_overhead.json")),
        # autotuner sweep (fast mode: reduced CI grid + scratch path so the
        # committed production-shape record isn't clobbered)
        "tuned_kernels": lambda: tuned_kernels.run(
            fast=args.fast,
            iters=1 if args.fast else 3,
            out_path=("BENCH_tuned_kernels_fast.json" if args.fast
                      else "BENCH_tuned_kernels.json")),
        "lowp_errors": lambda: projection_errors.run_lowp_errors(
            steps=4 if args.fast else 10),
    }
    chosen = (args.only.split(",") if args.only else list(sections))
    failures = 0
    for name in chosen:
        print(f"\n===== {name} =====")
        t0 = time.perf_counter()
        try:
            sections[name]()
        except Exception as e:                       # noqa: BLE001
            import traceback
            traceback.print_exc()
            print(f"[bench] {name} FAILED: {e}")
            failures += 1
        print(f"[bench] {name} done in {time.perf_counter() - t0:.1f}s")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
