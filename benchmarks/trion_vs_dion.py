"""Paper Table 1 / Figure 3: Trion vs Dion pre-training.

CPU-scale reproduction: same optimizer code paths, tiny Llama, three
ranks. The paper's claims checked here:
  (1) Trion train loss <= Dion train loss (DCT column selection + NS beats
      power-iteration+QR at equal rank);
  (2) Trion's optimizer state is smaller (no per-layer Q, only the shared
      DCT basis);
  (3) Trion step time is ~rank-independent while Dion grows with rank.
"""
from __future__ import annotations

from .common import fmt_row, tiny_llama, train


def run(steps: int = 40, ranks=(8, 16, 32)) -> list[dict]:
    cfg = tiny_llama()
    rows = []
    for rank in ranks:
        for name in ("trion", "dion"):
            r = train(cfg, name, steps=steps, rank=rank)
            r["rank"] = rank
            rows.append(r)
            print(fmt_row(f"{name}(r={rank})", r))
    # paper-claim checks (soft: print PASS/FAIL)
    by = {(r["optimizer"], r["rank"]): r for r in rows}
    for rank in ranks:
        t, d = by[("trion", rank)], by[("dion", rank)]
        ok_loss = t["final_loss"] <= d["final_loss"] * 1.05
        ok_mem = t["lowrank_state_bytes"] < d["lowrank_state_bytes"]
        print(f"[check] r={rank}: trion_loss<=dion_loss*1.05: "
              f"{'PASS' if ok_loss else 'FAIL'} "
              f"({t['final_loss']:.4f} vs {d['final_loss']:.4f}); "
              f"trion_state<dion_state: {'PASS' if ok_mem else 'FAIL'}")
    return rows


if __name__ == "__main__":
    run()
