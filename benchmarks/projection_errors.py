"""Paper Figure 1 / Appendix F: projection errors of Trion vs Dion.

Methodology per App. F: collect the gradient stream of a small Llama
(first transformer block's linear layers), maintain the same momentum
accumulator B_t for both optimizers, and compare the low-rank
factorization error each method commits at every step:
    Dion :  B ~ P_t Q_t^T from warm-started power iteration + QR
    Trion:  B ~ b_t Q_t^T from DCT dynamic column selection
Claim: the DCT selection yields lower (and over time decreasing) error.

``run_basis_errors`` extends the methodology across the basis registry
(DESIGN.md §10): per backend kind, the top-r column-selection
reconstruction error on the same gradient stream, normalized by the
rank-r SVD optimum — how much each predefined basis gives up against the
(per-matrix, expensive) optimal subspace, and whether each stays inside
its §4.1 contractive bound.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import transforms as tr
from repro.core.dct import dct2_matrix
from repro.core.selection import back_project, dynamic_column_selection
from repro.data.synthetic import SyntheticLM
from repro.train.steps import loss_fn

from .common import tiny_llama


def _dion_factor(b, q_prev):
    p = b @ q_prev                                   # (m, r)
    p, _ = jnp.linalg.qr(p)                          # orthonormalize
    q_new = b.T @ p                                  # (n, r)
    return p @ q_new.T, q_new / (jnp.linalg.norm(q_new, axis=0,
                                                 keepdims=True) + 1e-8)


def _trion_factor(b, dct, r):
    s = b @ dct
    idx, low = dynamic_column_selection(s, r)
    return back_project(low, dct, idx)


def _step_dion(state, g, mu, r):
    """Dion Alg: B = M + G; factor via warm power-iter; error-feedback
    momentum M = B - (1-mu) * low_rank(B)."""
    b = state["m"] + g
    approx, q_new = _dion_factor(b, state["q"])
    err = float(jnp.linalg.norm(b - approx))
    m = b - (1.0 - mu) * approx
    return {"m": m, "q": q_new}, err


def _step_trion(state, g, mu, r, dct):
    """Trion Alg 1: B = M + G; DCT column selection; error-feedback
    momentum M = B - (1-mu) * b Q^T. The EF term is what drives the
    decreasing error trend of the paper's Fig 1: whatever the fixed basis
    misses stays in M and accumulates until selected."""
    b = state["m"] + g
    approx = _trion_factor(b, dct, r)
    err = float(jnp.linalg.norm(b - approx))
    bound = float(jnp.sqrt(1.0 - r / b.shape[1]) * jnp.linalg.norm(b))
    m = b - (1.0 - mu) * approx
    return {"m": m}, err, bound


def run(steps: int = 30, rank: int = 16, mu: float = 0.95) -> dict:
    """App F methodology: the gradient stream comes from an actual
    training trajectory (params update each step — a frozen model's
    momentum degenerates to one persistent direction, which flatters
    power iteration and starves a fixed basis)."""
    dct = {}
    dstate: dict = {}
    tstate: dict = {}
    errs: dict = {}
    for grads in _grad_stream(steps):
        for n, g in grads.items():
            m, nn = g.shape
            r = min(rank, nn)
            if n not in dstate:
                dstate[n] = {"m": jnp.zeros_like(g), "q": jnp.eye(nn, r)}
                tstate[n] = {"m": jnp.zeros_like(g)}
                dct[n] = dct2_matrix(nn, jnp.float32)
                errs[n] = {"dion": [], "trion": []}
            dstate[n], ed = _step_dion(dstate[n], g, mu, r)
            tstate[n], et, bound = _step_trion(tstate[n], g, mu, r, dct[n])
            errs[n]["dion"].append(ed)
            errs[n]["trion"].append(et)
            errs[n].setdefault("bound", []).append(bound)
    names = list(errs)

    print("(ordering vs Dion is data-dependent — the paper's Fig 1 uses "
          "C4 gradients whose eigenbasis is DCT-like per §4.2; synthetic "
          "Zipf tokens lack that structure. The asserted check is the "
          "§4.1 contractive guarantee.)")
    for n in names:
        d = sum(errs[n]["dion"][-5:]) / 5
        tr = sum(errs[n]["trion"][-5:]) / 5
        bd = sum(errs[n]["bound"][-5:]) / 5
        ok = tr <= bd * 1.001              # theory: err <= sqrt(1-r/n)||B||
        order = "trion<dion (paper Fig1)" if tr <= d * 1.02 else \
            "dion<trion (data-dependent divergence, documented)"
        print(f"{n:10s} dion_err={d:9.4f} trion_err={tr:9.4f} "
              f"bound={bd:9.4f} contract={'PASS' if ok else 'FAIL'} "
              f"[{order}]")
        assert ok, (n, tr, bd)
    return errs


def _grad_stream(steps: int):
    """The App. F gradient stream: first-block linear-layer gradients from
    an evolving tiny-Llama training trajectory (a frozen model's momentum
    degenerates — see ``run``). Yields ``{name: (m, n) fp32}`` per step."""
    from repro.optim.api import get_optimizer
    from repro.train.steps import init_state, make_train_step

    cfg = tiny_llama()
    opt = get_optimizer("adamw", lr=3e-3)
    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=0)
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    grad = jax.jit(jax.grad(lambda p, b: loss_fn(p, b, cfg)[0]))

    seg = lambda g: g["segments"][0]["p0"]
    getters = {
        "attn.wq": lambda s: s["attn"]["wq"]["kernel"][0],
        "attn.wo": lambda s: s["attn"]["wo"]["kernel"][0],
        "mlp.wg": lambda s: s["mlp"]["wg"]["kernel"][0],
        "mlp.wd": lambda s: s["mlp"]["wd"]["kernel"][0],
    }
    for t in range(steps):
        batch = ds.batch(jnp.int32(t))
        g_tree = grad(state.params, batch)
        state, _ = step_fn(state, batch)
        out = {}
        for n, get in getters.items():
            g = get(seg(g_tree)).astype(jnp.float32)
            if g.shape[0] < g.shape[1]:
                g = g.T
            out[n] = g
        yield out


def run_basis_errors(steps: int = 10, rank: int = 16) -> dict:
    """Per-basis top-r selection error vs the rank-r SVD optimum.

    For every registered backend: ``err = ||G - G Q_r Q_r^T||_F`` with
    ``Q_r`` the top-r energy-selected columns, reported as the ratio to
    ``err_svd = sqrt(sum_{i>r} sigma_i^2)`` (the Eckart–Young floor).
    Asserts the ratio >= 1 (SVD is optimal) and that every basis stays
    inside the §4.1 contractive bound ``sqrt(1 - r/n) ||G||_F``.
    """
    kinds = tr.backend_kinds()
    sums = {k: 0.0 for k in kinds}
    svd_sum = 0.0
    count = 0
    bound_ok = {k: True for k in kinds}
    for grads in _grad_stream(steps):
        for name, g in grads.items():
            n = g.shape[1]
            r = min(rank, n)
            total = float(jnp.linalg.norm(g))
            s = jnp.linalg.svd(g, compute_uv=False)
            err_svd = float(jnp.sqrt(jnp.maximum(
                jnp.sum(s * s) - jnp.sum(s[:r] * s[:r]), 0.0)))
            svd_sum += err_svd
            bound = (1.0 - r / n) ** 0.5 * total
            for kind in kinds:
                q = tr.shared_basis(kind, n)
                sm = g @ q
                idx, low = dynamic_column_selection(sm, r)
                err = float(jnp.linalg.norm(g - back_project(low, q, idx)))
                sums[kind] += err
                if err > bound * 1.001:
                    bound_ok[kind] = False
            count += 1
    result = {"bench": "basis_errors", "rank": rank, "steps": steps,
              "svd_err_mean": svd_sum / count, "kinds": {}}
    for kind in kinds:
        ratio = sums[kind] / max(svd_sum, 1e-30)
        result["kinds"][kind] = {"err_mean": sums[kind] / count,
                                 "ratio_vs_svd": ratio,
                                 "contractive_bound_ok": bound_ok[kind]}
        print(f"[basis_errors] {kind:10s} err={sums[kind] / count:9.4f} "
              f"vs svd x{ratio:6.3f} "
              f"bound={'PASS' if bound_ok[kind] else 'FAIL'}")
        assert ratio >= 1.0 - 1e-3, (kind, ratio)   # Eckart–Young floor
        assert bound_ok[kind], f"{kind} violated the §4.1 bound"
    return result


def run_lowp_errors(steps: int = 10, rank: int = 16) -> dict:
    """Low-precision projection-matmul error gate (DESIGN.md §15).

    For every ``compute_dtype`` in ``COMPUTE_DTYPES``, runs the fused
    select+project on the same App. F gradient stream as ``run`` and
    measures, against the fp32 path: (a) the relative Frobenius error of
    the transform ``S = G Q`` and (b) the top-r selection overlap
    ``|idx_lowp ∩ idx_fp32| / r``. Asserts the error stays inside the
    documented ``LOWP_ERROR_BOUNDS`` and the selection overlap stays
    >= ``MIN_OVERLAP`` — the bound that licenses running the projection
    matmuls in bf16/int8 (kernels/lowp.py).
    """
    from repro.core.fused_step import (COMPUTE_DTYPES, LOWP_ERROR_BOUNDS,
                                       select_and_project)
    from repro.kernels.lowp import lowp_matmul

    MIN_OVERLAP = 0.90
    acc = {dt: {"err": 0.0, "overlap": 0.0, "count": 0}
           for dt in COMPUTE_DTYPES}
    dct = {}
    for grads in _grad_stream(steps):
        for name, g in grads.items():
            n = g.shape[1]
            r = min(rank, n)
            if name not in dct:
                dct[name] = dct2_matrix(n, jnp.float32)
            q = dct[name]
            s_ref = g @ q
            idx_ref, _ = select_and_project(g, q, r, mode="off")
            ref_set = set(map(int, idx_ref.reshape(-1)))
            nrm = float(jnp.linalg.norm(s_ref)) or 1.0
            for dt in COMPUTE_DTYPES:
                s_dt = lowp_matmul(g, q, dt)
                idx_dt, _ = select_and_project(g, q, r, mode="off",
                                               compute_dtype=dt)
                row = acc[dt]
                row["err"] += float(jnp.linalg.norm(s_dt - s_ref)) / nrm
                got = set(map(int, idx_dt.reshape(-1)))
                row["overlap"] += len(got & ref_set) / max(len(ref_set), 1)
                row["count"] += 1
    result = {"bench": "lowp_errors", "rank": rank, "steps": steps,
              "min_overlap": MIN_OVERLAP, "dtypes": {}}
    for dt in COMPUTE_DTYPES:
        row = acc[dt]
        err = row["err"] / max(row["count"], 1)
        overlap = row["overlap"] / max(row["count"], 1)
        bound = LOWP_ERROR_BOUNDS[dt]
        result["dtypes"][dt] = {"rel_err_mean": err,
                                "selection_overlap_mean": overlap,
                                "bound": bound}
        print(f"[lowp_errors] {dt:5s} rel_err={err:.5f} "
              f"(bound {bound}) overlap={overlap:.3f} "
              f"(floor {MIN_OVERLAP})")
        assert err <= bound + 1e-9, (dt, err, bound)
        assert overlap >= MIN_OVERLAP, (dt, overlap)
    return result


if __name__ == "__main__":
    run()
    run_basis_errors()
    run_lowp_errors()
