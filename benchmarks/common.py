"""Shared harness for the paper-table benchmarks.

All benches run CPU-sized stand-ins of the paper's Llama models (the full
sizes are exercised via the dry-run): same family, same optimizer code
paths, deterministic synthetic C4 stand-in data. Reported columns:
final train loss, optimizer-state bytes (the paper's memory claim at
exact ratio), and wall-clock per step (CPU; relative ordering only —
absolute GPU times live in the paper).

``bench_projected_step`` isolates the projected-Adam *optimizer step* itself
at production leaf shape (stacked ``(layers, 4096, 4096)``, rank 256) and
times the fused execution layer against the seed reference path — the
numbers behind ``BENCH_optimizer_step.json`` (DESIGN.md §3).
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.core.dct import dct2_matrix
from repro.data.synthetic import SyntheticLM
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim.api import get_optimizer
from repro.train.steps import TrainState, make_train_step


def platform_info() -> dict:
    """Host/accelerator identity block stamped into every BENCH json
    (DESIGN.md §15): perf records are only comparable within a platform,
    so the schema carries which backend produced the numbers."""
    import jaxlib
    dev = jax.devices()[0]
    return {
        "jax_backend": jax.default_backend(),
        "device_kind": getattr(dev, "device_kind", "unknown"),
        "device_count": jax.device_count(),
        "jax_version": jax.__version__,
        "jaxlib_version": jaxlib.__version__,
    }


def write_bench_json(path: str, result: dict) -> None:
    """Stamp the ``platform`` block and persist one BENCH record."""
    result.setdefault("platform", platform_info())
    with open(path, "w") as f:
        json.dump(result, f, indent=2)


def tiny_llama(d: int = 128, layers: int = 4, heads: int = 4,
               d_ff: int = 344, vocab: int = 512) -> ModelConfig:
    return ModelConfig(
        name=f"llama-tiny-d{d}", family="dense", d_model=d, n_heads=heads,
        n_kv_heads=heads, d_ff=d_ff, vocab_size=vocab,
        schedule=((("attn",), layers),), param_dtype="float32",
        compute_dtype="float32", remat=False, q_chunk=64, kv_chunk=64)


def state_bytes(opt_state) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(opt_state)
               if hasattr(x, "size"))


def lowrank_state_bytes(opt_state) -> int:
    """Bytes of the low-rank leaves only (excludes the AdamW fallback for
    embeddings/norms, which is identical across the compared optimizers)."""
    total = 0
    for leaf in jax.tree.leaves(opt_state.leaves,
                                is_leaf=lambda x: hasattr(x, "_fields")):
        if type(leaf).__name__ != "FullAdamLeaf":
            total += state_bytes(leaf)
    return total


def shared_basis_bytes(opt_state) -> int:
    return sum(v.size * v.dtype.itemsize for v in opt_state.bases.values())


def train(cfg, optimizer_name: str, steps: int = 40, *, seq: int = 64,
          batch: int = 8, lr: float = 3e-3, seed: int = 0,
          **opt_kw) -> dict:
    """Train `steps` steps; return loss trajectory + memory + timing."""
    opt = get_optimizer(optimizer_name, lr=lr, **opt_kw)
    params = T.init_params(cfg, jax.random.PRNGKey(seed))
    state = TrainState(jnp.zeros((), jnp.int32), params, opt.init(params))
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=seq,
                     global_batch=batch, seed=seed)
    step_fn = jax.jit(make_train_step(cfg, opt))

    losses = []
    t_steps = []
    for i in range(steps):
        b = ds.batch(jnp.int32(i))
        t0 = time.perf_counter()
        state, metrics = step_fn(state, b)
        jax.block_until_ready(metrics["loss"])
        t_steps.append(time.perf_counter() - t0)
        losses.append(float(metrics["ce"]))
    return {
        "optimizer": optimizer_name,
        "losses": losses,
        "final_loss": sum(losses[-5:]) / 5,
        "opt_state_bytes": state_bytes(state.opt_state),
        "lowrank_state_bytes": lowrank_state_bytes(state.opt_state),
        "shared_basis_bytes": shared_basis_bytes(state.opt_state),
        # skip compile step for timing
        "s_per_step": sum(t_steps[2:]) / max(len(t_steps) - 2, 1),
        "opt_kw": opt_kw,
    }


# ---------------------------------------------------------------------------
# optimizer-step microbench: fused execution layer vs seed reference path
# ---------------------------------------------------------------------------
class _DispatchSpy:
    """Counts fused-execution entry points reached while *tracing* the step.

    The bench drives the full chain API (partition -> lowrank_project ->
    rule), so if a refactor breaks dispatch — fused kernels no longer
    reached through ``partition`` — the counters stay zero and
    ``check`` raises, failing the CI bench job."""

    def __init__(self):
        self.counts = {"select_and_project": 0, "kernel": 0,
                       "newton_schulz": 0}
        self.ns_shapes = []

    def __enter__(self):
        from repro.core import fused_step
        from repro.kernels import ops as kops

        self._fs, self._kops = fused_step, kops
        self._orig_sp = fused_step.select_and_project
        self._orig_op = kops.dct_project_op
        self._orig_ns = kops.newton_schulz_op

        def sp(*a, **kw):
            self.counts["select_and_project"] += 1
            return self._orig_sp(*a, **kw)

        def op(*a, **kw):
            self.counts["kernel"] += 1
            return self._orig_op(*a, **kw)

        def ns(x, **kw):
            self.counts["newton_schulz"] += 1
            self.ns_shapes.append(tuple(x.shape))
            return self._orig_ns(x, **kw)

        fused_step.select_and_project = sp
        kops.dct_project_op = op
        kops.newton_schulz_op = ns
        return self

    def __exit__(self, *exc):
        self._fs.select_and_project = self._orig_sp
        self._kops.dct_project_op = self._orig_op
        self._kops.newton_schulz_op = self._orig_ns
        return False

    def check(self, mode: str):
        if mode != "off" and not self.counts["select_and_project"]:
            raise RuntimeError(
                f"fused mode {mode!r} never reached select_and_project "
                f"through the chain API — dispatch regression")
        if mode == "on" and not self.counts["kernel"]:
            raise RuntimeError(
                "fused mode 'on' never reached the Pallas dct_project "
                "kernel through the chain API — dispatch regression")

    def check_momentum(self, mode: str, rank, *, expect_select: bool = True):
        """Gate for the NS families: the one-pass select must be reached
        in any fused mode (when a subspace rank is set), and the Pallas
        NS kernel under mode "on" — on rank-sized blocks only.
        ``expect_select=False`` for dion, which has no column selection."""
        if mode != "off" and rank is not None and expect_select \
                and not self.counts["select_and_project"]:
            raise RuntimeError(
                f"fused mode {mode!r} never reached select_and_project "
                f"through the chain API — dispatch regression")
        if mode == "on":
            if not self.counts["newton_schulz"]:
                raise RuntimeError(
                    "fused mode 'on' never reached the Pallas newton_schulz "
                    "kernel through the chain API — dispatch regression")
            if rank is not None:
                for shape in self.ns_shapes:
                    if min(shape[-2:]) != rank:
                        raise RuntimeError(
                            f"subspace NS ran on {shape}, not a "
                            f"rank-{rank} block — fusion regression")


def compile_opt_step(rule, shape, *, seed: int = 0, telemetry: bool = False,
                     guard: bool = False):
    """Compile one full ``optimizer.update`` on a stacked lowrank leaf
    through the chain API (partition -> lowrank_project(rule)), under the
    dispatch spy. ``telemetry=True`` installs a stats collector around the
    traced update (the SubspaceStats pytree becomes a jit output) —
    exactly what enabling telemetry costs, benchmarks/telemetry_overhead.py
    gates it. ``guard=True`` appends the in-jit anomaly guard tail from
    ``make_train_step(..., guard=True)`` — ``all_finite_tree`` over the
    produced updates plus the ``select_tree`` commit/reject point on the
    optimizer state — exactly what ``--resilient`` costs per step,
    benchmarks/resilience_overhead.py gates it.
    Returns (compiled, inputs, fresh_state_fn, spy, peak_bytes)."""
    from repro.optim.transform import matrix_optimizer

    params = {"w": jnp.zeros(shape, jnp.float32)}
    grads = {"w": jax.random.normal(jax.random.PRNGKey(seed), shape,
                                    jnp.float32)}
    opt = matrix_optimizer(rule, 1e-3)
    state = opt.init(params)

    if telemetry:
        from repro.telemetry.stats import collect

        def update(grads, state, params):
            with collect() as col:
                d, new_state = opt.update(grads, state, params)
            return d, new_state, col.tree()
    else:
        update = opt.update

    if guard:
        from repro.train.resilience import all_finite_tree, select_tree

        inner = update

        def update(grads, state, params):
            out = inner(grads, state, params)
            d, new_state = out[0], out[1]
            flag = all_finite_tree(d)
            new_state = select_tree(flag, new_state, state)
            return (d, new_state, flag) + tuple(out[2:])

    with _DispatchSpy() as spy:
        compiled = jax.jit(update, donate_argnums=1).lower(
            grads, state, params).compile()
    mem = compiled.memory_analysis()
    peak = None
    if mem is not None:
        peak = int(mem.argument_size_in_bytes + mem.output_size_in_bytes
                   + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    return compiled, (grads, params), (lambda: opt.init(params)), spy, peak


def _time_opt_step(rule, shape, *, steps: int, warmup: int, seed: int = 0,
                   telemetry: bool = False):
    """Wall-time per full ``optimizer.update`` (see ``compile_opt_step``)."""
    compiled, (grads, params), init, spy, peak = compile_opt_step(
        rule, shape, seed=seed, telemetry=telemetry)
    state = init()
    times = []
    for _ in range(warmup + steps):
        tic = time.perf_counter()
        out = compiled(grads, state, params)
        state = out[1]
        jax.block_until_ready(out[0])
        times.append(time.perf_counter() - tic)
    timed = sorted(times[warmup:])
    return {
        "s_per_step": sum(times[warmup:]) / max(steps, 1),
        "s_per_step_median": timed[len(timed) // 2],
        "peak_live_bytes": peak,
        "dispatch": dict(spy.counts),
    }, spy


def bench_projected_step(*, layers: int = 2, dim: int = 4096, rank: int = 256,
                         steps: int = 3, warmup: int = 1,
                         out_path: str | None = "BENCH_optimizer_step.json",
                         ) -> dict:
    """Fused vs reference DCT-AdamW step on a stacked (layers, dim, dim)
    leaf, driven end-to-end through the chain API. The fused mode is the
    host-appropriate one: Pallas kernels on TPU, the Makhoul fft dataflow
    elsewhere (DESIGN.md §3). Raises if the fused execution layer is no
    longer reached through ``partition`` (dispatch regression)."""
    import dataclasses

    from repro.kernels import ops as kops
    from repro.optim.projected_adam import ProjectedAdamRule

    shape = (layers, dim, dim)
    base = ProjectedAdamRule(rank=rank, projector="dct", residual="ef",
                             ef_dtype="q8", fused="off")
    fused_mode = "on" if kops.ON_TPU else "fft"
    result = {
        "bench": "optimizer_step",
        "api": "chain",
        "leaf_shape": list(shape),
        "rank": rank,
        "steps_timed": steps,
        "backend": jax.default_backend(),
        "modes": {},
        "dispatch_gate": basis_dispatch_gate(),
    }
    for label, mode in (("reference", "off"), ("fused", fused_mode)):
        rule = dataclasses.replace(base, fused=mode)
        row, spy = _time_opt_step(rule, shape, steps=steps, warmup=warmup)
        spy.check(mode)
        row["fused_mode"] = mode
        result["modes"][label] = row
        print(f"[optimizer_step] {label:10s} ({mode:3s}) "
              f"{row['s_per_step'] * 1e3:9.1f} ms/step "
              f"peak={row['peak_live_bytes'] / 1e9 if row['peak_live_bytes'] else 0:.2f} GB")
    ref = result["modes"]["reference"]["s_per_step"]
    fus = result["modes"]["fused"]["s_per_step"]
    result["speedup_fused_vs_reference"] = ref / fus if fus > 0 else None
    print(f"[optimizer_step] speedup fused/reference = "
          f"{result['speedup_fused_vs_reference']:.2f}x")
    result["momentum"] = bench_momentum_step(layers=layers, dim=dim,
                                             rank=rank, steps=steps,
                                             warmup=warmup)
    result["momentum_dispatch_gate"] = momentum_dispatch_gate()
    if out_path:
        write_bench_json(out_path, result)
        print(f"[optimizer_step] wrote {out_path}")
    return result


def bench_momentum_step(*, layers: int = 2, dim: int = 4096, rank: int = 256,
                        steps: int = 3, warmup: int = 1) -> dict:
    """Subspace-fused muon/trion vs their seed paths (DESIGN.md §14).

    muon's seed path is *full-space* Newton–Schulz on the (dim, dim)
    momentum; the fused column projects into the selected rank-``rank``
    subspace first, so NS runs on (dim, rank) blocks — the tentpole
    speedup this record pins (>= 1.5x at the production shape). trion's
    seed is already subspace, so its column isolates the one-pass
    select + shared-gather fusion alone."""
    from repro.kernels import ops as kops
    from repro.optim.muon import MuonRule
    from repro.optim.trion import TrionRule

    shape = (layers, dim, dim)
    fused_mode = "on" if kops.ON_TPU else "fft"
    out = {"leaf_shape": list(shape), "rank": rank,
           "fused_mode": fused_mode, "families": {}}
    cases = (
        ("muon", MuonRule(fused="off"),
         MuonRule(rank=rank, fused=fused_mode)),
        ("trion", TrionRule(rank=rank, fused="off"),
         TrionRule(rank=rank, fused=fused_mode)),
    )
    for name, seed_rule, fused_rule in cases:
        row_seed, _ = _time_opt_step(seed_rule, shape, steps=steps,
                                     warmup=warmup)
        row_fused, spy = _time_opt_step(fused_rule, shape, steps=steps,
                                        warmup=warmup)
        spy.check_momentum(fused_mode, rank)
        sp = (row_seed["s_per_step"] / row_fused["s_per_step"]
              if row_fused["s_per_step"] > 0 else None)
        out["families"][name] = {"seed": row_seed, "fused": row_fused,
                                 "speedup_fused_vs_seed": sp}
        print(f"[optimizer_step] {name:10s} seed "
              f"{row_seed['s_per_step'] * 1e3:9.1f} ms/step  fused "
              f"{row_fused['s_per_step'] * 1e3:9.1f} ms/step  "
              f"speedup {sp:.2f}x")
    return out


def momentum_dispatch_gate(shape=(2, 128, 128), rank: int = 16) -> dict:
    """Hard-fail if muon/trion/dion stop reaching the fused kernels
    through the chain API under mode "on" — and if the Newton–Schulz
    they reach is no longer on rank-sized blocks (the tentpole shape
    pin; tests/test_subspace_fusion.py holds the same line in-tree)."""
    from repro.optim.dion import DionRule
    from repro.optim.muon import MuonRule
    from repro.optim.trion import TrionRule

    counts = {}
    for name, rule, expect_select in (
            ("muon", MuonRule(rank=rank, fused="on"), True),
            ("trion", TrionRule(rank=rank, fused="on"), True),
            ("dion", DionRule(rank=rank, fused="on"), False)):
        _, _, _, spy, _ = compile_opt_step(rule, shape)
        try:
            spy.check_momentum("on", rank, expect_select=expect_select)
        except RuntimeError as e:
            raise RuntimeError(
                f"momentum family {name!r} no longer reaches the fused "
                f"kernel path: {e}") from e
        counts[name] = dict(spy.counts)
        print(f"[optimizer_step] dispatch gate {name:10s} "
              f"newton_schulz={spy.counts['newton_schulz']} "
              f"select_and_project={spy.counts['select_and_project']}")
    return counts


def basis_dispatch_gate(kinds=("dct", "dst", "hadamard"),
                        shape=(2, 128, 128), rank: int = 16) -> dict:
    """Hard-fail if any predefined-basis kind stops reaching the fused
    kernel path through the chain API.

    The projection kernel is parameterized by the basis matrix (DESIGN.md
    §10), so every registered backend must dispatch to the same
    ``pallas_call`` under fused mode "on". Compiles one tiny step per kind
    under the spy; a zero kernel counter raises (the CI bench job runs
    this via ``bench_projected_step``). Returns the per-kind counters for
    the JSON record.
    """
    from repro.optim.projected_adam import ProjectedAdamRule

    counts = {}
    for kind in kinds:
        rule = ProjectedAdamRule(rank=rank, projector=kind, residual="ef",
                                 ef_dtype="q8", fused="on",
                                 needs_shared_basis=True)
        _, _, _, spy, _ = compile_opt_step(rule, shape)
        try:
            spy.check("on")
        except RuntimeError as e:
            raise RuntimeError(
                f"basis kind {kind!r} no longer reaches the fused kernel "
                f"path: {e}") from e
        counts[kind] = dict(spy.counts)
        print(f"[optimizer_step] dispatch gate {kind:10s} "
              f"kernel={spy.counts['kernel']} "
              f"select_and_project={spy.counts['select_and_project']}")
    return counts


def fmt_row(name: str, r: dict, extra: str = "") -> str:
    return (f"{name:28s} loss={r['final_loss']:.4f} "
            f"state={r['opt_state_bytes'] / 1e6:8.2f}MB "
            f"lowrank={r['lowrank_state_bytes'] / 1e6:8.2f}MB "
            f"basis={r['shared_basis_bytes'] / 1e6:6.2f}MB "
            f"{r['s_per_step'] * 1e3:7.1f}ms/step {extra}")
