"""Shared harness for the paper-table benchmarks.

All benches run CPU-sized stand-ins of the paper's Llama models (the full
sizes are exercised via the dry-run): same family, same optimizer code
paths, deterministic synthetic C4 stand-in data. Reported columns:
final train loss, optimizer-state bytes (the paper's memory claim at
exact ratio), and wall-clock per step (CPU; relative ordering only —
absolute GPU times live in the paper).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.data.synthetic import SyntheticLM
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim.api import get_optimizer
from repro.train.steps import TrainState, make_train_step


def tiny_llama(d: int = 128, layers: int = 4, heads: int = 4,
               d_ff: int = 344, vocab: int = 512) -> ModelConfig:
    return ModelConfig(
        name=f"llama-tiny-d{d}", family="dense", d_model=d, n_heads=heads,
        n_kv_heads=heads, d_ff=d_ff, vocab_size=vocab,
        schedule=((("attn",), layers),), param_dtype="float32",
        compute_dtype="float32", remat=False, q_chunk=64, kv_chunk=64)


def state_bytes(opt_state) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(opt_state)
               if hasattr(x, "size"))


def lowrank_state_bytes(opt_state) -> int:
    """Bytes of the low-rank leaves only (excludes the AdamW fallback for
    embeddings/norms, which is identical across the compared optimizers)."""
    total = 0
    for leaf in jax.tree.leaves(opt_state.leaves,
                                is_leaf=lambda x: hasattr(x, "_fields")):
        if type(leaf).__name__ != "FullAdamLeaf":
            total += state_bytes(leaf)
    return total


def shared_basis_bytes(opt_state) -> int:
    return sum(v.size * v.dtype.itemsize for v in opt_state.bases.values())


def train(cfg, optimizer_name: str, steps: int = 40, *, seq: int = 64,
          batch: int = 8, lr: float = 3e-3, seed: int = 0,
          **opt_kw) -> dict:
    """Train `steps` steps; return loss trajectory + memory + timing."""
    opt = get_optimizer(optimizer_name, lr=lr, **opt_kw)
    params = T.init_params(cfg, jax.random.PRNGKey(seed))
    state = TrainState(jnp.zeros((), jnp.int32), params, opt.init(params))
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=seq,
                     global_batch=batch, seed=seed)
    step_fn = jax.jit(make_train_step(cfg, opt))

    losses = []
    t_steps = []
    for i in range(steps):
        b = ds.batch(jnp.int32(i))
        t0 = time.perf_counter()
        state, metrics = step_fn(state, b)
        jax.block_until_ready(metrics["loss"])
        t_steps.append(time.perf_counter() - t0)
        losses.append(float(metrics["ce"]))
    return {
        "optimizer": optimizer_name,
        "losses": losses,
        "final_loss": sum(losses[-5:]) / 5,
        "opt_state_bytes": state_bytes(state.opt_state),
        "lowrank_state_bytes": lowrank_state_bytes(state.opt_state),
        "shared_basis_bytes": shared_basis_bytes(state.opt_state),
        # skip compile step for timing
        "s_per_step": sum(t_steps[2:]) / max(len(t_steps) - 2, 1),
        "opt_kw": opt_kw,
    }


def fmt_row(name: str, r: dict, extra: str = "") -> str:
    return (f"{name:28s} loss={r['final_loss']:.4f} "
            f"state={r['opt_state_bytes'] / 1e6:8.2f}MB "
            f"lowrank={r['lowrank_state_bytes'] / 1e6:8.2f}MB "
            f"basis={r['shared_basis_bytes'] / 1e6:6.2f}MB "
            f"{r['s_per_step'] * 1e3:7.1f}ms/step {extra}")
