"""Paper Table 2 / Figure 2: AdamW vs LDAdamW vs DCT-AdamW pre-training.

Claims checked: DCT-AdamW loss <= LDAdamW loss (approx); DCT-AdamW
low-rank state < LDAdamW state (two stored projection bases vs two index
sets + shared DCT); full AdamW is the reference lower bound on loss.
"""
from __future__ import annotations

from .common import fmt_row, tiny_llama, train


def run(steps: int = 40, rank: int = 16) -> list[dict]:
    cfg = tiny_llama()
    rows = []
    for name, kw in (
        ("adamw", {}),
        ("ldadamw", {"rank": rank}),
        ("dct_adamw", {"rank": rank, "ef_dtype": "q8"}),
        ("dct_adamw", {"rank": rank, "ef_dtype": "fp32"}),
    ):
        r = train(cfg, name, steps=steps, **kw)
        label = name + (f"[{kw.get('ef_dtype', '')}]" if name == "dct_adamw"
                        else "")
        r["label"] = label
        rows.append(r)
        print(fmt_row(label, r))
    byl = {r["label"]: r for r in rows}
    dct, ld = byl["dct_adamw[q8]"], byl["ldadamw"]
    print(f"[check] dct_adamw[q8]_loss<=ldadamw_loss*1.05: "
          f"{'PASS' if dct['final_loss'] <= ld['final_loss'] * 1.05 else 'FAIL'} "
          f"({dct['final_loss']:.4f} vs {ld['final_loss']:.4f})")
    print(f"[check] dct q8 lowrank state < ldadamw: "
          f"{'PASS' if dct['lowrank_state_bytes'] < ld['lowrank_state_bytes'] else 'FAIL'}")
    return rows


if __name__ == "__main__":
    run()
