"""Paper Table 2 / Figure 2: AdamW vs LDAdamW vs DCT-AdamW pre-training.

Claims checked: DCT-AdamW loss <= LDAdamW loss (approx); DCT-AdamW
low-rank state < LDAdamW state (two stored projection bases vs two index
sets + shared DCT); full AdamW is the reference lower bound on loss.

``run_step_bench`` additionally times the fused projected-Adam execution
layer (DESIGN.md §3) against the seed reference path on a production-shaped
stacked leaf and emits ``BENCH_optimizer_step.json`` — the per-PR perf
trajectory record for the optimizer hot path.
"""
from __future__ import annotations

from .common import bench_projected_step, fmt_row, tiny_llama, train


def run(steps: int = 40, rank: int = 16) -> list[dict]:
    cfg = tiny_llama()
    rows = []
    for name, kw in (
        ("adamw", {}),
        ("ldadamw", {"rank": rank}),
        ("dct_adamw", {"rank": rank, "ef_dtype": "q8"}),
        ("dct_adamw", {"rank": rank, "ef_dtype": "fp32"}),
    ):
        r = train(cfg, name, steps=steps, **kw)
        label = name + (f"[{kw.get('ef_dtype', '')}]" if name == "dct_adamw"
                        else "")
        r["label"] = label
        rows.append(r)
        print(fmt_row(label, r))
    byl = {r["label"]: r for r in rows}
    dct, ld = byl["dct_adamw[q8]"], byl["ldadamw"]
    print(f"[check] dct_adamw[q8]_loss<=ldadamw_loss*1.05: "
          f"{'PASS' if dct['final_loss'] <= ld['final_loss'] * 1.05 else 'FAIL'} "
          f"({dct['final_loss']:.4f} vs {ld['final_loss']:.4f})")
    print(f"[check] dct q8 lowrank state < ldadamw: "
          f"{'PASS' if dct['lowrank_state_bytes'] < ld['lowrank_state_bytes'] else 'FAIL'}")
    return rows


def run_step_bench(*, layers: int = 2, dim: int = 4096, rank: int = 256,
                   out_path: str = "BENCH_optimizer_step.json") -> dict:
    """Fused vs reference optimizer-step timing at production leaf shape."""
    return bench_projected_step(layers=layers, dim=dim, rank=rank,
                                out_path=out_path)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--rank", type=int, default=16)
    ap.add_argument("--skip-table", action="store_true",
                    help="only the optimizer-step microbench")
    ap.add_argument("--step-dim", type=int, default=4096)
    ap.add_argument("--step-layers", type=int, default=2)
    ap.add_argument("--step-rank", type=int, default=256)
    ap.add_argument("--step-out", default="BENCH_optimizer_step.json")
    args = ap.parse_args()
    if not args.skip_table:
        run(steps=args.steps, rank=args.rank)
    run_step_bench(layers=args.step_layers, dim=args.step_dim,
                   rank=args.step_rank, out_path=args.step_out)
