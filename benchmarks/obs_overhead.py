"""Observability overhead gate -> BENCH_obs_overhead.json.

The obs layer (src/repro/obs/, DESIGN.md §13) rides the hot paths of
both production loops: every serving step touches histograms, counters
and gauges in ``PagedServeEngine.step``, and every train step crosses
the phase spans + histograms in ``Trainer.run``. The deal it makes is
"one attribute test when disabled, cheap tuple-keyed dict updates when
enabled" — this benchmark holds it to that deal with hard gates:

  * serving: churn-wave decode throughput (tok/s) with obs **enabled**
    may be at most ``serve_threshold`` (default 2 %) below disabled;
  * training: wall per train-loop step with obs **enabled** may be at
    most ``train_threshold`` (default 1 %) above disabled.

Methodology is the repo's established overhead-gate recipe
(benchmarks/resilience_overhead.py), tightened for host-loop noise:
everything compiles up front, the two variants of every round run
back-to-back with alternating order (off,on / on,off / ...) so slow
machine-load drift cancels inside each round, and the serving gate
reads the **median paired ratio** across rounds (the train gate keeps
the min estimator — its waves are longer and quieter). The same engine
/ same jitted step serves both variants — toggling obs is a host-side
flag flip, and a sanity check asserts the flip is real: metric counts
must grow during enabled waves and stay frozen during disabled ones.

  PYTHONPATH=src python -m benchmarks.obs_overhead \
      [--waves 4] [--serve-threshold 0.02] [--train-threshold 0.01]
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro import obs


# ---------------------------------------------------------------------------
# serving side
# ---------------------------------------------------------------------------
def _churn_wave(eng, sess, rng, vocab, *, num_slots, prompt_len, budget):
    """One admit/retire churn wave (drip-fed submissions, mixed budgets);
    returns (tokens, seconds)."""
    budgets = [max(2, budget - 3 * (i % 4)) for i in range(2 * num_slots)]
    pending = [(rng.integers(0, vocab, (prompt_len,)), b) for b in budgets]
    hs = []
    t0 = time.perf_counter()
    while pending or eng.sched.has_work:
        if pending:
            p, b = pending.pop(0)
            hs.append(sess.submit(p, max_new_tokens=b))
        eng.step()
    dt = time.perf_counter() - t0
    assert all(h.done for h in hs)
    return sum(len(h.tokens) for h in hs), dt


def bench_serve(*, arch: str = "qwen2.5-32b", num_slots: int = 4,
                block_size: int = 8, prompt_len: int = 12,
                new_tokens: int = 16, waves: int = 4) -> dict:
    from repro.configs.registry import SMOKES
    from repro.models import transformer as T
    from repro.serve import PagedServeEngine, Session

    cfg = SMOKES[arch]
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    per_seq = -(-(prompt_len + new_tokens) // block_size)
    eng = PagedServeEngine(
        cfg, params, block_size=block_size,
        num_blocks=num_slots * per_seq, max_blocks_per_seq=2 * per_seq,
        num_slots=num_slots, max_prefill_len=prompt_len,
        prefill_chunk=prompt_len, num_splits=2)
    sess = Session(eng, "obsbench")

    obs.disable()
    _churn_wave(eng, sess, rng, cfg.vocab_size, num_slots=num_slots,
                prompt_len=prompt_len, budget=4)        # compile warmup

    tok_s: dict[str, list[float]] = {"off": [], "on": []}
    reg = obs.registry()

    def one(label: str) -> None:
        if label == "on":
            obs.enable()
        before = reg.get("serve_tokens_total").value()
        toks, dt = _churn_wave(eng, sess, rng, cfg.vocab_size,
                               num_slots=num_slots, prompt_len=prompt_len,
                               budget=new_tokens)
        grew = reg.get("serve_tokens_total").value() - before
        if label == "on":
            obs.disable()
            if grew != toks:
                raise RuntimeError(
                    f"obs-on wave emitted {toks} tokens but the counter "
                    f"grew by {grew} — serving instrumentation is not live")
        elif grew:
            raise RuntimeError(
                f"obs-off wave still grew serve_tokens_total by {grew} — "
                f"the disabled fast path is not a no-op")
        tok_s[label].append(toks / dt)

    # paired rounds with alternating order: the two variants of a round
    # run back-to-back, so slow machine-load drift cancels inside the
    # per-round ratio; the gate reads the median ratio across rounds
    for r in range(waves):
        for label in (("off", "on") if r % 2 == 0 else ("on", "off")):
            one(label)
    ratios = sorted(on / off
                    for off, on in zip(tok_s["off"], tok_s["on"]))
    return {
        "arch": arch,
        "num_slots": num_slots,
        "new_tokens": new_tokens,
        "waves_per_variant": waves,
        "tok_s_off": tok_s["off"],
        "tok_s_on": tok_s["on"],
        "tok_s_off_best": max(tok_s["off"]),
        "tok_s_on_best": max(tok_s["on"]),
        "paired_on_over_off": ratios,
        "paired_on_over_off_median": ratios[len(ratios) // 2],
    }


# ---------------------------------------------------------------------------
# training side
# ---------------------------------------------------------------------------
def bench_train(*, steps_per_wave: int = 25, waves: int = 4,
                seq: int = 32, batch: int = 4) -> dict:
    from benchmarks.common import tiny_llama
    from repro.data.synthetic import SyntheticLM
    from repro.models import transformer as T
    from repro.optim.api import get_optimizer
    from repro.train.loop import Trainer
    from repro.train.steps import TrainState, make_train_step

    cfg = tiny_llama(d=64, layers=2, heads=2, d_ff=172, vocab=256)
    opt = get_optimizer("dct_adamw", lr=1e-3, rank=16)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    import jax.numpy as jnp
    init_state = lambda: TrainState(jnp.zeros((), jnp.int32), params,  # noqa: E731
                                    opt.init(params))
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=seq,
                     global_batch=batch, seed=0)
    step_fn = jax.jit(make_train_step(cfg, opt))    # shared: compile once

    def wave() -> float:
        """Average wall seconds per step over one fresh Trainer run."""
        trainer = Trainer(train_step=step_fn, init_state_fn=init_state,
                          batch_fn=lambda i: ds.batch(jnp.int32(i)),
                          log_fn=lambda s: None, log_every=10**9)
        t0 = time.perf_counter()
        trainer.run(steps_per_wave, resume=False)
        return (time.perf_counter() - t0) / steps_per_wave

    obs.disable()
    wave()                                          # compile warmup
    s_step: dict[str, list[float]] = {"off": [], "on": []}
    reg = obs.registry()
    for k in range(2 * waves):
        label = ("off", "on")[(k + k // 2) % 2]
        if label == "on":
            obs.enable()
            before = reg.get("train_step_seconds").count()
        s = wave()
        if label == "on":
            grew = reg.get("train_step_seconds").count() - before
            obs.disable()
            if grew != steps_per_wave:
                raise RuntimeError(
                    f"obs-on wave ran {steps_per_wave} steps but the "
                    f"histogram saw {grew} — train instrumentation is "
                    f"not live")
        s_step[label].append(s)
    return {
        "model": cfg.name,
        "steps_per_wave": steps_per_wave,
        "waves_per_variant": waves,
        "s_per_step_off": s_step["off"],
        "s_per_step_on": s_step["on"],
        "s_per_step_off_min": min(s_step["off"]),
        "s_per_step_on_min": min(s_step["on"]),
    }


# ---------------------------------------------------------------------------
# driver + gates
# ---------------------------------------------------------------------------
def run(*, waves: int = 6, serve_new_tokens: int = 24,
        train_steps_per_wave: int = 25,
        serve_threshold: float = 0.02, train_threshold: float = 0.01,
        out_path: str | None = "BENCH_obs_overhead.json") -> dict:
    was_enabled = obs.enabled()
    try:
        serve = bench_serve(waves=waves, new_tokens=serve_new_tokens)
        train = bench_train(waves=waves,
                            steps_per_wave=train_steps_per_wave)
    finally:
        # the benchmark must not leave the process-wide flag flipped
        (obs.enable if was_enabled else obs.disable)()

    serve_frac = 1.0 - serve["paired_on_over_off_median"]
    train_frac = ((train["s_per_step_on_min"] - train["s_per_step_off_min"])
                  / max(train["s_per_step_off_min"], 1e-30))
    result = {
        "bench": "obs_overhead",
        "backend": jax.default_backend(),
        "serve": serve,
        "train": train,
        "serve_overhead_frac": serve_frac,
        "train_overhead_frac": train_frac,
        "serve_threshold": serve_threshold,
        "train_threshold": train_threshold,
    }
    print(f"[obs_overhead] serve churn: off {serve['tok_s_off_best']:.1f} "
          f"tok/s, on {serve['tok_s_on_best']:.1f} tok/s; paired median "
          f"overhead {serve_frac * 100:+.2f}% "
          f"(gate {serve_threshold * 100:.0f}%)")
    print(f"[obs_overhead] train loop: off "
          f"{train['s_per_step_off_min'] * 1e3:.2f} ms/step, on "
          f"{train['s_per_step_on_min'] * 1e3:.2f} ms/step "
          f"({train_frac * 100:+.2f}%, gate {train_threshold * 100:.0f}%)")
    if out_path:
        from benchmarks.common import write_bench_json
        write_bench_json(out_path, result)
        print(f"[obs_overhead] wrote {out_path}")
    failures = []
    if serve_frac > serve_threshold:
        failures.append(f"serving tok/s regressed {serve_frac * 100:+.2f}% "
                        f"(gate {serve_threshold * 100:.0f}%)")
    if train_frac > train_threshold:
        failures.append(f"train step regressed {train_frac * 100:+.2f}% "
                        f"(gate {train_threshold * 100:.0f}%)")
    if failures:
        raise RuntimeError("obs overhead gate: " + "; ".join(failures))
    return result


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--waves", type=int, default=6)
    ap.add_argument("--serve-new-tokens", type=int, default=24)
    ap.add_argument("--train-steps", type=int, default=25)
    ap.add_argument("--serve-threshold", type=float, default=0.02)
    ap.add_argument("--train-threshold", type=float, default=0.01)
    ap.add_argument("--out", default="BENCH_obs_overhead.json")
    args = ap.parse_args()
    run(waves=args.waves, serve_new_tokens=args.serve_new_tokens,
        train_steps_per_wave=args.train_steps,
        serve_threshold=args.serve_threshold,
        train_threshold=args.train_threshold, out_path=args.out)
