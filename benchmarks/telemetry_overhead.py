"""Telemetry overhead gate (DESIGN.md §8): stats collection must be ≤3 %.

Times the fused projected-Adam optimizer step on the production-shaped
stacked leaf — (2, 4096, 4096) rank 256, the same subject as
``BENCH_optimizer_step.json`` — with and without a stats collector
installed, through the full chain API. Fails (non-zero exit / raise) when
enabling SubspaceStats collection regresses the fused median step time by
more than ``threshold`` (default 3 %), or when the fused execution layer
stops being reached with telemetry on (dispatch-spy regression).

Both variants are compiled up front and the timed steps *interleave* them
(off, on, off, on, ...), so slow drift in machine load hits both equally;
medians gate, means are reported — single-step outliers on shared CI
boxes must not flap a 3 % comparison.

  PYTHONPATH=src python -m benchmarks.telemetry_overhead \
      [--dim 4096] [--rank 256] [--threshold 0.03] [--out ...]
"""
from __future__ import annotations

import time

import jax

from .common import compile_opt_step


def run(*, layers: int = 2, dim: int = 4096, rank: int = 256,
        steps: int = 9, warmup: int = 1, threshold: float = 0.03,
        out_path: str | None = "BENCH_telemetry_overhead.json") -> dict:
    from repro.kernels import ops as kops
    from repro.optim.projected_adam import ProjectedAdamRule

    fused_mode = "on" if kops.ON_TPU else "fft"
    shape = (layers, dim, dim)
    rule = ProjectedAdamRule(rank=rank, projector="dct", residual="ef",
                             ef_dtype="q8", fused=fused_mode)
    result = {
        "bench": "telemetry_overhead",
        "leaf_shape": list(shape),
        "rank": rank,
        "fused_mode": fused_mode,
        "steps_timed": steps,
        "threshold": threshold,
        "backend": jax.default_backend(),
        "modes": {},
    }
    variants = {}
    for label, telemetry in (("stats_off", False), ("stats_on", True)):
        compiled, (grads, params), init, spy, peak = compile_opt_step(
            rule, shape, telemetry=telemetry)
        # telemetry must not knock the step off the fused execution layer
        spy.check(fused_mode)
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else (ca or {})
        variants[label] = {"compiled": compiled, "grads": grads,
                           "params": params, "state": init(),
                           "peak": peak, "dispatch": dict(spy.counts),
                           "flops": float(ca.get("flops", 0.0)),
                           "bytes": float(ca.get("bytes accessed", 0.0)),
                           "times": []}

    def one_step(v, record: bool):
        tic = time.perf_counter()
        out = v["compiled"](v["grads"], v["state"], v["params"])
        v["state"] = out[1]
        jax.block_until_ready(out[0])
        if record:
            v["times"].append(time.perf_counter() - tic)

    labels = list(variants)
    for k in range(warmup + steps):                 # interleaved, with the
        order = labels if k % 2 == 0 else labels[::-1]   # order alternating
        for label in order:                              # per round
            one_step(variants[label], record=k >= warmup)

    for label, v in variants.items():
        ts = sorted(v["times"])
        result["modes"][label] = {
            "s_per_step": sum(ts) / len(ts),
            "s_per_step_median": ts[len(ts) // 2],
            "s_per_step_min": ts[0],
            "flops": v["flops"],
            "bytes_accessed": v["bytes"],
            "peak_live_bytes": v["peak"],
            "dispatch": v["dispatch"],
        }
        row = result["modes"][label]
        print(f"[telemetry_overhead] {label:9s} "
              f"median {row['s_per_step_median'] * 1e3:9.1f} ms/step "
              f"min {row['s_per_step_min'] * 1e3:9.1f} ms/step "
              f"flops {row['flops']:.3e} bytes {row['bytes_accessed']:.3e} "
              f"dispatch={row['dispatch']}")

    off, on = result["modes"]["stats_off"], result["modes"]["stats_on"]

    def frac(key):
        return (on[key] - off[key]) / max(off[key], 1e-30)

    # the deterministic gates: compiled flop/byte counts catch any real
    # extra pass regardless of machine noise; the wall gate uses the min
    # estimator (classic noise-robust choice) over interleaved samples
    result["overhead_frac"] = frac("s_per_step_median")
    result["overhead_frac_min"] = frac("s_per_step_min")
    result["overhead_frac_flops"] = frac("flops")
    result["overhead_frac_bytes"] = frac("bytes_accessed")
    print(f"[telemetry_overhead] overhead: median "
          f"{result['overhead_frac'] * 100:+.2f}% "
          f"min {result['overhead_frac_min'] * 100:+.2f}% "
          f"flops {result['overhead_frac_flops'] * 100:+.2f}% "
          f"bytes {result['overhead_frac_bytes'] * 100:+.2f}% "
          f"(gate: {threshold * 100:.0f}%)")
    if out_path:
        from benchmarks.common import write_bench_json
        write_bench_json(out_path, result)
        print(f"[telemetry_overhead] wrote {out_path}")
    failures = [k for k in ("overhead_frac_min", "overhead_frac_flops",
                            "overhead_frac_bytes")
                if result[k] > threshold]
    if failures:
        raise RuntimeError(
            f"enabling SubspaceStats collection regressed the fused step "
            f"beyond {threshold * 100:.0f}% at {shape} r={rank}: "
            + ", ".join(f"{k}={result[k] * 100:+.2f}%" for k in failures))
    return result


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--dim", type=int, default=4096)
    ap.add_argument("--rank", type=int, default=256)
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--threshold", type=float, default=0.03)
    ap.add_argument("--out", default="BENCH_telemetry_overhead.json")
    args = ap.parse_args()
    run(layers=args.layers, dim=args.dim, rank=args.rank, steps=args.steps,
        warmup=args.warmup, threshold=args.threshold, out_path=args.out)
