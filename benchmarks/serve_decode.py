"""Paged continuous-batching decode benchmark -> BENCH_serve.json.

Three claims, one JSON record (DESIGN.md §12):

  * memory — the paged pool is smaller than a dense KV cache of equal
    serving capacity (``num_slots`` sequences of up to ``max_seq_len``);
    the pool oversubscribes because blocks are granted on demand, and
    the record hard-asserts ``paged_cache_bytes < dense_bytes_equivalent``.
  * throughput — decode tok/s with a full static batch vs. under
    admit/retire churn (staggered submissions, mixed budgets), both on
    the same compiled step (continuous batching never retraces).
  * dispatch — a spy on ``repro.kernels.ops.flash_decode_op`` counts
    kernel entries while the step traces; zero means decode silently
    fell off the Pallas path and the bench raises (CI runs this).
"""
from __future__ import annotations

import time

import jax
import numpy as np


class _DecodeDispatchSpy:
    """Counts flash-decode kernel entries reached while tracing the
    serve step (one per attention layer per compiled step)."""

    def __init__(self):
        self.count = 0

    def __enter__(self):
        from repro.kernels import ops as kops

        self._kops = kops
        self._orig = kops.flash_decode_op

        def op(*a, **kw):
            self.count += 1
            return self._orig(*a, **kw)

        kops.flash_decode_op = op
        return self

    def __exit__(self, *exc):
        self._kops.flash_decode_op = self._orig
        return False

    def check(self):
        if not self.count:
            raise RuntimeError(
                "paged decode never reached the flash_decode kernel — "
                "dispatch regression (dense fallback?)")


def _wave_static(eng, sess, rng, vocab, *, num_slots, prompt_len, budget):
    hs = [sess.submit(rng.integers(0, vocab, (prompt_len,)),
                      max_new_tokens=budget) for _ in range(num_slots)]
    t0 = time.perf_counter()
    eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(h.tokens) for h in hs)
    return toks, dt


def _wave_churn(eng, sess, rng, vocab, *, num_slots, prompt_len, budget):
    budgets = [max(2, budget - 3 * (i % 4)) for i in range(2 * num_slots)]
    pending = [(rng.integers(0, vocab, (prompt_len,)), b) for b in budgets]
    hs = []
    t0 = time.perf_counter()
    while pending or eng.sched.has_work:
        # drip-feed submissions so slots churn mid-flight
        if pending:
            p, b = pending.pop(0)
            hs.append(sess.submit(p, max_new_tokens=b))
        eng.step()
    dt = time.perf_counter() - t0
    toks = sum(len(h.tokens) for h in hs)
    assert all(h.done for h in hs)
    return toks, dt


def run(arch: str = "qwen2.5-32b", *, num_slots: int = 4,
        block_size: int = 8, prompt_len: int = 12, new_tokens: int = 32,
        num_splits: int = 2, out_path: str | None = "BENCH_serve.json",
        ) -> dict:
    from repro.configs.registry import SMOKES
    from repro.models import transformer as T
    from repro.serve import PagedServeEngine, Session

    cfg = SMOKES[arch]
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    # pool sized for the workload but 2x oversubscribed vs worst case:
    # equal capacity (num_slots x max_seq_len) with half the blocks
    per_seq = -(-(prompt_len + new_tokens) // block_size)
    max_blocks_per_seq = 2 * per_seq
    num_blocks = num_slots * per_seq

    with _DecodeDispatchSpy() as spy:
        eng = PagedServeEngine(
            cfg, params, block_size=block_size, num_blocks=num_blocks,
            max_blocks_per_seq=max_blocks_per_seq, num_slots=num_slots,
            max_prefill_len=prompt_len, prefill_chunk=prompt_len,
            num_splits=num_splits)
        sess = Session(eng, "bench")
        # warmup wave: compiles prefill + decode step (traced under spy)
        _wave_static(eng, sess, rng, cfg.vocab_size,
                     num_slots=num_slots, prompt_len=prompt_len, budget=4)
    spy.check()

    toks_s, dt_s = _wave_static(eng, sess, rng, cfg.vocab_size,
                                num_slots=num_slots, prompt_len=prompt_len,
                                budget=new_tokens)
    toks_c, dt_c = _wave_churn(eng, sess, rng, cfg.vocab_size,
                               num_slots=num_slots, prompt_len=prompt_len,
                               budget=new_tokens)

    stats = eng.stats()
    paged = stats["cache_bytes"]
    dense = stats["dense_bytes_equivalent"]
    if not paged < dense:
        raise RuntimeError(
            f"paged pool ({paged}B) not smaller than the equal-capacity "
            f"dense cache ({dense}B) — paging memory claim broken")

    result = {
        "bench": "serve_decode",
        "arch": arch,
        "backend": jax.default_backend(),
        "num_slots": num_slots,
        "block_size": block_size,
        "num_blocks": num_blocks,
        "max_blocks_per_seq": max_blocks_per_seq,
        "num_splits": num_splits,
        "prompt_len": prompt_len,
        "new_tokens": new_tokens,
        "paged_cache_bytes": paged,
        "dense_bytes_equivalent": dense,
        "paged_over_dense": paged / dense,
        "tok_s_static": toks_s / dt_s,
        "tok_s_churn": toks_c / dt_c,
        "decode_steps": stats["steps"],
        "kernel_dispatch_count": spy.count,
    }
    print(f"[serve_decode] {arch} slots={num_slots} "
          f"paged={paged / 1e6:.2f}MB dense-equiv={dense / 1e6:.2f}MB "
          f"({result['paged_over_dense']:.2f}x)")
    print(f"[serve_decode] static {result['tok_s_static']:.1f} tok/s, "
          f"churn {result['tok_s_churn']:.1f} tok/s, "
          f"kernel dispatches at trace = {spy.count}")
    if out_path:
        from benchmarks.common import write_bench_json
        write_bench_json(out_path, result)
        print(f"[serve_decode] wrote {out_path}")
    return result
