import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before any jax-importing module: jax locks
#   the device count at first init, and the production meshes need 512
#   placeholder host devices (brief: MULTI-POD DRY-RUN step 0).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the real step function — train_step (fwd + bwd +
microbatch accumulation + the paper's optimizer), prefill, or serve_step —
against ShapeDtypeStruct inputs carrying the production NamedShardings,
compiles it, prints memory_analysis() (fits?) and cost_analysis()
(FLOPs/bytes for §Roofline), and parses the compiled HLO for collective
payloads. Results go to JSON for EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k \
      [--multi-pod] [--optimizer trion] [--rank 256] [--out results.json]
  python -m repro.launch.dryrun --all --out-dir results/dryrun/
"""
import argparse
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ARCHS, ASSIGNED
from repro.configs.shapes import SHAPES, batch_specs, skip_reason
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.parallel import compat
from repro.optim.api import get_optimizer
from repro.parallel import sharding as sh
from repro.roofline.analysis import analyze_compiled, model_flops
from repro.serve.engine import make_serve_step
from repro.train.steps import TrainState, init_state, make_train_step


def _with_ns(tree_sds, tree_specs, mesh):
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, p)),
        tree_sds, tree_specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _train_lowered(cfg, mesh, optimizer_name: str, rank: int,
                   shape_name: str, accum_dtype: str):
    spec = SHAPES[shape_name]
    opt_kw = {}
    if optimizer_name == "trion" and cfg.param_dtype == "bfloat16":
        # >=90B-class archs: bf16 momentum halves optimizer HBM
        # (DESIGN.md §7; quality trade recorded in EXPERIMENTS.md)
        opt_kw["momentum_dtype"] = "bfloat16"
    opt = get_optimizer(optimizer_name, lr=0.01, rank=rank, **opt_kw)
    state_sds = jax.eval_shape(
        partial(init_state, cfg, opt, jax.random.PRNGKey(0)))
    p_specs = sh.params_specs(state_sds.params, mesh)
    o_specs = sh.opt_state_specs(state_sds.opt_state, state_sds.params,
                                 p_specs)
    state_specs = TrainState(P(), p_specs, o_specs)

    batch_sds = batch_specs(cfg, shape_name)
    b_specs = sh.batch_specs_tree(batch_sds, mesh)

    state_in = _with_ns(state_sds, state_specs, mesh)
    batch_in = _with_ns(batch_sds, b_specs, mesh)

    step = make_train_step(cfg, opt, accum_dtype=accum_dtype)
    out_ns = (jax.tree.map(lambda p: NamedSharding(mesh, p), state_specs,
                           is_leaf=lambda x: isinstance(x, P)), None)
    fn = jax.jit(step, donate_argnums=0, out_shardings=out_ns)
    return fn.lower(state_in, batch_in)


def _prefill_lowered(cfg, mesh, shape_name: str):
    spec = SHAPES[shape_name]
    params_sds = jax.eval_shape(
        partial(T.init_params, cfg, jax.random.PRNGKey(0)))
    p_specs = sh.params_specs(params_sds, mesh)
    params_in = _with_ns(params_sds, p_specs, mesh)

    batch_sds = batch_specs(cfg, shape_name, with_targets=False)
    batch_in = _with_ns(batch_sds, sh.batch_specs_tree(batch_sds, mesh),
                        mesh)

    def prefill_fn(params, batch):
        logits, cache, _ = T.prefill(params, batch, cfg,
                                     max_len=spec.seq_len)
        return logits, cache

    return jax.jit(prefill_fn).lower(params_in, batch_in)


def _decode_lowered(cfg, mesh, shape_name: str):
    spec = SHAPES[shape_name]
    b, s = spec.global_batch, spec.seq_len
    params_sds = jax.eval_shape(
        partial(T.init_params, cfg, jax.random.PRNGKey(0)))
    p_specs = sh.params_specs(params_sds, mesh)
    params_in = _with_ns(params_sds, p_specs, mesh)

    cache_sds = jax.eval_shape(partial(T.init_cache, cfg, b, s))
    c_specs = sh.cache_specs_tree(cache_sds, mesh)
    cache_in = _with_ns(cache_sds, c_specs, mesh)

    dp = sh.dp_axes(mesh) or None
    dp_n = sh._axis_size(mesh, dp)
    tok_spec = P(dp) if dp and b % dp_n == 0 else P()
    token_in = jax.ShapeDtypeStruct((b,), jnp.int32,
                                    sharding=NamedSharding(mesh, tok_spec))
    pos_in = jax.ShapeDtypeStruct((), jnp.int32,
                                  sharding=NamedSharding(mesh, P()))

    serve = make_serve_step(cfg)
    out_ns = (None, jax.tree.map(lambda p: NamedSharding(mesh, p), c_specs,
                                 is_leaf=lambda x: isinstance(x, P)))
    fn = jax.jit(serve, donate_argnums=1, out_shardings=out_ns)
    return fn.lower(params_in, cache_in, token_in, pos_in)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             optimizer: str = "trion", rank: int = 256,
             accum_dtype: str | None = None, save_hlo: str | None = None,
             sp_attn: bool = False, layout: str | None = None,
             microbatch: int | None = None, baseline: bool = False,
             device_arch: str | None = None, verbose: bool = True) -> dict:
    import dataclasses

    cfg = ARCHS[arch]
    if baseline:
        cfg = dataclasses.replace(cfg, attn_sp=False, layout="fsdp_tp",
                                  decode_layout="fsdp_tp")
    if microbatch is not None:
        cfg = dataclasses.replace(cfg, train_microbatch=microbatch)
    if sp_attn:
        # iter-1 (kept): shard_map sequence-parallel attention.
        # iter-2 (sequence-parallel residual stream) was REFUTED under the
        # FSDP x TP layout — see EXPERIMENTS.md §Perf — so seq_parallel
        # stays off (the scoped policy below pins it).
        cfg = dataclasses.replace(cfg, attn_sp=True)
    spec = SHAPES[shape_name]
    # pure_dp applies to TRAIN cells only: at 32k-sequence inference the
    # model axis must keep spreading attention work — measured regression
    # otherwise (EXPERIMENTS.md §Perf iter-5 notes). decode cells use the
    # per-arch decode layout (§Perf iter-6).
    if layout:
        eff_layout = layout
    elif spec.kind == "train":
        eff_layout = cfg.layout
    elif spec.kind == "decode":
        eff_layout = cfg.decode_layout
    else:
        eff_layout = "fsdp_tp"
    if eff_layout == "pure_dp":
        # batch shards over every axis -> no microbatch loop needed
        cfg = dataclasses.replace(cfg, train_microbatch=0)
    mesh_name = "pod2x16x16" if multi_pod else "pod1x16x16"
    reason = skip_reason(cfg, shape_name)
    if reason is not None:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skip", "reason": reason}

    if accum_dtype is None:
        # bf16-weight archs (>=27B): bf16 gradient accumulators too
        # (halves grad HBM; precision trade in DESIGN.md §7)
        accum_dtype = ("bfloat16" if cfg.param_dtype == "bfloat16"
                       else "float32")

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.perf_counter()
    # layout scoped per cell (not a process-global): concurrent run_cell
    # calls under different layouts cannot race each other's specs
    with sh.use_policy(layout=eff_layout, seq_parallel=False), \
            compat.set_mesh(mesh):
        if spec.kind == "train":
            lowered = _train_lowered(cfg, mesh, optimizer, rank, shape_name,
                                     accum_dtype)
        elif spec.kind == "prefill":
            lowered = _prefill_lowered(cfg, mesh, shape_name)
        else:
            lowered = _decode_lowered(cfg, mesh, shape_name)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
    t_total = time.perf_counter() - t0

    mf = model_flops(cfg, spec.kind, spec.seq_len, spec.global_batch)
    report = analyze_compiled(
        compiled, arch=arch, shape=shape_name, mesh_name=mesh_name,
        n_devices=mesh.size, model_flops_total=mf,
        tp_degree=mesh.shape["model"], compile_s=t_total,
        device_arch=device_arch)

    if verbose:
        print(f"== {arch} x {shape_name} x {mesh_name} ==")
        print("memory_analysis:", compiled.memory_analysis())
        ca = compat.cost_analysis(compiled)
        print("xla cost_analysis (loop bodies once): flops=%.3e bytes=%.3e"
              % (ca.get("flops", 0), ca.get("bytes accessed", 0)))
        print("trip-aware per-device: flops=%.3e bytes=%.3e"
              % (report.flops_per_device, report.bytes_per_device))
        print("collectives:", json.dumps(report.collectives))
        print("roofline: compute=%.4fs memory=%.4fs collective=%.4fs "
              "dominant=%s mfu=%.4f useful=%.2f"
              % (report.compute_s, report.memory_s, report.collective_s,
                 report.dominant, report.mfu, report.useful_ratio))
        print(f"lower={t_lower:.1f}s compile={t_total - t_lower:.1f}s")

    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(compiled.as_text())

    rec = report.to_json()
    rec["status"] = "ok"
    rec["optimizer"] = optimizer if spec.kind == "train" else None
    rec["accum_dtype"] = accum_dtype if spec.kind == "train" else None
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="all 40 assigned cells on this mesh")
    ap.add_argument("--optimizer", default="trion")
    ap.add_argument("--rank", type=int, default=256)
    ap.add_argument("--accum-dtype", default=None)
    ap.add_argument("--sp-attn", action="store_true",
                    help="force sequence-parallel attention (§Perf iter-1)")
    ap.add_argument("--layout", choices=("fsdp_tp", "pure_dp", "decode_tp"), default=None,
                    help="override the per-arch layout policy")
    ap.add_argument("--baseline", action="store_true",
                    help="strip per-arch optimizations (paper-faithful)")
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--device-arch", default=None,
                    help="accelerator roofline table to price the report "
                         "against (repro.roofline.hw: v5e/v5p/a100/"
                         "cpu-est); --arch is the *model*, this is the "
                         "*device*; default REPRO_ARCH env or v5e")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--out", default=None, help="write JSON record(s) here")
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for arch in ASSIGNED:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    records = []
    n_fail = 0
    for arch, shape in cells:
        try:
            rec = run_cell(arch, shape, multi_pod=args.multi_pod,
                           optimizer=args.optimizer, rank=args.rank,
                           accum_dtype=args.accum_dtype,
                           sp_attn=args.sp_attn, layout=args.layout,
                           microbatch=args.microbatch,
                           baseline=args.baseline,
                           device_arch=args.device_arch,
                           save_hlo=args.save_hlo)
        except Exception as e:                      # noqa: BLE001
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape,
                   "mesh": "pod2x16x16" if args.multi_pod else "pod1x16x16",
                   "status": "error", "error": f"{type(e).__name__}: {e}"}
            n_fail += 1
        records.append(rec)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(records if len(records) > 1 else records[0], f,
                      indent=1)
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
