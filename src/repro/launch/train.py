"""Training driver.

  python -m repro.launch.train --arch llama-350m --optimizer trion \
      --rank 256 --steps 300 --seq-len 512 --batch 64 \
      --ckpt-dir /tmp/ckpt [--supervise] [--smoke]

On a real TPU deployment this binary runs once per host under the
production mesh; here (CPU container) it runs single-process, exercising
the identical code path: config -> data pipeline -> jit'd train_step with
the paper's optimizer -> checkpoint manager -> supervisor restarts.
``--supervise`` wraps the run in the restart supervisor (crash -> resume
from the latest checkpoint with backoff).
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp


def build(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-350m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--optimizer", default="trion")
    ap.add_argument("--rank", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--weight-decay", type=float, default=0.01)
    ap.add_argument("--fused", default=None,
                    choices=["auto", "on", "fft", "off"],
                    help="fused-step dispatch for the projected-Adam family")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--supervise", action="store_true")
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = build(argv)
    if args.supervise:
        from repro.train.supervisor import supervise
        child = [sys.executable, "-m", "repro.launch.train"] + [
            a for a in (argv or sys.argv[1:]) if a != "--supervise"]
        return supervise(child)

    from repro.configs.registry import get_config
    from repro.data.synthetic import make_batch_fn
    from repro.optim.api import get_optimizer
    from repro.train.loop import Trainer
    from repro.train.schedule import cosine_warmup
    from repro.train.steps import init_state, make_train_step

    cfg = get_config(args.arch, smoke=args.smoke)
    lr = cosine_warmup(args.lr, args.warmup, args.steps)
    opt_kw = {"weight_decay": args.weight_decay}
    if args.optimizer != "adamw":
        opt_kw["rank"] = args.rank
    if args.fused is not None:
        if args.optimizer not in ("dct_adamw", "ldadamw", "galore",
                                  "frugal", "fira"):
            raise SystemExit(f"--fused applies to the projected-Adam family "
                             f"only, not {args.optimizer!r}")
        opt_kw["fused"] = args.fused
    # each preset is a thin chain (partition -> rule / adam fallback ->
    # lr/decay); get_optimizer validates kwargs eagerly with the allowed set
    opt = get_optimizer(args.optimizer, lr=lr, **opt_kw)

    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=0)
    batch_fn = make_batch_fn(cfg, args.seq_len, args.batch, seed=args.seed)

    trainer = Trainer(
        train_step=step_fn,
        init_state_fn=lambda: init_state(cfg, opt,
                                         jax.random.PRNGKey(args.seed)),
        batch_fn=lambda s: batch_fn(jnp.int32(s)),
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        log_every=args.log_every)
    state = trainer.run(total_steps=args.steps)
    final = trainer.metrics_history[-1] if trainer.metrics_history else {}
    if final:
        print(f"[train] done at step {int(state.step)}: "
              f"loss {float(final['loss']):.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
