"""Training driver.

  python -m repro.launch.train --arch llama-350m --optimizer trion \
      --rank 256 --steps 300 --seq-len 512 --batch 64 \
      --ckpt-dir /tmp/ckpt [--supervise] [--smoke]

On a real TPU deployment this binary runs once per host under the
production mesh; here (CPU container) it runs single-process, exercising
the identical code path: config -> data pipeline -> jit'd train_step with
the paper's optimizer -> checkpoint manager -> supervisor restarts.
``--supervise`` wraps the run in the restart supervisor (crash -> resume
from the latest checkpoint with backoff).
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp

# the presets built on ProjectedAdamRule — the ones the adaptive
# controllers apply to
PROJECTED_ADAM_FAMILY = ("dct_adamw", "ldadamw", "galore", "frugal", "fira")
# presets with a fused-step dispatch field (DESIGN.md §3/§14): the
# projected-Adam family plus the momentum-orthogonalization rules
FUSED_FAMILY = PROJECTED_ADAM_FAMILY + ("muon", "trion", "dion")
# presets whose rule is unconditionally zero_shardable (DESIGN.md §9/§14);
# galore/frugal join when --basis swaps their dense svd projector for a
# registered basis backend
ZERO_ALWAYS = ("dct_adamw", "muon", "trion", "dion")


def build(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-350m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--optimizer", default="trion")
    ap.add_argument("--rank", type=int, default=None,
                    help="subspace rank for the low-rank families "
                         "(default 128); for muon the default is full-space "
                         "Newton-Schulz and --rank opts into subspace "
                         "orthogonalization (DESIGN.md §14)")
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--weight-decay", type=float, default=0.01)
    ap.add_argument("--fused", default=None,
                    choices=["auto", "on", "fft", "off"],
                    help="fused-step dispatch for the projected-Adam family "
                         "and muon/trion/dion (Pallas Newton-Schulz on the "
                         "rank-sized subspace factor)")
    ap.add_argument("--basis", default=None,
                    choices=["dct", "dst", "hadamard", "randortho"],
                    help="predefined orthogonal basis backend for "
                         "dct_adamw (or the projector for galore/frugal/"
                         "fira) — the whole fused/ZeRO/telemetry stack is "
                         "basis-agnostic (docs/transforms.md)")
    ap.add_argument("--compute-dtype", default=None,
                    choices=["fp32", "bf16", "int8"],
                    help="projection-matmul precision for dct_adamw "
                         "(DESIGN.md §15): int8 = quantized operands with "
                         "exact int32 accumulation; error bounds gated in "
                         "benchmarks/projection_errors.py")
    ap.add_argument("--tune-cache", default=None, metavar="PATH",
                    help="autotuned kernel block-size cache JSON "
                         "(repro.tune, docs/tuning.md); loaded into the "
                         "process-wide TuningCache before the step jits so "
                         "block=None kernel launches resolve tuned blocks")
    ap.add_argument("--zero", default="off", choices=["off", "1"],
                    help="ZeRO-1 partitioning of the low-rank optimizer "
                         "state across the data axes; the fused step runs "
                         "per-shard inside shard_map and updates are "
                         "all-gathered (dct_adamw/muon/trion/dion, or "
                         "galore/frugal with --basis; >1 device; see "
                         "docs/distributed.md)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--supervise", action="store_true")
    # telemetry + adaptive control (DESIGN.md §8)
    ap.add_argument("--telemetry", default="off",
                    choices=["off", "jsonl", "csv"],
                    help="collect per-leaf SubspaceStats in-jit and stream "
                         "step-bucketed rows to --telemetry-path")
    ap.add_argument("--telemetry-path", default=None,
                    help="output file (default telemetry.<fmt> next to "
                         "--ckpt-dir, else ./telemetry.<fmt>)")
    ap.add_argument("--telemetry-every", type=int, default=10,
                    help="steps aggregated per telemetry row")
    ap.add_argument("--adaptive-rank", action="store_true",
                    help="closed-loop per-layer rank reallocation from "
                         "captured energy (projected-Adam family only)")
    ap.add_argument("--adaptive-refresh", action="store_true",
                    help="closed-loop per-layer refresh-interval control "
                         "from index-overlap drift")
    ap.add_argument("--control-every", type=int, default=50,
                    help="steps between controller decisions")
    # runtime observability (DESIGN.md §13, docs/observability.md)
    ap.add_argument("--obs-dir", default=None, metavar="DIR",
                    help="enable the obs layer (host-side metrics + phase "
                         "spans) and write DIR/metrics.prom + "
                         "DIR/trace.json at the end of the run (halted "
                         "runs included)")
    ap.add_argument("--obs-sync-every", type=int, default=0,
                    help="with --obs-dir: every N steps also "
                         "block_until_ready the full train state into "
                         "train_full_sync_seconds (0 = off; see the "
                         "timing note in train/loop.py)")
    # resilience + fault injection (DESIGN.md §11, docs/resilience.md)
    ap.add_argument("--resilient", action="store_true",
                    help="arm the in-jit anomaly guard and the host-side "
                         "escalation ladder (skip -> rollback -> rollback+"
                         "LR-cut -> halt); builds the optimizer with the "
                         "lr_scale injected hyperparameter")
    ap.add_argument("--max-skips", type=int, default=2,
                    help="consecutive non-finite steps skipped before the "
                         "ladder escalates to a rollback")
    ap.add_argument("--max-rollbacks", type=int, default=3,
                    help="rollbacks before the run halts (exit code 86)")
    ap.add_argument("--lr-cut", type=float, default=0.5,
                    help="LR factor applied on the 2nd+ rollback")
    ap.add_argument("--chaos", default=None, metavar="PLAN.json",
                    help="deterministic fault-injection plan "
                         "(train/chaos.py; schema in docs/resilience.md)")
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = build(argv)
    if args.supervise:
        from repro.train.supervisor import checkpoint_progress_fn, supervise
        child = [sys.executable, "-m", "repro.launch.train"] + [
            a for a in (argv or sys.argv[1:]) if a != "--supervise"]
        # progress-aware restarts: the budget resets while checkpoints
        # advance, and a crash loop (no progress) halts early
        progress_fn = (checkpoint_progress_fn(args.ckpt_dir)
                       if args.ckpt_dir else None)
        return supervise(child, progress_fn=progress_fn)

    from repro.configs.registry import get_config
    from repro.data.synthetic import make_batch_fn
    from repro.optim.api import get_optimizer
    from repro.train.loop import Trainer
    from repro.train.schedule import cosine_warmup
    from repro.train.steps import init_state, make_train_step

    if args.tune_cache:
        # must happen before the first jit: block=None resolution runs at
        # trace time, and jit caches retraces only on shape/static changes
        from repro.tune import tuning_cache
        tuning_cache().load(args.tune_cache)
        print(f"[train] loaded tuning cache {args.tune_cache} "
              f"({len(tuning_cache())} entries)")

    cfg = get_config(args.arch, smoke=args.smoke)
    lr = cosine_warmup(args.lr, args.warmup, args.steps)
    chaos_plan = None
    if args.chaos is not None:
        from repro.train.chaos import ChaosPlan
        chaos_plan = ChaosPlan.load(args.chaos)
        print(f"[train] chaos plan armed: {len(chaos_plan.faults)} faults "
              f"from {args.chaos}")
    resilience = None
    if args.resilient:
        from repro.train.resilience import (ResilienceConfig,
                                            ResilienceManager)
        resilience = ResilienceManager(ResilienceConfig(
            max_skips=args.max_skips, max_rollbacks=args.max_rollbacks,
            lr_cut=args.lr_cut))
    opt_kw = {"weight_decay": args.weight_decay}
    if args.resilient:
        # the ladder's LR-cut rung needs the injected lr_scale leaf
        opt_kw["lr_scale"] = True
    if args.optimizer == "muon":
        # muon defaults to full-space Newton-Schulz; an explicit --rank
        # opts into subspace orthogonalization (DESIGN.md §14)
        if args.rank is not None:
            opt_kw["rank"] = args.rank
    elif args.optimizer != "adamw":
        opt_kw["rank"] = args.rank if args.rank is not None else 128
    if args.fused is not None:
        if args.optimizer not in FUSED_FAMILY:
            raise SystemExit(f"--fused applies to "
                             f"{'/'.join(FUSED_FAMILY)}, "
                             f"not {args.optimizer!r}")
        opt_kw["fused"] = args.fused
    if args.compute_dtype is not None:
        if args.optimizer != "dct_adamw":
            # only the dct_adamw preset exposes the rule's compute_dtype
            # field; the other family presets pin fp32
            raise SystemExit("--compute-dtype applies to dct_adamw, not "
                             f"{args.optimizer!r}")
        if args.compute_dtype != "fp32":
            # the lowp mirror only exists on the fused paths; fail at the
            # CLI instead of deep inside the first trace (fused="auto"
            # resolves to the reference path off-TPU)
            from repro.core import fused_step
            if fused_step.resolve(args.fused or "auto") == "off":
                raise SystemExit(
                    f"--compute-dtype {args.compute_dtype} requires a fused "
                    "dispatch mode; pass --fused on or --fused fft "
                    "(the default --fused auto resolves to the reference "
                    "path on this backend)")
        opt_kw["compute_dtype"] = args.compute_dtype
    if args.basis is not None:
        if args.optimizer == "dct_adamw":
            opt_kw["basis"] = args.basis
        elif args.optimizer in ("galore", "frugal", "fira"):
            opt_kw["projector"] = args.basis
        else:
            # ldadamw is defined by its power-iteration projector; the
            # non-family presets have no predefined-basis plug point
            raise SystemExit("--basis applies to dct_adamw/galore/frugal/"
                             f"fira, not {args.optimizer!r}")
    adaptive = args.adaptive_rank or args.adaptive_refresh
    zero_cfg = None
    mesh = None
    if args.zero != "off":
        zero_ok = (args.optimizer in ZERO_ALWAYS
                   or (args.optimizer in ("galore", "frugal")
                       and args.basis is not None))
        if not zero_ok:
            # every remaining combo keeps dense projector state
            # (power/svd) whose refresh is not row-decomposable, or (fira)
            # feeds psum'd norms into the update arithmetic — it would
            # silently keep every leaf replicated, so fail loudly instead
            raise SystemExit(
                "--zero needs a ZeRO-shardable optimizer: "
                f"{'/'.join(ZERO_ALWAYS)} (always), or galore/frugal with "
                "--basis <dct|dst|hadamard|randortho>; "
                f"{args.optimizer!r} would silently stay replicated")
        if adaptive:
            # a controller rebuild re-inits + migrates sharded state; that
            # composition is untested — fail loudly rather than subtly
            raise SystemExit("--zero cannot be combined with "
                             "--adaptive-rank/--adaptive-refresh yet")
        from repro.parallel.zero import ZeroConfig
        zero_cfg = ZeroConfig(mode=args.zero)
        opt_kw["zero"] = zero_cfg
        if jax.device_count() > 1:
            from repro.launch.mesh import make_mesh
            mesh = make_mesh((jax.device_count(),), ("data",))
        else:
            print("[train] --zero requested with a single visible device; "
                  "state stays replicated (on CPU, set XLA_FLAGS="
                  "--xla_force_host_platform_device_count=N to shard)")
    telemetry_on = args.telemetry != "off" or adaptive
    if adaptive and args.optimizer not in PROJECTED_ADAM_FAMILY:
        raise SystemExit("--adaptive-rank/--adaptive-refresh apply to the "
                         f"projected-Adam family only, not "
                         f"{args.optimizer!r}")
    if args.adaptive_refresh and args.optimizer != "dct_adamw":
        # drift is measured from index overlap, which only index-based
        # projectors emit (basis projectors report the -1 sentinel and the
        # scheduler would be silently inert) — the CLI presets for the
        # other family members use power/svd projectors
        raise SystemExit("--adaptive-refresh needs an index-based projector"
                         " (dct); use --optimizer dct_adamw")

    def make_optimizer(overrides=None):
        kw = dict(opt_kw)
        if overrides:
            kw["overrides"] = overrides
        return get_optimizer(args.optimizer, lr=lr, **kw)

    def make_step(opt):
        return jax.jit(make_train_step(cfg, opt, telemetry=telemetry_on,
                                       guard=args.resilient,
                                       chaos=chaos_plan),
                       donate_argnums=0)

    batch_fn = make_batch_fn(cfg, args.seq_len, args.batch, seed=args.seed)

    sink = None
    if args.telemetry != "off":
        from repro.telemetry.sink import TelemetrySink
        path = args.telemetry_path or (
            f"{args.ckpt_dir}/telemetry.{args.telemetry}" if args.ckpt_dir
            else f"telemetry.{args.telemetry}")
        # append exactly when this run will resume from a checkpoint: a
        # preemption restart must not truncate the pre-preemption
        # telemetry, while a fresh run must not inherit a stale file
        resuming = False
        if args.ckpt_dir:
            from repro.train.checkpoint import CheckpointManager
            resuming = CheckpointManager(
                args.ckpt_dir).latest_step() is not None
        sink = TelemetrySink(path, fmt=args.telemetry,
                             every=args.telemetry_every, append=resuming)

    obs_mod = None
    if args.obs_dir:
        from repro import obs as obs_mod
        obs_mod.enable()

    trainer_kw = dict(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                      log_every=args.log_every,
                      log_metrics=sink.log_metrics if sink else None,
                      resilience=resilience,
                      sync_sample_every=args.obs_sync_every)
    if chaos_plan is not None and args.ckpt_dir:
        trainer_kw["ckpt_fault_hook"] = chaos_plan.bind_checkpoint_dir(
            args.ckpt_dir)

    def trainer_batch_fn(s):
        return batch_fn(jnp.int32(s))
    if chaos_plan is not None:
        trainer_batch_fn = chaos_plan.wrap_batch_fn(trainer_batch_fn)

    if adaptive:
        from repro.telemetry.adaptive import AdaptiveOptimizerManager
        from repro.telemetry.controllers import (
            RankAllocator, RankAllocatorConfig, RefreshScheduler,
            RefreshSchedulerConfig, leaf_inventory)
        from repro.models import transformer as T

        params_sds = jax.eval_shape(
            lambda: T.init_params(cfg, jax.random.PRNGKey(args.seed)))
        leaves = leaf_inventory(params_sds)
        allocator = scheduler = None
        if args.adaptive_rank:
            allocator = RankAllocator(
                RankAllocatorConfig(base_rank=args.rank,
                                    decide_every=args.control_every),
                leaves)
        if args.adaptive_refresh:
            # the ladder is seeded from the preset's refresh cadence (the
            # dct_adamw CLI preset runs T_u=1) so a stretch doubles the
            # configured interval rather than resetting it
            scheduler = RefreshScheduler(
                RefreshSchedulerConfig(base_interval=1,
                                       decide_every=args.control_every,
                                       cooldown=args.control_every),
                leaves)
        manager = AdaptiveOptimizerManager(
            make_optimizer=make_optimizer, make_step=make_step,
            make_train_state=lambda opt: init_state(
                cfg, opt, jax.random.PRNGKey(args.seed)),
            rank_allocator=allocator, refresh_scheduler=scheduler)
        trainer = Trainer(train_step=manager.step,
                          init_state_fn=manager.init_state,
                          batch_fn=trainer_batch_fn,
                          control_hook=manager.control_hook,
                          extra_state=manager, **trainer_kw)
    else:
        opt = make_optimizer()
        step_fn = make_step(opt)

        def init_fn():
            return init_state(cfg, opt, jax.random.PRNGKey(args.seed))

        if mesh is not None:
            # ZeRO-1: derive the partitioned placement (moments/EF split
            # over the data axis) and install it at init; the Trainer also
            # uses it to re-partition on checkpoint restore, so the DP
            # width may change across restarts (docs/distributed.md)
            from repro.parallel import sharding as sh
            from repro.train.steps import TrainState
            from jax.sharding import PartitionSpec as P

            state_sds = jax.eval_shape(init_fn)
            p_specs = sh.params_specs(state_sds.params, mesh)
            o_specs = sh.opt_state_specs(state_sds.opt_state,
                                         state_sds.params, p_specs,
                                         zero=zero_cfg, mesh=mesh)
            shardings = sh.named_shardings(
                TrainState(P(), p_specs, o_specs), mesh)
            trainer_kw["state_shardings"] = shardings
            base_init = init_fn
            init_fn = lambda: jax.device_put(base_init(), shardings)  # noqa: E731

        trainer = Trainer(
            train_step=step_fn, init_state_fn=init_fn,
            batch_fn=trainer_batch_fn, **trainer_kw)

    from repro.train.resilience import HALT_EXIT_CODE, TrainingHalted
    try:
        if mesh is not None:
            from repro.parallel import compat
            with compat.set_mesh(mesh):
                state = trainer.run(total_steps=args.steps)
        else:
            state = trainer.run(total_steps=args.steps)
    except TrainingHalted as e:
        # rung 4: deterministic divergence — the diagnostic dump is already
        # on disk; the exit code tells the supervisor not to restart
        print(f"[train] halted: {e}")
        return HALT_EXIT_CODE
    finally:
        if sink is not None:
            sink.close()
        if obs_mod is not None:
            import os
            os.makedirs(args.obs_dir, exist_ok=True)
            prom = obs_mod.write_prometheus(
                os.path.join(args.obs_dir, "metrics.prom"))
            trace = obs_mod.write_chrome_trace(
                os.path.join(args.obs_dir, "trace.json"))
            print(f"[train] obs artifacts: {prom}, {trace}")
    final = trainer.metrics_history[-1] if trainer.metrics_history else {}
    if final:
        print(f"[train] done at step {int(state.step)}: "
              f"loss {float(final['loss']):.4f}")
    if adaptive and args.adaptive_rank:
        print(f"[train] final rank allocation: {allocator.alloc}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
