"""Production mesh construction (brief: MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state. Single-pod: (data=16, model=16) = 256 chips;
multi-pod: (pod=2, data=16, model=16) = 512 chips. ``pod`` and ``data``
jointly form the FSDP/batch axes; ``model`` is TP/EP.

Use ``with compat.set_mesh(mesh):`` around lowering — that installs the
mesh that repro.parallel.sharding reads (abstract mesh on current jax,
thread-resources physical mesh on older releases).
"""
from __future__ import annotations

from repro.parallel import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh for experiments (e.g. scaling the pod axis)."""
    return compat.make_mesh(shape, axes)
