"""Production mesh construction (brief: MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state. Single-pod: (data=16, model=16) = 256 chips;
multi-pod: (pod=2, data=16, model=16) = 512 chips. ``pod`` and ``data``
jointly form the FSDP/batch axes; ``model`` is TP/EP.

Use ``with jax.set_mesh(mesh):`` around lowering — that installs the
abstract mesh that repro.parallel.sharding reads (the legacy ``with mesh:``
context does NOT).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh for experiments (e.g. scaling the pod axis)."""
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))
