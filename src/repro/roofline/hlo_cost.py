"""Trip-count-aware, dtype-correct cost model over compiled HLO text.

Why not ``compiled.cost_analysis()``:
  1. XLA's analysis counts each while-loop body ONCE — a 61-layer
     `lax.scan` model reports ~1/61 of its real FLOPs/bytes, and every
     collective inside the layer loop is similarly undercounted.
  2. The CPU backend legalizes bf16 dots by inserting fp32 converts of
     whole operands (a TPU reads bf16 directly into the MXU), inflating
     `bytes accessed` by the fp32 copies.

This walker parses the compiled module text, recurses through
while/call/fusion with while trip counts recovered from the loop condition
(JAX scans lower to `compare(i, L), direction=LT`), multiplies costs by
trips, resolves operands **through converts** so traffic is counted at the
dtype the TPU would stream, and sums collective payloads per kind.

It is an estimator, not a simulator: elementwise flops are approximate,
fusions count operand+output traffic once (the TPU fusion model), and
dynamic-update-slice is treated as in-place (update bytes, not buffer
bytes). Validated against hand-counts in tests/test_roofline.py.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_SKIP_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "convert", "while", "call", "conditional",
                 "after-all", "custom-call", "reshape", "transpose",
                 "partition-id", "replica-id", "iota", "rng-bit-generator"}

_TRANSCENDENTAL = {"exponential", "log", "tanh", "power", "rsqrt", "sqrt",
                   "cosine", "sine", "logistic", "divide", "atan2",
                   "exponential-minus-one", "log-plus-one", "erf",
                   "cbrt"}

_ELEMENTWISE = {"add", "subtract", "multiply", "maximum", "minimum",
                "and", "or", "xor", "not", "negate", "abs", "compare",
                "select", "clamp", "floor", "ceil", "round-nearest-afz",
                "round-nearest-even", "sign", "shift-left",
                "shift-right-logical", "shift-right-arithmetic",
                "remainder", "is-finite", "popcnt", "clz"}


@dataclass
class Shape:
    dtype: str
    dims: tuple[int, ...]

    @property
    def size(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def bytes(self) -> int:
        return self.size * _DTYPE_BYTES.get(self.dtype, 4)


@dataclass
class Instr:
    name: str
    shapes: list[Shape]            # result shape(s); tuples flattened
    opcode: str
    operands: list[str]
    attrs: str
    raw: str

    @property
    def out_bytes(self) -> int:
        return sum(s.bytes for s in self.shapes)

    @property
    def out_size(self) -> int:
        return sum(s.size for s in self.shapes)


@dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: dict = field(default_factory=lambda: defaultdict(
        lambda: {"count": 0.0, "bytes": 0.0}))

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.collectives.items():
            self.collectives[k]["count"] += v["count"] * mult
            self.collectives[k]["bytes"] += v["bytes"] * mult


_SHAPE_RE = re.compile(r"(pred|[a-z]+\d+[a-z0-9]*)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*"
    r"([a-z][a-z0-9\-]*)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*\))?\s*->.*\{")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _parse_shapes(text: str) -> list[Shape]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        out.append(Shape(dtype, tuple(int(d) for d in dims.split(",") if d)))
    return out


def _split_operands_attrs(rest: str) -> tuple[str, str]:
    """rest starts after '<opcode>(' — split at the matching ')'."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i], rest[i + 1:]
    return rest, ""


def parse_module(hlo_text: str) -> dict[str, list[Instr]]:
    """{computation_name: [Instr, ...]}, plus '__entry__' alias."""
    comps: dict[str, list[Instr]] = {}
    current: list[Instr] | None = None
    entry_name = None
    comment_re = re.compile(r"/\*.*?\*/")
    for line in hlo_text.splitlines():
        stripped = comment_re.sub("", line).rstrip()
        if not stripped:
            continue
        m = _COMP_RE.match(stripped)
        if m and stripped.endswith("{") and "=" not in stripped.split("(")[0]:
            name = m.group(1)
            current = comps.setdefault(name, [])
            if stripped.startswith("ENTRY"):
                entry_name = name
            continue
        if stripped == "}":
            current = None
            continue
        if current is None:
            continue
        im = _INSTR_RE.match(stripped)
        if not im:
            continue
        name, result_txt, opcode, rest = im.groups()
        operands_txt, attrs = _split_operands_attrs(rest)
        current.append(Instr(
            name=name,
            shapes=_parse_shapes(result_txt),
            opcode=opcode,
            operands=_OPERAND_RE.findall(operands_txt),
            attrs=attrs,
            raw=stripped,
        ))
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


def _symbol_table(instrs: list[Instr]) -> dict[str, Instr]:
    return {i.name: i for i in instrs}


def _resolve_through_convert(name: str, sym: dict[str, Instr],
                             depth: int = 0) -> Instr | None:
    ins = sym.get(name)
    while (ins is not None and ins.opcode in ("convert", "bitcast", "copy")
           and ins.operands and depth < 8):
        nxt = sym.get(ins.operands[0])
        if nxt is None:
            break
        ins = nxt
        depth += 1
    return ins


def _attr_dims(attrs: str, key: str) -> tuple[int, ...]:
    m = re.search(key + r"=\{([\d,]*)\}", attrs)
    if not m:
        return ()
    return tuple(int(x) for x in m.group(1).split(",") if x)


def _dot_flops(ins: Instr, sym: dict[str, Instr]) -> float:
    lhs = _resolve_through_convert(ins.operands[0], sym) if ins.operands \
        else None
    if lhs is None or not lhs.shapes:
        return 2.0 * ins.out_size          # fallback
    cdims = _attr_dims(ins.attrs, "lhs_contracting_dims")
    k = 1
    for d in cdims:
        if d < len(lhs.shapes[0].dims):
            k *= lhs.shapes[0].dims[d]
    return 2.0 * ins.out_size * max(k, 1)


def _trip_count(cond_instrs: list[Instr]) -> int:
    """JAX scan conditions lower to compare(i, L) with L a constant."""
    consts: dict[str, int] = {}
    for ins in cond_instrs:
        if ins.opcode == "constant":
            m = re.search(r"constant\((\d+)\)", ins.raw)
            if m:
                consts[ins.name] = int(m.group(1))
    best = 1
    for ins in cond_instrs:
        if ins.opcode == "compare":
            for op in ins.operands:
                if op in consts:
                    best = max(best, consts[op])
    return best


def _called_comps(ins: Instr) -> list[str]:
    out = []
    for key in ("calls", "body", "condition", "to_apply",
                "true_computation", "false_computation"):
        m = re.search(key + r"=%?([\w.\-]+)", ins.attrs)
        if m:
            out.append(m.group(1))
    return out


def _operand_traffic(ins: Instr, sym: dict[str, Instr]) -> float:
    total = 0.0
    for op in ins.operands:
        r = _resolve_through_convert(op, sym)
        if r is None:
            continue
        if r.opcode == "constant" and r.out_bytes <= 256:
            continue                        # scalars folded into code
        total += r.out_bytes
    return total


_SLICING = {"dynamic-slice", "slice", "gather"}

# ops that only relocate / re-type data. A fusion whose body is made purely
# of these is CPU-legalization or layout plumbing (bf16<->f32 cache
# round-trips, per-layer transpose copies) that a TPU executable does not
# materialize — its traffic is skipped; the *consumers* of the data (dots,
# softmax fusions) still count their operand reads at source dtype.
_MOVEMENT = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "copy", "transpose", "convert", "reshape",
             "dynamic-slice", "dynamic-update-slice", "broadcast", "iota",
             "slice"}


def _is_pure_movement(body: list[Instr]) -> bool:
    return bool(body) and all(bi.opcode in _MOVEMENT for bi in body)


def _fusion_body(ins: Instr, comps: dict) -> list[Instr]:
    m = re.search(r"calls=%?([\w.\-]+)", ins.attrs)
    return comps.get(m.group(1), []) if m else []


def _root_instr(body: list[Instr]) -> Instr | None:
    for bi in body:
        if bi.raw.lstrip().startswith("ROOT"):
            return bi
    return body[-1] if body else None


def _resolve_body(name: str, bsym: dict[str, Instr]) -> Instr | None:
    ins = bsym.get(name)
    hops = 0
    while ins is not None and ins.opcode in ("bitcast", "copy", "convert") \
            and ins.operands and hops < 8:
        nxt = bsym.get(ins.operands[0])
        if nxt is None:
            break
        ins, hops = nxt, hops + 1
    return ins


def _fusion_traffic(ins: Instr, sym: dict[str, Instr], comps: dict) -> float:
    """Total HBM traffic of one fusion call, in-place aware.

    * A parameter consumed only through (dynamic-)slice inside the body
      reads just the slice (per-layer weight gathers from scan-stacked
      buffers), not the whole buffer.
    * A fusion whose ROOT is a dynamic-update-slice (or a tuple of them —
      scan carry/stacking writes) writes only the update slice; the
      aliased destination buffer is neither fully read nor written.
    """
    body = _fusion_body(ins, comps)
    bsym = _symbol_table(body)
    param_names = {}
    for bi in body:
        if bi.opcode == "parameter":
            pm = re.search(r"parameter\((\d+)\)", bi.raw)
            if pm:
                param_names[int(pm.group(1))] = bi.name

    # -- output side: resolve DUS-rooted (in-place) writes ------------------
    aliased_params: set[str] = set()
    out_traffic = 0.0
    root = _root_instr(body)
    root_elems: list[Instr | None] = []
    if root is not None:
        r = _resolve_body(root.name, bsym)
        if r is not None and r.opcode == "tuple":
            root_elems = [_resolve_body(o, bsym) for o in r.operands]
        else:
            root_elems = [r]
    if root_elems:
        for elem in root_elems:
            if elem is not None and elem.opcode == "dynamic-update-slice":
                upd = _resolve_body(elem.operands[1], bsym) \
                    if len(elem.operands) > 1 else None
                out_traffic += upd.out_bytes if upd is not None \
                    else elem.out_bytes
                dst = _resolve_body(elem.operands[0], bsym) \
                    if elem.operands else None
                if dst is not None and dst.opcode == "parameter":
                    aliased_params.add(dst.name)
            elif elem is not None:
                out_traffic += elem.out_bytes
    else:
        out_traffic = ins.out_bytes

    # -- operand side --------------------------------------------------------
    total = out_traffic
    for idx, opnd in enumerate(ins.operands):
        r = _resolve_through_convert(opnd, sym)
        if r is None:
            continue
        if r.opcode == "constant" and r.out_bytes <= 256:
            continue
        pname = param_names.get(idx)
        if pname is not None:
            if pname in aliased_params:
                continue                    # in-place destination
            consumers = [bi for bi in body if pname in bi.operands]
            if consumers and all(c.opcode in _SLICING for c in consumers):
                total += sum(c.out_bytes for c in consumers)
                continue
        total += r.out_bytes
    return total


def _comp_costs(name: str, comps: dict, memo: dict) -> Costs:
    if name in memo:
        return memo[name]
    memo[name] = Costs()                    # cycle guard
    instrs = comps.get(name, [])
    sym = _symbol_table(instrs)
    c = Costs()
    for ins in instrs:
        op = ins.opcode
        if op == "while":
            body, cond = None, None
            m = re.search(r"body=%?([\w.\-]+)", ins.attrs)
            if m:
                body = m.group(1)
            m = re.search(r"condition=%?([\w.\-]+)", ins.attrs)
            if m:
                cond = m.group(1)
            # XLA records the analyzed trip count in backend_config
            m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ins.attrs)
            if m:
                trips = int(m.group(1))
            else:
                trips = _trip_count(comps.get(cond, [])) if cond else 1
            if body:
                c.add(_comp_costs(body, comps, memo), mult=trips)
            continue
        if op in ("call", "conditional", "fusion", "map", "reduce",
                  "reduce-window", "sort", "scatter", "select-and-scatter",
                  "custom-call"):
            for sub in _called_comps(ins):
                if sub in comps:
                    # fused computation flops count once per output element
                    sub_c = _comp_costs(sub, comps, memo)
                    if op == "fusion":
                        # fusion body flops already elementwise-counted via
                        # its instructions; traffic handled at call site
                        c.flops += sub_c.flops
                        for k, v in sub_c.collectives.items():
                            c.collectives[k]["count"] += v["count"]
                            c.collectives[k]["bytes"] += v["bytes"]
                    else:
                        c.add(sub_c)
            if op == "fusion":
                if not _is_pure_movement(_fusion_body(ins, comps)):
                    c.bytes += _fusion_traffic(ins, sym, comps)
            elif op in ("reduce", "sort", "scatter", "reduce-window",
                        "select-and-scatter"):
                c.bytes += _operand_traffic(ins, sym) + ins.out_bytes
                c.flops += ins.out_size
            continue
        if op in _COLLECTIVES or (op.endswith("-start")
                                  and op[:-6] in _COLLECTIVES):
            kind = op[:-6] if op.endswith("-start") else op
            c.collectives[kind]["count"] += 1
            c.collectives[kind]["bytes"] += ins.out_bytes
            c.bytes += ins.out_bytes        # HBM side of the collective
            continue
        if op.endswith("-done"):
            continue
        if op == "dot":
            c.flops += _dot_flops(ins, sym)
            c.bytes += _operand_traffic(ins, sym) + ins.out_bytes
            continue
        if op == "convolution":
            c.flops += 2.0 * ins.out_size   # underestimate; no convs hot
            c.bytes += _operand_traffic(ins, sym) + ins.out_bytes
            continue
        if op == "dynamic-update-slice":
            # in-place: read+write the update slice only
            upd = _resolve_through_convert(ins.operands[1], sym) \
                if len(ins.operands) > 1 else None
            ub = upd.out_bytes if upd is not None else 0
            c.bytes += 2.0 * ub
            continue
        if op == "copy":
            continue                        # layout copy: TPU picks layouts
        if op in ("dynamic-slice", "gather", "slice", "pad", "concatenate",
                  "broadcast", "reverse", "dynamic-reshape"):
            c.bytes += ins.out_bytes * 2.0
            continue
        if op in _SKIP_TRAFFIC:
            continue
        if op in _TRANSCENDENTAL:
            c.flops += 10.0 * ins.out_size
            c.bytes += _operand_traffic(ins, sym) + ins.out_bytes
            continue
        if op in _ELEMENTWISE or True:      # default: elementwise-ish
            c.flops += float(ins.out_size)
            c.bytes += _operand_traffic(ins, sym) + ins.out_bytes
            continue
    memo[name] = c
    return c


def module_costs(hlo_text: str) -> Costs:
    """Trip-count-aware per-device costs for a compiled HLO module."""
    comps = parse_module(hlo_text)
    if "__entry__" not in comps:
        return Costs()
    # find entry computation name (alias shares the list object)
    entry = None
    for name, lst in comps.items():
        if name != "__entry__" and lst is comps["__entry__"]:
            entry = name
            break
    memo: dict[str, Costs] = {}
    return _comp_costs(entry, comps, memo)
