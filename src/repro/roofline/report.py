"""Render the EXPERIMENTS.md roofline table from dry-run JSON records.

  PYTHONPATH=src python -m repro.roofline.report results/dryrun/*.json
"""
from __future__ import annotations

import json
import sys


def load(paths) -> list[dict]:
    recs = []
    for p in paths:
        data = json.load(open(p))
        recs.extend(data if isinstance(data, list) else [data])
    return recs


def fmt_table(recs: list[dict], mesh: str | None = None) -> str:
    rows = []
    head = ("| arch | shape | comp s | mem s | coll s | dominant | "
            "MFU@roof | useful | step bound s | args GB | temp GB |")
    sep = "|" + "---|" * 11
    rows.append(head)
    rows.append(sep)
    for r in recs:
        if mesh and r.get("mesh") != mesh:
            continue
        if r.get("status") == "skip":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"skip | — | — | — | — | — |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"ERROR | — | — | — | — | — |")
            continue
        rows.append(
            "| {arch} | {shape} | {c:.3f} | {m:.3f} | {k:.3f} | {dom} | "
            "{mfu:.3f} | {useful:.2f} | {step:.3f} | {args:.1f} | "
            "{temp:.1f} |".format(
                arch=r["arch"], shape=r["shape"], c=r["compute_s"],
                m=r["memory_s"], k=r["collective_s"], dom=r["dominant"],
                mfu=r["mfu"], useful=r["useful_ratio"], step=r["step_s"],
                args=r["arg_bytes"] / 2**30, temp=r["temp_bytes"] / 2**30))
    return "\n".join(rows)


def main(argv=None):
    paths = argv or sys.argv[1:]
    recs = load(paths)
    meshes = sorted({r.get("mesh") for r in recs})
    for m in meshes:
        print(f"\n### mesh {m}\n")
        print(fmt_table(recs, m))


if __name__ == "__main__":
    main()
