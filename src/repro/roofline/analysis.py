"""Three-term roofline from a compiled dry-run artifact.

    compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory term     = HLO_bytes / HBM_bw               (per chip)
    collective term = wire_bytes / link_bw             (per chip)

cost_analysis() of an SPMD-compiled module is already the *per-device*
program, so no further division by chip count. MODEL_FLOPS = 6*N*D (dense)
or 6*N_active*D (MoE) is computed from the config and compared against the
compiled total (useful-compute ratio: catches remat/redundancy waste).
"""
from __future__ import annotations

import dataclasses
import json

from . import hw
from .hlo_parse import collective_bytes, wire_bytes


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_device: float
    bytes_per_device: float
    collectives: dict
    wire_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_total: float      # 6*N*D (or 6*N_active*D), whole step
    arg_bytes: int = 0
    out_bytes: int = 0
    temp_bytes: int = 0
    alias_bytes: int = 0
    compile_s: float = 0.0
    xla_flops: float = 0.0        # raw cost_analysis (loop bodies once)
    xla_bytes: float = 0.0
    device_arch: str = "v5e"      # hw.ARCHS key the time terms were priced at

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def memory_floor_s(self) -> float:
        """Dtype-correct HBM-streaming lower bound from memory_analysis:
        every argument read once + every non-aliased output written once.
        The cost_analysis `bytes accessed` proxy is CPU-legalized (bf16
        operands get fp32 convert copies that a TPU never materializes), so
        the table reports both (EXPERIMENTS.md §Roofline notes)."""
        traffic = self.arg_bytes + max(self.out_bytes - self.alias_bytes, 0)
        return traffic / hw.get_arch(self.device_arch).hbm_bw

    @property
    def step_s(self) -> float:
        """Roofline step time: max of the three (perfect overlap bound)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / total compiled FLOPs across devices."""
        total = self.flops_per_device * self.n_devices
        return self.model_flops_total / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline bound."""
        denom = (self.step_s * self.n_devices
                 * hw.get_arch(self.device_arch).peak_flops)
        return self.model_flops_total / denom if denom else 0.0

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(dominant=self.dominant, step_s=self.step_s,
                 useful_ratio=self.useful_ratio, mfu=self.mfu,
                 memory_floor_s=self.memory_floor_s)
        return d


def active_params(cfg) -> tuple[float, float]:
    """(N_total, N_active) parameter counts from the config (matrices only
    in the classic 6ND sense — embeddings included, as is standard)."""
    d = cfg.d_model
    per_kind = {}

    def attn_params():
        hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        return d * hd * (hq + 2 * hkv) + hq * hd * d

    def mla_params():
        qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        return (d * cfg.q_lora_rank
                + cfg.q_lora_rank * cfg.n_heads * qk
                + d * (cfg.kv_lora_rank + cfg.qk_rope_dim)
                + cfg.kv_lora_rank * cfg.n_heads * (cfg.qk_nope_dim
                                                    + cfg.v_head_dim)
                + cfg.n_heads * cfg.v_head_dim * d)

    def swiglu_params(f):
        return 3 * d * f

    def moe_params():
        total = cfg.n_experts * 3 * d * cfg.moe_d_ff + d * cfg.n_experts
        active = cfg.moe_top_k * 3 * d * cfg.moe_d_ff + d * cfg.n_experts
        if cfg.n_shared_experts:
            fs = cfg.shared_d_ff or cfg.moe_d_ff * cfg.n_shared_experts
            total += 3 * d * fs
            active += 3 * d * fs
        return total, active

    def mamba_params():
        din, st = cfg.mamba_d_inner, cfg.mamba_state
        return (d * 2 * din + din * (cfg.dt_rank + 2 * st)
                + cfg.dt_rank * din + din * d)

    def rwkv_params():
        return 5 * d * d + d * d + 2 * d * cfg.rwkv_decay_lora \
            + 2 * d * cfg.d_ff + d * d

    total = active = 0.0
    for pattern, repeats in cfg.schedule:
        for kind in pattern:
            if kind in ("attn", "local"):
                t = a = attn_params() + swiglu_params(cfg.d_ff)
            elif kind == "attn_moe":
                mt, ma = moe_params()
                t, a = attn_params() + mt, attn_params() + ma
            elif kind == "mla_dense":
                t = a = mla_params() + swiglu_params(cfg.d_ff)
            elif kind == "mla_moe":
                mt, ma = moe_params()
                t, a = mla_params() + mt, mla_params() + ma
            elif kind == "mamba_dense":
                t = a = mamba_params() + swiglu_params(cfg.d_ff)
            elif kind == "mamba_moe":
                mt, ma = moe_params()
                t, a = mamba_params() + mt, mamba_params() + ma
            elif kind == "rwkv":
                t = a = rwkv_params()
            elif kind == "cross":
                t = a = attn_params() + swiglu_params(cfg.d_ff)
            elif kind in ("enc", "dec"):
                t = a = attn_params() * (2 if kind == "dec" else 1) \
                    + 2 * d * cfg.d_ff
            else:
                raise ValueError(kind)
            total += t * repeats
            active += a * repeats
    if cfg.encoder_layers:
        per = attn_params() + 2 * d * cfg.d_ff
        total += per * cfg.encoder_layers
        active += per * cfg.encoder_layers
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    total += emb
    active += emb
    return total, active


def model_flops(cfg, shape_kind: str, seq_len: int, global_batch: int) -> float:
    """6*N_active*D for a train step; 2*N_active*D for inference forward
    (prefill); 2*N_active*B for one decode token."""
    _, n_active = active_params(cfg)
    if shape_kind == "train":
        return 6.0 * n_active * seq_len * global_batch
    if shape_kind == "prefill":
        return 2.0 * n_active * seq_len * global_batch
    return 2.0 * n_active * global_batch          # decode: one token


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                     n_devices: int, model_flops_total: float,
                     tp_degree: int = 16, compile_s: float = 0.0,
                     device_arch: str | None = None) -> RooflineReport:
    from repro.parallel import compat

    from .hlo_cost import module_costs

    ca = compat.cost_analysis(compiled)
    txt = compiled.as_text()
    # primary: our trip-count-aware, dtype-correct walker (XLA's analysis
    # counts scan bodies once and the CPU backend pads bf16 with fp32
    # converts — see hlo_cost.py)
    mc = module_costs(txt)
    flops = float(mc.flops)
    byts = float(mc.bytes)
    colls = {k: {"count": v["count"], "bytes": v["bytes"]}
             for k, v in mc.collectives.items()}
    colls["_total"] = {
        "count": sum(v["count"] for v in mc.collectives.values()),
        "bytes": sum(v["bytes"] for v in mc.collectives.values())}
    wires = wire_bytes(colls, n_devices_hint=tp_degree)
    mem = compiled.memory_analysis()
    spec = hw.get_arch(device_arch)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, n_devices=n_devices,
        flops_per_device=flops, bytes_per_device=byts,
        collectives=colls,
        wire_bytes_per_device=wires,
        compute_s=flops / spec.peak_flops,
        memory_s=byts / spec.hbm_bw,
        collective_s=wires / spec.ici_bw,
        device_arch=spec.name,
        model_flops_total=model_flops_total,
        xla_flops=float(ca.get("flops", 0.0)),
        xla_bytes=float(ca.get("bytes accessed", 0.0)),
        arg_bytes=int(getattr(mem, "argument_size_in_bytes", 0)),
        out_bytes=int(getattr(mem, "output_size_in_bytes", 0)),
        temp_bytes=int(getattr(mem, "temp_size_in_bytes", 0)),
        alias_bytes=int(getattr(mem, "alias_size_in_bytes", 0)),
        compile_s=compile_s,
    )
