from .analysis import RooflineReport, analyze_compiled, hw
from .hlo_parse import collective_bytes

__all__ = ["RooflineReport", "analyze_compiled", "collective_bytes", "hw"]
