"""Hardware arch table for roofline analysis (brief: ROOFLINE ANALYSIS).

The seed shipped TPU v5e constants hardcoded at module level, which made
every roofline prediction (and now the tune/ autotuner's block-grid
pruning) silently wrong on any other target. The constants live in an
arch table instead: ``get_arch("v5p")`` / ``set_arch("a100")`` /
``REPRO_ARCH=a100`` select the spec, and the legacy module-level names
(``PEAK_FLOPS_BF16`` etc.) remain as the **v5e defaults** for call sites
that predate the table.

``cpu-est`` is a deliberately rough order-of-magnitude stand-in for the
CI container (AVX-class core, DDR bandwidth): good enough to classify a
kernel as compute- vs memory-bound, not a performance model.
"""
from __future__ import annotations

import dataclasses
import os


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    """Per-device hardware envelope used by the roofline terms."""

    name: str
    peak_flops: float        # dense-matmul peak, FLOP/s (bf16 on TPUs)
    hbm_bw: float            # bytes/s main-memory bandwidth
    ici_bw: float            # bytes/s per interconnect link
    hbm_bytes: int           # device memory capacity
    vmem_bytes: int          # fast on-chip memory a kernel can tile into
    int8_flops: float = 0.0  # int8 matmul peak (0 = no native int8 path)

    @property
    def ridge_intensity(self) -> float:
        """FLOP/byte at which compute and memory terms balance."""
        return self.peak_flops / self.hbm_bw


ARCHS: dict[str, ArchSpec] = {
    # TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM, 16 GiB, ~128 MB/chip VMEM
    # budget is per-core ~16 MB usable for kernel tiles
    "v5e": ArchSpec(name="v5e", peak_flops=197e12, hbm_bw=819e9,
                    ici_bw=50e9, hbm_bytes=16 * 1024**3,
                    vmem_bytes=16 * 1024**2, int8_flops=394e12),
    # TPU v5p: 459 TFLOP/s bf16, 2765 GB/s HBM, 95 GiB
    "v5p": ArchSpec(name="v5p", peak_flops=459e12, hbm_bw=2765e9,
                    ici_bw=100e9, hbm_bytes=95 * 1024**3,
                    vmem_bytes=16 * 1024**2, int8_flops=918e12),
    # A100-80GB: 312 TFLOP/s bf16 tensor core, 2039 GB/s, NVLink 300 GB/s;
    # "vmem" maps to the combined L2 slice a persistent tile can hold
    "a100": ArchSpec(name="a100", peak_flops=312e12, hbm_bw=2039e9,
                     ici_bw=300e9, hbm_bytes=80 * 1024**3,
                     vmem_bytes=40 * 1024**2, int8_flops=624e12),
    # CI-container estimate: one AVX-512 core ~100 GFLOP/s, DDR ~20 GB/s.
    # Order-of-magnitude only — used so interpret-mode tuning runs still
    # prune with a finite ridge instead of v5e's.
    "cpu-est": ArchSpec(name="cpu-est", peak_flops=100e9, hbm_bw=20e9,
                        ici_bw=10e9, hbm_bytes=16 * 1024**3,
                        vmem_bytes=32 * 1024**2, int8_flops=200e9),
}

_DEFAULT_ARCH = "v5e"
_ACTIVE: str | None = None


def arch_names() -> tuple[str, ...]:
    return tuple(ARCHS)


def get_arch(name: str | None = None) -> ArchSpec:
    """Resolve an arch spec: explicit ``name`` > ``set_arch`` >
    ``REPRO_ARCH`` env > the v5e default (the seed behavior)."""
    if name is None:
        name = _ACTIVE or os.environ.get("REPRO_ARCH", _DEFAULT_ARCH)
    try:
        return ARCHS[name]
    except KeyError:
        raise ValueError(f"unknown arch {name!r}; known: {arch_names()}"
                         ) from None


def set_arch(name: str) -> ArchSpec:
    """Select the process-wide arch (``--arch`` on the CLIs routes here).
    Returns the spec so call sites can chain."""
    global _ACTIVE
    spec = get_arch(name)          # validate before committing
    _ACTIVE = spec.name
    return spec


def current() -> ArchSpec:
    """The active arch spec (see :func:`get_arch` resolution order)."""
    return get_arch()


# ---------------------------------------------------------------------------
# legacy module-level constants — the seed's v5e numbers. Kept so existing
# call sites keep importing; new code should go through get_arch()/current().
# ---------------------------------------------------------------------------
PEAK_FLOPS_BF16 = ARCHS["v5e"].peak_flops
HBM_BW = ARCHS["v5e"].hbm_bw
ICI_BW = ARCHS["v5e"].ici_bw
HBM_BYTES = ARCHS["v5e"].hbm_bytes
