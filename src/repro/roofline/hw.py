"""TPU v5e hardware constants (brief: ROOFLINE ANALYSIS)."""
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link
HBM_BYTES = 16 * 1024**3       # 16 GiB per chip
