"""Parse collective ops + payload bytes out of (S)HLO module text.

cost_analysis() has no collective-bytes entry, so we scan the compiled
module text for all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instructions and sum their payload sizes (brief:
ROOFLINE ANALYSIS). Works on both ``lowered.as_text()`` (StableHLO) and
``compiled.as_text()`` (post-SPMD HLO); the roofline uses the compiled
text — that is the per-device program with the real collective schedule.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

# result shape(s) then the op name, e.g.
#   %all-reduce.5 = f32[128,256]{1,0} all-reduce(...)
#   ROOT %tup = (f32[8]{0}, f32[4]{0}) all-reduce(...)
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"(pred|[a-z]+\d+[a-z0-9]*)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+(" + "|".join(_COLLECTIVES) + r")(-start|-done)?\(")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """{op_kind: {'count': int, 'bytes': result-payload bytes}, ...} plus a
    '_total' entry. '-done' halves of async pairs are skipped (their
    '-start' carries the payload)."""
    out: dict = defaultdict(lambda: {"count": 0, "bytes": 0})
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shapes_txt, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue
        b = _shape_bytes(shapes_txt)
        out[kind]["count"] += 1
        out[kind]["bytes"] += b
    total = {"count": sum(v["count"] for v in out.values()),
             "bytes": sum(v["bytes"] for v in out.values())}
    result = dict(out)
    result["_total"] = total
    return result


def wire_bytes(stats: dict, n_devices_hint: int = 16) -> float:
    """Approximate bytes a single device actually moves over links.

    Ring algorithms: all-gather / reduce-scatter move (n-1)/n of the result
    ~= 1x result bytes; all-reduce = reduce-scatter + all-gather ~= 2x its
    payload; all-to-all moves (n-1)/n; collective-permute 1x.
    """
    f = (n_devices_hint - 1) / max(n_devices_hint, 1)
    factors = {
        "all-gather": f,
        "reduce-scatter": f,
        "all-reduce": 2.0 * f,
        "all-to-all": f,
        "ragged-all-to-all": f,
        "collective-permute": 1.0,
    }
    total = 0.0
    for kind, v in stats.items():
        if kind.startswith("_"):
            continue
        total += factors.get(kind, 1.0) * v["bytes"]
    return total
