"""Core math of the paper: orthogonal-basis backends (DCT/DST/Hadamard/
random-orthogonal), dynamic column selection, Newton-Schulz, quantized
error feedback, and the pluggable projector family."""
from .dct import dct2, dct2_matrix, dct3_matrix, makhoul_dct2
from .error_feedback import QuantizedBuffer, dequantize_q8, quantize_q8, zeros_q8
from .newton_schulz import newton_schulz
from .projectors import (
    PROJECTOR_KINDS,
    Projector,
    projector_kinds,
    rotation_matrix,
    shared_basis_for,
)
from .selection import (
    back_project,
    column_norms,
    dynamic_column_selection,
    gather_columns,
    reconstruction_error_sq,
    select_top_r,
)
from .transforms import (
    BasisBackend,
    BasisCache,
    backend_kinds,
    basis_cache,
    dst2_matrix,
    fwht,
    get_backend,
    hadamard_matrix,
    is_backend,
    random_orthogonal_matrix,
    register_backend,
    shared_basis,
)

__all__ = [
    "dct2", "dct2_matrix", "dct3_matrix", "makhoul_dct2",
    "QuantizedBuffer", "dequantize_q8", "quantize_q8", "zeros_q8",
    "newton_schulz",
    "PROJECTOR_KINDS", "Projector", "projector_kinds", "rotation_matrix",
    "shared_basis_for",
    "back_project", "column_norms", "dynamic_column_selection",
    "gather_columns", "reconstruction_error_sq", "select_top_r",
    "BasisBackend", "BasisCache", "backend_kinds", "basis_cache",
    "dst2_matrix", "fwht", "get_backend", "hadamard_matrix", "is_backend",
    "random_orthogonal_matrix", "register_backend", "shared_basis",
]
