"""Newton–Schulz orthogonalization (Muon's NS5 polynomial iteration).

Pushes the singular values of a matrix toward 1, approximating ``U V^T`` from
the SVD. Trion's key trick (paper §2.3) is to run this on the **low-rank**
factor ``b_t ∈ R^{m×r}`` instead of the full momentum ``B_t ∈ R^{m×n}``, so
the Gram matrix is ``r×r``.

Coefficients are Keller Jordan's quintic ``(3.4445, -4.7750, 2.0315)``.
Broadcasts over leading stacked axes; matmuls accumulate in fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NS_COEFFS = (3.4445, -4.7750, 2.0315)


def _ns_step(x: jax.Array, coeffs=NS_COEFFS) -> jax.Array:
    a, b, c = coeffs
    # x: (..., k, m) with k <= m (wide orientation)
    xxt = jnp.einsum("...km,...nm->...kn", x, x, preferred_element_type=jnp.float32)
    bx_cx2 = b * xxt + c * jnp.einsum(
        "...kn,...nj->...kj", xxt, xxt, preferred_element_type=jnp.float32
    )
    return a * x + jnp.einsum("...kn,...nm->...km", bx_cx2, x,
                              preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("steps", "eps"))
def newton_schulz(m: jax.Array, steps: int = 5, eps: float = 1e-7) -> jax.Array:
    """Orthogonalize the last two dims of ``m`` via ``steps`` NS iterations.

    Works in the "wide" orientation (rows <= cols) so the Gram matrix has the
    small dimension — for Trion's (m, r) input with m >= r this means all NS
    matmuls are r-sized. fp32 internally; returns input dtype.
    """
    x = m.astype(jnp.float32)
    rows, cols = x.shape[-2], x.shape[-1]
    transposed = rows > cols
    if transposed:
        x = jnp.swapaxes(x, -1, -2)
    norm = jnp.linalg.norm(x, axis=(-2, -1), keepdims=True)
    x = x / (norm + eps)
    x = jax.lax.fori_loop(0, steps, lambda _, v: _ns_step(v), x) if steps > 3 else x
    if steps <= 3:  # unrolled for tiny step counts (cheaper than a loop)
        for _ in range(steps):
            x = _ns_step(x)
    if transposed:
        x = jnp.swapaxes(x, -1, -2)
    return x.astype(m.dtype)
