"""Discrete Cosine Transform bases and the fast Makhoul FFT transform.

Conventions (paper §2.2 / Appendix A):
  * ``dct3_matrix(n)`` is the paper's ``Q``: ``Q[i, j] = sqrt(2/n) *
    cos(i * (2j + 1) * pi / (2n))`` with the first **row** divided by
    ``sqrt(2)``. Rows are the orthonormal cosine basis vectors;
    ``Q @ Q.T = Q.T @ Q = I``.
  * ``dct2_matrix(n) = dct3_matrix(n).T`` (paper: "the DCT-II matrix is the
    transpose of DCT-III").
  * ``x @ dct2_matrix(n)`` computes the row-wise **orthonormal DCT-II** of
    ``x`` — exactly what Makhoul's N-point FFT algorithm computes in
    ``O(n log n)`` per row (paper Appendix D). This is the similarity matrix
    ``S`` of the dynamic column selection.

Precision note: naive ``cos(i*(2j+1)*pi/(2n))`` in float32 loses ~3 decimal
digits for n ~ 1e4 because the argument grows to ``O(n * pi)``. We reduce the
integer phase ``i*(2j+1) mod 4n`` exactly in int32 first (cos has period
``2*pi`` = phase ``4n``), so every cosine argument is < 2*pi and float32 gives
~1e-7 accurate entries at any supported size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

# (n-1)*(2n-1) must fit int32 for the exact phase reduction.
_MAX_DCT_ORDER = 32_000


@functools.partial(jax.jit, static_argnames=("n", "dtype"))
def dct3_matrix(n: int, dtype=jnp.float32) -> jax.Array:
    """Paper Appendix A DCT-III matrix of order ``n`` (orthonormal rows/cols)."""
    if n > _MAX_DCT_ORDER:
        raise ValueError(f"DCT order {n} exceeds int32-exact phase range")
    i = jax.lax.iota(jnp.int32, n)[:, None]
    j = jax.lax.iota(jnp.int32, n)[None, :]
    phase = (i * (2 * j + 1)) % (4 * n)           # exact in int32
    ang = phase.astype(jnp.float32) * (np.pi / (2.0 * n))
    q = np.sqrt(2.0 / n).astype(np.float32) * jnp.cos(ang)
    q = q.at[0, :].multiply(np.float32(1.0 / np.sqrt(2.0)))
    return q.astype(dtype)


@functools.partial(jax.jit, static_argnames=("n", "dtype"))
def dct2_matrix(n: int, dtype=jnp.float32) -> jax.Array:
    """DCT-II matrix = transpose of DCT-III. ``x @ dct2_matrix(n)`` = DCT-II."""
    return dct3_matrix(n, dtype).T


def dct_basis_np(n: int) -> np.ndarray:
    """Float64 NumPy DCT-III basis — the test oracle."""
    i = np.arange(n, dtype=np.float64)[:, None]
    j = np.arange(n, dtype=np.float64)[None, :]
    q = np.sqrt(2.0 / n) * np.cos(i * (2.0 * j + 1.0) * (np.pi / (2.0 * n)))
    q[0, :] /= np.sqrt(2.0)
    return q


@functools.lru_cache(maxsize=64)
def _makhoul_permutation(n: int) -> np.ndarray:
    """Makhoul input permutation: [a b c d e f] -> [a c e f d b].

    Even original indices in increasing order followed by odd original indices
    in decreasing order (paper Appendix D step 1). Cached per size.
    """
    idx = np.arange(n)
    return np.ascontiguousarray(np.concatenate([idx[0::2], idx[1::2][::-1]]))


@jax.jit
def makhoul_dct2(x: jax.Array) -> jax.Array:
    """Row-wise orthonormal DCT-II via Makhoul's N-point FFT algorithm.

    Numerically equal (to fp32 tolerance) to ``x @ dct2_matrix(n, x.dtype)``.
    Steps (paper Appendix D): permute -> FFT -> twiddle by
    ``W_k = exp(-i*pi*k/(2n))`` -> real part -> orthonormal scaling.
    """
    n = x.shape[-1]
    perm = jnp.asarray(_makhoul_permutation(n))
    v = jnp.take(x.astype(jnp.float32), perm, axis=-1)
    vf = jnp.fft.fft(v, axis=-1)
    k = jnp.arange(n, dtype=jnp.float32)
    w = jnp.exp(-1j * (np.pi / (2.0 * n)) * k.astype(jnp.complex64))
    y = 2.0 * jnp.real(vf * w)                     # factor-2 DCT-II
    # orthonormal scaling: y0 *= sqrt(1/(4n)); yk *= sqrt(1/(2n))
    scale = jnp.full((n,), np.sqrt(1.0 / (2.0 * n)), dtype=jnp.float32)
    scale = scale.at[0].set(np.sqrt(1.0 / (4.0 * n)))
    return (y * scale).astype(x.dtype)


def dct2(x: jax.Array, method: str = "matmul") -> jax.Array:
    """Row-wise orthonormal DCT-II: the similarity transform ``S = G @ Q``.

    ``method='matmul'`` is the TPU/MXU production path (see DESIGN.md §2);
    ``method='fft'`` is Makhoul's algorithm — the host/GPU fast path and the
    large-n oracle.
    """
    if method == "fft":
        return makhoul_dct2(x)
    n = x.shape[-1]
    return x @ dct2_matrix(n, dtype=x.dtype)
