"""Pluggable orthogonal-basis backends and the shared BasisCache.

The paper's key insight is that *any* predefined orthogonal basis computed
once at training start can replace per-layer SVD/QR — DCT is one instance,
chosen for its Makhoul FFT fast path (DESIGN.md §2). Online Subspace
Descent (Liang et al., 2024) shows convergence holds for arbitrary
projection families, so the basis is a first-class pluggable component
here: a :class:`BasisBackend` supplies the ``(n, n)`` orthogonal matrix,
an optional fast transform, and the column-energy ranking statistic that
the dynamic selection (core/selection.py) feeds on.

Built-in backends (``register_backend`` adds more):

  ``dct``       DCT-II — matmul on TPU, Makhoul N-point FFT fast path
                elsewhere. The paper's choice; bit-compatible with the
                historical hardcoded path.
  ``dst``       DST-II — the sine sibling (same exact-int32 phase
                reduction); matmul only.
  ``hadamard``  Walsh–Hadamard (Sylvester order) — entries ±1/sqrt(n), no
                twiddle factors; in-jit FHT butterfly fast path for
                power-of-two n (matmul-free), block-diagonal Sylvester
                decomposition + matmul fallback otherwise.
  ``randortho`` Seeded random orthogonal (QR of a fixed-seed Gaussian,
                sign-canonicalized) — the FRUGAL-style random-projection
                ablation with *shared-basis* index state.

All four keep per-leaf state of only ``r`` int32 indices (the paper's
memory win) and have a row-decomposable energy statistic, so they are all
ZeRO-1 eligible (DESIGN.md §9).

The process-wide :class:`BasisCache` (``shared_basis``) memoizes the
``(kind, n, dtype)`` -> matrix map, so adaptive-controller optimizer
rebuilds (telemetry/adaptive.py) re-use the already-materialized n×n
basis instead of recomputing it; ``basis_cache().hits`` makes the reuse
observable (asserted in tests/test_basis_backends.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .dct import _MAX_DCT_ORDER, dct2_matrix, makhoul_dct2
from .selection import allsum, column_norms


class BasisBackend:
    """One predefined orthogonal basis family.

    Subclasses define ``kind`` and ``matrix``; the default ``apply_fast``
    and ``energy_stat`` fall back to the matmul against ``matrix`` —
    override ``apply_fast`` (and set ``has_fast``) when an O(n log n)
    transform exists.
    """

    kind: str = ""
    #: a per-leaf PRNG key is needed at refresh (none of the built-ins:
    #: even ``randortho`` is a *fixed* seeded basis, cached process-wide)
    needs_key: bool = False
    #: the energy statistic decomposes over row blocks (one (n,)-sized
    #: psum completes it), so rules using this backend are ZeRO-1 eligible
    zero_shardable: bool = True
    #: ``apply_fast`` is genuinely cheaper than the matmul
    has_fast: bool = False

    def matrix(self, n: int, dtype=jnp.float32) -> jax.Array:
        """The ``(n, n)`` orthogonal basis ``Q`` (``x @ Q`` = transform)."""
        raise NotImplementedError

    def apply_fast(self, x: jax.Array, q: jax.Array | None = None) -> jax.Array:
        """Row-wise transform ``x @ Q`` — the host/GPU fast path when one
        exists, else a matmul against ``q`` (or a freshly built matrix)."""
        if q is None:
            q = self.matrix(x.shape[-1], x.dtype)
        return x @ q.astype(x.dtype)

    def energy_stat(self, g: jax.Array, q: jax.Array, *, norm: str = "l2",
                    psum_axes=None) -> jax.Array:
        """Per-column ranking statistic of ``S = G @ Q`` (..., n).

        The §4.1 energy statistic the dynamic selection ranks on. Row
        reductions are completed by a psum over ``psum_axes`` so every
        ZeRO shard derives the same statistic (DESIGN.md §9).
        """
        s = g @ q.astype(jnp.float32)
        return allsum(column_norms(s, norm), psum_axes)


# ---------------------------------------------------------------------------
# DCT-II (the paper's basis) and DST-II
# ---------------------------------------------------------------------------
class DCTBackend(BasisBackend):
    """Orthonormal DCT-II — the paper's basis (core/dct.py conventions)."""

    kind = "dct"
    has_fast = True

    def matrix(self, n: int, dtype=jnp.float32) -> jax.Array:
        return dct2_matrix(n, dtype)

    def apply_fast(self, x: jax.Array, q: jax.Array | None = None) -> jax.Array:
        """Makhoul's N-point FFT algorithm (paper Appendix D)."""
        return makhoul_dct2(x)


@functools.partial(jax.jit, static_argnames=("n", "dtype"))
def dst2_matrix(n: int, dtype=jnp.float32) -> jax.Array:
    """Orthonormal DST-II matrix: ``x @ dst2_matrix(n)`` is the row-wise
    DST-II with the last basis vector scaled by 1/sqrt(2) (the sine
    counterpart of ``dct2_matrix``; ``Q^T Q = I``).

    Same precision trick as the DCT (core/dct.py): the integer phase
    ``(2j+1)(k+1) mod 4n`` is reduced exactly in int32 before the float32
    ``sin``, so entries stay ~1e-7 accurate at any supported order.
    """
    if n > _MAX_DCT_ORDER:
        raise ValueError(f"DST order {n} exceeds int32-exact phase range")
    j = jax.lax.iota(jnp.int32, n)[:, None]
    k = jax.lax.iota(jnp.int32, n)[None, :]
    phase = ((2 * j + 1) * (k + 1)) % (4 * n)      # exact in int32
    ang = phase.astype(jnp.float32) * (np.pi / (2.0 * n))
    q = np.sqrt(2.0 / n).astype(np.float32) * jnp.sin(ang)
    q = q.at[:, n - 1].multiply(np.float32(1.0 / np.sqrt(2.0)))
    return q.astype(dtype)


class DSTBackend(BasisBackend):
    """Orthonormal DST-II. No fast path wired (a Makhoul-style FFT route
    exists but the matmul is the TPU path anyway — DESIGN.md §2)."""

    kind = "dst"

    def matrix(self, n: int, dtype=jnp.float32) -> jax.Array:
        return dst2_matrix(n, dtype)


# ---------------------------------------------------------------------------
# Walsh–Hadamard
# ---------------------------------------------------------------------------
def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def fwht(x: jax.Array) -> jax.Array:
    """In-jit fast Walsh–Hadamard transform along the last axis
    (Sylvester/natural order, *unnormalized*): ``fwht(x) == x @ H_n`` for
    the ±1 Sylvester matrix ``H_n``. Power-of-two length only.

    The butterfly is log2(n) reshape/stack passes — no matmul, no twiddle
    factors; each pass is one add and one subtract over the full row.
    """
    n = x.shape[-1]
    if not _is_pow2(n):
        raise ValueError(f"fwht needs a power-of-two length, got {n}")
    lead = x.shape[:-1]
    h = 1
    while h < n:
        x = x.reshape(*lead, n // (2 * h), 2, h)
        a, b = x[..., 0, :], x[..., 1, :]
        x = jnp.stack([a + b, a - b], axis=-2).reshape(*lead, n)
        h *= 2
    return x


@functools.partial(jax.jit, static_argnames=("n", "dtype"))
def hadamard_matrix(n: int, dtype=jnp.float32) -> jax.Array:
    """Orthonormal Walsh–Hadamard basis of order ``n``.

    Power-of-two ``n``: the Sylvester matrix ``H[i, j] =
    (-1)^popcount(i & j) / sqrt(n)`` (symmetric, orthogonal, entries
    ±1/sqrt(n)). Other ``n``: Hadamard matrices don't exist at every
    order, so the basis is the orthogonal block-diagonal of Sylvester
    blocks following the binary decomposition of ``n`` (e.g. 40 = 32 + 8,
    17 = 16 + 1) — still orthonormal, still matmul-free to *construct*,
    applied by matmul (``apply_fast`` falls back).
    """
    if _is_pow2(n):
        i = jax.lax.iota(jnp.int32, n)[:, None]
        j = jax.lax.iota(jnp.int32, n)[None, :]
        par = jax.lax.population_count(i & j) & 1
        sign = 1.0 - 2.0 * par.astype(jnp.float32)
        return (sign * np.float32(1.0 / np.sqrt(n))).astype(dtype)
    q = jnp.zeros((n, n), jnp.float32)
    off = 0
    for bit in reversed(range(n.bit_length())):        # big blocks first
        blk = 1 << bit
        if n & blk:
            q = jax.lax.dynamic_update_slice(
                q, hadamard_matrix(blk, jnp.float32), (off, off))
            off += blk
    return q.astype(dtype)


class HadamardBackend(BasisBackend):
    """Walsh–Hadamard basis: ±1/sqrt(n) entries, no transcendentals, and a
    matmul-free in-jit FHT butterfly for power-of-two n. When Hadamard
    beats DCT (and when it doesn't): docs/transforms.md."""

    kind = "hadamard"
    has_fast = True

    def matrix(self, n: int, dtype=jnp.float32) -> jax.Array:
        return hadamard_matrix(n, dtype)

    def apply_fast(self, x: jax.Array, q: jax.Array | None = None) -> jax.Array:
        n = x.shape[-1]
        if not _is_pow2(n):                            # odd-n matmul fallback
            return super().apply_fast(x, q)
        y = fwht(x.astype(jnp.float32)) * np.float32(1.0 / np.sqrt(n))
        return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Seeded random orthogonal
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("n", "dtype", "seed"))
def random_orthogonal_matrix(n: int, dtype=jnp.float32,
                             seed: int = 0) -> jax.Array:
    """Deterministic random orthogonal basis: QR of a fixed-seed Gaussian,
    sign-canonicalized (diag(R) >= 0) so the factorization — and therefore
    every run and every rebuild — picks the same representative."""
    g = jax.random.normal(jax.random.PRNGKey(seed), (n, n), jnp.float32)
    q, r = jnp.linalg.qr(g)
    d = jnp.diagonal(r)
    q = q * jnp.where(d < 0, -1.0, 1.0)[None, :]
    return q.astype(dtype)


class RandOrthoBackend(BasisBackend):
    """Seeded random-orthogonal basis (cached QR). Unlike the dense
    ``random`` projector kind — which redraws a per-leaf ``(n, r)`` basis
    from the step key at every refresh — this is one *shared* ``(n, n)``
    orthogonal matrix with index-set selection, i.e. the fair
    predefined-basis ablation against DCT/DST/Hadamard."""

    kind = "randortho"

    def __init__(self, seed: int = 0):
        self.seed = seed

    def matrix(self, n: int, dtype=jnp.float32) -> jax.Array:
        return random_orthogonal_matrix(n, dtype, seed=self.seed)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, BasisBackend] = {}


def register_backend(backend: BasisBackend, *, overwrite: bool = False) -> None:
    """Add a backend to the registry (``Projector``/presets dispatch on
    ``backend.kind``). Refuses silent replacement unless ``overwrite``."""
    if not backend.kind:
        raise ValueError("backend needs a non-empty .kind")
    if backend.kind in _REGISTRY and not overwrite:
        raise ValueError(f"basis backend {backend.kind!r} already "
                         f"registered; pass overwrite=True to replace")
    _REGISTRY[backend.kind] = backend


def get_backend(kind: str) -> BasisBackend:
    try:
        return _REGISTRY[kind]
    except KeyError:
        raise ValueError(f"unknown basis backend {kind!r}; registered: "
                         f"{backend_kinds()}") from None


def backend_kinds() -> tuple[str, ...]:
    """Registered predefined-basis kinds (registration order)."""
    return tuple(_REGISTRY)


def is_backend(kind) -> bool:
    return kind in _REGISTRY


register_backend(DCTBackend())
register_backend(DSTBackend())
register_backend(HadamardBackend())
register_backend(RandOrthoBackend())


# ---------------------------------------------------------------------------
# the shared basis cache
# ---------------------------------------------------------------------------
class BasisCache:
    """Process-wide ``(kind, n, dtype) -> (n, n) basis`` memo.

    One basis per distinct order serves the whole model (the paper's
    memory win) *and the whole process lifetime*: ``as_optimizer``'s
    stored-basis collection and ``shared_basis_for`` both route through
    here, so an adaptive-controller rebuild (telemetry/adaptive.py —
    ``optimizer.init`` on every adopted decision) hits the cache instead
    of recomputing n×n matrices. ``hits``/``misses`` make that
    observable.

    Tracer-safe: a matrix built inside an outer jit trace is returned but
    never stored (storing it would leak the tracer out of its trace).
    Donation-safe: entries are kept as *host* arrays and every ``get``
    materializes a fresh device buffer — the basis lands in optimizer
    state that train steps donate, so handing out one shared device array
    would leave the cache holding a deleted buffer after the first step.
    """

    def __init__(self):
        self._store: dict[tuple[str, int, str], np.ndarray] = {}
        self.hits = 0
        self.misses = 0

    def get(self, kind: str, n: int, dtype=jnp.float32) -> jax.Array:
        key = (kind, int(n), jnp.dtype(dtype).name)
        hit = self._store.get(key)
        if hit is not None:
            self.hits += 1
            return jnp.asarray(hit)
        q = get_backend(kind).matrix(int(n), dtype)
        self.misses += 1
        if not isinstance(q, jax.core.Tracer):
            self._store[key] = np.asarray(q)
        return q

    def __len__(self) -> int:
        return len(self._store)

    def stats(self) -> dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self._store)}

    def clear(self) -> None:
        self._store.clear()
        self.hits = 0
        self.misses = 0


_CACHE = BasisCache()

# The second process-wide kernel-configuration cache lives alongside the
# basis memo: tuned block sizes per (kernel, shape, rank, dtype, platform),
# consulted by every Pallas entry point on ``block=None`` (DESIGN.md §15).
# Re-exported here so "the caches" have one import home.
from repro.tune.cache import TuningCache, tuning_cache  # noqa: E402,F401


def basis_cache() -> BasisCache:
    """The process-wide cache instance (counters asserted in tests)."""
    return _CACHE


def shared_basis(kind: str, n: int, dtype=jnp.float32) -> jax.Array:
    """The model-wide shared basis for ``kind``, via the process cache."""
    return _CACHE.get(kind, n, dtype)


# ---------------------------------------------------------------------------
# basis-store keys (optimizer-state ``bases`` dict)
# ---------------------------------------------------------------------------
def normalize_basis_request(item) -> tuple[str, int]:
    """``basis_sizes`` entries are ``(kind, n)`` pairs; bare ints are the
    legacy spelling for the DCT basis."""
    if isinstance(item, tuple):
        kind, n = item
        return kind, int(n)
    return "dct", int(item)


def basis_store_key(kind: str, n: int) -> str:
    """Key of a basis in the optimizer-state ``bases`` dict. DCT keeps the
    historical bare ``str(n)`` (checkpoint/state-tree compatibility);
    other kinds are namespaced ``"kind:n"``."""
    return str(n) if kind == "dct" else f"{kind}:{n}"
