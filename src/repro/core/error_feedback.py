"""Quantized error-feedback buffers (paper §2.4; MicroAdam-style).

The EF buffer stores the low-rank projection residual ``Xi = G - g Q_r^T`` and
is re-added to the next gradient. DCT-AdamW supports storing it in 8-bit with
a per-row fp32 scale ("the lowest resolution we can quantize EF to is 8 bits
without degrading the optimizer performance", §2.4).

Symmetric linear quantization: ``q = round(x / s)``, ``s = max|row| / 127``.
Broadcasts over leading stacked axes (rows = axis -2's companion: we scale per
last-axis row vector, i.e. per (..., m) row of an (..., m, n) matrix).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class QuantizedBuffer(NamedTuple):
    """int8 payload + per-row scale; together a lossy fp tensor."""

    q: jax.Array          # (..., m, n) int8
    scale: jax.Array      # (..., m, 1) fp32


def quantize_q8(x: jax.Array) -> QuantizedBuffer:
    from repro.kernels.lowp import q8_scale  # lockstep scale guard

    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    # max(amax/127, tiny): a subnormal row would underflow amax/127 to 0.0
    # and x / 0 poisons the payload with NaNs (kernels/lowp.py)
    scale = q8_scale(amax)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return QuantizedBuffer(q=q, scale=scale)


def dequantize_q8(buf: QuantizedBuffer, dtype=jnp.float32) -> jax.Array:
    return (buf.q.astype(jnp.float32) * buf.scale).astype(dtype)


def zeros_q8(shape, batch_shape=()) -> QuantizedBuffer:
    full = tuple(batch_shape) + tuple(shape)
    return QuantizedBuffer(
        q=jnp.zeros(full, dtype=jnp.int8),
        scale=jnp.ones(full[:-1] + (1,), dtype=jnp.float32),
    )
