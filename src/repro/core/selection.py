"""Dynamic column selection (paper §2.1, Appendix B).

Given the similarity matrix ``S = G @ Q`` (scalar products of rows of ``G``
with columns of the fixed orthogonal basis ``Q``), rank the columns of ``S``
by their l1/l2 norm and return the indices of the top-``r``. Selecting the
top-r column alignments is the *optimal* column subset of ``Q`` for Frobenius
reconstruction error (paper §4.1) and yields a contractive compressor:
``||G - Q_r Q_r^T G||_F^2 <= (1 - r/n) ||G||_F^2``.

Everything here is basis-agnostic: ``Q`` may be any orthogonal matrix
(the §4.1 optimality and the contraction bound only use orthogonality),
which is what lets the transform registry (core/transforms.py) swap
DCT for DST / Walsh–Hadamard / random-orthogonal without touching the
selection machinery.

All functions broadcast over arbitrary leading (stacked-layer / expert) axes:
the matrix lives in the last two dims.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def allsum(x: jax.Array, axes) -> jax.Array:
    """Cross-shard sum of a row-block-local reduction (ZeRO-1, DESIGN.md
    §9); identity when ``axes`` is falsy so the replicated graph is
    untouched. The single definition every psum-aware call site shares —
    the sharded/replicated parity guarantee rests on them agreeing."""
    if not axes:
        return x
    return jax.lax.psum(x, axes)


def allgather_rows(x: jax.Array, axes) -> jax.Array:
    """Concatenate the row blocks (dim -2) of ``x`` across the ZeRO shards.

    Identity when ``axes`` is falsy (replicated path untouched). Inside a
    shard_map whose row dim is split over ``axes`` (in the mesh-axis order
    of the PartitionSpec), the tiled all-gather reassembles the *global*
    row order — the exact inverse of the sharding split, so downstream
    whole-matrix math (Newton-Schulz, QR) sees bitwise the same operand as
    the replicated step. Complement of :func:`local_row_block`.
    """
    if not axes:
        return x
    out = x
    # gather the innermost sharding axis first so the outermost axis ends
    # up outermost in the reassembled row order, matching P((axes,)) layout
    for a in reversed(axes):
        out = jax.lax.all_gather(out, a, axis=out.ndim - 2, tiled=True)
    return out


def shard_index(axes) -> jax.Array:
    """This device's linear position along ``axes`` (row-major, matching
    the ``P(axes)`` block layout and :func:`allgather_rows` order)."""
    idx = jnp.zeros((), jnp.int32)
    for a in axes:
        idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
    return idx


def local_row_block(x: jax.Array, axes, block: int) -> jax.Array:
    """Slice this shard's ``block`` rows (dim -2) back out of a full-row
    array — the inverse of :func:`allgather_rows`. Identity when ``axes``
    is falsy. Because row-blocked elementwise/matmul consumers only read
    their own rows, gather -> whole-matrix compute -> ``local_row_block``
    keeps the sharded step bit-identical to replicated."""
    if not axes:
        return x
    start = shard_index(axes) * block
    return jax.lax.dynamic_slice_in_dim(x, start, block, axis=x.ndim - 2)


def column_norms(s: jax.Array, ord: str = "l2") -> jax.Array:
    """Per-column ranking statistic of ``S`` over the row axis (-2).

    ``l2`` returns *squared* l2 norms (monotone-equivalent for ranking, one
    multiply cheaper, and exactly the quantity in the §4.1 optimality proof).
    Accumulates in fp32 regardless of input dtype.
    """
    sf = s.astype(jnp.float32)
    if ord == "l2":
        return jnp.sum(sf * sf, axis=-2)
    if ord == "l1":
        return jnp.sum(jnp.abs(sf), axis=-2)
    raise ValueError(f"unknown norm {ord!r}")


@functools.partial(jax.jit, static_argnames=("r", "sort"))
def select_top_r(norms: jax.Array, r: int, sort: bool = True) -> jax.Array:
    """Indices of the ``r`` largest entries of ``norms`` (last axis).

    ``sort=True`` returns indices in ascending index order — a canonical form
    that makes the subspace-rotation bookkeeping deterministic and makes the
    back-projection gather's access pattern monotone (TPU-friendly).
    """
    _, idx = jax.lax.top_k(norms, r)
    if sort:
        idx = jnp.sort(idx, axis=-1)
    return idx.astype(jnp.int32)


def dynamic_column_selection(
    s: jax.Array, r: int, ord: str = "l2", sort: bool = True
) -> tuple[jax.Array, jax.Array]:
    """Full two-step procedure: rank columns of ``S``, return ``(idx, b)``.

    ``idx``: (..., r) int32 column indices into ``Q``;
    ``b``: (..., m, r) the low-rank factor — extracted from ``S`` directly
    (paper Alg. 1 line 8: no second projection matmul is needed).
    """
    idx = select_top_r(column_norms(s, ord), r, sort=sort)
    b = jnp.take_along_axis(s, idx[..., None, :], axis=-1)
    return idx, b


def gather_columns(q: jax.Array, idx: jax.Array) -> jax.Array:
    """``Q_r = Q[:, idx]`` with broadcasting over leading axes of ``idx``.

    ``q``: (n, n) shared basis; ``idx``: (..., r) per-layer indices.
    Returns (..., n, r). Implemented as a *row* gather of ``Q.T`` (contiguous
    rows on TPU) followed by a transpose of the last two axes.
    """
    return jnp.swapaxes(jnp.take(q.T, idx, axis=0), -1, -2)


def back_project(b: jax.Array, q: jax.Array, idx: jax.Array) -> jax.Array:
    """``B_hat = b @ Q[:, idx].T`` — low-rank factor back to full width.

    ``b``: (..., m, r); ``q``: (n, n); ``idx``: (..., r) -> (..., m, n).
    ``Q[:, idx].T == Q.T[idx, :]`` is a contiguous row gather; the fused TPU
    version that never materializes the gather is kernels/colgather_matmul.
    """
    qr_t = jnp.take(q.T, idx, axis=0)       # (..., r, n)
    return b @ qr_t


def dual_back_project(b1: jax.Array, b2: jax.Array, q: jax.Array,
                      idx: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Two back-projections through the same selected columns, sharing one
    ``Q^T`` row gather (DESIGN.md §3): the projected-Adam step needs both
    the descent direction ``u @ Q_r^T`` and the residual reconstruction
    ``g_low @ Q_r^T`` every step, so the gathered ``(..., r, n)`` factor is
    materialized once instead of twice. TPU analogue:
    kernels/colgather_matmul_dual (one VMEM gather, zero HBM copies).
    """
    qr_t = jnp.take(q.T, idx, axis=0)       # (..., r, n)
    return b1 @ qr_t, b2 @ qr_t


def index_overlap(prev_idx: jax.Array, new_idx: jax.Array) -> jax.Array:
    """Fraction of ``new_idx`` entries also present in ``prev_idx``.

    Both are (..., r) int32 index sets; broadcasts over leading stacked
    axes. O(r^2) integer compares — the same trick as the 0/1 rotation
    matrix (DESIGN.md §1), so it costs nothing next to the matmuls. The
    complement ``1 - overlap`` is the per-refresh subspace drift that the
    adaptive refresh scheduler feeds on (DESIGN.md §8).
    """
    eq = prev_idx[..., :, None] == new_idx[..., None, :]
    return jnp.mean(jnp.any(eq, axis=-2).astype(jnp.float32), axis=-1)


def topr_margin(norms: jax.Array, r: int) -> jax.Array:
    """Relative gap between the r-th and (r+1)-th largest column statistic.

    ``(v_r - v_{r+1}) / v_1`` in [0, 1]: how decisively the top-r cut
    separates the kept columns from the first dropped one. 1.0 when
    ``r >= n`` (nothing is dropped). Operates on the already-computed
    ranking statistic — no extra pass over ``S``.
    """
    n = norms.shape[-1]
    if r >= n:
        return jnp.ones(norms.shape[:-1], jnp.float32)
    v, _ = jax.lax.top_k(norms.astype(jnp.float32), r + 1)
    return (v[..., r - 1] - v[..., r]) / (v[..., 0] + 1e-30)


def reconstruction_error_sq(g: jax.Array, q: jax.Array, idx: jax.Array) -> jax.Array:
    """``||G - Q_r Q_r^T' G||_F^2`` via the §4.1 identity (right projection):

    ``err = ||G||_F^2 - sum_selected ||G q_i||_2^2`` — no reconstruction
    materialized.
    """
    s = g.astype(jnp.float32) @ q.astype(jnp.float32)
    norms = column_norms(s, "l2")
    total = jnp.sum(g.astype(jnp.float32) ** 2, axis=(-2, -1))
    sel = jnp.take_along_axis(norms, idx, axis=-1).sum(axis=-1)
    return total - sel
