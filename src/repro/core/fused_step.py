"""Fused execution layer for the projected-Adam hot path (DESIGN.md §3).

The reference ``ProjectedAdamRule`` path performs, per predefined-basis
(DCT/DST/Hadamard/random-orthogonal) leaf and step:

    S = G @ Q          (refresh: ranking statistic, O(m n^2))
    g_low = G @ Q_r    (projection, O(m n r))       <- duplicated pass over G
    d     = u @ Q_r^T  (back-projection)            <- gathers Q_r^T
    recon = g_low @ Q_r^T                           <- gathers Q_r^T AGAIN
    EF    = dequant(q8) -> full fp32 (m, n) temp    <- materialized in HBM

This module is the fused dispatch that removes every redundancy: the
low-rank factor is extracted from ``S`` directly (paper Alg. 1 line 8 — no
second projection matmul), both back-projections share one ``Q_r^T`` gather,
and the int8 error-feedback buffer is consumed/produced by fused quantize
kernels so the fp32 EF temporary never exists.

Three concrete modes (``resolve`` maps a rule's ``fused`` field to one):

  ``"on"``   — Pallas kernel path (``kernels.ops``): TPU production;
               interpret mode off-TPU, which is how the parity tests run it.
  ``"fft"``  — pure-jnp fused dataflow with the forward transform computed by
               the basis backend's fast path (``BasisBackend.apply_fast``:
               Makhoul's N-point FFT for DCT, the FHT butterfly for
               Hadamard, a matmul for backends without one): the host/GPU
               fast path. ``S`` costs O(m n log n) instead of the
               O(m n^2) matmul; back-projection stays a (shared-gather)
               matmul, which at r << n is cheaper than an inverse
               transform.
  ``"off"``  — the seed jnp reference path, bit-identical to the seed repo.

``"auto"`` resolves to the kernel path on TPU and degrades to the reference
path elsewhere; benchmarks/tests opt into "on"/"fft" explicitly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.dct import makhoul_dct2
from repro.core.error_feedback import QuantizedBuffer, dequantize_q8, quantize_q8
from repro.core.newton_schulz import newton_schulz
from repro.core.selection import (
    allgather_rows,
    allsum,
    back_project,
    column_norms,
    dual_back_project,
    dynamic_column_selection,
    gather_columns,
    local_row_block,
    select_top_r,
)
from repro.kernels import lowp, ops
from repro.kernels.lowp import COMPUTE_DTYPES, LOWP_ERROR_BOUNDS  # noqa: F401

FUSED_MODES = ("auto", "off", "on", "fft")

# process-wide default consulted by rules whose ``fused`` field is "auto";
# itself "auto" = kernels on TPU, reference elsewhere.
_DEFAULT_MODE = "auto"


def set_default_fused_mode(mode: str) -> None:
    """Override the process-wide dispatch default (benchmarks/experiments)."""
    global _DEFAULT_MODE
    assert mode in FUSED_MODES, mode
    _DEFAULT_MODE = mode


def default_fused_mode() -> str:
    return _DEFAULT_MODE


def resolve(mode: str) -> str:
    """Rule-level mode -> concrete mode in {"off", "on", "fft"}."""
    if mode not in FUSED_MODES:
        raise ValueError(f"unknown fused mode {mode!r}; expected one of "
                         f"{FUSED_MODES}")
    if mode == "auto":
        mode = _DEFAULT_MODE
    if mode == "auto":
        return "on" if ops.ON_TPU else "off"
    return mode


# ---------------------------------------------------------------------------
# select + project: ONE pass over G
# ---------------------------------------------------------------------------
def select_and_project(gf: jax.Array, q: jax.Array, r: int, *,
                       norm: str = "l2", mode: str,
                       return_norms: bool = False, psum_axes=None,
                       backend=None, compute_dtype: str = "fp32"):
    """Dynamic column selection + low-rank extraction in one ``G``-sized pass.

    Returns ``(idx (..., r), g_low (..., m, r))``. The kernel path fuses the
    column-norm accumulation into the ``S = G @ Q`` matmul — the kernel is
    parameterized by the basis matrix ``q``, so every predefined-basis
    backend reaches it; the fft path computes ``S`` row-wise by the
    backend's fast transform (``backend.apply_fast``; default: Makhoul
    FFT, the DCT backend's). Either way ``g_low`` is sliced out of ``S``
    (``S[:, idx] == G @ Q[:, idx]`` exactly), so the reference path's
    second projection matmul never runs.

    ``return_norms=True`` appends the *squared-l2* column norms of ``S``
    (..., n) — the §4.1 energy statistic the telemetry layer feeds on. The
    kernel already accumulates them for ranking, so this is free on the
    "on" path and one reduction over the resident ``S`` on the fft path.

    ``psum_axes``: mesh axes the rows of ``gf`` are sharded over (inside a
    ZeRO-1 shard_map). The kernels see only the local row block; the
    column statistic is completed by one ``(n,)``-sized psum, so every
    shard selects the same indices.

    ``compute_dtype`` in {"fp32", "bf16", "int8"} selects the matmul
    precision (DESIGN.md §15): the kernel path passes it to dct_project;
    the off/fft paths run the jnp mirror (``lowp.lowp_matmul``) instead of
    the fast transform — there is no int8 FFT, and the mirror's exact
    int32 accumulation keeps the two dispatch modes in lockstep. The
    documented error bounds vs fp32 are ``LOWP_ERROR_BOUNDS``, gated on a
    real gradient stream in benchmarks/projection_errors.py.
    """
    lowp.check_compute_dtype(compute_dtype)
    if mode == "on":
        s, norms_sq = ops.dct_project_op(gf, q, compute_dtype=compute_dtype)
        norms_sq = allsum(norms_sq, psum_axes)
        rank_norms = (norms_sq if norm == "l2"
                      else allsum(column_norms(s, norm), psum_axes))
        idx = select_top_r(rank_norms, r)
        g_low = jnp.take_along_axis(s, idx[..., None, :], axis=-1)
        return (idx, g_low, norms_sq) if return_norms else (idx, g_low)
    if compute_dtype != "fp32":
        s = lowp.lowp_matmul(gf, q, compute_dtype)
    else:
        s = backend.apply_fast(gf, q) if backend is not None \
            else makhoul_dct2(gf)
    if not return_norms and psum_axes is None:
        return dynamic_column_selection(s, r, ord=norm)
    norms_sq = allsum(column_norms(s, "l2"), psum_axes)
    rank_norms = (norms_sq if norm == "l2"
                  else allsum(column_norms(s, norm), psum_axes))
    idx = select_top_r(rank_norms, r)
    g_low = jnp.take_along_axis(s, idx[..., None, :], axis=-1)
    return (idx, g_low, norms_sq) if return_norms else (idx, g_low)


def project_with_indices(gf: jax.Array, q: jax.Array, idx: jax.Array, *,
                         compute_dtype: str = "fp32") -> jax.Array:
    """Keep-branch projection ``G @ Q[:, idx]`` for non-refresh steps
    (T_u > 1). A gather + skinny matmul — no full-width ``S`` pass."""
    qr = gather_columns(q, idx)
    if compute_dtype != "fp32":
        return lowp.lowp_matmul(gf, qr.astype(jnp.float32), compute_dtype)
    return jnp.einsum("...mn,...nr->...mr", gf, qr.astype(gf.dtype))


# ---------------------------------------------------------------------------
# back-projection: both outputs from ONE Q_r^T gather
# ---------------------------------------------------------------------------
def fused_dual_backproject(u_low: jax.Array, g_low: jax.Array, q: jax.Array,
                           idx: jax.Array, *, mode: str,
                           compute_dtype: str = "fp32"
                           ) -> tuple[jax.Array, jax.Array]:
    """``(u_low @ Q_r^T, g_low @ Q_r^T)`` sharing one ``Q_r^T`` gather."""
    if mode == "on":
        qt = jnp.swapaxes(q, -1, -2)
        return ops.colgather_matmul_dual_op(u_low, g_low, qt, idx,
                                            compute_dtype=compute_dtype)
    if compute_dtype != "fp32":
        d, recon = lowp.lowp_gather_matmul(
            (u_low, g_low), jnp.swapaxes(q, -1, -2), idx, compute_dtype)
        return d.astype(u_low.dtype), recon.astype(g_low.dtype)
    return dual_back_project(u_low, g_low, q, idx)


def fused_backproject(u_low: jax.Array, q: jax.Array, idx: jax.Array, *,
                      mode: str, compute_dtype: str = "fp32") -> jax.Array:
    if mode == "on":
        return ops.colgather_matmul_op(u_low, jnp.swapaxes(q, -1, -2), idx,
                                       compute_dtype=compute_dtype)
    if compute_dtype != "fp32":
        (d,) = lowp.lowp_gather_matmul(
            (u_low,), jnp.swapaxes(q, -1, -2), idx, compute_dtype)
        return d.astype(u_low.dtype)
    return back_project(u_low, q, idx)


# ---------------------------------------------------------------------------
# Newton-Schulz on the low-rank factor (muon/trion subspace orthogonalization)
# ---------------------------------------------------------------------------

# The Pallas NS kernel keeps an (r, r) Gram scratch and the (r, r)
# polynomial block resident in VMEM, with r = min of the factor's trailing
# dims — its documented envelope is r <= 512 (1 MB fp32 each). Rank-sized
# factors always fit; full-space moments at production shapes (e.g.
# 4096x4096 -> 64 MB) do not and would fail to compile on TPU, so past
# this threshold dispatch degrades to the jnp iteration, whose full-size
# matmuls XLA tiles fine.
NS_PALLAS_MAX_RANK = 512


def fused_newton_schulz(b: jax.Array, *, steps: int, mode: str,
                        gather_axes=None) -> jax.Array:
    """Orthogonalize ``b`` via Newton-Schulz — Pallas kernel on the "on"
    path, the seed jnp iteration otherwise (DESIGN.md §14).

    ``b`` is the wide-or-tall factor the caller wants orthogonalized: the
    (..., m, r) low-rank momentum factor on the subspace path (the kernel
    runs r-sized Gram matrices — the paper's rank-sized NS claim), or the
    full (..., m, n) moment for full-space muon. The kernel handles
    factors whose short side fits its VMEM envelope
    (``NS_PALLAS_MAX_RANK``); larger full-space moments fall back to the
    jnp iteration even when ``mode == "on"``.

    ``gather_axes``: mesh axes the rows (dim -2) are sharded over inside a
    ZeRO-1 shard_map. NS mixes *rows* through the Gram matrix, so unlike
    the column statistic it cannot be completed by a psum — a psum of
    per-shard partial Grams would round differently than the replicated
    single-pass matmul and break the bit-exact sharded/replicated
    contract. Instead the factor is all-gathered, every shard runs the
    identical whole-matrix iteration, and each keeps only its own rows
    (row-blocked consumers make the slice exact). The gathered factor is
    (m, r) — r-sized, so the ZeRO communication term stays rank-sized
    too.
    """
    block = b.shape[-2]
    bf = allgather_rows(b, gather_axes)
    if mode == "on" and min(bf.shape[-2:]) <= NS_PALLAS_MAX_RANK:
        o = ops.newton_schulz_op(bf, steps=steps)
    else:
        o = newton_schulz(bf, steps=steps)
    return local_row_block(o, gather_axes, block)


# ---------------------------------------------------------------------------
# int8 error feedback: no fp32 (m, n) temporary
# ---------------------------------------------------------------------------
def ef_add(gf: jax.Array, ef, *, mode: str) -> jax.Array:
    """``G + EF`` — fused dequant-add on the kernel path, so the dequantized
    fp32 buffer never hits HBM."""
    if isinstance(ef, QuantizedBuffer):
        if mode == "on":
            return ops.dequant_add_ef_op(gf, ef.q, ef.scale)
        return gf + dequantize_q8(ef)
    return gf + ef


def ef_store(resid: jax.Array, ef_dtype: str, *, mode: str):
    """Residual -> EF buffer (int8 payload written in one pass)."""
    if ef_dtype == "q8":
        if mode == "on":
            qv, scale = ops.quantize_ef_op(resid)
            return QuantizedBuffer(q=qv, scale=scale)
        return quantize_q8(resid)
    return resid
