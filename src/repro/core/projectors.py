"""Pluggable low-rank projectors.

The paper's claim is that the DCT dynamic-column-selection projector is a
drop-in replacement for SVD/QR/power-iteration projectors inside *any*
low-rank optimizer (GaLore / FRUGAL / FIRA / LDAdamW). This module is that
plug point: every projector maps a gradient matrix ``G (..., m, n)`` (already
oriented so the *projected* dimension is the last one, ``n <= m``) to a rank-r
right basis, and exposes project / backproject.

State layout per kind (broadcast over leading stacked-layer axes):
  dct      -> int32 indices (..., r) into the shared DCT basis (paper: "only
              r integers per layer")
  svd      -> Q (..., n, r) top right-singular-vector basis
  power    -> Q (..., n, r) block-power-iteration basis (QR-orthonormalized)
  random   -> Q (..., n, r) random semi-orthogonal (FRUGAL baseline)
  randperm -> int32 indices (..., r) random column subset (FRUGAL baseline)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .dct import dct2_matrix
from .selection import (
    allsum,
    back_project,
    column_norms,
    gather_columns,
    select_top_r,
)

PROJECTOR_KINDS = ("dct", "svd", "power", "random", "randperm")


@dataclasses.dataclass(frozen=True)
class Projector:
    """Rank-r right-projector family. ``shared_q`` holds the DCT basis when
    kind == 'dct' (one per device for the whole model — the paper's memory
    win); other kinds keep a per-matrix basis in their state."""

    kind: str
    r: int
    norm: str = "l2"  # ranking norm for dct

    def init(self, shape: tuple[int, ...], key: jax.Array | None = None) -> Any:
        """Initial state for a (stacked) matrix of ``shape`` (..., m, n)."""
        *batch, m, n = shape
        r = min(self.r, n)
        if self.kind in ("dct", "randperm"):
            idx = jnp.broadcast_to(jnp.arange(r, dtype=jnp.int32), (*batch, r))
            return idx
        if self.kind in ("svd", "power", "random"):
            eye = jnp.eye(n, r, dtype=jnp.float32)
            return jnp.broadcast_to(eye, (*batch, n, r))
        raise ValueError(f"unknown projector kind {self.kind!r}")

    # -- basis refresh ------------------------------------------------------
    def update(self, g: jax.Array, state: Any, shared_q: jax.Array | None = None,
               key: jax.Array | None = None, psum_axes=None) -> Any:
        """Recompute the basis from the current gradient/momentum ``g``.

        ``psum_axes``: mesh axes the rows of ``g`` are sharded over (ZeRO-1
        shard_map, DESIGN.md §9). Row reductions — the dct column energies,
        the power iteration's ``G^T (G Q)`` contraction — are completed by
        a psum so every shard derives the same basis. ``svd`` is not
        row-decomposable and rejects sharded input; key-based kinds
        (random/randperm) draw from the replicated per-leaf key and need no
        communication.
        """
        n = g.shape[-1]
        r = min(self.r, n)
        gf = g.astype(jnp.float32)
        if self.kind == "dct":
            s = gf @ shared_q.astype(jnp.float32)
            return select_top_r(allsum(column_norms(s, self.norm), psum_axes),
                                r)
        if self.kind == "svd":
            if psum_axes:
                raise ValueError("svd projector refresh needs the full "
                                 "gradient; it cannot run on ZeRO row "
                                 "shards (rule.zero_shardable gates this)")
            _, _, vt = jnp.linalg.svd(gf, full_matrices=False)
            return jnp.swapaxes(vt[..., :r, :], -1, -2)
        if self.kind == "power":
            # one block power iteration warm-started from the previous basis
            z = jnp.einsum("...mn,...nr->...mr", gf, state)
            y = allsum(jnp.einsum("...mn,...mr->...nr", gf, z), psum_axes)
            q, _ = jnp.linalg.qr(y)
            return q
        if self.kind == "random":
            gauss = jax.random.normal(key, (*g.shape[:-2], n, r), dtype=jnp.float32)
            q, _ = jnp.linalg.qr(gauss)
            return q
        if self.kind == "randperm":
            perm = jax.random.permutation(key, n)[:r]
            return jnp.broadcast_to(jnp.sort(perm).astype(jnp.int32),
                                    (*g.shape[:-2], r))
        raise ValueError(self.kind)

    # -- application --------------------------------------------------------
    def project(self, g: jax.Array, state: Any,
                shared_q: jax.Array | None = None) -> jax.Array:
        """``g_low = G @ Q_r`` -> (..., m, r)."""
        if self.kind == "randperm":
            # Q = I: projection is a pure column take (no matmul)
            return jnp.take_along_axis(g, state[..., None, :], axis=-1)
        if self.kind == "dct":
            qr = gather_columns(shared_q, state)          # (..., n, r)
            return jnp.einsum("...mn,...nr->...mr", g, qr.astype(g.dtype))
        return jnp.einsum("...mn,...nr->...mr", g, state.astype(g.dtype))

    def backproject(self, low: jax.Array, state: Any,
                    shared_q: jax.Array | None = None, n: int | None = None
                    ) -> jax.Array:
        """``G_hat = g_low @ Q_r^T`` -> (..., m, n)."""
        if self.kind == "randperm":
            if n is None:
                if shared_q is None:
                    raise ValueError(
                        "randperm backproject needs the full dimension `n` "
                        "(or a shared_q to infer it from)")
                n = int(shared_q.shape[-1])
            out = jnp.zeros((*low.shape[:-1], n), low.dtype)
            idx = jnp.broadcast_to(state[..., None, :], low.shape[:-1] + state.shape[-1:])
            return jnp.put_along_axis(out, idx, low, axis=-1, inplace=False)
        if self.kind == "dct":
            return back_project(low, shared_q.astype(low.dtype), state)
        return jnp.einsum("...mr,...nr->...mn", low, state.astype(low.dtype))

    def basis_matrix(self, state: Any, n: int,
                     shared_q: jax.Array | None = None) -> jax.Array:
        """Materialize Q_r (..., n, r) — for tests / rotation matmul flag."""
        if self.kind == "randperm":
            return jnp.swapaxes(jnp.eye(n, dtype=jnp.float32)[state], -1, -2)
        if self.kind == "dct":
            return gather_columns(shared_q, state)
        return state

    @property
    def needs_shared_basis(self) -> bool:
        return self.kind == "dct"

    @property
    def needs_key(self) -> bool:
        return self.kind in ("random", "randperm")


def shared_basis_for(kind: str, n: int, dtype=jnp.float32) -> jax.Array | None:
    """The model-wide shared basis: the DCT matrix for 'dct' (one per device
    for the entire model — the paper's memory win), None otherwise."""
    if kind == "dct":
        return dct2_matrix(n, dtype)
    return None


def rotation_matrix(prev_state: Any, crt_state: Any, projector: Projector,
                    n: int, shared_q: jax.Array | None = None,
                    exact_matmul: bool = False) -> jax.Array:
    """Subspace rotation ``R = Q_prev^T Q_crt`` (paper Alg. 3 line 8).

    For index-based projectors (dct/randperm) the columns come from one
    orthogonal matrix, so ``R[a, b] = 1 iff prev_idx[a] == crt_idx[b]`` — a
    0/1 partial permutation. We build it by index comparison in O(r^2) int
    ops instead of the O(n r^2) matmul (exact algebraic equivalence; see
    DESIGN.md §1). ``exact_matmul=True`` restores the paper-literal matmul.
    """
    if projector.kind in ("dct", "randperm") and not exact_matmul:
        return (prev_state[..., :, None] == crt_state[..., None, :]).astype(jnp.float32)
    qp = projector.basis_matrix(prev_state, n, shared_q)
    qc = projector.basis_matrix(crt_state, n, shared_q)
    return jnp.einsum("...nr,...ns->...rs", qp.astype(jnp.float32),
                      qc.astype(jnp.float32))
