"""Pluggable low-rank projectors.

The paper's claim is that the DCT dynamic-column-selection projector is a
drop-in replacement for SVD/QR/power-iteration projectors inside *any*
low-rank optimizer (GaLore / FRUGAL / FIRA / LDAdamW). This module is that
plug point: every projector maps a gradient matrix ``G (..., m, n)`` (already
oriented so the *projected* dimension is the last one, ``n <= m``) to a rank-r
right basis, and exposes project / backproject.

Two families:

* **Predefined-basis kinds** — any :class:`~repro.core.transforms.BasisBackend`
  registered in the transform registry (``dct`` / ``dst`` / ``hadamard`` /
  ``randortho``): state is int32 indices ``(..., r)`` into the model-wide
  shared basis (paper: "only r integers per layer"), selection ranks the
  backend's column-energy statistic.
* **Dense kinds** — per-matrix ``(..., n, r)`` bases:
  ``svd`` (top right-singular vectors), ``power`` (block power iteration,
  QR-orthonormalized), ``random`` (per-refresh random semi-orthogonal,
  FRUGAL baseline); plus ``randperm`` — int32 random column subset
  (identity basis, FRUGAL baseline).

All state layouts broadcast over leading stacked-layer axes.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .selection import (
    allsum,
    back_project,
    gather_columns,
    select_top_r,
)
from .transforms import backend_kinds, get_backend, is_backend, shared_basis

#: projector kinds that are NOT predefined-basis backends
DENSE_KINDS = ("svd", "power", "random", "randperm")


def projector_kinds() -> tuple[str, ...]:
    """Every valid ``Projector.kind``: the registered basis backends plus
    the dense per-matrix kinds. Live view of the registry."""
    return backend_kinds() + DENSE_KINDS


# import-time snapshot, kept for back-compat (validation goes through
# ``projector_kinds()`` so late-registered backends are honoured)
PROJECTOR_KINDS = projector_kinds()


def _unknown_kind(kind) -> ValueError:
    """The one unknown-kind error, sourced from the registry — raised
    eagerly at construction and (defensively) on every dispatch path, so
    the message never degrades to a bare ``ValueError(kind)``."""
    return ValueError(f"unknown projector kind {kind!r}; allowed: "
                      f"{projector_kinds()}")


@dataclasses.dataclass(frozen=True)
class Projector:
    """Rank-r right-projector family. ``shared_q`` holds the predefined
    orthogonal basis for backend kinds (one per device for the whole model
    — the paper's memory win); dense kinds keep a per-matrix basis in
    their state."""

    kind: str
    r: int
    norm: str = "l2"  # ranking norm for predefined-basis kinds

    def __post_init__(self):
        if self.kind not in projector_kinds():
            raise _unknown_kind(self.kind)

    @property
    def backend(self):
        """The registered :class:`BasisBackend`, or None for dense kinds."""
        return get_backend(self.kind) if is_backend(self.kind) else None

    def _shared_q(self, shared_q: jax.Array | None, n: int,
                  dtype=jnp.float32) -> jax.Array:
        """The shared basis: the caller's (from ``ctx.basis``) when given,
        else built in-graph by the backend."""
        if shared_q is not None:
            return shared_q
        return get_backend(self.kind).matrix(n, dtype)

    def init(self, shape: tuple[int, ...], key: jax.Array | None = None) -> Any:
        """Initial state for a (stacked) matrix of ``shape`` (..., m, n)."""
        *batch, m, n = shape
        r = min(self.r, n)
        if is_backend(self.kind) or self.kind == "randperm":
            idx = jnp.broadcast_to(jnp.arange(r, dtype=jnp.int32), (*batch, r))
            return idx
        if self.kind in ("svd", "power", "random"):
            eye = jnp.eye(n, r, dtype=jnp.float32)
            return jnp.broadcast_to(eye, (*batch, n, r))
        raise _unknown_kind(self.kind)

    # -- basis refresh ------------------------------------------------------
    def update(self, g: jax.Array, state: Any, shared_q: jax.Array | None = None,
               key: jax.Array | None = None, psum_axes=None) -> Any:
        """Recompute the basis from the current gradient/momentum ``g``.

        ``psum_axes``: mesh axes the rows of ``g`` are sharded over (ZeRO-1
        shard_map, DESIGN.md §9). Row reductions — the backend column
        energies, the power iteration's ``G^T (G Q)`` contraction — are
        completed by a psum so every shard derives the same basis. ``svd``
        is not row-decomposable and rejects sharded input; key-based kinds
        (random/randperm) draw from the replicated per-leaf key and need no
        communication.
        """
        n = g.shape[-1]
        r = min(self.r, n)
        gf = g.astype(jnp.float32)
        backend = self.backend
        if backend is not None:
            stat = backend.energy_stat(gf, self._shared_q(shared_q, n),
                                       norm=self.norm, psum_axes=psum_axes)
            return select_top_r(stat, r)
        if self.kind == "svd":
            if psum_axes:
                raise ValueError("svd projector refresh needs the full "
                                 "gradient; it cannot run on ZeRO row "
                                 "shards (rule.zero_shardable gates this)")
            _, _, vt = jnp.linalg.svd(gf, full_matrices=False)
            return jnp.swapaxes(vt[..., :r, :], -1, -2)
        if self.kind == "power":
            # one block power iteration warm-started from the previous basis
            z = jnp.einsum("...mn,...nr->...mr", gf, state)
            y = allsum(jnp.einsum("...mn,...mr->...nr", gf, z), psum_axes)
            q, _ = jnp.linalg.qr(y)
            return q
        if self.kind == "random":
            gauss = jax.random.normal(key, (*g.shape[:-2], n, r), dtype=jnp.float32)
            q, _ = jnp.linalg.qr(gauss)
            return q
        if self.kind == "randperm":
            perm = jax.random.permutation(key, n)[:r]
            return jnp.broadcast_to(jnp.sort(perm).astype(jnp.int32),
                                    (*g.shape[:-2], r))
        raise _unknown_kind(self.kind)

    # -- application --------------------------------------------------------
    def project(self, g: jax.Array, state: Any,
                shared_q: jax.Array | None = None) -> jax.Array:
        """``g_low = G @ Q_r`` -> (..., m, r)."""
        if self.kind == "randperm":
            # Q = I: projection is a pure column take (no matmul)
            return jnp.take_along_axis(g, state[..., None, :], axis=-1)
        if is_backend(self.kind):
            q = self._shared_q(shared_q, g.shape[-1])
            qr = gather_columns(q, state)                 # (..., n, r)
            return jnp.einsum("...mn,...nr->...mr", g, qr.astype(g.dtype))
        if self.kind in ("svd", "power", "random"):
            return jnp.einsum("...mn,...nr->...mr", g, state.astype(g.dtype))
        raise _unknown_kind(self.kind)

    def backproject(self, low: jax.Array, state: Any,
                    shared_q: jax.Array | None = None, n: int | None = None
                    ) -> jax.Array:
        """``G_hat = g_low @ Q_r^T`` -> (..., m, n)."""
        if self.kind == "randperm":
            if n is None:
                if shared_q is None:
                    raise ValueError(
                        "randperm backproject needs the full dimension `n` "
                        "(or a shared_q to infer it from)")
                n = int(shared_q.shape[-1])
            out = jnp.zeros((*low.shape[:-1], n), low.dtype)
            idx = jnp.broadcast_to(state[..., None, :], low.shape[:-1] + state.shape[-1:])
            return jnp.put_along_axis(out, idx, low, axis=-1, inplace=False)
        if is_backend(self.kind):
            if shared_q is None and n is None:
                raise ValueError(
                    f"{self.kind} backproject needs the full dimension `n` "
                    f"(or a shared_q to infer it from)")
            q = self._shared_q(shared_q, n)
            return back_project(low, q.astype(low.dtype), state)
        if self.kind in ("svd", "power", "random"):
            return jnp.einsum("...mr,...nr->...mn", low, state.astype(low.dtype))
        raise _unknown_kind(self.kind)

    def basis_matrix(self, state: Any, n: int,
                     shared_q: jax.Array | None = None) -> jax.Array:
        """Materialize Q_r (..., n, r) — for tests / rotation matmul flag."""
        if self.kind == "randperm":
            return jnp.swapaxes(jnp.eye(n, dtype=jnp.float32)[state], -1, -2)
        if is_backend(self.kind):
            return gather_columns(self._shared_q(shared_q, n), state)
        if self.kind in ("svd", "power", "random"):
            return state
        raise _unknown_kind(self.kind)

    @property
    def index_based(self) -> bool:
        """State is an index set into one orthogonal matrix (every backend
        kind, plus randperm's identity-basis column subset)."""
        return is_backend(self.kind) or self.kind == "randperm"

    @property
    def needs_shared_basis(self) -> bool:
        return is_backend(self.kind)

    @property
    def needs_key(self) -> bool:
        if self.kind in ("random", "randperm"):
            return True
        backend = self.backend
        return backend is not None and backend.needs_key


def shared_basis_for(kind: str, n: int, dtype=jnp.float32) -> jax.Array | None:
    """The model-wide shared basis for predefined-basis kinds (one per
    device for the entire model — the paper's memory win), None for dense
    kinds. Served from the process-wide :class:`BasisCache`."""
    if is_backend(kind):
        return shared_basis(kind, n, dtype)
    return None


def rotation_matrix(prev_state: Any, crt_state: Any, projector: Projector,
                    n: int, shared_q: jax.Array | None = None,
                    exact_matmul: bool = False) -> jax.Array:
    """Subspace rotation ``R = Q_prev^T Q_crt`` (paper Alg. 3 line 8).

    For index-based projectors (any backend kind, randperm) the columns
    come from one orthogonal matrix, so ``R[a, b] = 1 iff prev_idx[a] ==
    crt_idx[b]`` — a 0/1 partial permutation. We build it by index
    comparison in O(r^2) int ops instead of the O(n r^2) matmul (exact
    algebraic equivalence; see DESIGN.md §1). ``exact_matmul=True``
    restores the paper-literal matmul.
    """
    if projector.index_based and not exact_matmul:
        return (prev_state[..., :, None] == crt_state[..., None, :]).astype(jnp.float32)
    qp = projector.basis_matrix(prev_state, n, shared_q)
    qc = projector.basis_matrix(crt_state, n, shared_q)
    return jnp.einsum("...nr,...ns->...rs", qp.astype(jnp.float32),
                      qc.astype(jnp.float32))
