"""repro — FFT/DCT dynamic subspace selection for low-rank adaptive
optimization (Trion + DCT-AdamW), as a multi-pod JAX training/inference
framework. See README.md / DESIGN.md / EXPERIMENTS.md."""

__version__ = "0.1.0"
