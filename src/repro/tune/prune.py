"""Roofline-seeded block-grid pruning (DESIGN.md §15).

Exhaustively timing every block-size combination is what classical
autotuners do; here the seed ``roofline/`` subsystem does most of that work
analytically. For each candidate block we know, in closed form, the HBM
traffic the grid layout implies (which tiles are re-fetched how many times)
and the FLOP count — so each candidate gets a
:class:`~repro.roofline.analysis.RooflineReport` priced at the active
:mod:`repro.roofline.hw` arch, and the measurement harness only ever times
the few candidates whose *predicted* ``step_s`` is competitive and whose
working set fits the arch's VMEM envelope. The prediction is a bound, not
a simulator — its job is ranking, and a handful of survivors
(``keep``, default 4) absorbs the model error.

Traffic models per kernel family (mirroring the BlockSpec index maps —
a block whose index map does not change between consecutive grid steps
stays resident and is not re-fetched):

``dct_project`` (grid ``(nb, nj, ni, nk)``): the ``G`` tile walks ``(i,
k)`` per output-column block, so ``G`` is read ``nj`` times; the ``Q``
tile walks ``(k, j)`` per row block, so ``Q`` is read ``nb * ni`` times;
``S`` and the norms are written once. Bigger ``bn`` cuts ``G`` re-reads,
bigger ``bm`` cuts ``Q`` re-reads, bigger everything costs VMEM — exactly
the tension the roofline arbitrates.

``colgather_matmul[_dual]`` (grid ``(nb, nj, ni)``): the ``(n, bn)``
stripe of ``Q^T`` and its gathered ``(r, bn)`` scratch are built once per
``(b, j)``; the skinny ``b`` factor is re-read per column block (``nj``
times); outputs written once.

``quant_ef`` / ``newton_schulz`` are bandwidth-bound streaming kernels:
traffic is block-independent to first order, so pruning is purely the
VMEM-fit filter plus padding waste (a block that forces row/column padding
streams the pad too).
"""
from __future__ import annotations

import dataclasses

from repro.roofline import hw
from repro.roofline.analysis import RooflineReport

_DTYPE_BYTES = {"float32": 4, "float64": 8, "bfloat16": 2, "float16": 2,
                "int8": 1, "int32": 4}


def dtype_bytes(dtype) -> int:
    return _DTYPE_BYTES.get(str(dtype), 4)


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One block-size candidate with its roofline prediction."""

    block: tuple | int
    flops: float
    bytes: float
    vmem_bytes: int
    report: RooflineReport

    @property
    def predicted_s(self) -> float:
        return self.report.step_s

    @property
    def bound(self) -> str:
        """"compute" or "memory" — the dominant roofline term."""
        return self.report.dominant


# ---------------------------------------------------------------------------
# candidate grids
# ---------------------------------------------------------------------------
def candidate_blocks(kernel: str, shape, rank: int = 0) -> list:
    """The untuned search grid per kernel family (before pruning)."""
    if kernel == "dct_project":
        sizes = (128, 256, 512)
        return [(bm, bn, bk) for bm in sizes for bn in sizes for bk in sizes]
    if kernel in ("colgather_matmul", "colgather_matmul_dual"):
        return [(bm, bn) for bm in (128, 256, 512, 1024)
                for bn in (128, 256, 512)]
    if kernel == "quant_ef":
        return [64, 128, 256, 512, 1024]
    if kernel == "newton_schulz":
        return [128, 256, 512, 1024, 2048]
    raise ValueError(f"unknown kernel family {kernel!r}")


# ---------------------------------------------------------------------------
# per-candidate cost model
# ---------------------------------------------------------------------------
def kernel_costs(kernel: str, shape, rank: int, dtype, block
                 ) -> tuple[float, float, int]:
    """(flops, hbm_bytes, vmem_bytes) for one candidate block.

    ``shape`` is the collapsed operand signature the cache keys on:
    ``(nb, m, n)`` for dct_project / colgather / quant_ef, ``(nb, r, m)``
    (wide-oriented) for newton_schulz.
    """
    db = dtype_bytes(dtype)
    if kernel == "dct_project":
        nb, m, n = shape
        bm, bn, bk = block
        ni, nj, nk = _cdiv(m, bm), _cdiv(n, bn), _cdiv(n, bk)
        mm, nn, kk = ni * bm, nj * bn, nk * bk
        flops = 2.0 * nb * mm * nn * kk + 2.0 * nb * mm * nn  # matmul + norms
        traffic = (nb * mm * kk * db * nj          # G re-read per column blk
                   + kk * nn * db * nb * ni        # Q re-read per row blk
                   + nb * mm * nn * db             # S written once
                   + nb * nn * 4)                  # norms
        vmem = (bm * bk + bk * bn) * db + bm * bn * db \
            + bm * bn * 4 + bn * 4                 # tiles + fp32 acc + norms
        return flops, float(traffic), int(vmem)

    if kernel in ("colgather_matmul", "colgather_matmul_dual"):
        nb, m, n = shape
        r = rank or n
        bm, bn = block
        ni, nj = _cdiv(m, bm), _cdiv(n, bn)
        mm, nn = ni * bm, nj * bn
        nops = 2 if kernel.endswith("_dual") else 1
        flops = 2.0 * nb * mm * r * nn * nops
        traffic = (nops * nb * mm * r * db * nj    # b re-read per column blk
                   + nb * n * nn * db              # Q^T stripe per (b, j)
                   + nops * nb * mm * nn * db)     # outputs written once
        vmem = bm * r * db * nops + n * bn * db + r * bn * db \
            + bm * bn * db * nops                  # b tiles + stripe + gather
        return flops, float(traffic), int(vmem)

    if kernel == "quant_ef":
        nb, m, n = shape
        bm = int(block)
        mm = _cdiv(m, bm) * bm
        # quantize (read fp + write i8/scale) + fused dequant-add
        flops = 8.0 * nb * mm * n
        traffic = nb * mm * (n * (2 * db + 2 * 1) + 2 * 4)
        vmem = bm * n * (db + 1) + bm * 4
        return flops, float(traffic), int(vmem)

    if kernel == "newton_schulz":
        nb, r, m = shape
        bm = int(block)
        mm = _cdiv(m, bm) * bm
        # per NS5 iteration: gram pass + apply pass (+ r^3 polynomial)
        flops = 4.0 * nb * r * r * mm + 2.0 * nb * r ** 3
        traffic = 3.0 * nb * r * mm * 4 + 2.0 * nb * r * r * 4
        vmem = 2 * r * bm * 4 + 2 * r * r * 4
        return flops, float(traffic), int(vmem)

    raise ValueError(f"unknown kernel family {kernel!r}")


def roofline_report(kernel: str, shape, rank: int, dtype, block, *,
                    arch: str | None = None) -> Candidate:
    """Price one candidate as a single-device RooflineReport at ``arch``."""
    spec = hw.get_arch(arch)
    flops, traffic, vmem = kernel_costs(kernel, shape, rank, dtype, block)
    report = RooflineReport(
        arch=f"{kernel}:{'x'.join(map(str, shape))}", shape=str(block),
        mesh="local", n_devices=1, flops_per_device=flops,
        bytes_per_device=traffic, collectives={}, wire_bytes_per_device=0.0,
        compute_s=flops / spec.peak_flops, memory_s=traffic / spec.hbm_bw,
        collective_s=0.0, model_flops_total=flops, device_arch=spec.name)
    return Candidate(block=block, flops=flops, bytes=traffic,
                     vmem_bytes=vmem, report=report)


def prune(kernel: str, shape, rank: int = 0, dtype="float32", *,
          arch: str | None = None, keep: int = 4,
          vmem_frac: float = 0.9) -> list[Candidate]:
    """The autotuner's grid pruner: every candidate priced by the roofline,
    VMEM-misfits dropped, survivors sorted by predicted ``step_s`` and cut
    to the ``keep`` best. If *nothing* fits the arch's VMEM envelope (tiny
    ``vmem_bytes`` arch entries), the ``keep`` smallest-footprint
    candidates survive so tuning can still measure something.
    """
    spec = hw.get_arch(arch)
    cands = [roofline_report(kernel, shape, rank, dtype, b, arch=arch)
             for b in candidate_blocks(kernel, shape, rank)]
    fit = [c for c in cands if c.vmem_bytes <= spec.vmem_bytes * vmem_frac]
    if not fit:
        fit = sorted(cands, key=lambda c: c.vmem_bytes)[:keep]
    fit.sort(key=lambda c: (c.predicted_s, c.vmem_bytes))
    return fit[:max(1, int(keep))]


def predicted_bound(kernel: str, shape, rank: int = 0, dtype="float32", *,
                    block=None, arch: str | None = None) -> str:
    """"compute" or "memory" for one (kernel, shape) at ``arch`` — the
    headline roofline classification (docs/tuning.md)."""
    if block is None:
        block = candidate_blocks(kernel, shape, rank)[0]
    return roofline_report(kernel, shape, rank, dtype, block,
                           arch=arch).bound


def grid_size(kernel: str, shape, rank: int = 0) -> int:
    return len(candidate_blocks(kernel, shape, rank))


def _fmt_bytes(b: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(b) < 1024 or unit == "GiB":
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}GiB"  # pragma: no cover


def describe(c: Candidate) -> str:
    """One-line human summary (the __main__ CLI prints these)."""
    return (f"block={c.block} pred={c.predicted_s * 1e6:.1f}us "
            f"bound={c.bound} vmem={_fmt_bytes(c.vmem_bytes)} "
            f"intensity={c.flops / max(c.bytes, 1.0):.1f}")


__all__ = ["Candidate", "candidate_blocks", "kernel_costs",
           "roofline_report", "prune", "predicted_bound", "grid_size",
           "describe", "dtype_bytes"]
