"""Process-wide tuned-block cache (DESIGN.md §15).

``TuningCache`` is the kernel-side half of the autotuner: a
``(kernel, shape, rank, dtype, platform) -> block`` memo that every
Pallas entry point consults when called with ``block=None`` (the new
default). Resolution order is

    explicit block  >  TuningCache hit  >  the kernel's DEFAULT_BLOCK

so an untuned process is bit-identical to the pre-autotuner repo: a miss
returns exactly the hardcoded default the kernels have always shipped.

This module is deliberately stdlib-only. The kernels import
:func:`resolve_block` at module level, and ``tune/__init__`` re-exports
the cache eagerly — if this file imported jax (or ``tune.autotune``,
which imports the kernels) the package would cycle. The one jax touch —
asking the runtime which platform we are on — is a lazy import inside
:func:`default_platform`.

Keys are fully static (ints/strings), so lookups happen at trace time:
``block`` is a static jit argument, which means a cache entry loaded
*after* a step function is compiled does not retrace it. Load the cache
(``--tune-cache`` on launch/train.py and benchmarks/run.py) before the
first step is jitted.

The JSON file format (``save``/``load``) is a flat entry list::

    {"version": 1,
     "entries": [{"kernel": "dct_project", "shape": [1, 4096, 4096],
                  "rank": 0, "dtype": "float32", "platform": "tpu",
                  "block": [256, 256, 256]}, ...]}

``block`` round-trips as a list (tuple-valued blocks) or a bare int
(``bm``-style scalar blocks for quant_ef / newton_schulz).
"""
from __future__ import annotations

import json
import os

_FORMAT_VERSION = 1

#: kernel families the cache knows how to key (autotune + tests iterate it)
KERNELS = ("dct_project", "colgather_matmul", "colgather_matmul_dual",
           "quant_ef", "newton_schulz")


def default_platform() -> str:
    """The jax backend platform string ("cpu"/"tpu"/"gpu"); "cpu" when jax
    is unavailable (keeps this module importable anywhere)."""
    try:
        import jax
        return jax.default_backend()
    except Exception:  # pragma: no cover - jax is always present in-repo
        return "cpu"


def _dtype_str(dtype) -> str:
    """"float32" from a np.dtype, a jnp scalar type, or a plain string —
    without importing numpy (this module stays stdlib-only)."""
    name = getattr(dtype, "name", None)        # np.dtype
    if isinstance(name, str):
        return name
    return str(getattr(dtype, "__name__", dtype))  # jnp.float32 et al.


def make_key(kernel: str, shape, rank: int, dtype, platform: str | None = None
             ) -> tuple:
    """Normalize to the canonical hashable key.

    ``shape`` is the collapsed operand signature the kernel grids over
    (e.g. ``(nb, m, n)`` for dct_project); ``rank`` is the subspace rank
    where the kernel has one (0 otherwise — the slot stays so all
    families share one schema); ``dtype`` is the operand dtype.
    """
    return (str(kernel), tuple(int(d) for d in shape), int(rank),
            _dtype_str(dtype), str(platform or default_platform()))


def _encode_block(block):
    return list(block) if isinstance(block, (tuple, list)) else int(block)


def _decode_block(block):
    return tuple(int(b) for b in block) if isinstance(block, list) \
        else int(block)


class TuningCache:
    """``make_key(...) -> block`` memo with hit/miss counters and JSON
    persistence. Lives alongside :class:`BasisCache` (core/transforms.py
    re-exports it) as the second process-wide kernel-configuration cache.
    """

    def __init__(self) -> None:
        self._store: dict[tuple, tuple | int] = {}
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, key: tuple) -> bool:
        return key in self._store

    def lookup(self, key: tuple):
        """The tuned block for ``key``, or None (counted as hit/miss)."""
        block = self._store.get(key)
        if block is None:
            self.misses += 1
        else:
            self.hits += 1
        return block

    def store(self, key: tuple, block) -> None:
        self._store[key] = _decode_block(_encode_block(block))

    def entries(self) -> dict:
        return dict(self._store)

    def clear(self) -> None:
        self._store.clear()
        self.hits = 0
        self.misses = 0

    # -- persistence --------------------------------------------------------
    def to_json(self) -> dict:
        ents = []
        for (kernel, shape, rank, dtype, platform), block in sorted(
                self._store.items()):
            ents.append({"kernel": kernel, "shape": list(shape), "rank": rank,
                         "dtype": dtype, "platform": platform,
                         "block": _encode_block(block)})
        return {"version": _FORMAT_VERSION, "entries": ents}

    def from_json(self, doc: dict, *, replace: bool = False) -> int:
        if doc.get("version") != _FORMAT_VERSION:
            raise ValueError(f"tuning-cache version {doc.get('version')!r} "
                             f"!= {_FORMAT_VERSION}")
        if replace:
            self._store.clear()
        n = 0
        for e in doc["entries"]:
            key = make_key(e["kernel"], e["shape"], e["rank"], e["dtype"],
                           e["platform"])
            self._store[key] = _decode_block(e["block"])
            n += 1
        return n

    def save(self, path: str) -> None:
        tmp = f"{path}.tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)

    def load(self, path: str, *, replace: bool = False) -> int:
        """Merge (or replace) entries from ``path``; returns entry count."""
        with open(path) as f:
            return self.from_json(json.load(f), replace=replace)


_CACHE = TuningCache()


def tuning_cache() -> TuningCache:
    """The process-wide cache instance (mirrors ``basis_cache()``)."""
    return _CACHE


def resolve_block(kernel: str, shape, rank: int, dtype, default,
                  platform: str | None = None):
    """``block=None`` resolution the kernel entry points call: tuned block
    on a cache hit, the kernel's hardcoded ``default`` otherwise (the
    bit-identical untuned path)."""
    block = _CACHE.lookup(make_key(kernel, shape, rank, dtype, platform))
    return default if block is None else block
