"""Compile+run measurement harness over the roofline-pruned survivors.

``tune_kernel`` is one cache entry's worth of work: prune the candidate
grid with :mod:`repro.tune.prune` (roofline predictions at the active
arch), time each survivor plus the kernel's hardcoded default with the
real jitted entry points (interpret mode off-TPU, so CI tuning runs are
hermetic), and store the winner in the process-wide
:class:`~repro.tune.cache.TuningCache` under the
``(kernel, shape, rank, dtype, platform)`` key the kernels resolve
``block=None`` against. ``tune_all`` sweeps a spec list and returns
JSON-able records (benchmarks/tuned_kernels.py persists them).

The default block is always measured alongside the survivors and wins
ties: a tuned cache can only match or beat the untuned defaults on the
machine that produced it (the BENCH_tuned_kernels.json gate).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from . import prune as prune_mod
from .cache import TuningCache, make_key, tuning_cache

#: kernel family -> (defining module, DEFAULT_* constant name); resolved
#: lazily so kernel imports stay out of module scope
_DEFAULT_BLOCKS = {
    "dct_project": ("repro.kernels.dct_project", "DEFAULT_BLOCK"),
    "colgather_matmul": ("repro.kernels.colgather_matmul", "DEFAULT_BLOCK"),
    "colgather_matmul_dual": ("repro.kernels.colgather_matmul",
                              "DEFAULT_BLOCK"),
    "quant_ef": ("repro.kernels.quant_ef", "DEFAULT_BM"),
    "newton_schulz": ("repro.kernels.newton_schulz", "DEFAULT_BM"),
}


def default_block(kernel: str):
    """The kernel's hardcoded untuned default block."""
    import importlib
    module, name = _DEFAULT_BLOCKS[kernel]
    return getattr(importlib.import_module(module), name)


def _operands(kernel: str, shape, rank: int, dtype):
    """Deterministic operands for one measurement (seed 0)."""
    key = jax.random.PRNGKey(0)
    if kernel == "dct_project":
        nb, m, n = shape
        k1, k2 = jax.random.split(key)
        return (jax.random.normal(k1, (nb, m, n), dtype),
                jax.random.normal(k2, (n, n), dtype))
    if kernel in ("colgather_matmul", "colgather_matmul_dual"):
        nb, m, n = shape
        r = rank or min(n, 64)
        k1, k2, k3 = jax.random.split(key, 3)
        b1 = jax.random.normal(k1, (nb, m, r), dtype)
        qt = jax.random.normal(k2, (n, n), dtype)
        idx = jnp.argsort(jax.random.uniform(k3, (nb, n)), axis=-1)
        idx = idx[:, :r].astype(jnp.int32)
        if kernel.endswith("_dual"):
            b2 = jax.random.normal(jax.random.fold_in(k1, 1), (nb, m, r),
                                   dtype)
            return b1, b2, qt, idx
        return b1, qt, idx
    if kernel == "quant_ef":
        nb, m, n = shape
        return (jax.random.normal(key, (nb, m, n), dtype),)
    if kernel == "newton_schulz":
        nb, r, m = shape
        return (jax.random.normal(key, (nb, r, m), dtype),)
    raise ValueError(f"unknown kernel family {kernel!r}")


def _runner(kernel: str, operands, block, interpret: bool):
    """A zero-arg thunk running one launch of ``kernel`` at ``block``."""
    from repro.kernels import (colgather_matmul, colgather_matmul_dual,
                               dct_project, dequant_add_ef, ns_iteration,
                               quantize_ef)
    if kernel == "dct_project":
        g, q = operands
        return lambda: dct_project(g, q, block=block, interpret=interpret)
    if kernel == "colgather_matmul":
        b, qt, idx = operands
        return lambda: colgather_matmul(b, qt, idx, block=block,
                                        interpret=interpret)
    if kernel == "colgather_matmul_dual":
        b1, b2, qt, idx = operands
        return lambda: colgather_matmul_dual(b1, b2, qt, idx, block=block,
                                             interpret=interpret)
    if kernel == "quant_ef":
        (x,) = operands

        def run():
            qv, scale = quantize_ef(x, bm=block, interpret=interpret)
            return dequant_add_ef(x, qv, scale, bm=block, interpret=interpret)
        return run
    if kernel == "newton_schulz":
        (x,) = operands
        return lambda: ns_iteration(x, bm=block, interpret=interpret)
    raise ValueError(f"unknown kernel family {kernel!r}")


def measure(kernel: str, shape, rank: int, dtype, block, *,
            interpret: bool | None = None, iters: int = 3,
            warmup: int = 1, operands=None) -> float:
    """Best-of-``iters`` wall seconds for one launch (after ``warmup``
    compile+run calls)."""
    if interpret is None:
        from repro.kernels import ops
        interpret = not ops.ON_TPU
    if operands is None:
        operands = _operands(kernel, shape, rank, dtype)
    run = _runner(kernel, operands, block, interpret)
    for _ in range(max(1, warmup)):
        jax.block_until_ready(run())
    best = float("inf")
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(run())
        best = min(best, time.perf_counter() - t0)
    return best


def tune_kernel(kernel: str, shape, rank: int = 0, dtype="float32", *,
                arch: str | None = None, keep: int = 4,
                interpret: bool | None = None, iters: int = 3,
                warmup: int = 1, cache: TuningCache | None = None,
                platform: str | None = None) -> dict:
    """Tune one cache entry; stores the winner and returns a record::

        {"kernel", "shape", "rank", "dtype", "platform", "grid_size",
         "survivors", "timings_s": {str(block): s}, "predicted_s": {...},
         "default_block", "default_s", "best_block", "best_s", "speedup"}
    """
    cache = cache if cache is not None else tuning_cache()
    dtype = str(jnp.dtype(dtype))
    survivors = prune_mod.prune(kernel, shape, rank, dtype, arch=arch,
                                keep=keep)
    dflt = default_block(kernel)
    blocks = [c.block for c in survivors]
    if dflt not in blocks:
        blocks.append(dflt)
    operands = _operands(kernel, shape, rank, dtype)
    timings = {}
    for b in blocks:
        timings[str(b)] = measure(kernel, shape, rank, dtype, b,
                                  interpret=interpret, iters=iters,
                                  warmup=warmup, operands=operands)
    default_s = timings[str(dflt)]
    # default wins ties: the cache can only match-or-beat the untuned path
    best_block = min(blocks, key=lambda b: (timings[str(b)], b != dflt))
    key = make_key(kernel, shape, rank, dtype, platform)
    cache.store(key, best_block)
    return {
        "kernel": kernel, "shape": list(shape), "rank": rank, "dtype": dtype,
        "platform": key[-1],
        "grid_size": prune_mod.grid_size(kernel, shape, rank),
        "survivors": [str(c.block) for c in survivors],
        "predicted_s": {str(c.block): c.predicted_s for c in survivors},
        "bound": survivors[0].bound if survivors else None,
        "timings_s": timings,
        "default_block": str(dflt), "default_s": default_s,
        "best_block": str(best_block), "best_s": timings[str(best_block)],
        "speedup": default_s / max(timings[str(best_block)], 1e-12),
    }


#: the reduced grid the CI ``tune`` job sweeps (small shapes, interpret mode)
REDUCED_SPECS = (
    ("dct_project", (1, 128, 128), 0),
    ("colgather_matmul", (1, 128, 128), 32),
    ("colgather_matmul_dual", (2, 64, 128), 32),
    ("quant_ef", (1, 128, 128), 0),
    ("newton_schulz", (1, 32, 128), 32),
)

#: a production-shaped sweep (one stacked transformer leaf per family)
FULL_SPECS = (
    ("dct_project", (2, 1024, 1024), 0),
    ("colgather_matmul", (2, 1024, 1024), 256),
    ("colgather_matmul_dual", (2, 1024, 1024), 256),
    ("quant_ef", (2, 1024, 1024), 0),
    ("newton_schulz", (2, 256, 1024), 256),
)


def tune_all(specs=REDUCED_SPECS, *, dtype="float32",
             arch: str | None = None, keep: int = 4,
             interpret: bool | None = None, iters: int = 3,
             warmup: int = 1, cache: TuningCache | None = None,
             platform: str | None = None, verbose: bool = False
             ) -> list[dict]:
    """Sweep ``(kernel, shape, rank)`` specs; returns one record each."""
    out = []
    for kernel, shape, rank in specs:
        rec = tune_kernel(kernel, shape, rank, dtype, arch=arch, keep=keep,
                          interpret=interpret, iters=iters, warmup=warmup,
                          cache=cache, platform=platform)
        if verbose:
            print(f"[tune] {kernel} {tuple(shape)} r={rank}: "
                  f"{rec['best_block']} ({rec['best_s'] * 1e3:.2f}ms, "
                  f"default {rec['default_s'] * 1e3:.2f}ms, "
                  f"x{rec['speedup']:.2f})")
        out.append(rec)
    return out
