"""Roofline-seeded kernel autotuner (DESIGN.md §15, docs/tuning.md).

Three layers:

  ``tune.cache``     — the process-wide :class:`TuningCache` the kernels
                       resolve ``block=None`` against (stdlib-only, safe
                       to import from kernel modules).
  ``tune.prune``     — per-family block grids + roofline cost models; cuts
                       each grid to a few plausible candidates before
                       anything is timed.
  ``tune.autotune``  — compile+run measurement over the survivors; stores
                       winners in the cache (``python -m repro.tune`` is
                       the CLI).

The cache re-exports eagerly (kernels need it); everything that imports
the kernels or roofline loads lazily via ``__getattr__`` so
``repro.kernels -> repro.tune.cache`` never cycles back through
``tune.autotune -> repro.kernels``.
"""
from __future__ import annotations

from .cache import (
    KERNELS,
    TuningCache,
    default_platform,
    make_key,
    resolve_block,
    tuning_cache,
)

_LAZY = {
    "prune": ("repro.tune.prune", None),
    "autotune": ("repro.tune.autotune", None),
    "candidate_blocks": ("repro.tune.prune", "candidate_blocks"),
    "kernel_costs": ("repro.tune.prune", "kernel_costs"),
    "roofline_report": ("repro.tune.prune", "roofline_report"),
    "Candidate": ("repro.tune.prune", "Candidate"),
    "measure": ("repro.tune.autotune", "measure"),
    "tune_kernel": ("repro.tune.autotune", "tune_kernel"),
    "tune_all": ("repro.tune.autotune", "tune_all"),
    "default_block": ("repro.tune.autotune", "default_block"),
    "REDUCED_SPECS": ("repro.tune.autotune", "REDUCED_SPECS"),
    "FULL_SPECS": ("repro.tune.autotune", "FULL_SPECS"),
}

__all__ = ["KERNELS", "TuningCache", "default_platform", "make_key",
           "resolve_block", "tuning_cache", *sorted(_LAZY)]


def __getattr__(name: str):
    try:
        module, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}") from None
    import importlib
    mod = importlib.import_module(module)
    value = mod if attr is None else getattr(mod, attr)
    globals()[name] = value
    return value
