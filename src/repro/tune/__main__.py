"""Autotuner CLI: ``python -m repro.tune --out tune_cache.json``.

Runs the roofline-pruned sweep (reduced grid by default; ``--full`` for
production shapes), prints per-entry winners, and persists the cache JSON
that ``--tune-cache`` on launch/train.py and benchmarks/run.py loads.
"""
from __future__ import annotations

import argparse

from repro.roofline import hw

from . import autotune
from .cache import tuning_cache


def _parse_shape(text: str) -> tuple[int, ...]:
    return tuple(int(d) for d in text.replace("x", ",").split(",") if d)


def main(argv=None):
    ap = argparse.ArgumentParser(prog="python -m repro.tune")
    ap.add_argument("--out", default="tune_cache.json",
                    help="cache JSON to write (merged if it exists)")
    ap.add_argument("--merge", action="store_true",
                    help="load --out first and merge new winners into it")
    ap.add_argument("--full", action="store_true",
                    help="production-shaped sweep instead of the reduced grid")
    ap.add_argument("--kernel", default=None,
                    help="tune one kernel family only")
    ap.add_argument("--shape", default=None, type=_parse_shape,
                    help="override shape for --kernel, e.g. 2x1024x1024")
    ap.add_argument("--rank", default=0, type=int)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--device-arch", default=None, choices=hw.arch_names(),
                    help="roofline arch for pruning (default: REPRO_ARCH/v5e)")
    ap.add_argument("--keep", default=4, type=int,
                    help="survivors measured per entry after pruning")
    ap.add_argument("--iters", default=3, type=int)
    args = ap.parse_args(argv)

    cache = tuning_cache()
    if args.merge:
        try:
            cache.load(args.out)
        except FileNotFoundError:
            pass
    if args.kernel:
        shape = args.shape or dict(
            (k, s) for k, s, _ in autotune.FULL_SPECS)[args.kernel]
        specs = [(args.kernel, shape, args.rank)]
    else:
        specs = autotune.FULL_SPECS if args.full else autotune.REDUCED_SPECS
    records = autotune.tune_all(specs, dtype=args.dtype,
                                arch=args.device_arch, keep=args.keep,
                                iters=args.iters, verbose=True)
    cache.save(args.out)
    print(f"[tune] wrote {len(cache)} entries -> {args.out}")
    return records


if __name__ == "__main__":
    main()
