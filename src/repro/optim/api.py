"""Optimizer registry — `get_optimizer(name, lr, **kw)`."""
from __future__ import annotations

from .adamw import adamw
from .common import Optimizer, Schedule, apply_updates
from .dion import dion
from .muon import muon
from .projected_adam import dct_adamw, fira, frugal, galore, ldadamw
from .trion import trion

OPTIMIZERS = {
    "adamw": adamw,
    "muon": muon,
    "dion": dion,
    "trion": trion,
    "dct_adamw": dct_adamw,
    "ldadamw": ldadamw,
    "galore": galore,
    "frugal": frugal,
    "fira": fira,
}


def get_optimizer(name: str, lr: Schedule, **kw) -> Optimizer:
    if name not in OPTIMIZERS:
        raise KeyError(f"unknown optimizer {name!r}; have {sorted(OPTIMIZERS)}")
    return OPTIMIZERS[name](lr, **kw)
