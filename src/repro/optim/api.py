"""Optimizer registry — `get_optimizer(name, lr, **kw)`.

Every preset here is a thin wrapper over the composable transform chains
in :mod:`repro.optim.transform` (DESIGN.md §4). ``TRANSFORMS`` exposes the
matching *transform-level* factories (``GradientTransform`` builders) for
composition: route them through ``partition`` for per-group policies or
wrap them in ``inject_hyperparams`` for runtime hyperparameter control.

The predefined orthogonal basis is itself pluggable (DESIGN.md §10):
``dct_adamw`` takes ``basis=`` and ``galore``/``frugal``/``fira`` take
``projector=`` — any registered backend kind
(:func:`repro.core.transforms.backend_kinds`: dct/dst/hadamard/randortho)
rides the identical fused/ZeRO/telemetry stack. Unknown kinds fail
eagerly at construction with the allowed set in the message.

The momentum-orthogonalization families ride the same stack (DESIGN.md
§14): ``muon``/``trion``/``dion`` take ``fused=`` (Pallas Newton-Schulz
on the rank-sized subspace factor; ``muon`` additionally takes ``rank=``
to opt into subspace orthogonalization) and ``zero=`` (ZeRO-1 state
partitioning, bit-identical to replicated).
"""
from __future__ import annotations

import inspect

from .adamw import adamw, adamw_transform
from .common import Optimizer, Schedule, apply_updates
from .dion import dion, dion_transform
from .muon import muon, muon_transform
from .projected_adam import (
    dct_adamw,
    dct_adamw_transform,
    fira,
    frugal,
    galore,
    ldadamw,
)
from .trion import trion, trion_transform

OPTIMIZERS = {
    "adamw": adamw,
    "muon": muon,
    "dion": dion,
    "trion": trion,
    "dct_adamw": dct_adamw,
    "ldadamw": ldadamw,
    "galore": galore,
    "frugal": frugal,
    "fira": fira,
}

# transform-level factories (matrix-leaf pipelines for the matrix rules,
# whole-tree for adamw) — the building blocks for partition/inject
TRANSFORMS = {
    "adamw": adamw_transform,
    "muon": muon_transform,
    "dion": dion_transform,
    "trion": trion_transform,
    "dct_adamw": dct_adamw_transform,
}


def _validate_kwargs(name: str, fn, kw: dict) -> None:
    """Reject unknown kwargs eagerly with the allowed set in the message
    (every preset has an explicit keyword-only signature)."""
    params = inspect.signature(fn).parameters
    if any(p.kind == inspect.Parameter.VAR_KEYWORD for p in params.values()):
        return
    allowed = sorted(p for p in params if p != "lr")
    unknown = sorted(set(kw) - set(allowed))
    if unknown:
        raise TypeError(f"{name!r} got unknown kwargs {unknown}; "
                        f"allowed: {allowed}")


def get_optimizer(name: str, lr: Schedule, **kw) -> Optimizer:
    if name not in OPTIMIZERS:
        raise KeyError(f"unknown optimizer {name!r}; have {sorted(OPTIMIZERS)}")
    fn = OPTIMIZERS[name]
    _validate_kwargs(name, fn, kw)
    return fn(lr, **kw)


def get_transform(name: str, lr: Schedule, **kw):
    """Transform-level counterpart of ``get_optimizer`` for composition."""
    if name not in TRANSFORMS:
        raise KeyError(f"unknown transform {name!r}; have {sorted(TRANSFORMS)}")
    fn = TRANSFORMS[name]
    _validate_kwargs(name, fn, kw)
    return fn(lr, **kw)
