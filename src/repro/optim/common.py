"""Shared optimizer framework.

Optax-style ``Optimizer(init, update)`` pairs plus the shared vocabulary of
the optimizer layer: leaf routing (``default_label_fn``), matrix
orientation, Adam moments, the per-leaf :class:`MatrixRule` protocol and
the :class:`Context` that carries step / shared DCT bases / PRNG key.

Matrix leaves may carry leading stacked axes — ``(layers, m, n)`` or
``(layers, experts, m, n)`` from scan-stacked models — and every rule
broadcasts over them, which is how "per-layer column indices" fall out for
free: the index state gets shape ``(layers, ..., r)``.

The monolithic ``make_matrix_optimizer`` harness at the bottom is the
*legacy reference implementation*: the live presets are built from the
composable transform chains in :mod:`repro.optim.transform`
(``chain`` / ``partition`` / ``inject_hyperparams`` — DESIGN.md §4), and
the harness is retained so tests/test_transform_api.py can pin the chains
bit-for-bit against the pre-refactor behaviour.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.transforms import (
    basis_store_key,
    get_backend,
    normalize_basis_request,
    shared_basis,
)

Schedule = Callable[[jax.Array], jax.Array] | float


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]  # (grads, state, params)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def sched_value(lr: Schedule, step: jax.Array) -> jax.Array:
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


# ---------------------------------------------------------------------------
# Leaf routing
# ---------------------------------------------------------------------------
_FULLRANK_NAME_HINTS = ("embed", "unembed", "lm_head", "vocab", "norm", "scale",
                        "bias", "pos_emb", "a_log", "dt", "decay", "conv")


def default_label_fn(path: str, leaf) -> str:
    """'lowrank' for linear-layer matrices, 'full' otherwise (paper practice)."""
    lname = path.lower()
    if any(h in lname for h in _FULLRANK_NAME_HINTS):
        return "full"
    if leaf.ndim >= 2 and min(leaf.shape[-2:]) >= 8:
        return "lowrank"
    return "full"


def path_str(kp) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)


def labelled_tree(params, label_fn=default_label_fn):
    return jax.tree_util.tree_map_with_path(
        lambda kp, p: label_fn(path_str(kp), p), params
    )


# ---------------------------------------------------------------------------
# Matrix orientation: rules are written for *right* projection of (…, m, n)
# with n = min(m, n) (paper: "compress the smallest dimension").
# ---------------------------------------------------------------------------
def orient_right(x: jax.Array) -> tuple[jax.Array, bool]:
    m, n = x.shape[-2], x.shape[-1]
    if n <= m:
        return x, False
    return jnp.swapaxes(x, -1, -2), True


def deorient(x: jax.Array, transposed: bool) -> jax.Array:
    return jnp.swapaxes(x, -1, -2) if transposed else x


def oriented_dims(shape) -> tuple[int, int]:
    m, n = shape[-2], shape[-1]
    return (m, n) if n <= m else (n, m)


# ---------------------------------------------------------------------------
# Adam moments (used by every Adam-family rule)
# ---------------------------------------------------------------------------
class AdamMoments(NamedTuple):
    m: jax.Array
    v: jax.Array


def adam_update(g, mom: AdamMoments, step, b1, b2, eps) -> tuple[jax.Array, AdamMoments]:
    gf = g.astype(jnp.float32)
    m = b1 * mom.m + (1.0 - b1) * gf
    v = b2 * mom.v + (1.0 - b2) * gf * gf
    t = step.astype(jnp.float32)
    mhat = m / (1.0 - b1**t)
    vhat = v / (1.0 - b2**t)
    return mhat / (jnp.sqrt(vhat) + eps), AdamMoments(m, v)


# ---------------------------------------------------------------------------
# The harness
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MatrixRule:
    """Per-matrix-leaf rule. ``ctx`` carries step, shared bases, prng."""

    def init(self, shape, dtype) -> Any:
        raise NotImplementedError

    def update(self, g, state, param, ctx) -> tuple[jax.Array, Any]:
        """Returns (descent direction D, new state). Update is -lr*D - lr*wd*p
        (decoupled weight decay applied by the harness)."""
        raise NotImplementedError

    def basis_sizes(self, shape) -> tuple:
        """Which shared bases this leaf needs: ``(kind, n)`` pairs, or bare
        orders ``n`` (the legacy spelling for the DCT basis). Default: the
        DCT basis at the min oriented dim."""
        return (oriented_dims(shape)[1],)

    needs_shared_basis: bool = False

    @property
    def zero_shardable(self) -> bool:
        """Whether this rule's update is row-parallel given psum'd column
        statistics — the precondition for ZeRO-1 partitioning of its state
        (repro.parallel.zero). Rules opt in explicitly."""
        return False


class FullAdamLeaf(NamedTuple):
    mom: AdamMoments


@dataclasses.dataclass(frozen=True)
class Context:
    step: jax.Array
    # shared predefined bases, keyed by ``transforms.basis_store_key``:
    # bare "n" for the DCT basis (historical), "kind:n" otherwise. May be
    # empty (on-the-fly mode).
    bases: dict
    key: jax.Array | None = None
    # telemetry channel (repro.telemetry.stats): the chain runtime installs
    # the active StatsCollector here; lowrank_project narrows it to a
    # per-leaf StatsScope. None = telemetry off -> rules skip stat
    # construction entirely, so the traced graph is unchanged.
    stats: Any = None
    # distributed execution (repro.parallel.zero, DESIGN.md §9):
    # ``zero`` carries the ZeroConfig installed by ``as_optimizer`` —
    # lowrank_project resolves it against the active mesh and wraps
    # eligible leaves in shard_map. ``axis`` is set *inside* that
    # shard_map to the mesh axes the oriented row dim is split over, so
    # rules/psum-aware helpers know which reductions span shards.
    zero: Any = None
    axis: tuple[str, ...] | None = None
    # set together with ``axis``: the caller already right-oriented the
    # gradient block (projected dim last). Rules must then skip their own
    # ``orient_right`` — a row *block*'s aspect ratio can differ from the
    # global leaf's, so re-deciding orientation locally would transpose
    # shard-dependent leaves.
    oriented: bool = False

    def record_stats(self, stats) -> None:
        """Emit this leaf's SubspaceStats into the active collector (no-op
        when telemetry is off)."""
        if self.stats is not None:
            self.stats.record(stats)

    def psum(self, x: jax.Array) -> jax.Array:
        """Sum a row-block-local reduction across the ZeRO shards.

        Identity outside shard_map (``axis`` unset) — the traced graph is
        then unchanged from the replicated path. Delegates to the single
        shared :func:`repro.core.selection.allsum` definition.
        """
        from repro.core.selection import allsum

        return allsum(x, self.axis)

    @property
    def wants_stats(self) -> bool:
        return self.stats is not None

    def basis(self, n: int, dtype=jnp.float32, kind: str = "dct") -> jax.Array:
        """The shared ``(n, n)`` basis of ``kind`` — from the stored bases
        when the runtime collected it, else rebuilt by the backend."""
        key = basis_store_key(kind, n)
        if self.bases and key in self.bases:
            return self.bases[key].astype(dtype)
        # on-the-fly mode: the basis is recomputed inside the step — zero
        # state memory, ~2*n^2 basis-construction flops (negligible vs.
        # the matmuls)
        return get_backend(kind).matrix(n, dtype)


class HarnessState(NamedTuple):
    step: jax.Array
    key: jax.Array
    bases: dict
    leaves: Any          # pytree matching params


def make_matrix_optimizer(
    rule: MatrixRule,
    lr: Schedule,
    *,
    weight_decay: float = 0.0,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    label_fn=default_label_fn,
    basis_mode: str = "stored",   # "stored" (paper) | "onthefly" (beyond-paper)
    seed: int = 0,
    fullrank_weight_decay: bool = True,
) -> Optimizer:
    """Wrap a MatrixRule into a full-model optimizer with AdamW fallback.

    Legacy reference implementation — the live presets are the equivalent
    transform chains built by ``transform.matrix_optimizer``; the parity
    suite pins the two bit-for-bit.
    """

    def init(params):
        labels = labelled_tree(params, label_fn)

        sizes = set()
        if rule.needs_shared_basis and basis_mode == "stored":
            def collect(lbl, p):
                if lbl == "lowrank":
                    sizes.update(normalize_basis_request(s)
                                 for s in rule.basis_sizes(p.shape))
            jax.tree.map(collect, labels, params)
        bases = {basis_store_key(k, n): shared_basis(k, n, jnp.float32)
                 for k, n in sorted(sizes)}

        def leaf_init(lbl, p):
            if lbl == "lowrank":
                return rule.init(p.shape, p.dtype)
            # distinct buffers: donation aliases leaves one-to-one
            return FullAdamLeaf(AdamMoments(jnp.zeros(p.shape, jnp.float32),
                                            jnp.zeros(p.shape, jnp.float32)))

        leaves = jax.tree.map(
            leaf_init, labels, params,
            is_leaf=lambda x: isinstance(x, str),
        )
        return HarnessState(
            step=jnp.zeros((), jnp.int32),
            key=jax.random.PRNGKey(seed),
            bases=bases,
            leaves=leaves,
        )

    def update(grads, state: HarnessState, params):
        step = state.step + 1
        lr_t = sched_value(lr, step)
        labels = labelled_tree(params, label_fn)
        key = jax.random.fold_in(state.key, step)

        def leaf_update(kp, lbl, g, s, p):
            if lbl == "lowrank":
                # per-leaf key: stable hash of the tree path, NOT flat
                # enumeration order — inserting/removing a parameter leaves
                # every other leaf's randomness unchanged
                from .transform import leaf_key
                ctx = Context(step=step, bases=state.bases,
                              key=leaf_key(key, path_str(kp)))
                d, new_s = rule.update(g, s, p, ctx)
                upd = -lr_t * d.astype(jnp.float32)
                upd = upd - lr_t * weight_decay * p.astype(jnp.float32)
                return upd, new_s
            direction, mom = adam_update(g, s.mom, step, b1, b2, eps)
            upd = -lr_t * direction
            if fullrank_weight_decay:
                upd = upd - lr_t * weight_decay * p.astype(jnp.float32)
            return upd, FullAdamLeaf(mom)

        pairs = jax.tree_util.tree_map_with_path(
            leaf_update, labels, grads, state.leaves, params,
            is_leaf=lambda x: isinstance(x, str),
        )
        # unzip the (update, state) pairs
        updates = jax.tree.map(lambda _, pr: pr[0], labels, pairs,
                               is_leaf=lambda x: isinstance(x, str))
        leaves = jax.tree.map(lambda _, pr: pr[1], labels, pairs,
                              is_leaf=lambda x: isinstance(x, str))
        return updates, HarnessState(step=step, key=state.key,
                                     bases=state.bases, leaves=leaves)

    return Optimizer(init=init, update=update)
