"""Muon baseline (Jordan et al., 2024): orthogonalized momentum via
Newton-Schulz on the *full-size* matrix — the compute/communication cost
Trion's low-rank NS avoids.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.newton_schulz import newton_schulz

from .common import MatrixRule, Optimizer, Schedule, make_matrix_optimizer


class MuonLeaf(NamedTuple):
    m: jax.Array


@dataclasses.dataclass(frozen=True)
class MuonRule(MatrixRule):
    mu: float = 0.95
    ns_steps: int = 5
    nesterov: bool = True
    needs_shared_basis: bool = False

    def init(self, shape, dtype):
        return MuonLeaf(m=jnp.zeros(shape, jnp.float32))

    def update(self, g, state, param, ctx):
        gf = g.astype(jnp.float32)
        new_m = self.mu * state.m + gf
        ns_in = gf + self.mu * new_m if self.nesterov else new_m
        o = newton_schulz(ns_in, steps=self.ns_steps)
        rows, cols = sorted(g.shape[-2:], reverse=True)
        scale = max(1.0, (rows / cols) ** 0.5)
        return scale * o, MuonLeaf(m=new_m)


def muon(lr: Schedule, *, mu: float = 0.95, weight_decay: float = 0.01,
         ns_steps: int = 5, nesterov: bool = True, label_fn=None,
         **adam_kw) -> Optimizer:
    rule = MuonRule(mu=mu, ns_steps=ns_steps, nesterov=nesterov)
    kw = dict(weight_decay=weight_decay, **adam_kw)
    if label_fn is not None:
        kw["label_fn"] = label_fn
    return make_matrix_optimizer(rule, lr, **kw)
