"""Muon (Jordan et al., 2024): orthogonalized momentum via Newton-Schulz —
plus the paper/SUMO-style subspace-fused variant (DESIGN.md §14).

``rank=None`` (default) is the full-space baseline: NS on the full
(m, n) moment, bit-identical to the seed repo. ``rank=r`` projects the
nesterov-adjusted moment into the dynamically selected DCT subspace via the
one-pass select+project (core/fused_step.py), runs Newton-Schulz on the
(rows, r) low-rank factor — r-sized Gram matrices instead of n-sized — and
back-projects through the shared ``Q_r^T`` gather. At full rank
(r = min(m, n)) this matches the full-space update up to NS's polynomial
tolerance, because NS commutes with right-multiplication by an orthogonal
matrix: ``NS(X Q) = NS(X) Q`` in exact arithmetic.

Momentum is stored *oriented* (projected dim last) so ZeRO-1 can row-shard
it; orientation is a transpose, so the stored values are bit-identical to
the seed's param-shaped buffer. The rule is ``zero_shardable``: selection
needs one psum'd column statistic, NS all-gathers the (rank-sized) factor
and keeps local rows (see ``fused_step.fused_newton_schulz``), everything
else is row-local — sharded updates are bit-identical to replicated in
the parity suite (exact column-energy ties could flip the psum'd
selection; see ``zero_shardable``).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import fused_step
from repro.core.selection import allsum, column_norms, select_top_r, topr_margin
from repro.telemetry import stats as tstats

from .common import (
    MatrixRule,
    Optimizer,
    Schedule,
    deorient,
    orient_right,
    oriented_dims,
)
from .transform import (
    GradientTransform,
    add_decayed_weights,
    chain,
    lowrank_project,
    matrix_optimizer,
    scale_by_learning_rate,
)

_RANKING_NORMS = ("l1", "l2")


class MuonLeaf(NamedTuple):
    m: jax.Array  # momentum, stored oriented (projected dim last)


@dataclasses.dataclass(frozen=True)
class MuonRule(MatrixRule):
    rank: int | None = None          # None = full-space NS (seed behaviour)
    mu: float = 0.95
    ns_steps: int = 5
    nesterov: bool = True
    ranking_norm: str = "l2"
    needs_shared_basis: bool = True  # basis_sizes() is () when rank is None
    fused: str = "auto"              # fused-step dispatch (DESIGN.md §3)
    emit_stats: bool = True          # SubspaceStats when rank is set and a
    #   telemetry collector is installed; full-space muon has no subspace
    #   to report on and emits nothing either way

    def __post_init__(self):
        if self.ranking_norm not in _RANKING_NORMS:
            raise ValueError(
                f"unknown ranking_norm {self.ranking_norm!r}; allowed: "
                f"{_RANKING_NORMS}")
        if self.fused not in fused_step.FUSED_MODES:
            raise ValueError(
                f"unknown fused mode {self.fused!r}; allowed: "
                f"{fused_step.FUSED_MODES}")
        if self.rank is not None and self.rank < 1:
            raise ValueError(f"rank must be >= 1 or None, got {self.rank}")

    @property
    def zero_shardable(self) -> bool:
        """Row-parallel given one psum'd column statistic (subspace path)
        plus the rank-sized NS all-gather; full-space NS all-gathers the
        moment. Sharded == replicated bitwise under the parity suite:
        muon's momentum is selection-independent, so the ~1-ulp rounding
        difference between the blockwise psum and the replicated
        single-pass reduction has no EF tie-attractor to latch onto
        (unlike trion) — but at an *exact* column-energy tie the
        selection could still flip between the two (DESIGN.md §14)."""
        return True

    def basis_sizes(self, shape) -> tuple:
        return () if self.rank is None else (oriented_dims(shape)[1],)

    def init(self, shape, dtype):
        *batch, _, _ = shape
        rows, cols = oriented_dims(shape)
        return MuonLeaf(m=jnp.zeros((*batch, rows, cols), jnp.float32))

    def update(self, g, state, param, ctx):
        if ctx.oriented:        # ZeRO row block: already right-oriented
            gf, transposed = g.astype(jnp.float32), False
        else:
            gf, transposed = orient_right(g.astype(jnp.float32))
        new_m = self.mu * state.m + gf
        ns_in = gf + self.mu * new_m if self.nesterov else new_m
        # Muon's shape-aware step scale from the GLOBAL leaf shape: inside
        # a ZeRO shard_map the gradient block's aspect ratio is
        # shard-dependent but ``param`` is passed replicated
        rows, cols = sorted(param.shape[-2:], reverse=True)
        scale = max(1.0, (rows / cols) ** 0.5)
        mode = fused_step.resolve(self.fused)

        if self.rank is None:
            o = fused_step.fused_newton_schulz(ns_in, steps=self.ns_steps,
                                               mode=mode,
                                               gather_axes=ctx.axis)
            return scale * deorient(o, transposed), MuonLeaf(m=new_m)

        r = min(self.rank, ns_in.shape[-1])
        q = ctx.basis(ns_in.shape[-1], jnp.float32)
        want_stats = ctx.wants_stats and self.emit_stats
        if mode != "off":
            sp = fused_step.select_and_project(
                ns_in, q, r, norm=self.ranking_norm, mode=mode,
                return_norms=want_stats, psum_axes=ctx.axis)
            idx, b_low = sp[0], sp[1]
            norms_sq = sp[2] if want_stats else None
        else:
            s = ns_in @ q
            norms_sq = (allsum(column_norms(s, "l2"), ctx.axis)
                        if want_stats or self.ranking_norm == "l2" else None)
            rank_norms = (norms_sq if self.ranking_norm == "l2"
                          else allsum(column_norms(s, self.ranking_norm),
                                      ctx.axis))
            idx = select_top_r(rank_norms, r)
            b_low = jnp.take_along_axis(s, idx[..., None, :], axis=-1)
        o = fused_step.fused_newton_schulz(b_low, steps=self.ns_steps,
                                           mode=mode, gather_axes=ctx.axis)
        d = fused_step.fused_backproject(o, q, idx, mode=mode)

        if want_stats:
            col_e = jnp.take_along_axis(norms_sq, idx, axis=-1)
            sel_sq = jnp.sum(col_e, axis=-1)
            total_sq = jnp.sum(jax.lax.optimization_barrier(norms_sq),
                               axis=-1)
            batch = ns_in.shape[:-2]
            ctx.record_stats(tstats.SubspaceStats(
                captured_energy=tstats.captured_energy(sel_sq, total_sq),
                topr_margin=topr_margin(norms_sq, r),
                index_overlap=-jnp.ones(batch, jnp.float32),
                ef_norm=jnp.zeros(batch, jnp.float32),
                rank_utilization=tstats.rank_utilization(col_e)))

        return scale * deorient(d, transposed), MuonLeaf(m=new_m)


def muon_transform(lr: Schedule, *, rank: int | None = None, mu: float = 0.95,
                   weight_decay: float = 0.01, ns_steps: int = 5,
                   nesterov: bool = True, ranking_norm: str = "l2",
                   fused: str = "auto") -> GradientTransform:
    """Matrix-leaf Muon pipeline (orthogonalize -> -lr -> decay) for use
    inside ``partition`` / ``inject_hyperparams``."""
    rule = MuonRule(rank=rank, mu=mu, ns_steps=ns_steps, nesterov=nesterov,
                    ranking_norm=ranking_norm, fused=fused)
    return chain(lowrank_project(rule), scale_by_learning_rate(lr),
                 add_decayed_weights(weight_decay, schedule=lr))


def muon(lr: Schedule, *, rank: int | None = None, mu: float = 0.95,
         weight_decay: float = 0.01, ns_steps: int = 5, nesterov: bool = True,
         ranking_norm: str = "l2", fused: str = "auto",
         basis_mode: str = "stored", b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, label_fn=None, zero=None,
         lr_scale: bool = False) -> Optimizer:
    rule = MuonRule(rank=rank, mu=mu, ns_steps=ns_steps, nesterov=nesterov,
                    ranking_norm=ranking_norm, fused=fused)
    kw = dict(weight_decay=weight_decay, basis_mode=basis_mode, b1=b1, b2=b2,
              eps=eps, zero=zero, lr_scale=lr_scale)
    if label_fn is not None:
        kw["label_fn"] = label_fn
    return matrix_optimizer(rule, lr, **kw)
