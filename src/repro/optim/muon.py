"""Muon baseline (Jordan et al., 2024): orthogonalized momentum via
Newton-Schulz on the *full-size* matrix — the compute/communication cost
Trion's low-rank NS avoids.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.newton_schulz import newton_schulz

from .common import MatrixRule, Optimizer, Schedule
from .transform import (
    GradientTransform,
    add_decayed_weights,
    chain,
    lowrank_project,
    matrix_optimizer,
    scale_by_learning_rate,
)


class MuonLeaf(NamedTuple):
    m: jax.Array


@dataclasses.dataclass(frozen=True)
class MuonRule(MatrixRule):
    mu: float = 0.95
    ns_steps: int = 5
    nesterov: bool = True
    needs_shared_basis: bool = False

    def init(self, shape, dtype):
        return MuonLeaf(m=jnp.zeros(shape, jnp.float32))

    def update(self, g, state, param, ctx):
        gf = g.astype(jnp.float32)
        new_m = self.mu * state.m + gf
        ns_in = gf + self.mu * new_m if self.nesterov else new_m
        o = newton_schulz(ns_in, steps=self.ns_steps)
        rows, cols = sorted(g.shape[-2:], reverse=True)
        scale = max(1.0, (rows / cols) ** 0.5)
        return scale * o, MuonLeaf(m=new_m)


def muon_transform(lr: Schedule, *, mu: float = 0.95,
                   weight_decay: float = 0.01, ns_steps: int = 5,
                   nesterov: bool = True) -> GradientTransform:
    """Matrix-leaf Muon pipeline (orthogonalize -> -lr -> decay) for use
    inside ``partition`` / ``inject_hyperparams``."""
    rule = MuonRule(mu=mu, ns_steps=ns_steps, nesterov=nesterov)
    return chain(lowrank_project(rule), scale_by_learning_rate(lr),
                 add_decayed_weights(weight_decay, schedule=lr))


def muon(lr: Schedule, *, mu: float = 0.95, weight_decay: float = 0.01,
         ns_steps: int = 5, nesterov: bool = True, b1: float = 0.9,
         b2: float = 0.999, eps: float = 1e-8, label_fn=None,
         lr_scale: bool = False) -> Optimizer:
    rule = MuonRule(mu=mu, ns_steps=ns_steps, nesterov=nesterov)
    kw = dict(weight_decay=weight_decay, b1=b1, b2=b2, eps=eps,
              lr_scale=lr_scale)
    if label_fn is not None:
        kw["label_fn"] = label_fn
    return matrix_optimizer(rule, lr, **kw)
