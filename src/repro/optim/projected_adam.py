"""Generic low-rank projected AdamW — one rule, five optimizers.

This is the plug point the paper argues for: the projector (DCT dynamic
column selection vs SVD vs block power iteration vs random/randperm) is a
swappable component inside an otherwise identical low-rank Adam(W):

  optimizer   projector   T_u    rotate   residual handling
  ---------   ---------   ----   ------   -----------------
  DCT-AdamW   dct         any    yes      error feedback (fp32 or int8)
  LDAdamW     power       1      yes      error feedback (optional)
  GaLore      svd         200    no       discarded
  FRUGAL      svd/dct/..  200    no       SignSGD on the state-free part
  FIRA        svd/dct     200    no       norm-scaled pass-through

Per 2D leaf (oriented so the projected dim is last, size n <= m):
    G_t  = grad (+ EF buffer)
    refresh (every T_u steps): new indices/basis from G_t; rotation
        R = Q_prev^T Q_crt applied to m, v (|.| on v) — for index-based
        projectors R is a 0/1 partial permutation (DESIGN.md §1)
    g_t  = G_t @ Q_crt                      (m x r)
    Xi   = G_t - g_t Q_crt^T                (residual; see table)
    m, v = Adam moments on g_t; u = mhat / (sqrt(vhat) + eps)
    D    = u @ Q_crt^T (+ residual term)
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.error_feedback import (
    QuantizedBuffer,
    dequantize_q8,
    quantize_q8,
    zeros_q8,
)
from repro.core.projectors import Projector, rotation_matrix

from .common import (
    MatrixRule,
    Optimizer,
    Schedule,
    deorient,
    make_matrix_optimizer,
    orient_right,
    oriented_dims,
)


class ProjAdamLeaf(NamedTuple):
    m: jax.Array            # (..., rows, r) first moment, low-rank
    v: jax.Array            # (..., rows, r) second moment, low-rank
    proj: Any               # projector state (indices or basis)
    ef: Any                 # None | fp32 array | QuantizedBuffer
    inner_step: jax.Array   # steps since last subspace refresh (bias corr.)


@dataclasses.dataclass(frozen=True)
class ProjectedAdamRule(MatrixRule):
    rank: int = 128
    projector: str = "dct"
    update_interval: int = 1          # T_u
    rotate: bool = True
    residual: str = "ef"              # "ef" | "discard" | "sign" | "fira"
    ef_dtype: str = "q8"              # "fp32" | "q8" (when residual == "ef")
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    ranking_norm: str = "l2"
    exact_rotation_matmul: bool = False   # paper-literal R via matmul
    needs_shared_basis: bool = True       # harness stores DCT bases if needed

    def _proj(self):
        return Projector(kind=self.projector, r=self.rank, norm=self.ranking_norm)

    def init(self, shape, dtype):
        *batch, _, _ = shape
        rows, cols = oriented_dims(shape)
        r = min(self.rank, cols)
        p = self._proj()
        # m and v must be distinct buffers (donation aliases leaves 1:1)
        mz = jnp.zeros((*batch, rows, r), jnp.float32)
        vz = jnp.zeros((*batch, rows, r), jnp.float32)
        if self.residual == "ef":
            orient_shape = (*batch, rows, cols)
            ef = (zeros_q8(orient_shape) if self.ef_dtype == "q8"
                  else jnp.zeros(orient_shape, jnp.float32))
        else:
            ef = None
        return ProjAdamLeaf(m=mz, v=vz, proj=p.init((*batch, rows, cols)),
                            ef=ef, inner_step=jnp.zeros((), jnp.int32))

    def update(self, g, state, param, ctx):
        p = self._proj()
        gf, transposed = orient_right(g.astype(jnp.float32))
        rows, cols = gf.shape[-2], gf.shape[-1]
        r = min(self.rank, cols)
        q = ctx.basis(cols, jnp.float32) if p.needs_shared_basis else None

        if state.ef is not None:
            ef_val = (dequantize_q8(state.ef) if isinstance(state.ef, QuantizedBuffer)
                      else state.ef)
            gf = gf + ef_val

        def refresh(_):
            new_proj = p.update(gf, state.proj, shared_q=q, key=ctx.key)
            if not self.rotate:
                return (new_proj,)
            rot = rotation_matrix(state.proj, new_proj, p, cols, shared_q=q,
                                  exact_matmul=self.exact_rotation_matmul)
            return new_proj, rot

        def keep(_):
            if not self.rotate:
                return (state.proj,)
            eye = jnp.eye(r, dtype=jnp.float32)
            eye = jnp.broadcast_to(eye, (*gf.shape[:-2], r, r))
            return state.proj, eye

        if self.update_interval == 1:
            out = refresh(None)
        else:
            do_refresh = (ctx.step % self.update_interval == 1) | (ctx.step == 1)
            out = jax.lax.cond(do_refresh, refresh, keep, None)
        proj_state = out[0]

        g_low = p.project(gf, proj_state, shared_q=q)           # (..., rows, r)

        if self.rotate:
            rot = out[1]
            m_prev = jnp.einsum("...mr,...rs->...ms", state.m, rot)
            v_prev = jnp.abs(jnp.einsum("...mr,...rs->...ms", state.v, rot))
        else:
            m_prev, v_prev = state.m, state.v
        inner = state.inner_step + 1

        m = self.b1 * m_prev + (1.0 - self.b1) * g_low
        v = self.b2 * v_prev + (1.0 - self.b2) * g_low * g_low
        t = inner.astype(jnp.float32)
        mhat = m / (1.0 - self.b1**t)
        vhat = v / (1.0 - self.b2**t)
        u_low = mhat / (jnp.sqrt(vhat) + self.eps)

        d = p.backproject(u_low, proj_state, shared_q=q, n=cols)

        new_ef = state.ef
        if self.residual != "discard":
            resid = gf - p.backproject(g_low, proj_state, shared_q=q, n=cols)
            if self.residual == "ef":
                new_ef = (quantize_q8(resid) if self.ef_dtype == "q8" else resid)
            elif self.residual == "sign":
                d = d + jnp.sign(resid)                         # FRUGAL state-free
            elif self.residual == "fira":
                phi = (jnp.linalg.norm(u_low, axis=(-2, -1), keepdims=True)
                       / (jnp.linalg.norm(g_low, axis=(-2, -1), keepdims=True)
                          + self.eps))
                d = d + phi * resid                             # FIRA scaling

        d = deorient(d, transposed)
        return d, ProjAdamLeaf(m=m, v=v, proj=proj_state, ef=new_ef,
                               inner_step=inner)


def _build(lr, rule_kw, harness_kw) -> Optimizer:
    rule_kw.setdefault("needs_shared_basis", rule_kw.get("projector") == "dct")
    rule = ProjectedAdamRule(**rule_kw)
    return make_matrix_optimizer(rule, lr, b1=rule.b1, b2=rule.b2, eps=rule.eps,
                                 **harness_kw)


def dct_adamw(lr: Schedule, *, rank: int = 128, update_interval: int = 1,
              weight_decay: float = 0.01, error_feedback: bool = True,
              ef_dtype: str = "q8", b1: float = 0.9, b2: float = 0.999,
              eps: float = 1e-8, exact_rotation_matmul: bool = False,
              basis_mode: str = "stored", label_fn=None) -> Optimizer:
    """The paper's DCT-AdamW (Algorithm 2)."""
    hk = dict(weight_decay=weight_decay, basis_mode=basis_mode)
    if label_fn is not None:
        hk["label_fn"] = label_fn
    return _build(lr, dict(rank=rank, projector="dct",
                           update_interval=update_interval, rotate=True,
                           residual="ef" if error_feedback else "discard",
                           ef_dtype=ef_dtype, b1=b1, b2=b2, eps=eps,
                           exact_rotation_matmul=exact_rotation_matmul), hk)


def ldadamw(lr: Schedule, *, rank: int = 128, weight_decay: float = 0.01,
            error_feedback: bool = True, b1: float = 0.9, b2: float = 0.999,
            eps: float = 1e-8, label_fn=None) -> Optimizer:
    """LDAdamW baseline: block power iteration, per-step subspace, rotation
    via real r x r matmul of two stored projection matrices."""
    hk = dict(weight_decay=weight_decay)
    if label_fn is not None:
        hk["label_fn"] = label_fn
    return _build(lr, dict(rank=rank, projector="power", update_interval=1,
                           rotate=True,
                           residual="ef" if error_feedback else "discard",
                           ef_dtype="fp32", b1=b1, b2=b2, eps=eps), hk)


def galore(lr: Schedule, *, rank: int = 128, update_interval: int = 200,
           weight_decay: float = 0.01, projector: str = "svd",
           b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
           label_fn=None) -> Optimizer:
    """GaLore baseline: SVD every T_u steps, residual discarded, no rotation."""
    hk = dict(weight_decay=weight_decay)
    if label_fn is not None:
        hk["label_fn"] = label_fn
    return _build(lr, dict(rank=rank, projector=projector,
                           update_interval=update_interval, rotate=False,
                           residual="discard", b1=b1, b2=b2, eps=eps), hk)


def frugal(lr: Schedule, *, rank: int = 128, update_interval: int = 200,
           weight_decay: float = 0.01, projector: str = "svd",
           b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
           label_fn=None) -> Optimizer:
    """FRUGAL baseline: state-full low-rank AdamW + state-free SignSGD on the
    residual. ``projector`` in {svd, dct, random, randperm} (paper Table 6)."""
    hk = dict(weight_decay=weight_decay)
    if label_fn is not None:
        hk["label_fn"] = label_fn
    return _build(lr, dict(rank=rank, projector=projector,
                           update_interval=update_interval, rotate=False,
                           residual="sign", b1=b1, b2=b2, eps=eps), hk)


def fira(lr: Schedule, *, rank: int = 128, update_interval: int = 200,
         weight_decay: float = 0.01, projector: str = "svd",
         b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         label_fn=None) -> Optimizer:
    """FIRA baseline: low-rank AdamW + norm-scaled full-rank residual."""
    hk = dict(weight_decay=weight_decay)
    if label_fn is not None:
        hk["label_fn"] = label_fn
    return _build(lr, dict(rank=rank, projector=projector,
                           update_interval=update_interval, rotate=False,
                           residual="fira", b1=b1, b2=b2, eps=eps), hk)
