"""Generic low-rank projected AdamW — one rule, five optimizers.

This is the plug point the paper argues for: the projector (DCT dynamic
column selection vs SVD vs block power iteration vs random/randperm) is a
swappable component inside an otherwise identical low-rank Adam(W):

  optimizer   projector   T_u    rotate   residual handling
  ---------   ---------   ----   ------   -----------------
  DCT-AdamW   dct         any    yes      error feedback (fp32 or int8)
  LDAdamW     power       1      yes      error feedback (optional)
  GaLore      svd         200    no       discarded
  FRUGAL      svd/dct/..  200    no       SignSGD on the state-free part
  FIRA        svd/dct     200    no       norm-scaled pass-through

Per 2D leaf (oriented so the projected dim is last, size n <= m):
    G_t  = grad (+ EF buffer)
    refresh (every T_u steps): new indices/basis from G_t; rotation
        R = Q_prev^T Q_crt applied to m, v (|.| on v) — for index-based
        projectors R is a 0/1 partial permutation (DESIGN.md §1)
    g_t  = G_t @ Q_crt                      (m x r)
    Xi   = G_t - g_t Q_crt^T                (residual; see table)
    m, v = Adam moments on g_t; u = mhat / (sqrt(vhat) + eps)
    D    = u @ Q_crt^T (+ residual term)

Execution dispatch (``fused`` field, DESIGN.md §3): for every
predefined-basis projector (a registered
:class:`~repro.core.transforms.BasisBackend`: dct/dst/hadamard/randortho)
the hot path runs through core/fused_step.py — one fused select+project
pass over G (g_t extracted from S, no second matmul), one shared Q_r^T
gather for both back-projections, and int8 EF consumed/produced by fused
quantize kernels. "off" is the bit-identical seed reference path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import fused_step
from repro.core.error_feedback import QuantizedBuffer, zeros_q8
from repro.core.projectors import (
    Projector,
    projector_kinds,
    rotation_matrix,
)
from repro.core.selection import index_overlap, topr_margin
from repro.core.transforms import get_backend, is_backend
from repro.telemetry import stats as tstats

from .common import (
    MatrixRule,
    Optimizer,
    Schedule,
    deorient,
    orient_right,
    oriented_dims,
)
from .transform import (
    GradientTransform,
    add_decayed_weights,
    chain,
    lowrank_project,
    matrix_optimizer,
    scale_by_learning_rate,
)

RESIDUAL_MODES = ("ef", "discard", "sign", "fira")
EF_DTYPES = ("q8", "fp32")
RANKING_NORMS = ("l1", "l2")


class ProjAdamLeaf(NamedTuple):
    m: jax.Array            # (..., rows, r) first moment, low-rank
    v: jax.Array            # (..., rows, r) second moment, low-rank
    proj: Any               # projector state (indices or basis)
    ef: Any                 # None | fp32 array | QuantizedBuffer
    inner_step: jax.Array   # steps since last subspace refresh (bias corr.)


@dataclasses.dataclass(frozen=True)
class ProjectedAdamRule(MatrixRule):
    rank: int = 128
    projector: str = "dct"
    update_interval: int = 1          # T_u
    rotate: bool = True
    residual: str = "ef"              # "ef" | "discard" | "sign" | "fira"
    ef_dtype: str = "q8"              # "fp32" | "q8" (when residual == "ef")
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    ranking_norm: str = "l2"
    exact_rotation_matmul: bool = False   # paper-literal R via matmul
    needs_shared_basis: bool = True       # harness stores DCT bases if needed
    fused: str = "auto"                   # fused-step dispatch (DESIGN.md §3):
    #   "auto" (kernels on TPU, reference elsewhere) | "on" (Pallas kernels,
    #   interpret off-TPU) | "fft" (Makhoul host fast path) | "off" (seed jnp)
    emit_stats: bool = True               # emit SubspaceStats when a
    #   telemetry collector is installed (DESIGN.md §8). With no collector
    #   the traced graph is identical either way; False opts this rule out
    #   even under an active collector.
    compute_dtype: str = "fp32"           # projection-matmul precision
    #   (DESIGN.md §15): "fp32" (bit-identical default) | "bf16" | "int8"
    #   (per-row/column scales folded into the epilogue). Applies to the
    #   select+project pass and both back-projections on the fused modes
    #   only — the reference path has no lowp mirror, so a non-fp32 dtype
    #   with fused="off" (eager), or resolving to the reference path at
    #   trace time (fused="auto" off-TPU, dense-basis projectors), raises
    #   instead of silently running fp32. Error vs fp32 bounded by
    #   fused_step.LOWP_ERROR_BOUNDS (gated in
    #   benchmarks/projection_errors.py).

    def __post_init__(self):
        """Eager config validation: fail at construction with the allowed
        values, not deep inside the jit trace. Only static (string/int)
        fields are checked so floats may be tracers (inject_hyperparams)."""
        def check(name, value, allowed):
            if value not in allowed:
                raise ValueError(f"{type(self).__name__}: unknown {name} "
                                 f"{value!r}; allowed: {allowed}")

        check("projector", self.projector, projector_kinds())
        check("residual", self.residual, RESIDUAL_MODES)
        check("ef_dtype", self.ef_dtype, EF_DTYPES)
        check("ranking_norm", self.ranking_norm, RANKING_NORMS)
        check("fused", self.fused, fused_step.FUSED_MODES)
        check("compute_dtype", self.compute_dtype, fused_step.COMPUTE_DTYPES)
        if self.compute_dtype != "fp32" and self.fused == "off":
            raise ValueError(
                f"{type(self).__name__}: compute_dtype={self.compute_dtype!r} "
                "requires the fused dataflow (fused='on'/'fft'); the fused"
                "='off' reference path has no low-precision mirror and would "
                "silently run fp32")
        if isinstance(self.rank, int) and self.rank < 1:
            raise ValueError(f"rank must be >= 1, got {self.rank}")
        if isinstance(self.update_interval, int) and self.update_interval < 1:
            raise ValueError(
                f"update_interval must be >= 1, got {self.update_interval}")

    @property
    def zero_shardable(self) -> bool:
        """Index-into-shared-basis projectors keep only ``r`` integers of
        projector state and their whole step is row-parallel given one
        psum'd column statistic — the ZeRO-1 precondition (DESIGN.md §9).
        Any registered basis backend with a row-decomposable energy
        statistic (``backend.zero_shardable``) qualifies, as does the
        identity-basis randperm; dense-basis refreshes (svd) need all
        rows and stay replicated.

        The FIRA residual is also excluded: its ``phi`` scaling feeds
        psum'd norms into the *update arithmetic* (not just ranking), and
        a psum of per-shard partial sums rounds differently than the
        replicated single-pass reduction — it would break the bit-exact
        sharded/replicated contract the parity suite pins."""
        if self.residual == "fira":
            return False
        if is_backend(self.projector):
            return get_backend(self.projector).zero_shardable
        return self.projector == "randperm"

    def _proj(self):
        return Projector(kind=self.projector, r=self.rank, norm=self.ranking_norm)

    def basis_sizes(self, shape) -> tuple:
        """The shared basis this leaf needs: ``(kind, n)`` at the min
        oriented dim (bare ``n`` for dct — the legacy store key). Dense
        projector kinds (svd/power/random/randperm) request nothing, even
        when ``needs_shared_basis`` was left True on the rule."""
        if not is_backend(self.projector):
            return ()
        n = oriented_dims(shape)[1]
        return ((self.projector, n),) if self.projector != "dct" else (n,)

    def init(self, shape, dtype):
        *batch, _, _ = shape
        rows, cols = oriented_dims(shape)
        r = min(self.rank, cols)
        p = self._proj()
        # m and v must be distinct buffers (donation aliases leaves 1:1)
        mz = jnp.zeros((*batch, rows, r), jnp.float32)
        vz = jnp.zeros((*batch, rows, r), jnp.float32)
        if self.residual == "ef":
            orient_shape = (*batch, rows, cols)
            ef = (zeros_q8(orient_shape) if self.ef_dtype == "q8"
                  else jnp.zeros(orient_shape, jnp.float32))
        else:
            ef = None
        return ProjAdamLeaf(m=mz, v=vz, proj=p.init((*batch, rows, cols)),
                            ef=ef, inner_step=jnp.zeros((), jnp.int32))

    def update(self, g, state, param, ctx):
        p = self._proj()
        if ctx.oriented:        # ZeRO row block: already right-oriented
            gf, transposed = g.astype(jnp.float32), False
        else:
            gf, transposed = orient_right(g.astype(jnp.float32))
        rows, cols = gf.shape[-2], gf.shape[-1]
        r = min(self.rank, cols)
        backend = get_backend(self.projector) if is_backend(self.projector) \
            else None
        q = (ctx.basis(cols, jnp.float32, kind=self.projector)
             if p.needs_shared_basis else None)
        mode = fused_step.resolve(self.fused)
        # the fused dataflow exists for the index-into-shared-basis
        # projectors (any registered basis backend); dense-basis kinds keep
        # the reference math (EF still goes fused)
        fused = mode != "off" and backend is not None
        if self.compute_dtype != "fp32" and not fused:
            # only the fused dataflow has the lowp mirror; refuse loudly
            # instead of silently running fp32 (reachable past __post_init__
            # via fused="auto" resolving to "off", or a dense-basis
            # projector)
            raise ValueError(
                f"compute_dtype={self.compute_dtype!r} needs the fused "
                f"dataflow, but this update resolved to the reference path "
                f"(fused={self.fused!r} -> mode={mode!r}, "
                f"projector={self.projector!r}); pass fused='on'/'fft' with "
                "a registered basis backend")

        if state.ef is not None:
            gf = fused_step.ef_add(gf, state.ef, mode=mode)

        def eye_rot():
            eye = jnp.eye(r, dtype=jnp.float32)
            return jnp.broadcast_to(eye, (*gf.shape[:-2], r, r))

        # telemetry (DESIGN.md §8): both cond branches append a small aux
        # tuple (margin, overlap, total energy) so per-step stats ride the
        # existing control flow. With no collector installed (want_stats
        # False) nothing is appended and the graph is unchanged. On the
        # fused refresh path the total comes from the already-reduced
        # column norms (||S||_F^2 == ||G||_F^2, Q orthogonal) — zero extra
        # G-sized work; elsewhere it is one reduction fused into reads of
        # gf the step performs anyway.
        want_stats = ctx.wants_stats and self.emit_stats
        need_resid = self.residual != "discard"
        idx_based = p.index_based
        batch = gf.shape[:-2]

        def keep_aux(g_low):
            # keep step: no selection happened, so neither margin nor
            # overlap is a measurement — both report the -1 sentinel
            # (consumers gate on >= 0). Col energies from the skinny g_low
            # (an (m, r) reduction). Row reductions psum across ZeRO
            # shards (ctx.axis; identity when replicated).
            return (-jnp.ones(batch, jnp.float32),
                    -jnp.ones(batch, jnp.float32),
                    ctx.psum(jnp.sum(gf * gf, axis=(-2, -1))),
                    None if g_low is None
                    else ctx.psum(jnp.sum(g_low * g_low, axis=-2)))

        def refresh_aux(new_proj, norms_sq):
            margin = (topr_margin(norms_sq, r) if norms_sq is not None
                      else -jnp.ones(batch, jnp.float32))
            overlap = (index_overlap(state.proj, new_proj) if idx_based
                       else -jnp.ones(batch, jnp.float32))
            # the barrier pins this tiny (n,) -> () reduction to the
            # already-materialized norms: without it XLA re-derives the sum
            # from the G-sized squared-S, an extra full read of S that the
            # ≤3% overhead gate (telemetry_overhead bench) catches
            total = (jnp.sum(jax.lax.optimization_barrier(norms_sq),
                             axis=-1) if norms_sq is not None
                     else ctx.psum(jnp.sum(gf * gf, axis=(-2, -1))))
            # selected column energies ||G q_i||^2 == norms_sq[idx]: a free
            # (n,) -> (r,) gather of the already-reduced ranking statistic,
            # NOT a fresh reduction over S/g_low (that extra S-sized read
            # is exactly what the ≤3% overhead gate caught)
            col_e = (None if norms_sq is None else
                     jnp.take_along_axis(norms_sq, new_proj, axis=-1))
            return (margin, overlap, total, col_e)

        if fused:
            # refresh folds selection AND projection into one pass over G:
            # g_low falls out of S (Alg. 1 line 8), so both branches return it
            def refresh(_):
                sp = fused_step.select_and_project(
                    gf, q, r, norm=self.ranking_norm, mode=mode,
                    return_norms=want_stats, psum_axes=ctx.axis,
                    backend=backend, compute_dtype=self.compute_dtype)
                new_proj, g_low = sp[0], sp[1]
                out = (new_proj, g_low)
                if self.rotate:
                    rot = rotation_matrix(state.proj, new_proj, p, cols,
                                          shared_q=q,
                                          exact_matmul=self.exact_rotation_matmul)
                    out = (new_proj, rot, g_low)
                return out + ((refresh_aux(new_proj, sp[2]),) if want_stats
                              else ())

            def keep(_):
                g_low = fused_step.project_with_indices(
                    gf, q, state.proj, compute_dtype=self.compute_dtype)
                out = ((state.proj, g_low) if not self.rotate
                       else (state.proj, eye_rot(), g_low))
                return out + ((keep_aux(g_low),) if want_stats else ())
        else:
            def refresh(_):
                new_proj = p.update(gf, state.proj, shared_q=q, key=ctx.key,
                                    psum_axes=ctx.axis)
                out = (new_proj,)
                if self.rotate:
                    rot = rotation_matrix(state.proj, new_proj, p, cols,
                                          shared_q=q,
                                          exact_matmul=self.exact_rotation_matmul)
                    out = (new_proj, rot)
                return out + ((refresh_aux(new_proj, None),) if want_stats
                              else ())

            def keep(_):
                out = ((state.proj,) if not self.rotate
                       else (state.proj, eye_rot()))
                return out + ((keep_aux(None),) if want_stats else ())

        if self.update_interval == 1:
            out = refresh(None)
        else:
            do_refresh = (ctx.step % self.update_interval == 1) | (ctx.step == 1)
            out = jax.lax.cond(do_refresh, refresh, keep, None)
        proj_state = out[0]
        stats_aux = out[-1] if want_stats else None

        if fused:
            g_low = out[2 if self.rotate else 1]                # (..., rows, r)
        else:
            g_low = p.project(gf, proj_state, shared_q=q)       # (..., rows, r)

        if self.rotate:
            rot = out[1]
            m_prev = jnp.einsum("...mr,...rs->...ms", state.m, rot)
            v_prev = jnp.abs(jnp.einsum("...mr,...rs->...ms", state.v, rot))
        else:
            m_prev, v_prev = state.m, state.v
        inner = state.inner_step + 1

        m = self.b1 * m_prev + (1.0 - self.b1) * g_low
        v = self.b2 * v_prev + (1.0 - self.b2) * g_low * g_low
        t = inner.astype(jnp.float32)
        mhat = m / (1.0 - self.b1**t)
        vhat = v / (1.0 - self.b2**t)
        u_low = mhat / (jnp.sqrt(vhat) + self.eps)

        if fused:
            if need_resid:
                d, recon = fused_step.fused_dual_backproject(
                    u_low, g_low, q, proj_state, mode=mode,
                    compute_dtype=self.compute_dtype)
                resid = gf - recon
            else:
                d = fused_step.fused_backproject(
                    u_low, q, proj_state, mode=mode,
                    compute_dtype=self.compute_dtype)
        else:
            d = p.backproject(u_low, proj_state, shared_q=q, n=cols)
            if need_resid:
                resid = gf - p.backproject(g_low, proj_state, shared_q=q,
                                           n=cols)

        new_ef = state.ef
        if need_resid:
            if self.residual == "ef":
                new_ef = fused_step.ef_store(resid, self.ef_dtype, mode=mode)
            elif self.residual == "sign":
                d = d + jnp.sign(resid)                         # FRUGAL state-free
            elif self.residual == "fira":
                # sqrt-of-psum'd-square-sums == jnp.linalg.norm when
                # unsharded; under ZeRO the norms span all row shards
                u_n = jnp.sqrt(ctx.psum(
                    jnp.sum(u_low * u_low, axis=(-2, -1), keepdims=True)))
                g_n = jnp.sqrt(ctx.psum(
                    jnp.sum(g_low * g_low, axis=(-2, -1), keepdims=True)))
                d = d + (u_n / (g_n + self.eps)) * resid        # FIRA scaling

        if want_stats:
            # every term is resident already: selected column energies and
            # total energy from the branch aux, and the residual mass from
            # the exact orthogonal split ||Xi||^2 = ||G||^2 - ||g_low||^2 —
            # never a reduction over the materialized residual
            col_e = stats_aux[3]                                # (..., r)
            if col_e is None:      # reference path: reduce the skinny g_low
                col_e = ctx.psum(jnp.sum(g_low * g_low, axis=-2))
            sel_sq = jnp.sum(col_e, axis=-1)
            total_sq = stats_aux[2]
            if self.residual == "ef":
                ef_norm = jnp.sqrt(jnp.maximum(total_sq - sel_sq, 0.0))
            else:
                ef_norm = jnp.zeros(batch, jnp.float32)
            ctx.record_stats(tstats.SubspaceStats(
                captured_energy=tstats.captured_energy(sel_sq, total_sq),
                topr_margin=stats_aux[0],
                index_overlap=stats_aux[1],
                ef_norm=ef_norm,
                rank_utilization=tstats.rank_utilization(col_e)))

        d = deorient(d, transposed)
        return d, ProjAdamLeaf(m=m, v=v, proj=proj_state, ef=new_ef,
                               inner_step=inner)


def _rule(rule_kw) -> ProjectedAdamRule:
    rule_kw.setdefault("needs_shared_basis",
                       is_backend(rule_kw.get("projector")))
    return ProjectedAdamRule(**rule_kw)


def _build(lr, rule_kw, harness_kw) -> Optimizer:
    rule = _rule(rule_kw)
    return matrix_optimizer(rule, lr, b1=rule.b1, b2=rule.b2, eps=rule.eps,
                            **harness_kw)


def projected_adam_transform(rule: ProjectedAdamRule, lr: Schedule, *,
                             weight_decay: float = 0.0,
                             overrides: dict[str, dict] | None = None
                             ) -> GradientTransform:
    """Matrix-leaf projected-Adam pipeline (rule -> -lr -> decay) for use
    inside ``partition`` (e.g. dct-adamw-on-attention + muon-on-mlp)."""
    return chain(lowrank_project(rule, overrides=overrides),
                 scale_by_learning_rate(lr),
                 add_decayed_weights(weight_decay, schedule=lr))


def dct_adamw_transform(lr: Schedule, *, rank: int = 128,
                        update_interval: int = 1, weight_decay: float = 0.01,
                        error_feedback: bool = True, ef_dtype: str = "q8",
                        b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
                        fused: str = "auto", basis: str = "dct",
                        compute_dtype: str = "fp32",
                        overrides: dict | None = None) -> GradientTransform:
    """Matrix-leaf DCT-AdamW pipeline for ``partition``/``inject_hyperparams``.
    ``basis`` swaps the predefined orthogonal basis (any registered
    backend: dct/dst/hadamard/randortho — docs/transforms.md)."""
    rule = _rule(dict(rank=rank, projector=basis,
                      update_interval=update_interval, rotate=True,
                      residual="ef" if error_feedback else "discard",
                      ef_dtype=ef_dtype, b1=b1, b2=b2, eps=eps, fused=fused,
                      compute_dtype=compute_dtype))
    return projected_adam_transform(rule, lr, weight_decay=weight_decay,
                                    overrides=overrides)


def dct_adamw(lr: Schedule, *, rank: int = 128, update_interval: int = 1,
              weight_decay: float = 0.01, error_feedback: bool = True,
              ef_dtype: str = "q8", b1: float = 0.9, b2: float = 0.999,
              eps: float = 1e-8, exact_rotation_matmul: bool = False,
              fused: str = "auto", basis: str = "dct",
              compute_dtype: str = "fp32", basis_mode: str = "stored",
              label_fn=None, overrides: dict | None = None,
              zero=None, lr_scale: bool = False) -> Optimizer:
    """The paper's DCT-AdamW (Algorithm 2). ``fused`` selects the execution
    layer: "auto" | "on" (Pallas kernels) | "fft" (the backend's fast
    transform: Makhoul FFT for dct, FHT for hadamard) | "off" (jnp
    reference) — see core/fused_step.py / DESIGN.md §3.
    ``basis``: the predefined orthogonal basis — any registered
    :class:`~repro.core.transforms.BasisBackend` kind
    (dct/dst/hadamard/randortho); the whole fused/ZeRO/telemetry stack is
    basis-agnostic (DESIGN.md §10).
    ``overrides``: per-leaf-path rule field overrides (e.g. per-layer ranks
    from the adaptive rank allocator, DESIGN.md §8)."""
    if not is_backend(basis):
        from repro.core.transforms import backend_kinds
        raise ValueError(f"unknown basis {basis!r}; registered backends: "
                         f"{backend_kinds()}")
    hk = dict(weight_decay=weight_decay, basis_mode=basis_mode,
              overrides=overrides, zero=zero, lr_scale=lr_scale)
    if label_fn is not None:
        hk["label_fn"] = label_fn
    return _build(lr, dict(rank=rank, projector=basis,
                           update_interval=update_interval, rotate=True,
                           residual="ef" if error_feedback else "discard",
                           ef_dtype=ef_dtype, b1=b1, b2=b2, eps=eps,
                           exact_rotation_matmul=exact_rotation_matmul,
                           fused=fused, compute_dtype=compute_dtype), hk)


def ldadamw(lr: Schedule, *, rank: int = 128, weight_decay: float = 0.01,
            error_feedback: bool = True, b1: float = 0.9, b2: float = 0.999,
            eps: float = 1e-8, fused: str = "auto", label_fn=None,
            overrides: dict | None = None, zero=None,
            lr_scale: bool = False) -> Optimizer:
    """LDAdamW baseline: block power iteration, per-step subspace, rotation
    via real r x r matmul of two stored projection matrices. ``fused``
    covers the EF quantize/dequant kernels (the power projector itself
    keeps the reference math)."""
    hk = dict(weight_decay=weight_decay, overrides=overrides, zero=zero,
              lr_scale=lr_scale)
    if label_fn is not None:
        hk["label_fn"] = label_fn
    return _build(lr, dict(rank=rank, projector="power", update_interval=1,
                           rotate=True,
                           residual="ef" if error_feedback else "discard",
                           ef_dtype="fp32", b1=b1, b2=b2, eps=eps,
                           fused=fused), hk)


def galore(lr: Schedule, *, rank: int = 128, update_interval: int = 200,
           weight_decay: float = 0.01, projector: str = "svd",
           b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
           fused: str = "auto", label_fn=None,
           overrides: dict | None = None, zero=None,
           lr_scale: bool = False) -> Optimizer:
    """GaLore baseline: SVD every T_u steps, residual discarded, no rotation."""
    hk = dict(weight_decay=weight_decay, overrides=overrides, zero=zero,
              lr_scale=lr_scale)
    if label_fn is not None:
        hk["label_fn"] = label_fn
    return _build(lr, dict(rank=rank, projector=projector,
                           update_interval=update_interval, rotate=False,
                           residual="discard", b1=b1, b2=b2, eps=eps,
                           fused=fused), hk)


def frugal(lr: Schedule, *, rank: int = 128, update_interval: int = 200,
           weight_decay: float = 0.01, projector: str = "svd",
           b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
           fused: str = "auto", label_fn=None,
           overrides: dict | None = None, zero=None,
           lr_scale: bool = False) -> Optimizer:
    """FRUGAL baseline: state-full low-rank AdamW + state-free SignSGD on the
    residual. ``projector`` in {svd, random, randperm} or any registered
    basis-backend kind (dct/dst/hadamard/randortho — paper Table 6)."""
    hk = dict(weight_decay=weight_decay, overrides=overrides, zero=zero,
              lr_scale=lr_scale)
    if label_fn is not None:
        hk["label_fn"] = label_fn
    return _build(lr, dict(rank=rank, projector=projector,
                           update_interval=update_interval, rotate=False,
                           residual="sign", b1=b1, b2=b2, eps=eps,
                           fused=fused), hk)


def fira(lr: Schedule, *, rank: int = 128, update_interval: int = 200,
         weight_decay: float = 0.01, projector: str = "svd",
         b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         fused: str = "auto", label_fn=None,
         overrides: dict | None = None, zero=None,
         lr_scale: bool = False) -> Optimizer:
    """FIRA baseline: low-rank AdamW + norm-scaled full-rank residual."""
    hk = dict(weight_decay=weight_decay, overrides=overrides, zero=zero,
              lr_scale=lr_scale)
    if label_fn is not None:
        hk["label_fn"] = label_fn
    return _build(lr, dict(rank=rank, projector=projector,
                           update_interval=update_interval, rotate=False,
                           residual="fira", b1=b1, b2=b2, eps=eps,
                           fused=fused), hk)
