"""Trion (paper Algorithm 1): Dion with the Power-Iteration/QR replaced by
DCT dynamic column selection, and Newton-Schulz run on the *low-rank*
momentum factor.

Per 2D leaf (oriented so the projected dim is last, size C <= R):
    B_t = M_{t-1} + G_t
    S_t = B_t @ D_C                      (DCT-II similarity; matmul or Makhoul)
    i_t = top-r columns of S_t by l1/l2 norm
    b_t = S_t[:, i_t]                    (low-rank momentum, free extraction)
    M_t = B_t - (1-mu) * b_t Q_t^T       (error feedback)
    o_t = NewtonSchulz(b_t)              (r-sized Gram matrices!)
    O_t = o_t Q_t^T
    theta <- (1 - lr*wd) theta - lr * max(1, sqrt(R/C)) * O_t

State per leaf: the momentum M, stored *oriented* (projected dim last) so
ZeRO-1 can row-shard it — *no* per-layer projection matrix (the paper's
memory win vs Dion); indices are recomputed each step and never persisted.

Execution dispatch (``fused`` field, DESIGN.md §3/§14): "on"/"fft" run the
one-pass select+project (selection + b_t from one S pass), the Pallas
Newton-Schulz on the (rows, r) factor, and both back-projections — the EF
reconstruction ``b_t Q_t^T`` and the update ``o_t Q_t^T`` — through ONE
shared ``Q_r^T`` gather (``colgather_matmul_dual``). "off" is the
bit-identical seed path.

ZeRO-1 (``zero_shardable``): trion shards by gather-compute-slice — the
momentum sum ``B`` is all-gathered, every shard runs the identical
whole-matrix step, and each keeps its own rows of ``M_t``/``O_t``. The
cheaper psum'd-column-statistic scheme the projected-Adam family uses is
NOT bitwise safe here: a blockwise psum rounds the ranking statistic
differently (~1 ulp) than the replicated single-pass reduction, and
trion's error feedback *attracts* boundary columns toward ties — each
selected column's energy is damped by (1-mu) while its unselected
neighbour's is not, so the top-r margin shrinks every step until a 1-ulp
difference flips the selection. Gathering ``B`` makes sharded untied
from reduction order and bit-identical to replicated by construction.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from typing import NamedTuple

from repro.core import fused_step
from repro.core.dct import makhoul_dct2
from repro.core.selection import (
    allgather_rows,
    column_norms,
    dynamic_column_selection,
    local_row_block,
    topr_margin,
)
from repro.telemetry import stats as tstats

from .common import (
    MatrixRule,
    Optimizer,
    Schedule,
    deorient,
    orient_right,
    oriented_dims,
)
from .transform import (
    GradientTransform,
    add_decayed_weights,
    chain,
    lowrank_project,
    matrix_optimizer,
    scale_by_learning_rate,
)

_RANKING_NORMS = ("l1", "l2")
_DCT_METHODS = ("matmul", "fft")


class TrionLeaf(NamedTuple):
    m: jax.Array  # full-size momentum, stored oriented


@dataclasses.dataclass(frozen=True)
class TrionRule(MatrixRule):
    rank: int = 128
    mu: float = 0.95
    ns_steps: int = 5
    ranking_norm: str = "l2"
    dct_method: str = "matmul"       # "matmul" (TPU/MXU) | "fft" (Makhoul)
    momentum_dtype: str = "float32"
    needs_shared_basis: bool = True
    fused: str = "auto"              # fused-step dispatch (DESIGN.md §3):
    #   "auto" (kernels on TPU, reference elsewhere) | "on" (Pallas kernels,
    #   interpret off-TPU) | "fft" (Makhoul host fast path) | "off" (seed jnp)
    emit_stats: bool = True

    def __post_init__(self):
        if self.ranking_norm not in _RANKING_NORMS:
            raise ValueError(
                f"unknown ranking_norm {self.ranking_norm!r}; allowed: "
                f"{_RANKING_NORMS}")
        if self.dct_method not in _DCT_METHODS:
            raise ValueError(
                f"unknown dct_method {self.dct_method!r}; allowed: "
                f"{_DCT_METHODS}")
        if self.fused not in fused_step.FUSED_MODES:
            raise ValueError(
                f"unknown fused mode {self.fused!r}; allowed: "
                f"{fused_step.FUSED_MODES}")
        if isinstance(self.rank, int) and self.rank < 1:
            raise ValueError(f"rank must be >= 1, got {self.rank}")

    @property
    def zero_shardable(self) -> bool:
        """Row-shardable by gather-compute-slice (see module docstring:
        the EF tie-attractor rules out the psum'd-statistic scheme);
        sharded is bitwise replicated by construction. DESIGN.md §14."""
        return True

    def init(self, shape, dtype):
        *batch, _, _ = shape
        rows, cols = oriented_dims(shape)
        return TrionLeaf(m=jnp.zeros((*batch, rows, cols),
                                     jnp.dtype(self.momentum_dtype)))

    def update(self, g, state, param, ctx):
        if ctx.oriented:        # ZeRO row block: already right-oriented
            gf, transposed = g.astype(jnp.float32), False
        else:
            gf, transposed = orient_right(g.astype(jnp.float32))
        mf = state.m.astype(jnp.float32)     # stored oriented already
        cols = gf.shape[-1]
        r = min(self.rank, cols)
        # global-shape scale: inside a ZeRO shard_map the local block's
        # aspect ratio is shard-dependent, param is replicated
        g_rows, g_cols = oriented_dims(param.shape)
        scale = max(1.0, (g_rows / g_cols) ** 0.5)
        mode = fused_step.resolve(self.fused)
        want_stats = ctx.wants_stats and self.emit_stats

        # ZeRO gather-compute-slice: reassemble the global momentum sum,
        # run the identical whole-matrix step per shard (identity when
        # replicated), keep local rows of M_t / O_t at the end
        block = gf.shape[-2]
        b_full = allgather_rows(mf + gf, ctx.axis)         # B_t
        q = ctx.basis(cols, jnp.float32)
        if mode != "off":
            sp = fused_step.select_and_project(
                b_full, q, r, norm=self.ranking_norm, mode=mode,
                return_norms=want_stats)
            idx, b = sp[0], sp[1]
            norms_sq = sp[2] if want_stats else None
        else:
            if self.dct_method == "fft":
                s = makhoul_dct2(b_full)
            else:
                s = b_full @ q
            idx, b = dynamic_column_selection(s, r, ord=self.ranking_norm)
            norms_sq = column_norms(s, "l2") if want_stats else None

        o = fused_step.fused_newton_schulz(b, steps=self.ns_steps, mode=mode)
        # both back-projections — EF reconstruction b_t Q_t^T and update
        # o_t Q_t^T — share one Q_r^T gather
        out, low_rank_part = fused_step.fused_dual_backproject(
            o, b, q, idx, mode=mode)
        new_m = b_full - (1.0 - self.mu) * low_rank_part   # Alg.1 line 10
        new_m = local_row_block(new_m, ctx.axis, block)
        out = local_row_block(out, ctx.axis, block)

        if want_stats:
            col_e = jnp.take_along_axis(norms_sq, idx, axis=-1)
            sel_sq = jnp.sum(col_e, axis=-1)
            total_sq = jnp.sum(jax.lax.optimization_barrier(norms_sq),
                               axis=-1)
            batch = b_full.shape[:-2]
            ctx.record_stats(tstats.SubspaceStats(
                captured_energy=tstats.captured_energy(sel_sq, total_sq),
                topr_margin=topr_margin(norms_sq, r),
                index_overlap=-jnp.ones(batch, jnp.float32),
                ef_norm=jnp.sqrt(jnp.maximum(total_sq - sel_sq, 0.0)),
                rank_utilization=tstats.rank_utilization(col_e)))

        d = deorient(scale * out, transposed)
        return d, TrionLeaf(m=new_m.astype(state.m.dtype))


def trion_transform(lr: Schedule, *, rank: int = 128, mu: float = 0.95,
                    weight_decay: float = 0.01, ns_steps: int = 5,
                    ranking_norm: str = "l2", dct_method: str = "matmul",
                    momentum_dtype: str = "float32",
                    fused: str = "auto") -> GradientTransform:
    """Matrix-leaf Trion pipeline for ``partition`` / ``inject_hyperparams``."""
    rule = TrionRule(rank=rank, mu=mu, ns_steps=ns_steps,
                     ranking_norm=ranking_norm, dct_method=dct_method,
                     momentum_dtype=momentum_dtype, fused=fused)
    return chain(lowrank_project(rule), scale_by_learning_rate(lr),
                 add_decayed_weights(weight_decay, schedule=lr))


def trion(lr: Schedule, *, rank: int = 128, mu: float = 0.95,
          weight_decay: float = 0.01, ns_steps: int = 5,
          ranking_norm: str = "l2", dct_method: str = "matmul",
          momentum_dtype: str = "float32", basis_mode: str = "stored",
          fused: str = "auto", b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, label_fn=None, zero=None,
          lr_scale: bool = False) -> Optimizer:
    rule = TrionRule(rank=rank, mu=mu, ns_steps=ns_steps,
                     ranking_norm=ranking_norm, dct_method=dct_method,
                     momentum_dtype=momentum_dtype, fused=fused)
    kw = dict(weight_decay=weight_decay, basis_mode=basis_mode,
              b1=b1, b2=b2, eps=eps, zero=zero, lr_scale=lr_scale)
    if label_fn is not None:
        kw["label_fn"] = label_fn
    return matrix_optimizer(rule, lr, **kw)
