"""Trion (paper Algorithm 1): Dion with the Power-Iteration/QR replaced by
DCT dynamic column selection, and Newton-Schulz run on the *low-rank*
momentum factor.

Per 2D leaf (oriented so the projected dim is last, size C <= R):
    B_t = M_{t-1} + G_t
    S_t = B_t @ D_C                      (DCT-II similarity; matmul or Makhoul)
    i_t = top-r columns of S_t by l1/l2 norm
    b_t = S_t[:, i_t]                    (low-rank momentum, free extraction)
    M_t = B_t - (1-mu) * b_t Q_t^T       (error feedback)
    o_t = NewtonSchulz(b_t)              (r-sized Gram matrices!)
    O_t = o_t Q_t^T
    theta <- (1 - lr*wd) theta - lr * max(1, sqrt(R/C)) * O_t

State per leaf: the momentum M (same shape as the param) — *no* per-layer
projection matrix (the paper's memory win vs Dion); indices are recomputed
each step and never persisted.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from typing import NamedTuple

from repro.core.dct import makhoul_dct2
from repro.core.newton_schulz import newton_schulz
from repro.core.selection import back_project, dynamic_column_selection

from .common import MatrixRule, Optimizer, Schedule, deorient, orient_right
from .transform import (
    GradientTransform,
    add_decayed_weights,
    chain,
    lowrank_project,
    matrix_optimizer,
    scale_by_learning_rate,
)

_RANKING_NORMS = ("l1", "l2")
_DCT_METHODS = ("matmul", "fft")


class TrionLeaf(NamedTuple):
    m: jax.Array  # full-size momentum


@dataclasses.dataclass(frozen=True)
class TrionRule(MatrixRule):
    rank: int = 128
    mu: float = 0.95
    ns_steps: int = 5
    ranking_norm: str = "l2"
    dct_method: str = "matmul"       # "matmul" (TPU/MXU) | "fft" (Makhoul)
    momentum_dtype: str = "float32"
    needs_shared_basis: bool = True

    def __post_init__(self):
        if self.ranking_norm not in _RANKING_NORMS:
            raise ValueError(
                f"unknown ranking_norm {self.ranking_norm!r}; allowed: "
                f"{_RANKING_NORMS}")
        if self.dct_method not in _DCT_METHODS:
            raise ValueError(
                f"unknown dct_method {self.dct_method!r}; allowed: "
                f"{_DCT_METHODS}")
        if isinstance(self.rank, int) and self.rank < 1:
            raise ValueError(f"rank must be >= 1, got {self.rank}")

    def init(self, shape, dtype):
        return TrionLeaf(m=jnp.zeros(shape, jnp.dtype(self.momentum_dtype)))

    def update(self, g, state, param, ctx):
        gf, transposed = orient_right(g.astype(jnp.float32))
        mf, _ = orient_right(state.m.astype(jnp.float32))
        rows, cols = gf.shape[-2], gf.shape[-1]
        r = min(self.rank, cols)

        b_full = mf + gf                                   # B_t
        q = ctx.basis(cols, jnp.float32)
        if self.dct_method == "fft":
            s = makhoul_dct2(b_full)
        else:
            s = b_full @ q
        idx, b = dynamic_column_selection(s, r, ord=self.ranking_norm)
        low_rank_part = back_project(b, q, idx)            # b_t Q_t^T
        new_m = b_full - (1.0 - self.mu) * low_rank_part   # Alg.1 line 10
        o = newton_schulz(b, steps=self.ns_steps)          # on R x r factor
        out = back_project(o, q, idx)                      # O_t
        scale = max(1.0, (rows / cols) ** 0.5)
        d = deorient(scale * out, transposed)
        new_m = deorient(new_m, transposed).astype(state.m.dtype)
        return d, TrionLeaf(m=new_m)


def trion_transform(lr: Schedule, *, rank: int = 128, mu: float = 0.95,
                    weight_decay: float = 0.01, ns_steps: int = 5,
                    ranking_norm: str = "l2", dct_method: str = "matmul",
                    momentum_dtype: str = "float32") -> GradientTransform:
    """Matrix-leaf Trion pipeline for ``partition`` / ``inject_hyperparams``."""
    rule = TrionRule(rank=rank, mu=mu, ns_steps=ns_steps,
                     ranking_norm=ranking_norm, dct_method=dct_method,
                     momentum_dtype=momentum_dtype)
    return chain(lowrank_project(rule), scale_by_learning_rate(lr),
                 add_decayed_weights(weight_decay, schedule=lr))


def trion(lr: Schedule, *, rank: int = 128, mu: float = 0.95,
          weight_decay: float = 0.01, ns_steps: int = 5,
          ranking_norm: str = "l2", dct_method: str = "matmul",
          momentum_dtype: str = "float32", basis_mode: str = "stored",
          b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          label_fn=None, lr_scale: bool = False) -> Optimizer:
    rule = TrionRule(rank=rank, mu=mu, ns_steps=ns_steps,
                     ranking_norm=ranking_norm, dct_method=dct_method,
                     momentum_dtype=momentum_dtype)
    kw = dict(weight_decay=weight_decay, basis_mode=basis_mode,
              b1=b1, b2=b2, eps=eps, lr_scale=lr_scale)
    if label_fn is not None:
        kw["label_fn"] = label_fn
    return matrix_optimizer(rule, lr, **kw)
