"""Dion baseline (Ahn et al., 2025): low-rank orthonormal updates via
amortized Power-Iteration + QR (the method Trion replaces).

Per 2D leaf (oriented, C <= R):
    B_t = M_{t-1} + G_t
    P_t = QR(B_t @ Q_{t-1}).Q           (power-iteration step, R x r)
    R_t = B_t^T P_t                      (C x r)
    M_t = B_t - (1-mu) P_t R_t^T         (error feedback)
    Q_t = column-normalize(R_t)          (next iteration's basis)
    O_t = P_t Q_t^T
    theta <- (1 - lr*wd) theta - lr * max(1, sqrt(R/C)) * O_t

State per leaf: momentum M (stored *oriented*, projected dim last, so
ZeRO-1 can row-shard it) *plus* a per-layer projection matrix Q (C x r) —
exactly the extra memory (and rank-dependent QR runtime) the paper removes.

``fused`` swaps the power-iteration orthonormalization: "off" keeps the
seed QR; "on"/"fft" orthonormalize ``B Q`` by Newton-Schulz on the
(rows, r) factor instead (SUMO's NS-for-QR substitution — PAPERS.md),
which reaches the Pallas r-sized-Gram kernel on the "on" path. Both
factors span the same subspace; NS returns the polar factor rather than
QR's Q, orthonormal to the kernel's polynomial tolerance.

ZeRO-1: ``R_t = B^T P`` contracts over the *row* dim, so unlike the
selection families no psum'd column statistic suffices — the momentum sum
``B`` is all-gathered, every shard runs the identical full computation,
and each keeps its own rows of ``M_t``/``O_t`` (``Q_t`` comes out
replicated, and stays so in the placement rules). Sharded updates are
bit-identical to replicated.

Telemetry: with a collector installed the rule emits ``SubspaceStats``
like muon/trion — captured energy of span(P_t) from the ``R_t`` column
norms, EF mass from ``M_t`` — with the ranking-specific fields (top-r
margin, index overlap) at their -1 sentinel since Dion never ranks the
full column set.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import fused_step
from repro.core.selection import allgather_rows, local_row_block
from repro.telemetry import stats as tstats

from .common import (
    MatrixRule,
    Optimizer,
    Schedule,
    deorient,
    orient_right,
    oriented_dims,
)
from .transform import (
    GradientTransform,
    add_decayed_weights,
    chain,
    lowrank_project,
    matrix_optimizer,
    scale_by_learning_rate,
)


class DionLeaf(NamedTuple):
    m: jax.Array  # full-size momentum, stored oriented
    q: jax.Array  # per-layer projection basis (C, r) — Dion's memory cost


@dataclasses.dataclass(frozen=True)
class DionRule(MatrixRule):
    rank: int = 128
    mu: float = 0.95
    eps: float = 1e-8
    ns_steps: int = 5
    needs_shared_basis: bool = False
    fused: str = "auto"   # "off"/"auto"-off-TPU: seed QR; "on"/"fft": NS
    emit_stats: bool = True  # SubspaceStats from the R_t factor when a
    #   telemetry collector is installed (captured energy of span(P_t))

    def __post_init__(self):
        if self.fused not in fused_step.FUSED_MODES:
            raise ValueError(
                f"unknown fused mode {self.fused!r}; allowed: "
                f"{fused_step.FUSED_MODES}")
        if isinstance(self.rank, int) and self.rank < 1:
            raise ValueError(f"rank must be >= 1, got {self.rank}")

    @property
    def zero_shardable(self) -> bool:
        """Row-shardable by gather-compute-slice: the whole step is
        recomputed identically per shard from the all-gathered momentum
        sum, which keeps sharded bitwise equal to replicated while still
        cutting persistent optimizer bytes by (N-1)/N (DESIGN.md §14)."""
        return True

    def init(self, shape, dtype):
        *batch, _, _ = shape
        rows, cols = oriented_dims(shape)
        r = min(self.rank, cols)
        eye = jnp.eye(cols, r, dtype=jnp.float32)
        return DionLeaf(
            m=jnp.zeros((*batch, rows, cols), jnp.float32),
            q=jnp.broadcast_to(eye, (*batch, cols, r)),
        )

    def update(self, g, state, param, ctx):
        if ctx.oriented:        # ZeRO row block: already right-oriented
            gf, transposed = g.astype(jnp.float32), False
        else:
            gf, transposed = orient_right(g.astype(jnp.float32))
        g_rows, g_cols = oriented_dims(param.shape)
        scale = max(1.0, (g_rows / g_cols) ** 0.5)
        mode = fused_step.resolve(self.fused)
        want_stats = ctx.wants_stats and self.emit_stats
        block = gf.shape[-2]

        # gather -> identical full-row compute per shard -> slice local rows
        b_full = allgather_rows(gf + state.m, ctx.axis)
        z = jnp.einsum("...mc,...cr->...mr", b_full, state.q)
        if mode == "off":
            p, _ = jnp.linalg.qr(z)                          # R x r orthonormal
        else:
            # SUMO-style: Newton-Schulz polar factor instead of QR —
            # same column span, r-sized Gram matrices, Pallas on "on"
            p = fused_step.fused_newton_schulz(z, steps=self.ns_steps,
                                               mode=mode)
        r_t = jnp.einsum("...mc,...mr->...cr", b_full, p)
        new_m = b_full - (1.0 - self.mu) * jnp.einsum(
            "...mr,...cr->...mc", p, r_t)
        col_norm = jnp.linalg.norm(r_t, axis=-2, keepdims=True)
        q_t = r_t / (col_norm + self.eps)
        out = jnp.einsum("...mr,...cr->...mc", p, q_t)       # O_t

        if want_stats:
            # P_t orthonormal => energy captured by span(P_t) is
            # ||P^T B||_F^2 = ||R_t||_F^2; per-column energies of R_t play
            # the role the selected column norms play for muon/trion. All
            # terms derive from the gathered full matrices, so sharded
            # telemetry matches replicated. Dion ranks nothing over the n
            # columns, so margin/overlap stay at the -1 sentinel.
            col_e = jnp.sum(r_t * r_t, axis=-2)
            sel_sq = jnp.sum(col_e, axis=-1)
            total_sq = jnp.sum(b_full * b_full, axis=(-2, -1))
            batch = b_full.shape[:-2]
            ctx.record_stats(tstats.SubspaceStats(
                captured_energy=tstats.captured_energy(sel_sq, total_sq),
                topr_margin=-jnp.ones(batch, jnp.float32),
                index_overlap=-jnp.ones(batch, jnp.float32),
                ef_norm=jnp.linalg.norm(new_m, axis=(-2, -1)),
                rank_utilization=tstats.rank_utilization(col_e)))

        new_m = local_row_block(new_m, ctx.axis, block)
        out = local_row_block(out, ctx.axis, block)
        d = deorient(scale * out, transposed)
        return d, DionLeaf(m=new_m, q=q_t)


def dion_transform(lr: Schedule, *, rank: int = 128, mu: float = 0.95,
                   weight_decay: float = 0.01, ns_steps: int = 5,
                   fused: str = "auto") -> GradientTransform:
    """Matrix-leaf Dion pipeline for ``partition`` / ``inject_hyperparams``."""
    rule = DionRule(rank=rank, mu=mu, ns_steps=ns_steps, fused=fused)
    return chain(lowrank_project(rule), scale_by_learning_rate(lr),
                 add_decayed_weights(weight_decay, schedule=lr))


def dion(lr: Schedule, *, rank: int = 128, mu: float = 0.95,
         weight_decay: float = 0.01, ns_steps: int = 5, fused: str = "auto",
         b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8, label_fn=None,
         zero=None, lr_scale: bool = False) -> Optimizer:
    rule = DionRule(rank=rank, mu=mu, ns_steps=ns_steps, fused=fused)
    kw = dict(weight_decay=weight_decay, b1=b1, b2=b2, eps=eps, zero=zero,
              lr_scale=lr_scale)
    if label_fn is not None:
        kw["label_fn"] = label_fn
    return matrix_optimizer(rule, lr, **kw)
