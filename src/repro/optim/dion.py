"""Dion baseline (Ahn et al., 2025): low-rank orthonormal updates via
amortized Power-Iteration + QR (the method Trion replaces).

Per 2D leaf (oriented, C <= R):
    B_t = M_{t-1} + G_t
    P_t = QR(B_t @ Q_{t-1}).Q           (power-iteration step, R x r)
    R_t = B_t^T P_t                      (C x r)
    M_t = B_t - (1-mu) P_t R_t^T         (error feedback)
    Q_t = column-normalize(R_t)          (next iteration's basis)
    O_t = P_t Q_t^T
    theta <- (1 - lr*wd) theta - lr * max(1, sqrt(R/C)) * O_t

State per leaf: momentum M *plus* a per-layer projection matrix Q (C x r) —
exactly the extra memory (and rank-dependent QR runtime) the paper removes.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .common import MatrixRule, Optimizer, Schedule, deorient, orient_right
from .transform import (
    GradientTransform,
    add_decayed_weights,
    chain,
    lowrank_project,
    matrix_optimizer,
    scale_by_learning_rate,
)


class DionLeaf(NamedTuple):
    m: jax.Array  # full-size momentum
    q: jax.Array  # per-layer projection basis (C, r) — Dion's memory cost


@dataclasses.dataclass(frozen=True)
class DionRule(MatrixRule):
    rank: int = 128
    mu: float = 0.95
    eps: float = 1e-8
    needs_shared_basis: bool = False

    def init(self, shape, dtype):
        *batch, m, n = shape
        rows, cols = (m, n) if n <= m else (n, m)
        r = min(self.rank, cols)
        eye = jnp.eye(cols, r, dtype=jnp.float32)
        return DionLeaf(
            m=jnp.zeros(shape, jnp.float32),
            q=jnp.broadcast_to(eye, (*batch, cols, r)),
        )

    def update(self, g, state, param, ctx):
        gf, transposed = orient_right(g.astype(jnp.float32))
        mf, _ = orient_right(state.m)
        rows, cols = gf.shape[-2], gf.shape[-1]

        b_full = mf + gf
        z = jnp.einsum("...mc,...cr->...mr", b_full, state.q)
        p, _ = jnp.linalg.qr(z)                              # R x r orthonormal
        r_t = jnp.einsum("...mc,...mr->...cr", b_full, p)
        new_m = b_full - (1.0 - self.mu) * jnp.einsum(
            "...mr,...cr->...mc", p, r_t)
        col_norm = jnp.linalg.norm(r_t, axis=-2, keepdims=True)
        q_t = r_t / (col_norm + self.eps)
        out = jnp.einsum("...mr,...cr->...mc", p, q_t)       # O_t
        scale = max(1.0, (rows / cols) ** 0.5)
        d = deorient(scale * out, transposed)
        return d, DionLeaf(m=deorient(new_m, transposed), q=q_t)


def dion_transform(lr: Schedule, *, rank: int = 128, mu: float = 0.95,
                   weight_decay: float = 0.01) -> GradientTransform:
    """Matrix-leaf Dion pipeline for ``partition`` / ``inject_hyperparams``."""
    rule = DionRule(rank=rank, mu=mu)
    return chain(lowrank_project(rule), scale_by_learning_rate(lr),
                 add_decayed_weights(weight_decay, schedule=lr))


def dion(lr: Schedule, *, rank: int = 128, mu: float = 0.95,
         weight_decay: float = 0.01, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, label_fn=None,
         lr_scale: bool = False) -> Optimizer:
    rule = DionRule(rank=rank, mu=mu)
    kw = dict(weight_decay=weight_decay, b1=b1, b2=b2, eps=eps,
              lr_scale=lr_scale)
    if label_fn is not None:
        kw["label_fn"] = label_fn
    return matrix_optimizer(rule, lr, **kw)
