"""Low-rank adaptive optimizers: the paper's Trion & DCT-AdamW plus every
baseline it compares against (Dion, Muon, GaLore, LDAdamW, FRUGAL, FIRA,
full-rank AdamW)."""
from .adamw import adamw
from .api import OPTIMIZERS, get_optimizer
from .common import Optimizer, apply_updates
from .dion import dion
from .muon import muon
from .projected_adam import dct_adamw, fira, frugal, galore, ldadamw
from .trion import trion

__all__ = [
    "OPTIMIZERS", "get_optimizer", "Optimizer", "apply_updates",
    "adamw", "muon", "dion", "trion", "dct_adamw", "ldadamw",
    "galore", "frugal", "fira",
]
