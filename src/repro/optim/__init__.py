"""Low-rank adaptive optimizers: the paper's Trion & DCT-AdamW plus every
baseline it compares against (Dion, Muon, GaLore, LDAdamW, FRUGAL, FIRA,
full-rank AdamW), built from the composable gradient-transform API
(``transform.chain`` / ``partition`` / ``inject_hyperparams``)."""
from .adamw import adamw, adamw_transform
from .api import OPTIMIZERS, TRANSFORMS, get_optimizer, get_transform
from .common import Optimizer, apply_updates
from .dion import dion, dion_transform
from .muon import muon, muon_transform
from .projected_adam import dct_adamw, dct_adamw_transform, fira, frugal, galore, ldadamw
from .transform import (
    ChainState,
    GradientTransform,
    add_decayed_weights,
    as_optimizer,
    chain,
    clip_global_norm,
    inject_hyperparams,
    lowrank_project,
    matrix_optimizer,
    partition,
    scale_by_adam,
    scale_by_learning_rate,
    scale_by_schedule,
)
from .trion import trion, trion_transform

__all__ = [
    "OPTIMIZERS", "TRANSFORMS", "get_optimizer", "get_transform",
    "Optimizer", "apply_updates",
    "adamw", "muon", "dion", "trion", "dct_adamw", "ldadamw",
    "galore", "frugal", "fira",
    "adamw_transform", "muon_transform", "dion_transform", "trion_transform",
    "dct_adamw_transform",
    "GradientTransform", "ChainState", "chain", "partition",
    "inject_hyperparams", "as_optimizer", "matrix_optimizer",
    "lowrank_project", "scale_by_adam", "scale_by_learning_rate",
    "scale_by_schedule", "add_decayed_weights", "clip_global_norm",
]
