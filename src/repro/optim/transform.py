"""Composable gradient-transform optimizer API (DESIGN.md §4).

The paper's thesis is that the projector is a *swappable component* inside
an otherwise identical low-rank Adam.  This module makes the whole
optimizer swappable, optax-style: a ``GradientTransform`` is an
``(init, update)`` pair with the stable signature

    init(params)                      -> state
    update(updates, state, params, ctx) -> (updates, state)

where ``ctx`` is the harness :class:`~repro.optim.common.Context` (global
step, shared DCT bases, PRNG key) threaded by the chain runtime — any
transform in the stack can request a basis via ``ctx.basis(n)``.

Combinators
-----------
- ``chain(*transforms)``          — sequential composition
- ``partition(by_label, label_fn)`` — route leaves to different transforms
  by an arbitrary label set (generalizes the old lowrank/full split: per
  group ranks, dct-adamw-on-attention + muon-on-mlp, …)
- ``inject_hyperparams(factory)`` — float hyperparameters (lr/wd/b1/b2/…)
  become state leaves updatable at runtime, no retrace

Primitives
----------
``clip_global_norm``, ``scale_by_schedule``, ``scale_by_learning_rate``,
``add_decayed_weights``, ``scale_by_adam`` (full-rank Adam direction) and
``lowrank_project(rule)`` which lifts any per-matrix-leaf
:class:`~repro.optim.common.MatrixRule` (``ProjectedAdamRule``, ``TrionRule``,
…, including the fused Pallas path) to a whole-tree transform.

``as_optimizer(transform)`` closes a transform into the legacy
``Optimizer(init, update)`` interface: it owns the step counter, the PRNG
key and the shared-basis store, and emits a :class:`ChainState` whose
field names (``step``/``key``/``bases``/``leaves``) match the old
``HarnessState`` so state-walking consumers keep working.

Per-leaf PRNG keys are derived from a *stable hash of the tree path*
(``fold_in(fold_in(key, step), crc32(path))``), not flat enumeration order
— adding or removing a parameter leaves every other leaf's randomness
unchanged.
"""
from __future__ import annotations

import dataclasses
import inspect
import zlib
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.transforms import (
    basis_store_key,
    normalize_basis_request,
    shared_basis,
)

from .common import (
    AdamMoments,
    Context,
    FullAdamLeaf,
    MatrixRule,
    Optimizer,
    Schedule,
    adam_update,
    default_label_fn,
    labelled_tree,
    path_str,
    sched_value,
)


class GradientTransform(NamedTuple):
    """Composable optimizer building block.

    ``basis_sizes(params)`` declares which shared predefined bases the
    transform needs — ``(kind, n)`` pairs, or bare orders ``n`` (legacy
    spelling for the DCT basis); the chain runtime (``as_optimizer``)
    collects the union over the whole stack and stores one ``(n, n)``
    basis matrix per distinct request in the optimizer state
    (``basis_mode="stored"``), served from the process-wide
    :class:`~repro.core.transforms.BasisCache`.
    """

    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, Context], tuple[Any, Any]]
    basis_sizes: Callable[[Any], set] = lambda params: set()


class EmptyState(NamedTuple):
    """State of a stateless transform (jit-stable placeholder)."""


class MaskedNode:
    """Placeholder for leaves hidden from a partitioned sub-transform.

    Registered as a pytree node with zero leaves, so ``jax.tree.map`` (and
    flatten/unflatten, checkpoint path-flattening, donation) simply skips
    the masked positions — sub-transforms need no masking awareness.
    """

    def __repr__(self):
        return "MaskedNode"

    def __eq__(self, other):
        return isinstance(other, MaskedNode)

    def __hash__(self):
        return hash(MaskedNode)


jax.tree_util.register_pytree_node(
    MaskedNode, lambda _: ((), None), lambda *_: MaskedNode()
)

MASKED = MaskedNode()

_is_str = lambda x: isinstance(x, str)  # noqa: E731


def path_hash(path: str) -> int:
    """Stable 31-bit hash of a tree path ('block/0/wq') — the per-leaf PRNG
    fold constant. crc32 is deterministic across processes and jax versions
    (unlike Python's salted ``hash``)."""
    return zlib.crc32(path.encode("utf-8")) & 0x7FFFFFFF


def leaf_key(key: jax.Array | None, path: str) -> jax.Array | None:
    """Per-leaf PRNG key: fold a stable path hash into the step key."""
    if key is None:
        return None
    return jax.random.fold_in(key, path_hash(path))


# ---------------------------------------------------------------------------
# chain
# ---------------------------------------------------------------------------
def chain(*transforms: GradientTransform) -> GradientTransform:
    """Apply ``transforms`` in sequence; state is the tuple of member states."""

    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(updates, state, params, ctx):
        new_state = []
        for t, s in zip(transforms, state):
            updates, s = t.update(updates, s, params, ctx)
            new_state.append(s)
        return updates, tuple(new_state)

    def basis_sizes(params):
        sizes = set()
        for t in transforms:
            sizes |= t.basis_sizes(params)
        return sizes

    return GradientTransform(init, update, basis_sizes)


# ---------------------------------------------------------------------------
# partition
# ---------------------------------------------------------------------------
def _mask(labels, tree, label):
    """Replace subtrees whose label != ``label`` with MASKED."""
    return jax.tree.map(lambda lbl, sub: sub if lbl == label else MASKED,
                        labels, tree, is_leaf=_is_str)


def merge_by_label(labels, by_label: dict):
    """Inverse of ``_mask``: combine per-label trees (with MASKED holes)
    into one tree, taking each leaf position from its own label's tree."""
    order = list(by_label)
    return jax.tree.map(
        lambda lbl, *subs: subs[order.index(lbl)],
        labels, *(by_label[k] for k in order), is_leaf=_is_str,
    )


def partition(
    transforms: dict[str, GradientTransform],
    label_fn=default_label_fn,
) -> GradientTransform:
    """Route each parameter leaf to the transform of its label.

    ``label_fn(path, leaf) -> str`` may return any label in ``transforms``
    — not just the classic ``lowrank``/``full`` pair: per-group ranks,
    per-module rules (dct-adamw on attention + muon on mlp), frozen
    groups, etc. An unknown label raises eagerly at ``init``.
    """

    def _labels(params):
        labels = labelled_tree(params, label_fn)
        seen = {l for l in jax.tree.leaves(labels, is_leaf=_is_str)}
        unknown = seen - set(transforms)
        if unknown:
            raise ValueError(
                f"label_fn produced labels {sorted(unknown)} with no "
                f"transform; have {sorted(transforms)}")
        return labels

    def init(params):
        labels = _labels(params)
        return {lbl: t.init(_mask(labels, params, lbl))
                for lbl, t in transforms.items()}

    def update(updates, state, params, ctx):
        labels = _labels(params)
        outs, new_state = {}, {}
        for lbl, t in transforms.items():
            u, s = t.update(_mask(labels, updates, lbl), state[lbl],
                            _mask(labels, params, lbl), ctx)
            outs[lbl] = u
            new_state[lbl] = s
        return merge_by_label(labels, outs), new_state

    def basis_sizes(params):
        labels = _labels(params)
        sizes = set()
        for lbl, t in transforms.items():
            sizes |= t.basis_sizes(_mask(labels, params, lbl))
        return sizes

    return GradientTransform(init, update, basis_sizes)


# ---------------------------------------------------------------------------
# inject_hyperparams
# ---------------------------------------------------------------------------
class InjectHyperparamsState(NamedTuple):
    hyperparams: dict[str, jax.Array]
    inner: Any


def inject_hyperparams(factory: Callable[..., GradientTransform],
                       *, static_args: tuple[str, ...] = ()):
    """Make a transform factory's float hyperparameters runtime-updatable.

    ``inject_hyperparams(adamw_transform)(lr=1e-3, weight_decay=0.1)``
    returns a transform whose state carries ``{"lr": …, "weight_decay": …}``
    as fp32 scalars; overwriting them between steps (LR surgery, schedule
    sweeps) changes the next update *without retracing* — the transform is
    rebuilt inside the traced update from the state leaves.

    Python floats are injected; ints, bools, strings, callables
    (schedules), rules and anything named in ``static_args`` stay static.
    """
    sig = inspect.signature(factory)

    def wrapped(*args, **kwargs) -> GradientTransform:
        bound = sig.bind(*args, **kwargs)
        bound.apply_defaults()
        hyper: dict[str, float] = {}
        static: dict[str, Any] = {}
        for name, val in bound.arguments.items():
            kind = sig.parameters[name].kind
            if kind == inspect.Parameter.VAR_KEYWORD:
                for k, v in val.items():
                    if k not in static_args and isinstance(v, float) \
                            and not isinstance(v, bool):
                        hyper[k] = v
                    else:
                        static[k] = v
            elif name not in static_args and isinstance(val, float) \
                    and not isinstance(val, bool):
                hyper[name] = val
            else:
                static[name] = val

        def make(hp):
            return factory(**static, **hp)

        def init(params):
            return InjectHyperparamsState(
                hyperparams={k: jnp.asarray(v, jnp.float32)
                             for k, v in hyper.items()},
                inner=make(hyper).init(params))

        def update(updates, state, params, ctx):
            t = make({k: state.hyperparams[k] for k in hyper})
            updates, inner = t.update(updates, state.inner, params, ctx)
            return updates, InjectHyperparamsState(dict(state.hyperparams),
                                                   inner)

        def basis_sizes(params):
            return make(hyper).basis_sizes(params)

        return GradientTransform(init, update, basis_sizes)

    return wrapped


# ---------------------------------------------------------------------------
# primitive transforms
# ---------------------------------------------------------------------------
def stateless(update_fn) -> GradientTransform:
    """Lift ``update_fn(updates, params, ctx) -> updates`` to a transform."""
    return GradientTransform(
        init=lambda params: EmptyState(),
        update=lambda u, s, p, ctx: (update_fn(u, p, ctx), s),
    )


def clip_global_norm(max_norm: float) -> GradientTransform:
    """Scale updates so their global l2 norm is at most ``max_norm``."""

    def upd(updates, params, ctx):
        norm = jnp.sqrt(sum(jnp.sum(jnp.square(u.astype(jnp.float32)))
                            for u in jax.tree.leaves(updates)))
        scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
        return jax.tree.map(lambda u: u * scale, updates)

    return stateless(upd)


def scale_by_schedule(step_size_fn: Schedule) -> GradientTransform:
    """Multiply updates by ``step_size_fn(step)`` (or a constant)."""

    def upd(updates, params, ctx):
        s = sched_value(step_size_fn, ctx.step)
        return jax.tree.map(lambda u: s * u, updates)

    return stateless(upd)


def scale_by_learning_rate(lr: Schedule) -> GradientTransform:
    """Descent scaling ``u -> -lr_t * u`` (fp32), the harness convention."""

    def upd(updates, params, ctx):
        lr_t = sched_value(lr, ctx.step)
        return jax.tree.map(lambda u: -lr_t * u.astype(jnp.float32), updates)

    return stateless(upd)


def add_decayed_weights(weight_decay: float, *,
                        schedule: Schedule | None = None) -> GradientTransform:
    """Decoupled weight decay.

    Without ``schedule``: ``u + wd * p`` (optax convention — place *before*
    the lr scaling). With ``schedule``: ``u - lr_t * wd * p`` (place *after*
    ``scale_by_learning_rate``; bit-for-bit the matrix harness's decay).
    """

    def upd(updates, params, ctx):
        if schedule is None:
            return jax.tree.map(
                lambda u, p: u + weight_decay * p.astype(jnp.float32),
                updates, params)
        lr_t = sched_value(schedule, ctx.step)
        return jax.tree.map(
            lambda u, p: u - lr_t * weight_decay * p.astype(jnp.float32),
            updates, params)

    return stateless(upd)


def lr_scale_transform(initial: float = 1.0) -> GradientTransform:
    """A runtime LR multiplier as an injected hyperparameter.

    Appended at the end of a chain it scales the *final* update — exactly
    what scaling the learning rate would do (descent and tied weight decay
    alike). Its ``lr_scale`` state leaf is what the resilience ladder's
    LR-cut rung rewrites between steps
    (:func:`repro.train.resilience.scale_hyperparam` — pure state surgery,
    zero retrace). Enable via ``as_optimizer(..., lr_scale=True)``.
    """

    def factory(lr_scale: float = 1.0) -> GradientTransform:
        return stateless(
            lambda updates, params, ctx: jax.tree.map(
                lambda u: u * lr_scale, updates))

    return inject_hyperparams(factory)(lr_scale=float(initial))


def scale_by_adam(b1: float = 0.9, b2: float = 0.999,
                  eps: float = 1e-8) -> GradientTransform:
    """Full-rank Adam direction ``mhat / (sqrt(vhat) + eps)`` per leaf,
    bias-corrected by the global step (the harness's full-rank fallback)."""

    def init(params):
        return jax.tree.map(
            lambda p: FullAdamLeaf(AdamMoments(
                jnp.zeros(p.shape, jnp.float32),
                jnp.zeros(p.shape, jnp.float32))),
            params)

    def update(updates, state, params, ctx):
        pairs = jax.tree.map(
            lambda g, s: adam_update(g, s.mom, ctx.step, b1, b2, eps),
            updates, state,
            is_leaf=lambda x: isinstance(x, FullAdamLeaf))
        d = jax.tree.map(lambda g, pr: pr[0], updates, pairs)
        new_state = jax.tree.map(lambda g, pr: FullAdamLeaf(pr[1]),
                                 updates, pairs)
        return d, new_state

    return GradientTransform(init, update)


def lowrank_project(rule: MatrixRule, *,
                    overrides: dict[str, dict] | None = None
                    ) -> GradientTransform:
    """Lift a per-matrix-leaf :class:`MatrixRule` to a whole-tree transform.

    Each leaf gets a per-leaf :class:`Context` whose PRNG key folds in a
    stable hash of the leaf's tree path; the shared predefined bases (any
    registered backend kind the rule requests) arrive via the chain
    runtime; the telemetry collector (if one is installed) is narrowed to
    the leaf's path so the rule's :class:`SubspaceStats` land under a
    stable key. Emits the rule's raw descent direction ``D`` —
    compose with ``scale_by_learning_rate`` / ``add_decayed_weights``.

    ``overrides`` maps leaf tree paths (``path_str`` form, the same keys
    telemetry emits under) to per-leaf field replacements on ``rule`` —
    e.g. ``{"block/0/wq": {"rank": 192, "update_interval": 4}}``. This is
    the plug point the adaptive rank/refresh controllers drive
    (DESIGN.md §8): rank is a static shape parameter, so changed overrides
    mean a rebuilt optimizer + state migration, handled host-side by
    :mod:`repro.telemetry.adaptive`.
    """

    def rule_for(path: str) -> MatrixRule:
        if overrides and path in overrides:
            return dataclasses.replace(rule, **overrides[path])
        return rule

    def init(params):
        return jax.tree_util.tree_map_with_path(
            lambda kp, p: rule_for(path_str(kp)).init(p.shape, p.dtype),
            params)

    def update(updates, state, params, ctx):
        from repro.parallel import zero as zero_mod

        # ZeRO-1 (DESIGN.md §9): resolve the config once per update against
        # the active mesh; eligible leaves run their rule inside shard_map
        # on row blocks, the rest fall through to the replicated path.
        zctx = zero_mod.resolve(ctx.zero)

        def leaf(kp, g, s, p):
            path = path_str(kp)
            r = rule_for(path)
            leaf_ctx = dataclasses.replace(
                ctx, key=leaf_key(ctx.key, path),
                stats=ctx.stats.scope(path) if ctx.stats is not None
                else None)
            if (zctx is not None and r.zero_shardable
                    and zero_mod.eligible(p.shape, zctx.n_shards)):
                return zero_mod.sharded_leaf_update(r, g, s, p, leaf_ctx,
                                                    zctx)
            return r.update(g, s, p, leaf_ctx)

        pairs = jax.tree_util.tree_map_with_path(leaf, updates, state, params)
        d = jax.tree.map(lambda g, pr: pr[0], updates, pairs)
        new_state = jax.tree.map(lambda g, pr: pr[1], updates, pairs)
        return d, new_state

    def basis_sizes(params):
        sizes = set()
        if rule.needs_shared_basis:
            for p in jax.tree.leaves(params):
                sizes.update(rule.basis_sizes(p.shape))
        return sizes

    return GradientTransform(init, update, basis_sizes)


# ---------------------------------------------------------------------------
# the chain runtime: GradientTransform -> Optimizer
# ---------------------------------------------------------------------------
class ChainState(NamedTuple):
    """Top-level optimizer state emitted by ``as_optimizer``.

    Field names match the legacy ``HarnessState`` (``step``/``key``/
    ``bases``/``leaves``) so structure-agnostic consumers (checkpointing,
    sharding-spec derivation, state-bytes accounting) work unchanged;
    ``leaves`` holds the wrapped transform's state.
    """

    step: jax.Array
    key: jax.Array
    bases: dict
    leaves: Any


def as_optimizer(transform: GradientTransform, *, seed: int = 0,
                 basis_mode: str = "stored", zero=None,
                 lr_scale: bool = False) -> Optimizer:
    """Close a transform into the ``Optimizer(init, update)`` interface.

    The runtime owns the global step, the PRNG key (per-step fold) and the
    shared-basis store: ``basis_mode="stored"`` materializes one ``(n, n)``
    basis matrix per distinct ``(kind, n)`` requested by the stack (the
    paper's whole-model shared basis, via the process-wide
    :class:`~repro.core.transforms.BasisCache` so adaptive-controller
    rebuilds re-use it); ``"onthefly"`` stores nothing and lets
    ``Context.basis`` recompute inside the step.

    ``zero``: a :class:`repro.parallel.zero.ZeroConfig` enabling ZeRO-1
    partitioning of eligible low-rank leaf state across the data axes
    (DESIGN.md §9). It rides the :class:`Context` into every transform;
    ``lowrank_project`` resolves it against the mesh active at trace time,
    so one optimizer object works on any topology (including none).

    ``lr_scale=True`` appends :func:`lr_scale_transform` — the resilience
    ladder's retrace-free LR-cut seam (off by default: the chain and its
    state are then bit-identical to builds that predate the knob).
    """
    if basis_mode not in ("stored", "onthefly"):
        raise ValueError(f"unknown basis_mode {basis_mode!r}; expected "
                         f"'stored' or 'onthefly'")
    if lr_scale:
        transform = chain(transform, lr_scale_transform())

    def init(params):
        sizes = transform.basis_sizes(params) if basis_mode == "stored" else ()
        reqs = sorted({normalize_basis_request(s) for s in sizes})
        bases = {basis_store_key(k, n): shared_basis(k, n, jnp.float32)
                 for k, n in reqs}
        return ChainState(
            step=jnp.zeros((), jnp.int32),
            key=jax.random.PRNGKey(seed),
            bases=bases,
            leaves=transform.init(params),
        )

    def update(grads, state: ChainState, params):
        from repro.telemetry.stats import active_collector

        step = state.step + 1
        # the collector (if installed via telemetry.stats.collect around
        # this — traced — call) rides the ctx; rules record SubspaceStats
        # into it and the caller returns collector.tree() as a jit output
        ctx = Context(step=step, bases=state.bases,
                      key=jax.random.fold_in(state.key, step),
                      stats=active_collector(), zero=zero)
        updates, leaves = transform.update(grads, state.leaves, params, ctx)
        return updates, ChainState(step=step, key=state.key,
                                   bases=state.bases, leaves=leaves)

    return Optimizer(init=init, update=update)


def matrix_optimizer(
    rule: MatrixRule,
    lr: Schedule,
    *,
    weight_decay: float = 0.0,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    label_fn=default_label_fn,
    basis_mode: str = "stored",
    seed: int = 0,
    fullrank_weight_decay: bool = True,
    overrides: dict[str, dict] | None = None,
    zero=None,
    lr_scale: bool = False,
) -> Optimizer:
    """The classic matrix-optimizer preset, rebuilt as a chain: route
    matrix leaves to ``rule`` and everything else to full-rank Adam, then
    apply lr scaling and decoupled weight decay. Drop-in replacement for
    the legacy ``make_matrix_optimizer`` (bit-for-bit, see
    tests/test_transform_api.py). ``overrides`` is the per-leaf-path rule
    field override map forwarded to :func:`lowrank_project` (the adaptive
    rank/refresh controllers' plug point); ``zero`` and ``lr_scale``
    (the resilience ladder's LR-cut seam) are forwarded to
    :func:`as_optimizer`."""
    routes = {"lowrank": lowrank_project(rule, overrides=overrides),
              "full": scale_by_adam(b1, b2, eps)}
    if fullrank_weight_decay:
        t = chain(partition(routes, label_fn),
                  scale_by_learning_rate(lr),
                  add_decayed_weights(weight_decay, schedule=lr))
    else:
        t = partition({
            "lowrank": chain(routes["lowrank"], scale_by_learning_rate(lr),
                             add_decayed_weights(weight_decay, schedule=lr)),
            "full": chain(routes["full"], scale_by_learning_rate(lr)),
        }, label_fn)
    return as_optimizer(t, seed=seed, basis_mode=basis_mode, zero=zero,
                        lr_scale=lr_scale)
