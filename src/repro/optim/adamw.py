"""Full-rank AdamW — the paper's reference optimizer."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import (
    AdamMoments,
    FullAdamLeaf,
    HarnessState,
    Optimizer,
    Schedule,
    adam_update,
    sched_value,
)


def adamw(lr: Schedule, *, weight_decay: float = 0.01, b1: float = 0.9,
          b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    def init(params):
        leaves = jax.tree.map(
            lambda p: FullAdamLeaf(AdamMoments(jnp.zeros(p.shape, jnp.float32),
                                               jnp.zeros(p.shape, jnp.float32))),
            params,
        )
        return HarnessState(step=jnp.zeros((), jnp.int32),
                            key=jax.random.PRNGKey(0), bases={}, leaves=leaves)

    def update(grads, state, params):
        step = state.step + 1
        lr_t = sched_value(lr, step)

        def leaf(g, s, p):
            d, mom = adam_update(g, s.mom, step, b1, b2, eps)
            return (-lr_t * d - lr_t * weight_decay * p.astype(jnp.float32),
                    FullAdamLeaf(mom))

        # flatten state/params "up to" the grads structure, then unzip pairs
        pairs = jax.tree.map(leaf, grads, state.leaves, params)
        updates = jax.tree.map(lambda _, pr: pr[0], grads, pairs)
        leaves = jax.tree.map(lambda _, pr: pr[1], grads, pairs)
        return updates, HarnessState(step=step, key=state.key, bases={},
                                     leaves=leaves)

    return Optimizer(init=init, update=update)
