"""Full-rank AdamW — the paper's reference optimizer, as a transform chain.

``adamw_transform`` is the composable building block (usable inside
``partition`` or ``inject_hyperparams``); ``adamw`` closes it into the
legacy ``Optimizer(init, update)`` interface.
"""
from __future__ import annotations

from .common import Optimizer, Schedule
from .transform import (
    GradientTransform,
    add_decayed_weights,
    as_optimizer,
    chain,
    scale_by_adam,
    scale_by_learning_rate,
)


def adamw_transform(lr: Schedule, *, weight_decay: float = 0.01,
                    b1: float = 0.9, b2: float = 0.999,
                    eps: float = 1e-8) -> GradientTransform:
    """Adam direction -> -lr scaling -> decoupled weight decay."""
    return chain(
        scale_by_adam(b1, b2, eps),
        scale_by_learning_rate(lr),
        add_decayed_weights(weight_decay, schedule=lr),
    )


def adamw(lr: Schedule, *, weight_decay: float = 0.01, b1: float = 0.9,
          b2: float = 0.999, eps: float = 1e-8,
          lr_scale: bool = False) -> Optimizer:
    return as_optimizer(adamw_transform(lr, weight_decay=weight_decay,
                                        b1=b1, b2=b2, eps=eps),
                        lr_scale=lr_scale)
