"""Exporters: Prometheus text exposition + JSONL metric snapshots.

Both exporters read :meth:`MetricsRegistry.snapshot` — the instruments'
hot path never formats strings; all naming/escaping happens here, at
export cadence (end of a run, every N steps, on demand).

:func:`prometheus_exposition` renders the standard text format
(``# HELP`` / ``# TYPE`` lines, ``{label="value"}`` series, histogram
``_bucket``/``_sum``/``_count`` with cumulative ``le`` buckets ending at
``+Inf``). :class:`PrometheusExporter` writes it atomically
(``.tmp`` + rename) so a scraper reading the snapshot file never sees a
torn write — the file-based equivalent of a ``/metrics`` endpoint for a
batch process.

:class:`JSONLExporter` appends one JSON object per ``write()`` call —
a timestamped full snapshot — giving a replayable metric history.
"""
from __future__ import annotations

import json
import os
import re
import time

from .metrics import MetricsRegistry

_NAME_OK = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*$")


def _escape(value) -> str:
    return (str(value).replace("\\", r"\\").replace('"', r'\"')
            .replace("\n", r"\n"))


def _fmt(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    if v == float("-inf"):
        return "-Inf"
    f = float(v)
    return str(int(f)) if f.is_integer() else repr(f)


def _series_suffix(label_names, label_values, extra=()) -> str:
    pairs = [f'{n}="{_escape(v)}"'
             for n, v in zip(label_names, label_values)]
    pairs += [f'{n}="{_escape(v)}"' for n, v in extra]
    return "{" + ",".join(pairs) + "}" if pairs else ""


def prometheus_exposition(registry: MetricsRegistry) -> str:
    """Render the registry as Prometheus text exposition format 0.0.4."""
    lines: list[str] = []
    for name, snap in registry.snapshot().items():
        if not _NAME_OK.match(name):
            raise ValueError(f"metric name {name!r} is not a valid "
                             "Prometheus metric name")
        kind = snap["type"]
        if snap["help"]:
            lines.append(f"# HELP {name} {_escape(snap['help'])}")
        lines.append(f"# TYPE {name} {kind}")
        label_names = snap["labels"]
        if kind in ("counter", "gauge"):
            for lv, v in sorted(snap["series"].items()):
                lines.append(
                    f"{name}{_series_suffix(label_names, lv)} {_fmt(v)}")
        else:                                           # histogram
            edges = snap["edges"]
            for lv, s in sorted(snap["series"].items()):
                cum = 0
                for edge, c in zip(edges + [float("inf")], s["buckets"]):
                    cum += c
                    suffix = _series_suffix(label_names, lv,
                                            extra=(("le", _fmt(edge)),))
                    lines.append(f"{name}_bucket{suffix} {cum}")
                base = _series_suffix(label_names, lv)
                lines.append(f"{name}_sum{base} {_fmt(s['sum'])}")
                lines.append(f"{name}_count{base} {s['count']}")
    return "\n".join(lines) + "\n" if lines else ""


class PrometheusExporter:
    """Writes the registry as an atomically-replaced text snapshot file."""

    def __init__(self, registry: MetricsRegistry, path: str):
        self.registry = registry
        self.path = path

    def write(self) -> str:
        """Render and atomically publish the snapshot; returns the path."""
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            f.write(prometheus_exposition(self.registry))
        os.replace(tmp, self.path)
        return self.path


class JSONLExporter:
    """Appends one timestamped registry snapshot per ``write()`` call.

    Histogram series are exported with their raw bucket counts plus the
    derived p50/p90/p99 so downstream consumers don't need the edges
    logic; tuple label keys become ``|``-joined strings (JSON objects
    need string keys)."""

    def __init__(self, registry: MetricsRegistry, path: str):
        self.registry = registry
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)

    def _jsonable(self) -> dict:
        out: dict = {}
        for name, snap in self.registry.snapshot().items():
            entry = {k: v for k, v in snap.items() if k != "series"}
            series = {}
            for lv, v in snap["series"].items():
                key = "|".join(str(x) for x in lv) if lv else ""
                if snap["type"] == "histogram":
                    hist = self.registry.get(name)
                    v = dict(v)
                    v["p50"] = hist.quantile(0.5, lv)
                    v["p90"] = hist.quantile(0.9, lv)
                    v["p99"] = hist.quantile(0.99, lv)
                series[key] = v
            entry["series"] = series
            out[name] = entry
        return out

    def write(self, *, step: int | None = None) -> str:
        rec = {"time": time.time(), "metrics": self._jsonable()}
        if step is not None:
            rec["step"] = step
        with open(self.path, "a") as f:
            f.write(json.dumps(rec) + "\n")
        return self.path
