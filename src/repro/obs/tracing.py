"""Span tracer: nested host-side spans into a fixed-size ring buffer.

A *span* is one timed region of host code (a decode step, a prefill, a
checkpoint write, a train-loop phase); spans nest through a per-thread
stack, so a ``ckpt/write`` span opened inside a ``train/step`` span
records its parent depth. An *event* is a zero-duration instant (a guard
trip, a rank reallocation) carrying structured args.

The buffer is a preallocated list written by a monotonically increasing
cursor (index = ``seq % capacity``) — append is one slot store + one
integer increment, no locking on the hot path (CPython's atomic list
item assignment is sufficient for single-writer-per-thread use; the
cursor is guarded only when exporting). When the tracer is disabled,
``span`` returns a shared no-op context manager and ``instant`` returns
immediately, so the cost of *compiled-in* instrumentation is one
attribute test.

Exports:

  * :meth:`SpanTracer.chrome_trace` / :meth:`write_chrome_trace` — the
    Chrome ``trace_event`` JSON format (load in ``chrome://tracing`` or
    Perfetto): complete ``"X"`` events with microsecond ``ts``/``dur``,
    instants as ``"i"`` events.
  * :meth:`SpanTracer.to_sink` — step-bucketed JSONL/CSV through the
    existing :class:`repro.telemetry.sink.TelemetrySink` machinery: span
    durations become ``span/<name>`` fields of per-step records, so
    runtime phase timings land in the same bucketed stream as the
    subspace telemetry.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Optional


class _NoopSpan:
    """Shared do-nothing context manager for the disabled fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP = _NoopSpan()


class _Span:
    """A live span handle; records on ``__exit__``."""

    __slots__ = ("tracer", "name", "cat", "step", "args", "t0", "depth")

    def __init__(self, tracer, name, cat, step, args):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.step = step
        self.args = args

    def __enter__(self):
        tls = self.tracer._tls
        self.depth = getattr(tls, "depth", 0)
        tls.depth = self.depth + 1
        self.t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter_ns() - self.t0
        self.tracer._tls.depth = self.depth
        self.tracer._record({
            "ph": "X", "name": self.name, "cat": self.cat,
            "ts": self.t0, "dur": dur, "depth": self.depth,
            "tid": threading.get_ident(), "step": self.step,
            "args": self.args,
        })
        return False


class SpanTracer:
    """Ring buffer of spans/instants with Chrome-trace and sink export."""

    def __init__(self, capacity: int = 4096, *, enabled: bool = True):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.enabled = enabled
        self._buf: list[Optional[dict]] = [None] * capacity
        self._seq = 0                        # total records ever written
        self._tls = threading.local()
        self._lock = threading.Lock()        # export-time consistency only

    # -- recording ----------------------------------------------------------
    def _record(self, rec: dict) -> None:
        seq = self._seq
        self._buf[seq % self.capacity] = rec
        self._seq = seq + 1

    def span(self, name: str, *, cat: str = "host",
             step: Optional[int] = None, **args):
        """``with tracer.span("serve/decode", step=i): ...`` — times the
        block and records it (nested spans record their depth)."""
        if not self.enabled:
            return _NOOP
        return _Span(self, name, cat, step, args or None)

    def instant(self, name: str, *, cat: str = "event",
                step: Optional[int] = None, **args) -> None:
        """Zero-duration structured event (ladder decisions, controller
        re-allocations, admissions)."""
        if not self.enabled:
            return
        self._record({
            "ph": "i", "name": name, "cat": cat,
            "ts": time.perf_counter_ns(), "dur": 0, "depth": 0,
            "tid": threading.get_ident(), "step": step,
            "args": args or None,
        })

    # -- reads --------------------------------------------------------------
    @property
    def dropped(self) -> int:
        """Records overwritten by ring wraparound since construction."""
        return max(0, self._seq - self.capacity)

    def records(self) -> list[dict]:
        """Retained records, oldest first (at most ``capacity``)."""
        with self._lock:
            seq = self._seq
            if seq <= self.capacity:
                return [r for r in self._buf[:seq]]
            cut = seq % self.capacity
            return self._buf[cut:] + self._buf[:cut]

    def clear(self) -> None:
        with self._lock:
            self._buf = [None] * self.capacity
            self._seq = 0

    # -- exports ------------------------------------------------------------
    def chrome_trace(self) -> dict:
        """The retained buffer as a Chrome ``trace_event`` object
        (``ts``/``dur`` in microseconds, as the format requires)."""
        events = []
        for r in self.records():
            ev = {
                "name": r["name"], "cat": r["cat"], "ph": r["ph"],
                "ts": r["ts"] / 1e3, "pid": 0, "tid": r["tid"],
            }
            if r["ph"] == "X":
                ev["dur"] = r["dur"] / 1e3
            if r["ph"] == "i":
                ev["s"] = "t"                # thread-scoped instant
            args = dict(r["args"] or {})
            if r["step"] is not None:
                args["step"] = r["step"]
            if args:
                ev["args"] = args
            events.append(ev)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return path

    def to_sink(self, sink) -> int:
        """Feed retained spans into a :class:`TelemetrySink` as per-step
        records: every span with a ``step`` becomes
        ``{"step": s, "span/<name>": seconds}`` (instants contribute a
        ``event/<name>`` count of 1). Records flow through the sink's
        normal step bucketing/aggregation; returns the number fed. The
        caller owns the sink's lifecycle (``flush``/``close``)."""
        fed = 0
        for r in self.records():
            if r["step"] is None:
                continue
            if r["ph"] == "X":
                rec: dict[str, Any] = {"step": r["step"],
                                       f"span/{r['name']}": r["dur"] / 1e9}
            else:
                rec = {"step": r["step"], f"event/{r['name']}": 1.0}
            sink.log_metrics(rec)
            fed += 1
        return fed


#: process-wide default tracer — starts disabled alongside the registry
_default = SpanTracer(enabled=False)


def tracer() -> SpanTracer:
    """The process-wide default tracer every instrumented module uses."""
    return _default
