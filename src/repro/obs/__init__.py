"""Runtime observability (DESIGN.md §13, docs/observability.md).

Three pieces, all host-side (never inside a jit, so enabling them cannot
change a traced graph — pinned by tests/test_obs.py):

  metrics.py    process-wide :class:`MetricsRegistry` — counters, gauges,
                fixed-bucket histograms with quantile estimation; labeled
                series keyed by plain tuples (no string formatting on the
                hot path); ``snapshot()`` for tests.
  tracing.py    :class:`SpanTracer` — nested host spans + instant events
                into a fixed ring buffer; exports Chrome ``trace_event``
                JSON and step-bucketed JSONL through the existing
                :class:`repro.telemetry.sink.TelemetrySink`.
  exporters.py  Prometheus text-exposition snapshot file (atomic
                replace) + JSONL snapshot appender.

Observability is **opt-in and process-wide**: everything starts disabled
and every instrumented call site costs one attribute test until
:func:`enable` is called. The instrumented layers are serving
(``serve/engine.py`` — TTFT/ITL/queue-wait/E2E histograms, pool and slot
gauges, admission counters), training (``train/loop.py`` phase spans,
``train/resilience.py`` ladder events, ``telemetry/controllers.py``
re-allocation events) and checkpointing (``train/checkpoint.py``
durations + bytes).

Typical use::

    from repro import obs
    obs.enable()
    ... run ...
    obs.write_prometheus("metrics.prom")
    obs.write_chrome_trace("trace.json")
    snap = obs.registry().snapshot()
"""
from __future__ import annotations

from .exporters import (JSONLExporter, PrometheusExporter,
                        prometheus_exposition)
from .metrics import (DEFAULT_BUCKETS, Counter, Gauge, Histogram,
                      MetricsRegistry, registry)
from .tracing import SpanTracer, tracer

__all__ = [
    "MetricsRegistry", "Counter", "Gauge", "Histogram", "DEFAULT_BUCKETS",
    "SpanTracer", "PrometheusExporter", "JSONLExporter",
    "prometheus_exposition", "registry", "tracer",
    "enable", "disable", "enabled", "reset",
    "write_prometheus", "write_chrome_trace",
]


def enable() -> None:
    """Turn on the process-wide registry and tracer."""
    registry().enable()
    tracer().enabled = True


def disable() -> None:
    """Turn off both; instrumented sites fall back to the no-op path."""
    registry().disable()
    tracer().enabled = False


def enabled() -> bool:
    return registry().enabled


def reset() -> None:
    """Clear every recorded series and the span ring (instruments stay
    registered; the enabled state is unchanged)."""
    registry().reset()
    tracer().clear()


def write_prometheus(path: str) -> str:
    """Snapshot the default registry as a Prometheus text file."""
    return PrometheusExporter(registry(), path).write()


def write_chrome_trace(path: str) -> str:
    """Dump the default tracer's ring as Chrome ``trace_event`` JSON."""
    return tracer().write_chrome_trace(path)
