"""Process-wide metrics registry: counters, gauges, histograms.

The runtime counterpart of the *math-level* telemetry in
``repro.telemetry`` (DESIGN.md §13): where SubspaceStats measure the
optimizer's subspace inside the jit, these instruments measure the
*host runtime* around it — request latencies, pool occupancy, phase
durations, ladder events — with a hot path cheap enough to run on every
serving step and every train step.

Hot-path discipline:

  * Label sets are plain tuples used directly as dict keys — no string
    formatting, no label joining, no allocation beyond the tuple the
    caller already holds. Formatting happens only at export time
    (:mod:`repro.obs.exporters`).
  * Every instrument holds a reference to its registry's ``enabled``
    flag holder; a disabled registry makes ``inc``/``set``/``observe``
    a single attribute test and return. Instrumented code therefore
    never needs its own ``if obs.enabled()`` guards.
  * Instruments are host-side only: nothing here touches jax, so
    instrumenting a step function can never alter its traced graph
    (pinned by tests/test_obs.py's bit-identity tests).

Histograms use fixed bucket edges chosen at registration (defaults
cover 100µs..100s in log-spaced steps, the serving-latency range).
``observe`` is a bisect into those edges; quantiles are estimated at
read time by linear interpolation inside the bucket — the classic
Prometheus-style fixed-bucket estimator, exact at bucket edges.
"""
from __future__ import annotations

import threading
from bisect import bisect_left
from typing import Iterable, Optional

#: default histogram edges: log-spaced 100µs .. 100s (seconds) — covers
#: token latencies, step phases, and checkpoint IO on CPU and accelerator
DEFAULT_BUCKETS = tuple(
    round(m * 10.0 ** e, 10)
    for e in range(-4, 2)
    for m in (1.0, 2.5, 5.0)
) + (100.0,)

_NO_LABELS = ()


class _Enabled:
    """Shared mutable flag; instruments read ``.on`` on every record."""

    __slots__ = ("on",)

    def __init__(self, on: bool = True):
        self.on = on


class Counter:
    """Monotonic counter family, one float per label tuple."""

    __slots__ = ("name", "help", "label_names", "series", "_enabled")

    def __init__(self, name: str, help: str, label_names: tuple[str, ...],
                 enabled: _Enabled):
        self.name = name
        self.help = help
        self.label_names = label_names
        self.series: dict[tuple, float] = {}
        self._enabled = enabled

    def inc(self, amount: float = 1.0, labels: tuple = _NO_LABELS) -> None:
        if not self._enabled.on:
            return
        self.series[labels] = self.series.get(labels, 0.0) + amount

    def value(self, labels: tuple = _NO_LABELS) -> float:
        return self.series.get(labels, 0.0)

    def snapshot(self) -> dict:
        return {"type": "counter", "help": self.help,
                "labels": list(self.label_names),
                "series": {k: v for k, v in self.series.items()}}


class Gauge:
    """Set-to-current-value instrument, one float per label tuple."""

    __slots__ = ("name", "help", "label_names", "series", "_enabled")

    def __init__(self, name: str, help: str, label_names: tuple[str, ...],
                 enabled: _Enabled):
        self.name = name
        self.help = help
        self.label_names = label_names
        self.series: dict[tuple, float] = {}
        self._enabled = enabled

    def set(self, value: float, labels: tuple = _NO_LABELS) -> None:
        if not self._enabled.on:
            return
        self.series[labels] = value

    def add(self, amount: float, labels: tuple = _NO_LABELS) -> None:
        if not self._enabled.on:
            return
        self.series[labels] = self.series.get(labels, 0.0) + amount

    def value(self, labels: tuple = _NO_LABELS) -> float:
        return self.series.get(labels, 0.0)

    def snapshot(self) -> dict:
        return {"type": "gauge", "help": self.help,
                "labels": list(self.label_names),
                "series": {k: v for k, v in self.series.items()}}


class _HistSeries:
    """One label tuple's histogram state: per-bucket counts + running
    count/sum/min/max."""

    __slots__ = ("counts", "count", "sum", "min", "max")

    def __init__(self, n_buckets: int):
        self.counts = [0] * n_buckets     # one per edge + overflow
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")


class Histogram:
    """Fixed-bucket histogram family with quantile estimation.

    ``edges`` are the inclusive upper bounds of the finite buckets (a
    value lands in the first bucket whose edge is >= value); values above
    the last edge land in the implicit +Inf overflow bucket. Quantiles
    interpolate linearly within the winning bucket; an overflow-bucket
    quantile reports the observed max (the only honest bound there).
    """

    __slots__ = ("name", "help", "label_names", "edges", "series",
                 "_enabled")

    def __init__(self, name: str, help: str, label_names: tuple[str, ...],
                 edges: tuple[float, ...], enabled: _Enabled):
        if not edges or list(edges) != sorted(edges):
            raise ValueError(f"histogram {name!r}: bucket edges must be a "
                             f"non-empty ascending sequence, got {edges}")
        self.name = name
        self.help = help
        self.label_names = label_names
        self.edges = tuple(float(e) for e in edges)
        self.series: dict[tuple, _HistSeries] = {}
        self._enabled = enabled

    def observe(self, value: float, labels: tuple = _NO_LABELS) -> None:
        if not self._enabled.on:
            return
        s = self.series.get(labels)
        if s is None:
            s = self.series[labels] = _HistSeries(len(self.edges) + 1)
        s.counts[bisect_left(self.edges, value)] += 1
        s.count += 1
        s.sum += value
        if value < s.min:
            s.min = value
        if value > s.max:
            s.max = value

    # -- reads --------------------------------------------------------------
    def count(self, labels: tuple = _NO_LABELS) -> int:
        s = self.series.get(labels)
        return s.count if s else 0

    def sum(self, labels: tuple = _NO_LABELS) -> float:
        s = self.series.get(labels)
        return s.sum if s else 0.0

    def mean(self, labels: tuple = _NO_LABELS) -> float:
        s = self.series.get(labels)
        return s.sum / s.count if s and s.count else 0.0

    def quantile(self, q: float, labels: tuple = _NO_LABELS) -> float:
        """Estimated ``q``-quantile (0 <= q <= 1) from the bucket counts.

        Linear interpolation inside the winning bucket, with the bucket's
        lower bound clamped to the observed min (first bucket) and the
        overflow bucket reporting the observed max."""
        s = self.series.get(labels)
        if not s or not s.count:
            return 0.0
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        rank = q * s.count
        seen = 0
        for i, c in enumerate(s.counts):
            if c == 0:
                continue
            if seen + c >= rank:
                if i == len(self.edges):          # overflow bucket
                    return s.max
                lo = self.edges[i - 1] if i else min(s.min, self.edges[0])
                lo = max(lo, s.min)
                hi = min(self.edges[i], s.max)
                if hi <= lo:
                    return hi
                frac = (rank - seen) / c
                return lo + frac * (hi - lo)
            seen += c
        return s.max

    def snapshot(self) -> dict:
        out = {}
        for labels, s in self.series.items():
            out[labels] = {
                "count": s.count, "sum": s.sum,
                "min": s.min if s.count else None,
                "max": s.max if s.count else None,
                "buckets": list(s.counts),
            }
        return {"type": "histogram", "help": self.help,
                "labels": list(self.label_names),
                "edges": list(self.edges), "series": out}


class MetricsRegistry:
    """Named instruments, created once and looked up cheaply.

    ``counter``/``gauge``/``histogram`` are get-or-create: a second
    registration with the same name returns the existing instrument (and
    raises on a kind/labels/edges mismatch — two call sites silently
    sharing a name with different meanings is a bug). Instrumented
    modules therefore register at call-site module scope without
    coordinating.
    """

    def __init__(self, *, enabled: bool = True):
        self._enabled = _Enabled(enabled)
        self._metrics: dict[str, Counter | Gauge | Histogram] = {}
        self._lock = threading.Lock()

    # -- enable/disable -----------------------------------------------------
    @property
    def enabled(self) -> bool:
        return self._enabled.on

    def enable(self) -> None:
        self._enabled.on = True

    def disable(self) -> None:
        self._enabled.on = False

    # -- registration -------------------------------------------------------
    def _get_or_create(self, cls, name: str, help: str, labels, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if not isinstance(m, cls):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{type(m).__name__}, requested {cls.__name__}")
                if tuple(labels) != m.label_names:
                    raise ValueError(
                        f"metric {name!r} label mismatch: registered "
                        f"{m.label_names}, requested {tuple(labels)}")
                if kw.get("edges") is not None \
                        and tuple(kw["edges"]) != m.edges:
                    raise ValueError(
                        f"histogram {name!r} bucket-edge mismatch")
                return m
            if cls is Histogram:
                edges = kw.get("edges") or DEFAULT_BUCKETS
                m = Histogram(name, help, tuple(labels), edges,
                              self._enabled)
            else:
                m = cls(name, help, tuple(labels), self._enabled)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "",
                labels: Iterable[str] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Iterable[str] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Iterable[str] = (),
                  edges: Optional[Iterable[float]] = None) -> Histogram:
        return self._get_or_create(Histogram, name, help, labels,
                                   edges=tuple(edges) if edges else None)

    # -- reads --------------------------------------------------------------
    def get(self, name: str):
        return self._metrics.get(name)

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def snapshot(self) -> dict:
        """Plain-dict view of every instrument — the test/exporter API."""
        return {name: self._metrics[name].snapshot()
                for name in sorted(self._metrics)}

    def reset(self) -> None:
        """Drop every recorded series (instruments stay registered) —
        lets tests and benchmark phases start from a clean slate."""
        with self._lock:
            for m in self._metrics.values():
                m.series = {}


# ---------------------------------------------------------------------------
# process-wide default registry
# ---------------------------------------------------------------------------
#: observability is opt-in: the default registry starts disabled, so an
#: un-configured process pays one attribute test per instrumented site
_default = MetricsRegistry(enabled=False)


def registry() -> MetricsRegistry:
    """The process-wide default registry every instrumented module uses."""
    return _default
