"""Paged KV cache: a global pool of fixed-size token blocks.

The seed engine allocated a dense ``(B, max_len, Hkv, hd)`` cache per
layer — every admitted sequence paid for ``max_len`` tokens whether it
used them or not. Here cache memory is a single pool of ``num_blocks``
blocks of ``block_size`` tokens each (per attention layer), and every
sequence owns a *block table*: the ordered list of pool blocks holding
its tokens. Token ``t`` of a sequence lives at
``pool[table[t // block_size], t % block_size]``.

Two halves:

  * :class:`BlockAllocator` — the host-side free-list. ``alloc`` /
    ``extend`` / ``free`` move block ids between the free list and
    per-sequence tables; admission backpressure is a ``can_alloc``
    check, never an exception mid-stream. Stats report utilization
    (tokens held / token capacity of the blocks held) and internal
    fragmentation (the complement: tail-of-block waste).
  * :class:`PagedKVCache` — the device-side pools, one ``{k, v}`` pair
    of ``(repeats, num_blocks, block_size, Hkv, hd)`` arrays per
    attention position in the model schedule (mirroring the
    ``lax.scan`` segment structure the dense cache uses), plus the
    padded int32 block-table array the flash-decode kernel reads
    through scalar prefetch.

Block ids are shared across layers: one table entry addresses the same
block index in every layer's pool, so the allocator is layer-agnostic.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# re-exported from the model layer (single source of truth): the block
# kinds the paged path serves; other kinds (MLA latents, SSM/RWKV
# recurrent state, encdec) keep the dense engine
from repro.models.transformer import PAGED_KINDS, paged_supported


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Number of blocks needed to hold ``n_tokens``."""
    return -(-max(n_tokens, 0) // block_size)


class OutOfBlocksError(RuntimeError):
    """Raised by ``alloc``/``extend`` when the pool cannot satisfy a
    reservation the caller did not pre-check with ``can_alloc``."""


class BlockAllocator:
    """Host-side free-list over ``num_blocks`` pool blocks.

    Sequences are keyed by an opaque hashable id. ``alloc`` reserves
    blocks for a token budget, ``extend`` grows an existing
    reservation, ``free`` returns every block. Freed blocks go to the
    tail of the free list (FIFO) so reuse is deterministic and easy to
    assert in tests.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks <= 0 or block_size <= 0:
            raise ValueError("num_blocks and block_size must be positive")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: list[int] = list(range(num_blocks))
        self._tables: dict[object, list[int]] = {}
        self._lengths: dict[object, int] = {}

    # -- queries ----------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - len(self._free)

    def can_alloc(self, n_tokens: int) -> bool:
        return blocks_for(n_tokens, self.block_size) <= len(self._free)

    def table(self, seq_id) -> list[int]:
        """The live block-id list for ``seq_id`` (do not mutate)."""
        return self._tables[seq_id]

    def length(self, seq_id) -> int:
        return self._lengths[seq_id]

    # -- mutations --------------------------------------------------------
    def alloc(self, seq_id, n_tokens: int) -> list[int]:
        """Reserve blocks for ``n_tokens`` tokens. Returns the table."""
        if seq_id in self._tables:
            raise ValueError(f"sequence {seq_id!r} already allocated")
        need = blocks_for(n_tokens, self.block_size)
        if need > len(self._free):
            raise OutOfBlocksError(
                f"need {need} blocks, {len(self._free)} free")
        self._tables[seq_id] = [self._free.pop(0) for _ in range(need)]
        self._lengths[seq_id] = n_tokens
        return self._tables[seq_id]

    def extend(self, seq_id, new_len: int) -> list[int]:
        """Grow ``seq_id``'s reservation to ``new_len`` tokens. Returns
        the newly appended block ids (possibly empty)."""
        table = self._tables[seq_id]
        need = blocks_for(new_len, self.block_size) - len(table)
        if need > len(self._free):
            raise OutOfBlocksError(
                f"extend needs {need} blocks, {len(self._free)} free")
        fresh = [self._free.pop(0) for _ in range(max(need, 0))]
        table.extend(fresh)
        self._lengths[seq_id] = max(self._lengths[seq_id], new_len)
        return fresh

    def free(self, seq_id) -> int:
        """Return every block of ``seq_id`` to the pool; returns count."""
        table = self._tables.pop(seq_id)
        self._lengths.pop(seq_id)
        self._free.extend(table)
        return len(table)

    # -- stats ------------------------------------------------------------
    def stats(self) -> dict:
        """Pool occupancy: used/free blocks, token utilization of the
        held blocks, and internal fragmentation (1 - utilization)."""
        held_tokens = sum(self._lengths.values())
        held_capacity = self.used_blocks * self.block_size
        util = held_tokens / held_capacity if held_capacity else 0.0
        return {
            "num_blocks": self.num_blocks,
            "block_size": self.block_size,
            "used_blocks": self.used_blocks,
            "free_blocks": self.free_blocks,
            "sequences": len(self._tables),
            "held_tokens": held_tokens,
            "utilization": util,
            "fragmentation": 1.0 - util if held_capacity else 0.0,
        }


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    """Sizing for one :class:`PagedKVCache`.

    ``num_blocks`` is the pool's global budget; ``max_blocks_per_seq``
    bounds one sequence's table (= max model length / block_size) and
    fixes the padded block-table width the jit'd step sees, so batch
    composition can churn without retracing.
    """
    block_size: int
    num_blocks: int
    max_blocks_per_seq: int

    @property
    def max_seq_len(self) -> int:
        return self.block_size * self.max_blocks_per_seq


class PagedKVCache:
    """Device pools + host allocator + the padded block-table array.

    ``pools`` mirrors the model's segment/scan structure:
    ``pools[seg][f"p{j}"] = {"k": (R, NB, bs, Hkv, hd), "v": ...}`` for
    every attention position — the exact pytree
    ``models.transformer.decode_step_paged`` scans over.

    The block table is kept as a host ``(num_slots, max_blocks_per_seq)``
    int32 array (mutated at admit/retire/extend boundaries only) and
    uploaded once per decode step; unused entries hold 0 and are never
    read because the kernel skips blocks past each slot's length.
    """

    def __init__(self, cfg, cache_cfg: PagedCacheConfig, num_slots: int):
        from repro.models import transformer as T

        self.model_cfg = cfg
        self.cfg = cache_cfg
        self.num_slots = num_slots
        self.allocator = BlockAllocator(cache_cfg.num_blocks,
                                        cache_cfg.block_size)
        self.pools = T.init_paged_pools(cfg, cache_cfg.num_blocks,
                                        cache_cfg.block_size)
        self._table = np.zeros((num_slots, cache_cfg.max_blocks_per_seq),
                               np.int32)

    # -- table maintenance (host) -----------------------------------------
    def bind_slot(self, slot: int, seq_id) -> None:
        """Copy ``seq_id``'s (padded) block list into table row ``slot``."""
        blocks = self.allocator.table(seq_id)
        if len(blocks) > self.cfg.max_blocks_per_seq:
            raise ValueError("sequence exceeds max_blocks_per_seq")
        row = np.zeros((self.cfg.max_blocks_per_seq,), np.int32)
        row[:len(blocks)] = blocks
        self._table[slot] = row

    def clear_slot(self, slot: int) -> None:
        self._table[slot] = 0

    def block_table(self) -> jax.Array:
        """The padded device block table for this step."""
        return jnp.asarray(self._table)

    # -- sizing -----------------------------------------------------------
    def cache_bytes(self) -> int:
        """Total bytes held by the paged pools."""
        return sum(int(x.size * x.dtype.itemsize)
                   for x in jax.tree.leaves(self.pools))

    def dense_bytes_equivalent(self) -> int:
        """Bytes a dense ``(num_slots, max_seq_len)`` cache of the same
        capacity would hold (the apples-to-apples comparison the
        serve benchmark gates on)."""
        per_token = 0
        for x in jax.tree.leaves(self.pools):
            r, nb, bs = x.shape[:3]
            rest = int(np.prod(x.shape[3:]))
            per_token += r * rest * x.dtype.itemsize
        return per_token * self.num_slots * self.cfg.max_seq_len

    def stats(self) -> dict:
        s = self.allocator.stats()
        s["cache_bytes"] = self.cache_bytes()
        s["dense_bytes_equivalent"] = self.dense_bytes_equivalent()
        return s
