"""Batched serving: prefill + jit'd decode steps over a shared KV cache.

``make_serve_step`` is the function the decode-shape dry-run cells lower:
one new token for every sequence in the batch against a ``seq_len``-sized
cache (exactly the brief's ``decode_*`` contract). ``ServeEngine`` is the
runnable wrapper used by examples/serve_batch.py: greedy or temperature
sampling, synchronized positions, eos early-exit mask.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import transformer as T


def make_serve_step(cfg):
    """(params, cache, token (B,), pos ()) -> (logits (B,V), cache)."""

    def serve_step(params, cache, token, pos):
        return T.decode_step(params, cache, token, pos, cfg)

    return serve_step


class ServeEngine:
    def __init__(self, cfg, params, *, max_len: int = 2048,
                 temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.temperature = temperature
        self._step = jax.jit(make_serve_step(cfg))
        self._key = jax.random.PRNGKey(seed)

    def _sample(self, logits):
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._key, sub = jax.random.split(self._key)
        return jax.random.categorical(
            sub, logits / self.temperature, axis=-1).astype(jnp.int32)

    def generate(self, batch: dict, *, max_new_tokens: int = 32,
                 eos_id: int | None = None):
        """batch: {'tokens': (B, S) prompt, + modality stubs}. Returns
        (B, <=max_new_tokens) int32 generations (greedy/temperature)."""
        prompt = batch["tokens"]
        b, s = prompt.shape
        last_logits, cache, n = T.prefill(self.params, batch, self.cfg,
                                          max_len=self.max_len)
        token = self._sample(last_logits)
        out = [token]
        done = jnp.zeros((b,), bool) if eos_id is not None else None
        pos = s
        for _ in range(max_new_tokens - 1):
            logits, cache = self._step(self.params, cache, token,
                                       jnp.int32(pos))
            token = self._sample(logits)
            if eos_id is not None:
                done = done | (token == eos_id)
                token = jnp.where(done, eos_id, token)
                if bool(done.all()):
                    out.append(token)
                    break
            out.append(token)
            pos += 1
        return jnp.stack(out, axis=1)
