"""Serving engines: fixed-batch dense and continuous-batching paged.

``make_serve_step`` is the function the decode-shape dry-run cells lower:
one new token for every sequence in the batch against a ``seq_len``-sized
cache (exactly the brief's ``decode_*`` contract).

``ServeEngine`` is the fixed-batch dense engine (all families): one
prefill, then jit'd decode steps. Sampling, eos detection and
done-masking all run in-trace; the host reads back one small
``(tokens, done)`` pair per step — needed anyway to stream tokens and
stop early — instead of the seed's per-token host sampling loop.
Positions are a per-sequence ``(B,)`` lane end to end.

``PagedServeEngine`` is the production path for the paged families
(DESIGN.md §12): block-pool KV cache (serve/kv_cache.py), chunked
prefill into the pools, a continuous-batching scheduler
(serve/scheduler.py) admitting and retiring requests between jit'd
decode steps, per-sequence sampling lanes (serve/session.py), and the
Pallas flash-decode kernel reading K/V through the block table. One
compiled step serves arbitrary admit/retire churn; a sequence's output
depends only on its own prompt, seed and budget, never on its
neighbours.
"""
from __future__ import annotations

import math
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import transformer as T

from .kv_cache import PagedCacheConfig, PagedKVCache
from .scheduler import Scheduler
from .session import (GenerationHandle, Request, SamplingParams, fold_keys,
                      sample_tokens)


def _serve_metrics():
    """Register (or fetch) the serving instruments on the process-wide
    registry. Label/bucket formatting happens only at export; the per-step
    hot path below is tuple-keyed dict updates (no-ops while obs is
    disabled). Metric catalog: docs/observability.md."""
    r = obs.registry()
    return {
        "ttft": r.histogram(
            "serve_ttft_seconds",
            "submit -> first token (includes queue wait and prefill)"),
        "itl": r.histogram(
            "serve_itl_seconds",
            "inter-token latency (gap between consecutive emissions)"),
        "queue_wait": r.histogram(
            "serve_queue_wait_seconds", "submit -> admission"),
        "e2e": r.histogram(
            "serve_e2e_seconds", "submit -> finish (any reason)"),
        "tokens": r.counter("serve_tokens_total", "tokens emitted"),
        "submitted": r.counter("serve_requests_submitted_total",
                               "requests accepted by submit()"),
        "finished": r.counter("serve_requests_finished_total",
                              "requests retired, by finish reason",
                              labels=("reason",)),
        "admissions": r.counter("serve_admissions_total",
                                "requests admitted into a slot"),
        "backpressure": r.counter(
            "serve_backpressure_steps_total",
            "steps the queue head stayed blocked, by cause",
            labels=("cause",)),
        "cancels": r.counter("serve_cancellations_total",
                             "cancellations processed, by request state",
                             labels=("state",)),
        "slots_active": r.gauge("serve_slots_active",
                                "occupied decode slot lanes"),
        "queue_depth": r.gauge("serve_queue_depth", "pending requests"),
        "pool_util": r.gauge(
            "serve_pool_utilization",
            "tokens held / token capacity of the held blocks"),
        "pool_frag": r.gauge(
            "serve_pool_fragmentation",
            "internal fragmentation of held blocks (1 - utilization)"),
        "pool_used": r.gauge("serve_pool_used_blocks", "blocks in use"),
        "pool_free": r.gauge("serve_pool_free_blocks", "blocks free"),
    }


def make_serve_step(cfg):
    """(params, cache, token (B,), pos () or (B,)) -> (logits (B,V), cache)."""

    def serve_step(params, cache, token, pos):
        return T.decode_step(params, cache, token, pos, cfg)

    return serve_step


# ---------------------------------------------------------------------------
# dense fixed-batch engine
# ---------------------------------------------------------------------------
def _dense_sample(logits, key, temperature):
    """Shared-key batch sampling for the dense engine (temperature is a
    static engine-level float here, matching the seed API)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits / temperature, axis=-1).astype(jnp.int32)


def _make_dense_gen_step(cfg, temperature):
    """decode + sample + eos/done masking, all in one trace. ``eos`` is a
    traced scalar (-1 = no eos) so toggling it never retraces."""

    def step(params, cache, token, pos, done, key, eos):
        logits, cache = T.decode_step(params, cache, token, pos, cfg)
        key, sub = jax.random.split(key)
        tok = _dense_sample(logits, sub, temperature)
        has_eos = eos >= 0
        done = done | (has_eos & (tok == eos))
        tok = jnp.where(done & has_eos, eos, tok)
        return cache, tok, pos + 1, done, key

    return step


def _make_dense_first(temperature):
    def first(logits, key, eos):
        key, sub = jax.random.split(key)
        tok = _dense_sample(logits, sub, temperature)
        done = (eos >= 0) & (tok == eos)
        return tok, done, key

    return first


class ServeEngine:
    def __init__(self, cfg, params, *, max_len: int = 2048,
                 temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.temperature = temperature
        self._step = jax.jit(make_serve_step(cfg))
        self._gen_step = jax.jit(_make_dense_gen_step(cfg, temperature))
        self._first = jax.jit(_make_dense_first(temperature))
        self._key = jax.random.PRNGKey(seed)

    def generate(self, batch: dict, *, max_new_tokens: int = 32,
                 eos_id: int | None = None):
        """batch: {'tokens': (B, S) prompt, + modality stubs}. Returns
        (B, <=max_new_tokens) int32 generations (greedy/temperature).
        Sampling and done-masking run in-trace; the host syncs once per
        step on the small (token, done) pair to stream and early-exit."""
        prompt = batch["tokens"]
        b, s = prompt.shape
        eos = jnp.int32(-1 if eos_id is None else eos_id)
        last_logits, cache, _ = T.prefill(self.params, batch, self.cfg,
                                          max_len=self.max_len)
        token, done, self._key = self._first(last_logits, self._key, eos)
        out = [token]
        pos = jnp.full((b,), s, jnp.int32)
        for _ in range(max_new_tokens - 1):
            if eos_id is not None and bool(done.all()):
                break
            cache, token, pos, done, self._key = self._gen_step(
                self.params, cache, token, pos, done, self._key, eos)
            out.append(token)
        return jnp.stack(out, axis=1)


# ---------------------------------------------------------------------------
# paged continuous-batching engine
# ---------------------------------------------------------------------------
def _make_paged_step(cfg, num_splits):
    """One continuous-batching decode step, fully in-trace: paged
    attention over the block table, per-slot sampling with position-
    folded key lanes, eos hit detection and inactive-row masking. The
    host reads back only the (tokens, eos_hit) lanes."""

    def step(params, pools, token, pos, table, active, keys, temp, top_k,
             top_p, eos):
        logits, pools = T.decode_step_paged(
            params, pools, token, pos, table, active, cfg,
            num_splits=num_splits)
        step_keys = fold_keys(keys, pos)
        tok = sample_tokens(logits, step_keys, temp, top_k, top_p)
        hit = active & (eos >= 0) & (tok == eos)
        tok = jnp.where(active, tok, 0)
        return pools, logits, tok, hit

    return step


def _make_paged_first():
    """Sample the first token of one request from its prefill logits,
    with the same key-folding scheme the decode step uses (folded at
    the last prompt position), so the whole sample stream is a pure
    function of (seed, position)."""

    def first(logits, key, pos, temp, top_k, top_p, eos):
        keys = fold_keys(key[None], pos[None])
        tok = sample_tokens(logits, keys, temp[None], top_k[None],
                            top_p[None])[0]
        hit = (eos >= 0) & (tok == eos)
        return tok, hit

    return first


class PagedServeEngine:
    """Continuous-batching serving over a paged KV cache.

    Submit :class:`~repro.serve.session.Request` objects (usually via a
    :class:`~repro.serve.session.Session`); call :meth:`step` to advance
    every running sequence by one token (admitting queued requests and
    retiring finished ones at the boundary), or :meth:`run` to drain.
    ``num_slots`` fixes the decode batch width; ``block_size`` /
    ``num_blocks`` size the cache pool; admission reserves a request's
    worst-case blocks up front, so backpressure is a queue, never a
    mid-stream failure.
    """

    def __init__(self, cfg, params, *, block_size: int = 16,
                 num_blocks: int = 256, max_blocks_per_seq: int | None = None,
                 num_slots: int = 4, max_prefill_len: int | None = None,
                 prefill_chunk: int = 16, num_splits: int = 1):
        self.cfg = cfg
        self.params = params
        mbs = max_blocks_per_seq if max_blocks_per_seq is not None \
            else num_blocks
        self.cache_cfg = PagedCacheConfig(
            block_size=block_size, num_blocks=num_blocks,
            max_blocks_per_seq=mbs)
        # raises for families the paged path does not serve
        self.cache = PagedKVCache(cfg, self.cache_cfg, num_slots)
        self.sched = Scheduler(num_slots, self.cache.allocator,
                               max_blocks_per_seq=mbs)
        self.prefill_chunk = prefill_chunk
        mpl = max_prefill_len if max_prefill_len is not None \
            else self.cache_cfg.max_seq_len
        # the scratch length must tile both the fixed-width prefill chunk
        # and the pool blocks (the final scatter reshapes into blocks)
        tile = math.lcm(prefill_chunk, block_size)
        self.max_prefill_len = -(-mpl // tile) * tile
        self.scratch = T.init_prefill_scratch(cfg, self.max_prefill_len)

        self.handles: dict[str, GenerationHandle] = {}
        self._cancelled: set[str] = set()
        self.steps = 0
        self.tokens_emitted = 0
        # per-step runtime stats (slot occupancy, pool utilization /
        # fragmentation from the BlockAllocator, queue depth) — refreshed
        # at every step boundary whether or not the obs layer is enabled
        self.step_stats: dict = {}
        self._m = _serve_metrics()
        self._tracer = obs.tracer()

        self._decode = jax.jit(_make_paged_step(cfg, num_splits))
        self._first = jax.jit(_make_paged_first())
        self._prefill = jax.jit(
            lambda p, scratch, toks, start, take:
            T.prefill_chunk(p, scratch, toks, start, take, cfg))
        self._write = jax.jit(
            lambda pools, scratch, ids, length:
            T.write_prefill_to_pools(pools, scratch, ids, length,
                                     block_size))

    # -- submission API ----------------------------------------------------
    def submit(self, req: Request,
               on_token: Optional[Callable] = None) -> GenerationHandle:
        if req.request_id in self.handles:
            raise ValueError(f"duplicate request id {req.request_id!r}")
        if len(req.prompt) > self.max_prefill_len:
            raise ValueError(
                f"prompt of {len(req.prompt)} tokens exceeds "
                f"max_prefill_len={self.max_prefill_len}")
        self.sched.enqueue(req)           # validates the block budget
        handle = GenerationHandle(req, self, on_token=on_token)
        handle.t_submit = time.perf_counter()
        self.handles[req.request_id] = handle
        self._m["submitted"].inc()
        return handle

    def cancel(self, request_id: str) -> None:
        """Mark a request for cancellation; it is dropped (queued) or
        retired with its blocks freed (running) at the next step
        boundary."""
        if request_id in self.handles and \
                not self.handles[request_id].done:
            self._cancelled.add(request_id)

    # -- internals ---------------------------------------------------------
    def _retire(self, slot: int, reason: str) -> None:
        req = self.sched.retire(slot)
        self.cache.clear_slot(slot)
        handle = self.handles[req.request_id]
        handle._finish(reason)
        self._m["finished"].inc(1, (reason,))
        if handle.e2e is not None:
            self._m["e2e"].observe(handle.e2e)

    def _process_cancellations(self) -> None:
        for rid in list(self._cancelled):
            self._cancelled.discard(rid)
            if self.sched.drop_pending(rid):
                self.handles[rid]._finish("cancelled")
                self._m["cancels"].inc(1, ("queued",))
                self._m["finished"].inc(1, ("cancelled",))
                continue
            slot = self.sched.slot_of(rid)
            if slot is not None:
                self._m["cancels"].inc(1, ("running",))
                self._retire(slot, "cancelled")

    def _admit(self, slot: int, req: Request) -> None:
        """Chunked prefill into the dense scratch, whole-block scatter
        into the pools, then sample the request's first token."""
        handle = self.handles[req.request_id]
        handle.t_admit = time.perf_counter()
        self._m["admissions"].inc()
        if handle.queue_wait is not None:
            self._m["queue_wait"].observe(handle.queue_wait)
        s = len(req.prompt)
        c = self.prefill_chunk
        with self._tracer.span("serve/admit", step=self.steps,
                               prompt_len=s, slot=slot):
            padded = np.zeros((1, self.max_prefill_len), np.int32)
            padded[0, :s] = req.prompt
            last = None
            for start in range(0, s, c):
                take = max(min(s - 1 - start, c - 1), 0)
                logits, self.scratch = self._prefill(
                    self.params, self.scratch,
                    jnp.asarray(padded[:, start:start + c]),
                    jnp.int32(start), jnp.int32(take))
                if start <= s - 1 < start + c:
                    last = logits

            ids = np.zeros((self.cache_cfg.max_blocks_per_seq,), np.int32)
            table = self.sched.allocator.table(req.request_id)
            ids[:len(table)] = table
            self.cache.pools = self._write(self.cache.pools, self.scratch,
                                           jnp.asarray(ids), jnp.int32(s))
            self.cache.bind_slot(slot, req.request_id)

            lanes = self.sched.lanes
            tok, hit = self._first(
                last, jnp.asarray(lanes.key[slot]), jnp.int32(s - 1),
                jnp.float32(lanes.temperature[slot]),
                jnp.int32(lanes.top_k[slot]), jnp.float32(lanes.top_p[slot]),
                jnp.int32(lanes.eos[slot]))
            tok_i = int(tok)
        handle._emit(tok_i)
        self.tokens_emitted += 1
        self._m["tokens"].inc()
        if handle.ttft is not None:
            self._m["ttft"].observe(handle.ttft)
        n = self.sched.note_token(slot)
        if bool(hit):
            self._retire(slot, "eos")
        elif n >= req.max_new_tokens:
            self._retire(slot, "length")
        else:
            lanes.token[slot] = tok_i
            lanes.pos[slot] = s

    def step(self) -> bool:
        """Advance every running sequence by one token. Admissions and
        retirements happen at this boundary; the compiled decode step
        never retraces. Returns True while work remains."""
        self._process_cancellations()
        for slot, req in self.sched.admit_ready():
            self._admit(slot, req)
        cause = self.sched.blocked_reason()
        if cause is not None:
            self._m["backpressure"].inc(1, (cause,))
        if not self.sched.running:
            self._refresh_step_stats()
            return self.sched.has_work

        lanes = self.sched.lanes
        with self._tracer.span("serve/decode_step", step=self.steps,
                               batch=len(self.sched.running)):
            pools, logits, tok, hit = self._decode(
                self.params, self.cache.pools, jnp.asarray(lanes.token),
                jnp.asarray(lanes.pos), self.cache.block_table(),
                jnp.asarray(lanes.active), jnp.asarray(lanes.key),
                jnp.asarray(lanes.temperature), jnp.asarray(lanes.top_k),
                jnp.asarray(lanes.top_p), jnp.asarray(lanes.eos))
            self.cache.pools = pools
            self.last_logits = logits   # device array; tests/debug only
            self.steps += 1
            # the single host sync of the step: streamed tokens + eos hits
            tok_h = np.asarray(tok)
            hit_h = np.asarray(hit)
        for slot in sorted(self.sched.running):
            req = self.sched.running[slot]
            t = int(tok_h[slot])
            handle = self.handles[req.request_id]
            handle._emit(t)
            self.tokens_emitted += 1
            self._m["tokens"].inc()
            tt = handle.token_times
            if len(tt) >= 2:
                self._m["itl"].observe(tt[-1] - tt[-2])
            n = self.sched.note_token(slot)
            lanes.token[slot] = t
            lanes.pos[slot] += 1
            if hit_h[slot]:
                self._retire(slot, "eos")
            elif n >= req.max_new_tokens:
                self._retire(slot, "length")
        self._refresh_step_stats()
        return self.sched.has_work

    def _refresh_step_stats(self) -> None:
        """Rebuild :attr:`step_stats` (and, when obs is on, the gauges)
        from host-side scheduler/allocator state. Always runs at the step
        boundary — the dict is the no-obs-needed view of slot occupancy
        and block-pool health (utilization, internal fragmentation)."""
        alloc = self.cache.allocator.stats()
        running = len(self.sched.running)
        pending = len(self.sched.pending)
        self.step_stats = {
            "step": self.steps,
            "running": running,
            "pending": pending,
            "tokens_emitted": self.tokens_emitted,
            "used_blocks": alloc["used_blocks"],
            "free_blocks": alloc["free_blocks"],
            "utilization": alloc["utilization"],
            "fragmentation": alloc["fragmentation"],
        }
        self._m["slots_active"].set(running)
        self._m["queue_depth"].set(pending)
        self._m["pool_util"].set(alloc["utilization"])
        self._m["pool_frag"].set(alloc["fragmentation"])
        self._m["pool_used"].set(alloc["used_blocks"])
        self._m["pool_free"].set(alloc["free_blocks"])

    def run(self) -> None:
        """Drain the queue: step until every request has finished."""
        while self.step():
            pass

    def stats(self) -> dict:
        s = self.cache.stats()
        s["pending"] = len(self.sched.pending)
        s["running"] = len(self.sched.running)
        s["steps"] = self.steps
        s["tokens_emitted"] = self.tokens_emitted
        return s
