"""Sessions, requests, and per-sequence sampling state.

The serving engine is multi-tenant: every generation is a
:class:`Request` carrying its own prompt, token budget, stop condition
and :class:`SamplingParams`. Requests live in fixed-capacity *slots*
while decoding (serve/scheduler.py); everything per-sequence that the
jit'd step needs — temperature, top-k, top-p, the PRNG key lane — rides
in slot-indexed device arrays so batch composition can change without
retracing.

Sampling itself is in-trace (:func:`sample_tokens`): one (B, V) logits
block in, one (B,) token lane out, with per-row temperature / top-k /
top-p masking and per-row PRNG keys. Greedy rows (temperature <= 0)
take the argmax; the key lanes are folded with the row's position
in-trace so a sequence's sample stream depends only on its own seed and
positions, never on which slot it landed in or who else is in the
batch.

:class:`Session` is the tenant-facing wrapper: it namespaces request
ids, applies tenant-default sampling, and hands out
:class:`GenerationHandle` objects for streaming (callback or iterator)
and cancellation.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, Iterator, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

_NEG = jnp.float32(-1e30)


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling knobs. ``temperature <= 0`` means greedy
    (top_k / top_p are then ignored). ``top_k <= 0`` disables top-k;
    ``top_p >= 1`` disables nucleus filtering."""
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    def key_data(self) -> np.ndarray:
        """Raw uint32 key lane for this request's PRNG stream."""
        return np.asarray(jax.random.key_data(
            jax.random.PRNGKey(self.seed)), np.uint32)


def _mask_top_k(scaled: jax.Array, top_k: jax.Array) -> jax.Array:
    v = scaled.shape[-1]
    desc = -jnp.sort(-scaled, axis=-1)
    kth = jnp.take_along_axis(
        desc, jnp.clip(top_k - 1, 0, v - 1)[:, None], axis=-1)
    keep = (top_k <= 0)[:, None] | (scaled >= kth)
    return jnp.where(keep, scaled, _NEG)


def _mask_top_p(scaled: jax.Array, top_p: jax.Array) -> jax.Array:
    b = scaled.shape[0]
    probs = jax.nn.softmax(scaled, axis=-1)
    order = jnp.argsort(-probs, axis=-1)
    sp = jnp.take_along_axis(probs, order, axis=-1)
    # keep the smallest prefix whose mass reaches top_p (always >= 1 token)
    keep_sorted = (jnp.cumsum(sp, axis=-1) - sp) < top_p[:, None]
    keep = jnp.zeros_like(keep_sorted).at[
        jnp.arange(b)[:, None], order].set(keep_sorted)
    return jnp.where(keep, scaled, _NEG)


def sample_tokens(logits: jax.Array, keys: jax.Array,
                  temperature: jax.Array, top_k: jax.Array,
                  top_p: jax.Array) -> jax.Array:
    """In-trace batched sampling with per-row parameters.

    logits (B, V) f32; keys (B, 2) uint32 raw key lanes; temperature /
    top_p (B,) f32; top_k (B,) int32. Returns (B,) int32 tokens. Every
    row is computed independently (vmap'd categorical over the row's own
    key), so a row's sample never depends on its neighbours.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t = jnp.maximum(temperature, 1e-6)[:, None]
    scaled = (logits.astype(jnp.float32)) / t
    scaled = _mask_top_k(scaled, top_k)
    scaled = _mask_top_p(scaled, top_p)
    sampled = jax.vmap(
        lambda k, row: jax.random.categorical(
            jax.random.wrap_key_data(k), row))(keys, scaled)
    return jnp.where(temperature <= 0.0, greedy,
                     sampled.astype(jnp.int32))


def fold_keys(keys: jax.Array, pos: jax.Array) -> jax.Array:
    """Fold each row's position into its key lane (in-trace), so step t
    of a sequence uses the same key no matter when it was admitted."""
    def one(k, p):
        folded = jax.random.fold_in(jax.random.wrap_key_data(k), p)
        return jax.random.key_data(folded)
    return jax.vmap(one)(keys, pos)


# ---------------------------------------------------------------------------
# requests and handles
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Request:
    """One generation job. ``prompt`` is a 1-D int token array/list."""
    request_id: str
    prompt: np.ndarray
    max_new_tokens: int = 32
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    eos_id: Optional[int] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")


class GenerationHandle:
    """Live view of one request: collected tokens, completion state,
    streaming, cancellation. Produced by ``PagedServeEngine.submit``.

    Lifecycle wall-clock timestamps (``time.perf_counter`` seconds) are
    stamped by the engine at its existing host boundaries — submit,
    admission, each token's host readback, finish — so per-request
    latencies (queue wait, TTFT, inter-token, end-to-end) are always
    reconstructable from the handle, with or without the obs layer:
    ``t_submit`` / ``t_admit`` / ``t_finish`` plus ``token_times[i]``
    (the emission time of ``tokens[i]``).
    """

    def __init__(self, request: Request, engine,
                 on_token: Optional[Callable[[Request, int], None]] = None):
        self.request = request
        self.tokens: list[int] = []
        self.finish_reason: Optional[str] = None
        self.t_submit: Optional[float] = None
        self.t_admit: Optional[float] = None
        self.t_finish: Optional[float] = None
        self.token_times: list[float] = []
        self._engine = engine
        self._on_token = on_token

    @property
    def done(self) -> bool:
        return self.finish_reason is not None

    # called by the engine ------------------------------------------------
    def _emit(self, token: int) -> None:
        self.tokens.append(token)
        self.token_times.append(time.perf_counter())
        if self._on_token is not None:
            self._on_token(self.request, token)

    def _finish(self, reason: str) -> None:
        if self.finish_reason is None:
            self.finish_reason = reason
            self.t_finish = time.perf_counter()

    # latency views --------------------------------------------------------
    @property
    def queue_wait(self) -> Optional[float]:
        """Seconds between submission and admission (None until admitted
        — e.g. a request cancelled while still queued)."""
        if self.t_admit is None or self.t_submit is None:
            return None
        return self.t_admit - self.t_submit

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token, measured from submission (includes queue
        wait and prefill)."""
        if not self.token_times or self.t_submit is None:
            return None
        return self.token_times[0] - self.t_submit

    def inter_token_latencies(self) -> list[float]:
        """Gaps between consecutive token emissions (empty for <2
        tokens). The engine emits at decode-step boundaries, so each gap
        is quantized to whole decode steps."""
        tt = self.token_times
        return [b - a for a, b in zip(tt, tt[1:])]

    @property
    def e2e(self) -> Optional[float]:
        """End-to-end seconds from submission to finish."""
        if self.t_finish is None or self.t_submit is None:
            return None
        return self.t_finish - self.t_submit

    def latency_summary(self) -> dict:
        """Per-request latency record (the ``--metrics`` table row)."""
        itl = self.inter_token_latencies()
        return {
            "request_id": self.request.request_id,
            "finish_reason": self.finish_reason,
            "n_tokens": len(self.tokens),
            "queue_wait": self.queue_wait,
            "ttft": self.ttft,
            "itl_mean": sum(itl) / len(itl) if itl else None,
            "e2e": self.e2e,
        }

    # called by the tenant -------------------------------------------------
    def cancel(self) -> None:
        """Stop this request at the next step boundary; its cache blocks
        return to the pool. Queued requests leave the queue immediately."""
        self._engine.cancel(self.request.request_id)

    def stream(self) -> Iterator[int]:
        """Yield this request's tokens as they are produced, pumping the
        engine while other tenants' requests make progress too."""
        seen = 0
        while True:
            while seen < len(self.tokens):
                yield self.tokens[seen]
                seen += 1
            if self.done:
                return
            self._engine.step()


class Session:
    """A tenant's view of a shared engine: namespaced request ids plus
    default sampling params. Multiple sessions submit into the same
    engine and their requests interleave in the continuous batch."""

    _ids = itertools.count()

    def __init__(self, engine, name: Optional[str] = None,
                 default_sampling: SamplingParams = SamplingParams()):
        self.engine = engine
        self.name = name or f"session{next(Session._ids)}"
        self.default_sampling = default_sampling
        self._req_ids = itertools.count()
        self.handles: dict[str, GenerationHandle] = {}

    def submit(self, prompt: Sequence[int], *, max_new_tokens: int = 32,
               sampling: Optional[SamplingParams] = None,
               eos_id: Optional[int] = None,
               on_token: Optional[Callable[[Request, int], None]] = None,
               ) -> GenerationHandle:
        rid = f"{self.name}/r{next(self._req_ids)}"
        req = Request(rid, np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens,
                      sampling=sampling or self.default_sampling,
                      eos_id=eos_id)
        handle = self.engine.submit(req, on_token=on_token)
        self.handles[rid] = handle
        return handle
