"""Continuous-batching scheduler: admit/retire without retracing.

The decode step is jit'd over fixed-capacity *slot lanes* — ``(capacity,)``
arrays of token / position / active plus the per-sequence sampling lanes
from serve/session.py. Admitting a request fills a free slot's lanes;
retiring zeroes them. The jit signature never changes, so the engine
keeps stepping one compiled function while batch composition churns.

Admission policy: strict FIFO with block-reservation backpressure. A
request needs ``ceil((len(prompt) + max_new_tokens) / block_size)``
cache blocks for its worst case; it is admitted only when a slot is
free AND the allocator can reserve that many blocks up front. If the
queue head does not fit, admission stops (no skip-ahead) — the request
stays queued, never dropped, and is retried every step as retirements
return blocks. Reserving the worst case at admission means an admitted
request can never hit an out-of-blocks condition mid-stream.

Every lane is a host numpy array mutated only at admit/retire
boundaries and uploaded once per step; per-slot computations in the
step are batch-row-independent, so a surviving sequence's logits are
bit-for-bit unchanged by its neighbours coming and going (tested in
tests/test_serve_paged.py).
"""
from __future__ import annotations

from collections import deque
from typing import Optional

import numpy as np

from .kv_cache import BlockAllocator, blocks_for
from .session import Request


class SlotLanes:
    """The per-slot device-step inputs, host-side."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.token = np.zeros((capacity,), np.int32)
        self.pos = np.zeros((capacity,), np.int32)
        self.active = np.zeros((capacity,), bool)
        self.done = np.zeros((capacity,), bool)
        self.temperature = np.zeros((capacity,), np.float32)
        self.top_k = np.zeros((capacity,), np.int32)
        self.top_p = np.ones((capacity,), np.float32)
        self.key = np.zeros((capacity, 2), np.uint32)
        self.eos = np.full((capacity,), -1, np.int32)

    def clear(self, slot: int) -> None:
        self.token[slot] = 0
        self.pos[slot] = 0
        self.active[slot] = False
        self.done[slot] = False
        self.temperature[slot] = 0.0
        self.top_k[slot] = 0
        self.top_p[slot] = 1.0
        self.key[slot] = 0
        self.eos[slot] = -1

    def fill(self, slot: int, req: Request) -> None:
        sp = req.sampling
        self.token[slot] = 0
        self.pos[slot] = 0
        self.active[slot] = True
        self.done[slot] = False
        self.temperature[slot] = sp.temperature
        self.top_k[slot] = sp.top_k
        self.top_p[slot] = sp.top_p
        self.key[slot] = sp.key_data()
        self.eos[slot] = -1 if req.eos_id is None else req.eos_id


class Scheduler:
    """FIFO admission + slot lifecycle over a shared block allocator."""

    def __init__(self, capacity: int, allocator: BlockAllocator, *,
                 max_blocks_per_seq: int):
        self.capacity = capacity
        self.allocator = allocator
        self.max_blocks_per_seq = max_blocks_per_seq
        self.lanes = SlotLanes(capacity)
        self.pending: deque[Request] = deque()
        self.running: dict[int, Request] = {}      # slot -> request
        self._free_slots: list[int] = list(range(capacity))
        self._generated: dict[int, int] = {}       # slot -> tokens emitted

    # -- queries ----------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return bool(self.pending or self.running)

    def slot_of(self, request_id: str) -> Optional[int]:
        for slot, req in self.running.items():
            if req.request_id == request_id:
                return slot
        return None

    def blocks_needed(self, req: Request) -> int:
        return blocks_for(len(req.prompt) + req.max_new_tokens,
                          self.allocator.block_size)

    def blocked_reason(self) -> Optional[str]:
        """Why the queue head is not admitted right now: ``"slots"`` (no
        free slot lane), ``"blocks"`` (pool cannot reserve its worst
        case), or None when the queue is empty / admission would proceed.
        Called after ``admit_ready`` drained what fits, this is the
        backpressure cause for this step."""
        if not self.pending:
            return None
        if not self._free_slots:
            return "slots"
        head = self.pending[0]
        if not self.allocator.can_alloc(len(head.prompt)
                                        + head.max_new_tokens):
            return "blocks"
        return None

    # -- lifecycle --------------------------------------------------------
    def enqueue(self, req: Request) -> None:
        need = self.blocks_needed(req)
        if need > self.max_blocks_per_seq:
            raise ValueError(
                f"request {req.request_id!r} needs {need} blocks, over the "
                f"per-sequence limit {self.max_blocks_per_seq}")
        if need > self.allocator.num_blocks:
            raise ValueError(
                f"request {req.request_id!r} needs {need} blocks, pool has "
                f"{self.allocator.num_blocks} total")
        self.pending.append(req)

    def admit_ready(self) -> list[tuple[int, Request]]:
        """Admit queue-head requests while a slot and blocks are free.
        Reserves the request's worst-case blocks and fills its slot
        lanes; the engine then prefills and sets token/pos."""
        admitted = []
        while (self.pending and self._free_slots
               and self.allocator.can_alloc(
                   len(self.pending[0].prompt)
                   + self.pending[0].max_new_tokens)):
            req = self.pending.popleft()
            slot = self._free_slots.pop(0)
            self.allocator.alloc(req.request_id,
                                 len(req.prompt) + req.max_new_tokens)
            self.lanes.fill(slot, req)
            self.running[slot] = req
            self._generated[slot] = 0
            admitted.append((slot, req))
        return admitted

    def retire(self, slot: int) -> Request:
        """Free the slot's blocks and lanes; returns the request."""
        req = self.running.pop(slot)
        self.allocator.free(req.request_id)
        self.lanes.clear(slot)
        self._generated.pop(slot)
        self._free_slots.append(slot)
        self._free_slots.sort()
        return req

    def drop_pending(self, request_id: str) -> bool:
        """Remove a queued (not yet admitted) request."""
        for req in self.pending:
            if req.request_id == request_id:
                self.pending.remove(req)
                return True
        return False

    def note_token(self, slot: int) -> int:
        """Count one emitted token for ``slot``; returns the new total."""
        self._generated[slot] += 1
        return self._generated[slot]

    def generated(self, slot: int) -> int:
        return self._generated[slot]
