from .engine import PagedServeEngine, ServeEngine, make_serve_step
from .kv_cache import (BlockAllocator, OutOfBlocksError, PagedCacheConfig,
                       PagedKVCache, blocks_for, paged_supported)
from .scheduler import Scheduler, SlotLanes
from .session import (GenerationHandle, Request, SamplingParams, Session,
                      sample_tokens)

__all__ = [
    "ServeEngine", "PagedServeEngine", "make_serve_step",
    "BlockAllocator", "OutOfBlocksError", "PagedCacheConfig", "PagedKVCache",
    "blocks_for", "paged_supported", "Scheduler", "SlotLanes",
    "GenerationHandle", "Request", "SamplingParams", "Session",
    "sample_tokens",
]
