"""Resilient training: in-jit anomaly guards + a host-side escalation
ladder (DESIGN.md §11, docs/resilience.md).

The paper's pitch is cheap low-rank optimization for *long* pre-training
runs; what kills long runs in practice is not throughput but a NaN that
checkpoints itself, a loss spike that compounds for thousands of steps, or
a corrupted ``state.npz`` discovered only at restore time. This module is
the policy layer over three mechanisms:

**In-jit guard** (``make_train_step(..., guard=True)``): the step computes
one ``all_finite`` flag from quantities that are already resident — the
loss, the gradient global norm (``isfinite`` of a sum of squares catches
any NaN/Inf in the tree), and a per-leaf ``isfinite().all()`` over the
updates (fused by XLA into the pass that produces them). The new state is
then selected *inside* the jitted step — ``jnp.where(flag, new, old)`` per
leaf — which is the only correct place: with ``donate_argnums=0`` the old
state's buffers are donated, so the host can never "keep the old state"
after the fact. Untouched leaves (shared bases, the PRNG key, keep-step
index sets) select between identical tensors and XLA folds the select
away, so the lowered HLO differs from an unguarded step only by the
finite-flag selects (gated ≤1 % flops/bytes by
``benchmarks/resilience_overhead.py``).

**Escalation ladder** (:class:`ResilienceManager`): the host consumes the
flag (and a loss-vs-EMA divergence signal) every step and escalates:

1. *skip* — the guard already refused the update; drop the offending
   batch (the data step advances, the optimizer step does not) and retry
   with fresh data, up to ``max_skips`` consecutive times;
2. *rollback* — restore the last **verified** checkpoint
   (``CheckpointManager.restore_latest`` walks past corrupt ones) and
   skip the offending data window, so the deterministic batch sequence
   cannot re-poison the run;
3. *rollback + LR cut* — subsequent rollbacks also cut the learning rate
   by ``lr_cut`` through the ``inject_hyperparams`` state leaf
   (:func:`scale_hyperparam` — pure state surgery, zero retrace);
4. *halt* — a deterministic divergence that survives rollbacks and LR
   cuts is not recoverable by restarting; dump diagnostics and exit with
   :data:`HALT_EXIT_CODE` so the supervisor stops instead of burning its
   restart budget on a crash loop.

The ladder's counters (and the cumulative LR scale and data offset) ride
the checkpoint manifest, so a preemption mid-recovery resumes mid-ladder.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro import obs

#: Exit code for an unrecoverable halt (rung 4). The supervisor treats it
#: as permanent — no restart, the failure is deterministic.
HALT_EXIT_CODE = 86


def _ladder_metrics():
    """Escalation-ladder instruments (no-ops until ``obs.enable()``).
    Every decision also lands as a structured ``resilience/...`` instant
    on the span tracer with before/after ladder state."""
    r = obs.registry()
    return {
        "guard_trips": r.counter(
            "resilience_guard_trips_total",
            "steps where the in-jit guard reported non-finite"),
        "spikes": r.counter("resilience_loss_spikes_total",
                            "finite steps flagged as loss spikes"),
        "actions": r.counter("resilience_actions_total",
                             "ladder decisions, by rung",
                             labels=("kind",)),
        "lr_cuts": r.counter("resilience_lr_cuts_total",
                             "rollbacks that also cut the learning rate"),
        "lr_scale": r.gauge("resilience_lr_scale",
                            "cumulative learning-rate scale"),
        "rollback_budget": r.gauge(
            "resilience_rollbacks_used",
            "rollbacks consumed against cfg.max_rollbacks"),
    }


class TrainingHalted(RuntimeError):
    """Raised when the escalation ladder is exhausted (rung 4)."""


# ---------------------------------------------------------------------------
# in-jit guard primitives
# ---------------------------------------------------------------------------
def all_finite_tree(tree) -> jax.Array:
    """Scalar bool: every element of every inexact leaf is finite.

    Per-leaf ``isfinite().all()`` reductions fuse with the producers of the
    leaves (the update arithmetic), so checking a tree that is already
    being materialized costs no extra memory traffic."""
    flag = jnp.asarray(True)
    for leaf in jax.tree.leaves(tree):
        if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.inexact):
            flag = jnp.logical_and(flag, jnp.isfinite(leaf).all())
    return flag


def select_tree(flag: jax.Array, new, old):
    """``jnp.where(flag, new, old)`` on every leaf of two same-structure
    trees — the donation-safe commit/reject point of the guarded step.
    Leaves the step did not touch are the *same* tensor in both trees and
    XLA folds their select away."""
    return jax.tree.map(lambda n, o: jnp.where(flag, n, o), new, old)


def scale_hyperparam(opt_state, name: str, factor) -> tuple[Any, int]:
    """Multiply every ``inject_hyperparams`` state entry called ``name`` by
    ``factor`` — pure value surgery on the optimizer state (same shapes,
    same dtypes), so the already-compiled step keeps running without a
    retrace. Returns ``(new_state, n_scaled)``; ``n_scaled == 0`` means
    the optimizer was built without that injected hyperparameter."""
    hits = 0

    def visit(kp, leaf):
        nonlocal hits
        if len(kp) >= 2 \
                and getattr(kp[-2], "name", None) == "hyperparams" \
                and str(getattr(kp[-1], "key", "")) == name:
            hits += 1
            return (leaf * jnp.asarray(factor, leaf.dtype)).astype(leaf.dtype)
        return leaf

    new_state = jax.tree_util.tree_map_with_path(visit, opt_state)
    return new_state, hits


# ---------------------------------------------------------------------------
# host-side escalation ladder
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Knobs of the escalation ladder (docs/resilience.md for the guide)."""

    #: consecutive bad steps tolerated as plain batch skips before the
    #: ladder escalates to a rollback
    max_skips: int = 2
    #: rollbacks (to the last verified checkpoint) before the run halts
    max_rollbacks: int = 3
    #: learning-rate factor applied on the second and later rollbacks
    #: (through the ``lr_scale`` injected hyperparameter; cumulative)
    lr_cut: float = 0.5
    #: loss > spike_factor * EMA(loss) counts as a divergence signal
    spike_factor: float = 4.0
    #: EMA decay for the divergence reference
    ema_decay: float = 0.98
    #: healthy steps before spike detection arms (the reference is noise
    #: until the EMA has seen a window)
    ema_warmup: int = 10
    #: consecutive spiking (but finite) steps tolerated before rollback —
    #: finite spikes have already been committed, so there is no skip rung
    spike_patience: int = 3
    #: healthy steps after which the rollback budget heals back to zero
    #: (an isolated recovered incident should not count against a fault
    #: thousands of steps later)
    heal_steps: int = 200


class Action(NamedTuple):
    """One ladder decision. ``kind``: ``ok`` | ``skip`` | ``rollback`` |
    ``halt``. ``lr_factor`` < 1 asks the trainer to cut the LR after the
    rollback restore; ``reason`` is the log/diagnostic line."""

    kind: str
    reason: str = ""
    lr_factor: float = 1.0


class ResilienceManager:
    """Consumes per-step health signals, emits ladder :class:`Action`\\ s,
    and owns the recovery bookkeeping that must survive restarts
    (cumulative ``lr_scale``, the data-window ``data_offset``, the
    rollback budget). The Trainer executes the actions; this class never
    touches device state itself."""

    def __init__(self, cfg: ResilienceConfig | None = None, *,
                 log_fn: Callable[[str], None] = print):
        self.cfg = cfg or ResilienceConfig()
        self.log = log_fn
        self._m = _ladder_metrics()
        self._tracer = obs.tracer()
        self.consecutive_bad = 0
        self.consecutive_spikes = 0
        self.n_rollbacks = 0
        self.n_skips = 0
        self.healthy_streak = 0
        self.lr_scale = 1.0
        self.data_offset = 0
        self.loss_ema: float | None = None
        self.ema_steps = 0
        self.halted: str | None = None
        self._recent: list[dict] = []   # rolling diagnostics window

    # -- policy -------------------------------------------------------------
    def observe(self, step: int, loss: float, all_finite: bool) -> Action:
        """Classify one completed step and decide the ladder rung.

        ``all_finite=False`` means the in-jit guard already refused the
        update (state unchanged); a finite loss above ``spike_factor`` ×
        EMA is a divergence signal on a step that *did* commit — it has no
        skip rung, only patience before rollback."""
        self._recent.append({"step": step, "loss": float(loss),
                             "all_finite": bool(all_finite)})
        del self._recent[:-50]
        if not all_finite:
            self.consecutive_bad += 1
            self.healthy_streak = 0
            self._m["guard_trips"].inc()
            self._tracer.instant("resilience/guard_trip", step=step,
                                 loss=float(loss),
                                 consecutive=self.consecutive_bad)
            if self.consecutive_bad <= self.cfg.max_skips:
                self.n_skips += 1
                return self._decided(step, Action(
                    "skip", f"non-finite step ({self.consecutive_bad}/"
                            f"{self.cfg.max_skips} consecutive)"))
            return self._decided(step, self._escalate(
                "non-finite steps persist through "
                f"{self.cfg.max_skips} skipped batches"))
        spiking = (self.ema_steps >= self.cfg.ema_warmup
                   and self.loss_ema is not None
                   and loss > self.cfg.spike_factor * self.loss_ema)
        if spiking:
            self.consecutive_spikes += 1
            self.healthy_streak = 0
            self._m["spikes"].inc()
            self._tracer.instant("resilience/loss_spike", step=step,
                                 loss=float(loss), ema=float(self.loss_ema),
                                 consecutive=self.consecutive_spikes)
            if self.consecutive_spikes <= self.cfg.spike_patience:
                return Action("ok",
                              f"loss spike {loss:.3g} vs EMA "
                              f"{self.loss_ema:.3g} ({self.consecutive_spikes}"
                              f"/{self.cfg.spike_patience})")
            return self._decided(step, self._escalate(
                f"loss diverged: {loss:.3g} > {self.cfg.spike_factor:g}x "
                f"EMA {self.loss_ema:.3g} for "
                f"{self.cfg.spike_patience} steps"))
        # healthy step: update the divergence reference, heal the ladder
        self.consecutive_bad = 0
        self.consecutive_spikes = 0
        self.healthy_streak += 1
        d = self.cfg.ema_decay
        self.loss_ema = (loss if self.loss_ema is None
                         else d * self.loss_ema + (1.0 - d) * loss)
        self.ema_steps += 1
        if self.healthy_streak == self.cfg.heal_steps and self.n_rollbacks:
            self.log(f"[resilience] {self.cfg.heal_steps} healthy steps — "
                     f"rollback budget healed")
            self.n_rollbacks = 0
        return Action("ok")

    def _decided(self, step: int, action: Action) -> Action:
        """Record a non-ok ladder decision: rung counter, before/after
        gauges, and a structured instant carrying the full decision."""
        self._m["actions"].inc(1, (action.kind,))
        if action.lr_factor != 1.0:
            self._m["lr_cuts"].inc()
        self._m["lr_scale"].set(self.lr_scale)
        self._m["rollback_budget"].set(self.n_rollbacks)
        self._tracer.instant(f"resilience/{action.kind}", step=step,
                             reason=action.reason,
                             lr_factor=action.lr_factor,
                             lr_scale=self.lr_scale,
                             rollbacks=self.n_rollbacks,
                             skips=self.n_skips)
        return action

    def _escalate(self, reason: str) -> Action:
        self.consecutive_bad = 0
        self.consecutive_spikes = 0
        self.n_rollbacks += 1
        if self.n_rollbacks > self.cfg.max_rollbacks:
            self.halted = (f"{reason}; ladder exhausted after "
                           f"{self.cfg.max_rollbacks} rollbacks")
            return Action("halt", self.halted)
        lr_factor = self.cfg.lr_cut if self.n_rollbacks >= 2 else 1.0
        if lr_factor != 1.0:
            self.lr_scale *= lr_factor
        return Action("rollback",
                      f"{reason} (rollback {self.n_rollbacks}/"
                      f"{self.cfg.max_rollbacks}"
                      + (f", lr x{self.lr_scale:g}" if lr_factor != 1.0
                         else "") + ")",
                      lr_factor=lr_factor)

    def rolled_back(self, from_step: int, to_step: int) -> None:
        """Trainer callback after a restore: shift the data window past the
        offending batches and reset the divergence reference (the EMA was
        tracking the diverged trajectory)."""
        # next fetch at trainer step `to_step` must consume the batch
        # *after* the one that went bad at trainer step `from_step`
        self.data_offset += (from_step - to_step) + 1
        self.loss_ema = None
        self.ema_steps = 0
        self.healthy_streak = 0

    def skipped(self) -> None:
        """Trainer callback after a skip: the optimizer step is retried
        with the next batch, so the data window advances by one."""
        self.data_offset += 1

    def apply_lr_scale(self, opt_state):
        """Re-impose the cumulative LR cut on a freshly restored optimizer
        state (the checkpointed ``lr_scale`` leaf predates the cuts)."""
        if self.lr_scale == 1.0:
            return opt_state
        new_state, hits = scale_hyperparam(opt_state, "lr_scale",
                                           self.lr_scale)
        if not hits:
            self.log("[resilience] LR-cut rung unavailable: optimizer has "
                     "no injected 'lr_scale' hyperparameter (build it with "
                     "lr_scale=True); continuing with plain rollback")
            return opt_state
        return new_state

    # -- diagnostics --------------------------------------------------------
    def dump(self, path: str, context: dict | None = None) -> str:
        """Write the halt diagnostic (ladder state + the recent-step
        window) as JSON; returns the path."""
        record = {
            "halted": self.halted,
            "time": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "ladder": self.state_dict(),
            "recent_steps": self._recent,
            **(context or {}),
        }
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(record, f, indent=2)
        self.log(f"[resilience] halt diagnostics -> {path}")
        return path

    # -- persistence (rides the checkpoint manifest) ------------------------
    def state_dict(self) -> dict:
        return {
            "n_rollbacks": self.n_rollbacks,
            "n_skips": self.n_skips,
            "lr_scale": self.lr_scale,
            "data_offset": self.data_offset,
            "healthy_streak": self.healthy_streak,
        }

    def load_state_dict(self, d: dict) -> None:
        self.n_rollbacks = int(d.get("n_rollbacks", 0))
        self.n_skips = int(d.get("n_skips", 0))
        self.lr_scale = float(d.get("lr_scale", 1.0))
        self.data_offset = int(d.get("data_offset", 0))
        self.healthy_streak = int(d.get("healthy_streak", 0))
