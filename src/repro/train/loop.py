"""Training loop with checkpoint/restart, preemption handling, and elastic
restore — the single-process core that ``launch/train.py --supervise``
wraps with a restart supervisor for node-failure tolerance.

Observability and control plug in through three hooks (DESIGN.md §8):

``log_metrics(record)``
    Structured per-step metrics: ``record`` is ``{"step": int,
    "s_per_step": float, **metrics}`` with metric values still device-side
    (consumers decide when to sync). The trainer's own console line is
    built from the same records by an internal default formatter, so plain
    ``print`` and the telemetry sink are both just consumers of this hook.
``control_hook(step, state, metrics) -> state | None``
    Closed-loop controllers (adaptive rank/refresh): called every step;
    a non-None return replaces the train state (the hook owner also swaps
    its jitted step function — pass a delegating ``train_step``).
``extra_state``
    Object with ``state_dict() -> dict`` / ``load_state_dict(dict)``:
    JSON-serializable controller state checkpointed in the manifest and
    restored *before* ``init_state_fn`` runs, because restored controller
    state determines the optimizer-state shapes of the restore target.

Distributed state (DESIGN.md §9): checkpoints are saved mesh-agnostic
(gathered host arrays), so a ZeRO-partitioned run hands the Trainer its
``state_shardings`` (a TrainState-shaped tree of NamedShardings for the
*current* mesh) and restore re-partitions onto it — the DP width may
change between the save and the resume (elastic restart / resharding on
topology change).
"""
from __future__ import annotations

import signal
import time
from typing import Any, Callable

import jax

from repro.data.pipeline import DataPipeline

from .checkpoint import CheckpointManager
from .steps import TrainState


class Trainer:
    def __init__(self, *, train_step, init_state_fn, batch_fn,
                 ckpt_dir: str | None = None, ckpt_every: int = 50,
                 keep: int = 3, log_every: int = 10,
                 log_fn: Callable[[str], None] = print,
                 log_metrics: Callable[[dict], None] | None = None,
                 control_hook=None, extra_state=None,
                 state_shardings=None):
        self.train_step = train_step
        self.init_state_fn = init_state_fn
        self.batch_fn = batch_fn
        self.ckpt = CheckpointManager(ckpt_dir, keep) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self.log_every = log_every
        self.log = log_fn
        self.log_metrics = log_metrics
        self.control_hook = control_hook
        self.extra_state = extra_state
        self.state_shardings = state_shardings
        self._preempted = False
        self._window: list[float] = []

    def _install_sigterm(self):
        def handler(signum, frame):
            # preemption notice: finish the current step, checkpoint, exit
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:              # not on main thread (tests)
            pass

    def _default_log_metrics(self, record: dict):
        """Console formatter over the structured records — same cadence and
        string as the historic pre-formatted logging."""
        self._window.append(record["s_per_step"])
        step = record["step"]
        if step % self.log_every == 0:
            dt = sum(self._window) / len(self._window)
            self._window = []
            self.log(f"[trainer] step {step} loss "
                     f"{float(record['loss']):.4f} "
                     f"({dt * 1e3:.0f} ms/step)")

    def _emit(self, step: int, metrics: dict, dt: float):
        record = {"step": step, "s_per_step": dt, **metrics}
        self._default_log_metrics(record)
        if self.log_metrics is not None:
            self.log_metrics(record)

    def _ckpt_extra(self) -> dict | None:
        if self.extra_state is None:
            return None
        return {"extra_state": self.extra_state.state_dict()}

    def run(self, total_steps: int, resume: bool = True) -> TrainState:
        self._install_sigterm()
        start = 0
        resume_step = None
        if resume and self.ckpt is not None:
            resume_step = self.ckpt.latest_step()
            if resume_step is not None and self.extra_state is not None:
                # controller state first: it shapes the restore target
                extra = self.ckpt.manifest(resume_step).get("extra_state")
                if extra:
                    self.extra_state.load_state_dict(extra)
        state = self.init_state_fn()
        if resume_step is not None:
            state = self.ckpt.restore(resume_step, state,
                                      shardings=self.state_shardings)
            start = resume_step
            self.log(f"[trainer] resumed from checkpoint step {resume_step}")

        pipeline = DataPipeline(self.batch_fn, start_step=start)
        losses = []
        try:
            for step in range(start, total_steps):
                t0 = time.perf_counter()
                batch = pipeline.get(step)
                state, metrics = self.train_step(state, batch)
                # block on the loss before stopping the clock — the same
                # sync point the historic float(loss) imposed — so
                # s_per_step measures compute, not async dispatch latency
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                if "telemetry" in metrics and (
                        self.log_metrics is not None
                        or self.control_hook is not None):
                    # one bulk device->host transfer shared by the sink and
                    # the controllers (instead of per-field fetches twice)
                    metrics["telemetry"] = jax.device_get(
                        metrics["telemetry"])
                # metrics_history keeps scalars only: retaining every
                # step's per-leaf stats pytree would grow device memory
                # unbounded, and the sink's ring/file already persist them
                losses.append({k: v for k, v in metrics.items()
                               if k != "telemetry"})
                self._emit(step + 1, metrics, dt)
                if self.control_hook is not None:
                    new_state = self.control_hook(step + 1, state, metrics)
                    if new_state is not None:
                        state = new_state
                if self.ckpt is not None and (
                        (step + 1) % self.ckpt_every == 0 or self._preempted):
                    self.ckpt.async_save(step + 1, state,
                                         extra=self._ckpt_extra())
                if self._preempted:
                    self.log("[trainer] SIGTERM -> checkpointed, exiting")
                    break
        finally:
            pipeline.close()
            if self.ckpt is not None:
                self.ckpt.wait()
        self.metrics_history = losses
        return state
