"""Training loop with checkpoint/restart, preemption handling, and elastic
restore — the single-process core that ``launch/train.py --supervise``
wraps with a restart supervisor for node-failure tolerance.
"""
from __future__ import annotations

import signal
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.data.pipeline import DataPipeline

from .checkpoint import CheckpointManager
from .steps import TrainState


class Trainer:
    def __init__(self, *, train_step, init_state_fn, batch_fn,
                 ckpt_dir: str | None = None, ckpt_every: int = 50,
                 keep: int = 3, log_every: int = 10,
                 log_fn: Callable[[str], None] = print):
        self.train_step = train_step
        self.init_state_fn = init_state_fn
        self.batch_fn = batch_fn
        self.ckpt = CheckpointManager(ckpt_dir, keep) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self.log_every = log_every
        self.log = log_fn
        self._preempted = False

    def _install_sigterm(self):
        def handler(signum, frame):
            # preemption notice: finish the current step, checkpoint, exit
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:              # not on main thread (tests)
            pass

    def run(self, total_steps: int, resume: bool = True) -> TrainState:
        self._install_sigterm()
        state = self.init_state_fn()
        start = 0
        if resume and self.ckpt is not None:
            step, restored = self.ckpt.restore_latest(state)
            if step is not None:
                state, start = restored, step
                self.log(f"[trainer] resumed from checkpoint step {step}")

        pipeline = DataPipeline(self.batch_fn, start_step=start)
        losses = []
        try:
            t0 = time.perf_counter()
            for step in range(start, total_steps):
                batch = pipeline.get(step)
                state, metrics = self.train_step(state, batch)
                losses.append(metrics)
                if (step + 1) % self.log_every == 0:
                    loss = float(metrics["loss"])
                    dt = (time.perf_counter() - t0) / self.log_every
                    self.log(f"[trainer] step {step + 1} loss {loss:.4f} "
                             f"({dt * 1e3:.0f} ms/step)")
                    t0 = time.perf_counter()
                if self.ckpt is not None and (
                        (step + 1) % self.ckpt_every == 0 or self._preempted):
                    self.ckpt.async_save(step + 1, state)
                if self._preempted:
                    self.log("[trainer] SIGTERM -> checkpointed, exiting")
                    break
        finally:
            pipeline.close()
            if self.ckpt is not None:
                self.ckpt.wait()
        self.metrics_history = losses
        return state
