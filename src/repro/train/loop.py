"""Training loop with checkpoint/restart, preemption handling, and elastic
restore — the single-process core that ``launch/train.py --supervise``
wraps with a restart supervisor for node-failure tolerance.

Observability and control plug in through three hooks (DESIGN.md §8):

``log_metrics(record)``
    Structured per-step metrics: ``record`` is ``{"step": int,
    "s_per_step": float, **metrics}`` with metric values still device-side
    (consumers decide when to sync). ``s_per_step`` is the wall time of
    the whole step body — data wait + dispatch + blocking on the loss —
    see the timing note inside :meth:`Trainer.run` for exactly what that
    does and does not measure. The trainer's own console line is built
    from the same records by an internal default formatter, so plain
    ``print`` and the telemetry sink are both just consumers of this hook.
``control_hook(step, state, metrics) -> state | None``
    Closed-loop controllers (adaptive rank/refresh): called every step;
    a non-None return replaces the train state (the hook owner also swaps
    its jitted step function — pass a delegating ``train_step``).
``extra_state``
    Object with ``state_dict() -> dict`` / ``load_state_dict(dict)``:
    JSON-serializable controller state checkpointed in the manifest and
    restored *before* ``init_state_fn`` runs, because restored controller
    state determines the optimizer-state shapes of the restore target.

Distributed state (DESIGN.md §9): checkpoints are saved mesh-agnostic
(gathered host arrays), so a ZeRO-partitioned run hands the Trainer its
``state_shardings`` (a TrainState-shaped tree of NamedShardings for the
*current* mesh) and restore re-partitions onto it — the DP width may
change between the save and the resume (elastic restart / resharding on
topology change).
"""
from __future__ import annotations

import os
import signal
import time
from typing import Any, Callable

import jax

from repro import obs
from repro.data.pipeline import DataPipeline

from .checkpoint import CheckpointManager
from .resilience import TrainingHalted
from .steps import TrainState


def _train_metrics():
    """Training-loop instruments on the process-wide registry (no-ops
    until ``obs.enable()``). Catalog: docs/observability.md."""
    r = obs.registry()
    return {
        "data_wait": r.histogram(
            "train_data_wait_seconds",
            "blocking on the data pipeline for the step's batch"),
        "dispatch": r.histogram(
            "train_dispatch_seconds",
            "train_step call: trace/dispatch only, returns before "
            "the device finishes"),
        "host_sync": r.histogram(
            "train_host_sync_seconds",
            "blocking on the loss scalar after dispatch"),
        "step_wall": r.histogram(
            "train_step_seconds",
            "full step body wall time (data wait + dispatch + loss sync)"),
        "full_sync": r.histogram(
            "train_full_sync_seconds",
            "sampled data-ready -> whole-TrainState-ready wall time "
            "(only when sync_sample_every > 0)"),
        "steps": r.counter("train_steps_total",
                           "step outcomes", labels=("outcome",)),
    }


class Trainer:
    def __init__(self, *, train_step, init_state_fn, batch_fn,
                 ckpt_dir: str | None = None, ckpt_every: int = 50,
                 keep: int = 3, log_every: int = 10,
                 log_fn: Callable[[str], None] = print,
                 log_metrics: Callable[[dict], None] | None = None,
                 control_hook=None, extra_state=None,
                 state_shardings=None, resilience=None,
                 ckpt_fault_hook=None, sync_sample_every: int = 0):
        self.train_step = train_step
        self.init_state_fn = init_state_fn
        self.batch_fn = batch_fn
        self.ckpt = (CheckpointManager(ckpt_dir, keep,
                                       fault_hook=ckpt_fault_hook,
                                       log=log_fn)
                     if ckpt_dir else None)
        self.ckpt_every = ckpt_every
        self.log_every = log_every
        self.log = log_fn
        self.log_metrics = log_metrics
        self.control_hook = control_hook
        self.extra_state = extra_state
        self.state_shardings = state_shardings
        self.resilience = resilience
        # 0 disables the sampled full-state sync; K > 0 blocks on the
        # whole TrainState every K steps to measure true per-step compute
        # (s_per_step alone can't — see the timing note in run())
        self.sync_sample_every = sync_sample_every
        self._m = _train_metrics()
        self._tracer = obs.tracer()
        self._preempted = False
        self._window: list[float] = []

    def _install_sigterm(self):
        def handler(signum, frame):
            # preemption notice: finish the current step, checkpoint, exit
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:              # not on main thread (tests)
            pass

    def _default_log_metrics(self, record: dict):
        """Console formatter over the structured records — same cadence and
        string as the historic pre-formatted logging."""
        self._window.append(record["s_per_step"])
        step = record["step"]
        if step % self.log_every == 0:
            dt = sum(self._window) / len(self._window)
            self._window = []
            self.log(f"[trainer] step {step} loss "
                     f"{float(record['loss']):.4f} "
                     f"({dt * 1e3:.0f} ms/step)")

    def _emit(self, step: int, metrics: dict, dt: float):
        record = {"step": step, "s_per_step": dt, **metrics}
        self._default_log_metrics(record)
        if self.log_metrics is not None:
            self.log_metrics(record)

    def _ckpt_extra(self) -> dict | None:
        extra = {}
        if self.extra_state is not None:
            extra["extra_state"] = self.extra_state.state_dict()
        if self.resilience is not None:
            extra["resilience"] = self.resilience.state_dict()
        return extra or None

    def _load_checkpoint(self, step: int, *,
                         load_resilience: bool) -> TrainState:
        """Restore ``step``: manifest-carried state first (controller state
        shapes the restore target; the ladder's counters only on a fresh
        resume — a mid-run rollback must *keep* its escalation state), then
        the arrays, then re-impose the cumulative LR cut (the checkpointed
        ``lr_scale`` leaf predates the cuts)."""
        manifest = self.ckpt.manifest(step)
        if self.extra_state is not None:
            extra = manifest.get("extra_state")
            if extra:
                self.extra_state.load_state_dict(extra)
        if load_resilience and self.resilience is not None:
            rs = manifest.get("resilience")
            if rs:
                self.resilience.load_state_dict(rs)
        state = self.ckpt.restore(step, self.init_state_fn(),
                                  shardings=self.state_shardings)
        if self.resilience is not None:
            state = state._replace(
                opt_state=self.resilience.apply_lr_scale(state.opt_state))
        return state

    def run(self, total_steps: int, resume: bool = True) -> TrainState:
        self._install_sigterm()
        res = self.resilience
        start = 0
        state = None
        if resume and self.ckpt is not None:
            # newest checkpoint that passes CRC verification — corrupt ones
            # are quarantined and the next-older candidate is tried
            resume_step = self.ckpt.latest_verified_step()
            if resume_step is not None:
                state = self._load_checkpoint(resume_step,
                                              load_resilience=True)
                start = resume_step
                self.log(f"[trainer] resumed from checkpoint step "
                         f"{resume_step}")
        if state is None:
            state = self.init_state_fn()

        offset = res.data_offset if res is not None else 0
        pipeline = DataPipeline(self.batch_fn, start_step=start + offset)
        losses = []
        step = start
        try:
            while step < total_steps:
                t0 = time.perf_counter()
                data_step = step + (res.data_offset if res is not None
                                    else 0)
                with self._tracer.span("train/data_wait", step=step + 1):
                    batch = pipeline.get(data_step)
                t_data = time.perf_counter()
                with self._tracer.span("train/dispatch", step=step + 1):
                    state, metrics = self.train_step(state, batch)
                t_disp = time.perf_counter()
                # Timing note: blocking on the loss scalar is the same
                # sync point the historic float(loss) imposed, so
                # s_per_step is comparable across versions — but it is
                # NOT pure compute. It includes the data wait above and
                # only proves the loss is ready; donated/async outputs of
                # the step (params, opt state) may still be in flight.
                # The honest full-state figure is the sampled sync below
                # (sync_sample_every), exported as
                # train_full_sync_seconds.
                with self._tracer.span("train/host_sync", step=step + 1):
                    jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                self._m["data_wait"].observe(t_data - t0)
                self._m["dispatch"].observe(t_disp - t_data)
                self._m["host_sync"].observe(max(dt - (t_disp - t0), 0.0))
                self._m["step_wall"].observe(dt)
                if self.sync_sample_every > 0 \
                        and (step + 1) % self.sync_sample_every == 0:
                    with self._tracer.span("train/full_sync",
                                           step=step + 1):
                        jax.block_until_ready(state)
                    # data-ready -> whole-state-ready: per-step compute
                    self._m["full_sync"].observe(
                        time.perf_counter() - t_data)
                if "telemetry" in metrics and (
                        self.log_metrics is not None
                        or self.control_hook is not None):
                    # one bulk device->host transfer shared by the sink and
                    # the controllers (instead of per-field fetches twice)
                    metrics["telemetry"] = jax.device_get(
                        metrics["telemetry"])
                committed = True
                if res is not None:
                    action = res.observe(
                        step + 1, float(metrics["loss"]),
                        bool(metrics.get("all_finite", True)))
                    if action.reason:
                        self.log(f"[resilience] {action.kind}: "
                                 f"{action.reason}")
                    if action.kind == "skip":
                        # the guard already refused the update in-jit; the
                        # optimizer step stands still, the data step moves
                        # past the offending batch (offset+1 keeps the
                        # prefetch stream contiguous)
                        res.skipped()
                        committed = False
                        self._m["steps"].inc(1, ("skipped",))
                    elif action.kind == "rollback":
                        state, step, pipeline = self._rollback(step,
                                                               pipeline)
                        committed = False
                        self._m["steps"].inc(1, ("rolled_back",))
                    elif action.kind == "halt":
                        if self.ckpt is not None:
                            res.dump(os.path.join(self.ckpt.dir,
                                                  "halt.json"),
                                     context={"trainer_step": step})
                        raise TrainingHalted(action.reason)
                if committed:
                    self._m["steps"].inc(1, ("committed",))
                    # metrics_history keeps scalars only: retaining every
                    # step's per-leaf stats pytree would grow device memory
                    # unbounded, and the sink's ring/file persist them
                    losses.append({k: v for k, v in metrics.items()
                                   if k != "telemetry"})
                    self._emit(step + 1, metrics, dt)
                    if self.control_hook is not None:
                        new_state = self.control_hook(step + 1, state,
                                                      metrics)
                        if new_state is not None:
                            state = new_state
                    step += 1
                if self.ckpt is not None and (
                        (committed and step % self.ckpt_every == 0)
                        or self._preempted):
                    self.ckpt.async_save(step, state,
                                         extra=self._ckpt_extra())
                if self._preempted:
                    self.log("[trainer] SIGTERM -> checkpointed, exiting")
                    break
        finally:
            pipeline.close()
            if self.ckpt is not None:
                self.ckpt.wait()
        self.metrics_history = losses
        return state

    def _rollback(self, step: int, pipeline):
        """Ladder rung 2/3: restore the last verified checkpoint (or a
        fresh init when none survives verification), shift the data window
        past the offending batches, and rebuild the prefetch pipeline on
        the shifted stream."""
        if self.ckpt is not None:
            self.ckpt.wait()            # never read under a pending writer
            to_step = self.ckpt.latest_verified_step()
        else:
            to_step = None
        if to_step is not None:
            state = self._load_checkpoint(to_step, load_resilience=False)
        else:
            # nothing restorable — roll all the way back to initialization
            to_step = 0
            state = self.init_state_fn()
            state = state._replace(
                opt_state=self.resilience.apply_lr_scale(state.opt_state))
        self.log(f"[trainer] rollback: step {step} -> {to_step}")
        self.resilience.rolled_back(from_step=step, to_step=to_step)
        pipeline.close()
        pipeline = DataPipeline(
            self.batch_fn,
            start_step=to_step + self.resilience.data_offset)
        return state, to_step, pipeline
