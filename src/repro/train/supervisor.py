"""Restart supervisor: node-failure tolerance for the training driver.

Runs the training entrypoint in a child process; on a non-zero exit
(crash, OOM, killed node in a real deployment) it restarts from the latest
checkpoint, with capped exponential backoff and a max-restart budget.
Because checkpoints are mesh-agnostic (train/checkpoint.py), the restarted
run may come back with a different data-parallel width (elastic).
"""
from __future__ import annotations

import subprocess
import sys
import time


def supervise(cmd: list[str], *, max_restarts: int = 10,
              backoff_s: float = 2.0, max_backoff_s: float = 60.0,
              log=print) -> int:
    attempt = 0
    while True:
        log(f"[supervisor] launching (attempt {attempt + 1}): {' '.join(cmd)}")
        proc = subprocess.run(cmd)
        if proc.returncode == 0:
            log("[supervisor] clean exit")
            return 0
        attempt += 1
        if attempt > max_restarts:
            log(f"[supervisor] giving up after {max_restarts} restarts")
            return proc.returncode
        delay = min(backoff_s * (2 ** (attempt - 1)), max_backoff_s)
        log(f"[supervisor] exit code {proc.returncode}; restarting from "
            f"latest checkpoint in {delay:.0f}s")
        time.sleep(delay)


def main():                             # pragma: no cover - thin CLI
    sys.exit(supervise(sys.argv[1:]))


if __name__ == "__main__":              # pragma: no cover
    main()
