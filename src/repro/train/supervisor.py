"""Restart supervisor: node-failure tolerance for the training driver.

Runs the training entrypoint in a child process; on a non-zero exit
(crash, OOM, killed node in a real deployment) it restarts from the latest
checkpoint, with capped exponential backoff and a max-restart budget.
Because checkpoints are mesh-agnostic (train/checkpoint.py), the restarted
run may come back with a different data-parallel width (elastic).

Progress-aware restarts (DESIGN.md §11): a crash is only worth a restart
if restarts can make progress. ``progress_fn`` (typically
:func:`checkpoint_progress_fn` over the run's checkpoint dir) is sampled
before and after every attempt — the supervisor logs the child's resume
context, *resets* the restart budget whenever the checkpoint step
advanced (a run that keeps moving deserves fresh attempts), and halts
after ``crash_loop_limit`` consecutive no-progress restarts (a
deterministic crash right after restore would otherwise burn the whole
budget replaying itself). A child exiting with
:data:`~repro.train.resilience.HALT_EXIT_CODE` has already diagnosed its
failure as deterministic (escalation-ladder rung 4) and is never
restarted.
"""
from __future__ import annotations

import subprocess
import sys
import time
from typing import Callable

from .resilience import HALT_EXIT_CODE


def checkpoint_progress_fn(ckpt_dir: str) -> Callable[[], int | None]:
    """A ``progress_fn`` reading the latest published checkpoint step in
    ``ckpt_dir`` (a pure directory scan — no verification, no manager
    side effects; the child verifies on restore)."""
    import os
    import re

    def fn() -> int | None:
        steps = []
        try:
            names = os.listdir(ckpt_dir)
        except FileNotFoundError:
            return None
        for name in names:
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(ckpt_dir, name, "OK")):
                steps.append(int(m.group(1)))
        return max(steps) if steps else None
    return fn


def supervise(cmd: list[str], *, max_restarts: int = 10,
              backoff_s: float = 2.0, max_backoff_s: float = 60.0,
              log=print, progress_fn: Callable[[], int | None] | None = None,
              crash_loop_limit: int = 3) -> int:
    attempt = 0
    no_progress = 0
    while True:
        before = progress_fn() if progress_fn is not None else None
        if progress_fn is not None:
            log(f"[supervisor] resume context: latest checkpoint step "
                f"{before if before is not None else '<none>'}")
        log(f"[supervisor] launching (attempt {attempt + 1}): {' '.join(cmd)}")
        proc = subprocess.run(cmd)
        after = progress_fn() if progress_fn is not None else None
        if proc.returncode == 0:
            log("[supervisor] clean exit")
            return 0
        if proc.returncode == HALT_EXIT_CODE:
            log(f"[supervisor] child halted deliberately (exit "
                f"{HALT_EXIT_CODE}: escalation ladder exhausted) — "
                f"not restarting")
            return proc.returncode
        if progress_fn is not None:
            log(f"[supervisor] child exited {proc.returncode}; checkpoint "
                f"step {before if before is not None else '<none>'} -> "
                f"{after if after is not None else '<none>'}")
            if after is not None and (before is None or after > before):
                if attempt or no_progress:
                    log("[supervisor] checkpoint advanced — restart "
                        "budget reset")
                attempt = 0
                no_progress = 0
            else:
                no_progress += 1
                if no_progress >= crash_loop_limit:
                    log(f"[supervisor] crash loop: {no_progress} restarts "
                        f"without checkpoint progress — halting")
                    return proc.returncode
        attempt += 1
        if attempt > max_restarts:
            log(f"[supervisor] giving up after {max_restarts} restarts")
            return proc.returncode
        delay = min(backoff_s * (2 ** (attempt - 1)), max_backoff_s)
        log(f"[supervisor] exit code {proc.returncode}; restarting from "
            f"latest checkpoint in {delay:.0f}s")
        time.sleep(delay)


def main():                             # pragma: no cover - thin CLI
    sys.exit(supervise(sys.argv[1:]))


if __name__ == "__main__":              # pragma: no cover
    main()
