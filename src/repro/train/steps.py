"""Train/eval step factories — the functions the dry-run lowers.

``make_train_step`` returns a pure (state, batch) -> (state, metrics)
function containing the forward, backward, gradient-accumulation microbatch
loop, global-norm clipping, and the *optimizer update itself* — the paper's
contribution is optimizer-side, so the DCT projection, dynamic column
selection, Newton-Schulz and the low-rank collectives are all part of the
lowered HLO that the roofline analysis reads.

Gradient accumulation: ``cfg.train_microbatch`` rows per inner step via
`lax.scan`, fp32 accumulators. Cross-device gradient reduction is GSPMD's
(from the batch sharding); the §Perf log tracks what XLA does with the
per-microbatch all-reduces.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.optim import apply_updates


class TrainState(NamedTuple):
    step: jax.Array
    params: Any
    opt_state: Any


def _cross_entropy(logits, targets):
    """Mean next-token NLL; fp32 log-softmax. targets: (B, S) int32."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return nll.mean()


def loss_fn(params, batch, cfg):
    inputs = {k: v for k, v in batch.items() if k != "targets"}
    logits, aux = T.forward(params, inputs, cfg)
    loss = _cross_entropy(logits, batch["targets"])
    metrics = {"ce": loss}
    loss = loss + aux["moe_aux"]
    if aux.get("mtp_logits") is not None:
        # MTP head predicts target_{t+1} from position t (DeepSeek-V3);
        # full-length logits, final position masked (rolled target)
        mtp_tgt = jnp.roll(batch["targets"], -1, axis=1)
        logp = jax.nn.log_softmax(aux["mtp_logits"].astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(logp, mtp_tgt[..., None], -1)[..., 0]
        s = nll.shape[1]
        w = (jnp.arange(s) < s - 1).astype(jnp.float32)[None, :]
        mtp = (nll * w).sum() / w.sum() / nll.shape[0]
        loss = loss + 0.3 * mtp
        metrics["mtp_ce"] = mtp
    metrics["loss"] = loss
    return loss, metrics


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def _clip_by_global_norm(tree, max_norm):
    norm = _global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, tree), norm


def _split_micro(batch, n_micro):
    """(B, ...) -> (n_micro, B/n_micro, ...) on every leaf."""
    return jax.tree.map(
        lambda x: x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:]),
        batch)


def grad_fn(params, batch, cfg):
    (loss, metrics), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(params, batch, cfg)
    return grads, metrics


def make_train_step(cfg, optimizer, *, grad_clip: float = 1.0,
                    accum_dtype: str = "float32", telemetry: bool = False,
                    guard: bool = False, chaos=None):
    """(TrainState, batch) -> (TrainState, metrics).

    ``accum_dtype``: microbatch gradient-accumulator dtype. fp32 default;
    bf16 halves the gradient HBM footprint for the >=90B archs (recorded as
    a precision trade in DESIGN.md §7).

    ``telemetry=True`` installs a stats collector around the (traced)
    optimizer update; the per-leaf :class:`SubspaceStats` the rules emit
    come back under ``metrics["telemetry"]`` (DESIGN.md §8). Off by
    default — the graph is then bit-identical to a telemetry-free build.

    ``guard=True`` arms the in-jit anomaly guard (DESIGN.md §11): one
    ``all_finite`` flag over loss / gradient norm / updates decides —
    *inside* the jitted step, donation-safe — whether the new state
    commits or the old one passes through unchanged
    (``resilience.select_tree``); the flag comes back under
    ``metrics["all_finite"]`` for the host-side escalation ladder. Off by
    default: the lowered HLO is then bit-identical to a guard-free build
    (``benchmarks/resilience_overhead.py`` gates the armed overhead).

    ``chaos``: a :class:`~repro.train.chaos.ChaosPlan` whose ``grads``
    faults are injected into the traced step, keyed on the data step the
    plan's batch wrapper stamps into each batch (tests/CI only).
    """
    adt = jnp.dtype(accum_dtype)

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        chaos_step = None
        if chaos is not None:
            from repro.train.chaos import strip_chaos_key

            batch, chaos_step = strip_chaos_key(batch)
        b = batch["tokens"].shape[0]
        mb = cfg.train_microbatch or b
        n_micro = max(1, b // mb)

        if n_micro == 1:
            grads, metrics = grad_fn(state.params, batch, cfg)
            grads = jax.tree.map(lambda g: g.astype(adt), grads)
        else:
            micro = _split_micro(batch, n_micro)

            def acc_step(acc, mbatch):
                g, m = grad_fn(state.params, mbatch, cfg)
                acc = jax.tree.map(
                    lambda a, gi: a + (gi / n_micro).astype(adt), acc, g)
                return acc, m

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, adt), state.params)
            grads, ms = jax.lax.scan(acc_step, zeros, micro)
            metrics = jax.tree.map(lambda x: x.mean(), ms)

        if chaos is not None and chaos_step is not None:
            grads = chaos.tamper_grads(chaos_step, grads)

        if grad_clip:
            grads, gnorm = _clip_by_global_norm(grads, grad_clip)
        else:
            gnorm = _global_norm(grads)

        metrics = dict(metrics)
        if telemetry:
            from repro.telemetry.stats import collect

            # the context manager lives entirely at trace time: the rules
            # record tracer-valued SubspaceStats into the collector and the
            # collected tree is returned as a regular jit output
            with collect() as col:
                updates, new_opt = optimizer.update(grads, state.opt_state,
                                                    state.params)
            tel = col.tree()
            if tel:
                metrics["telemetry"] = tel
        else:
            updates, new_opt = optimizer.update(grads, state.opt_state,
                                                state.params)
        new_params = apply_updates(state.params, updates)
        metrics["grad_norm"] = gnorm
        new_state = TrainState(state.step + 1, new_params, new_opt)
        if guard:
            from repro.train.resilience import all_finite_tree, select_tree

            # one flag over loss / grad-norm / updates: gnorm is a sum of
            # squares over every gradient leaf, so any NaN/Inf anywhere in
            # the gradients poisons it for free; updates cover the
            # optimizer's own arithmetic. The commit point is a per-leaf
            # select between new and old state — donation-safe (the donated
            # old buffers feed the select, never aliased ambiguously), and
            # XLA folds select(p, x, x) for leaves the step didn't change.
            flag = (jnp.isfinite(metrics["loss"])
                    & jnp.isfinite(gnorm)
                    & all_finite_tree(updates))
            new_state = select_tree(flag, new_state, state)
            metrics["all_finite"] = flag
        return new_state, metrics

    return train_step


def make_eval_step(cfg):
    def eval_step(params, batch):
        _, metrics = loss_fn(params, batch, cfg)
        return metrics
    return eval_step


def init_state(cfg, optimizer, key) -> TrainState:
    params = T.init_params(cfg, key)
    return TrainState(jnp.zeros((), jnp.int32), params,
                      optimizer.init(params))
