from .schedule import constant, cosine_warmup, linear_warmup
from .steps import TrainState, loss_fn, make_eval_step, make_train_step

__all__ = ["TrainState", "loss_fn", "make_train_step", "make_eval_step",
           "cosine_warmup", "linear_warmup", "constant"]
