"""Fault-tolerant checkpointing: atomic, keep-k, async, mesh-agnostic.

Format: one directory per step containing a flat .npz of every leaf
(path-keyed) plus a manifest. Writes go to ``<dir>.tmp`` then os.rename —
a crash mid-write can never corrupt the latest checkpoint. Saves are
offloaded to a writer thread (``async_save``) so the train loop never
blocks on storage; ``wait()`` drains before exit/preemption.

Checkpoints are saved *unsharded-logical* (fully addressable host arrays):
restore takes the target mesh/shardings and uses jax.device_put with the
new NamedShardings, so the data-parallel width may change between runs
(elastic restart — DESIGN.md §5).

ZeRO-partitioned optimizer state (DESIGN.md §9) rides the same contract:
``_flatten``'s device_get gathers each row-partitioned moment/EF leaf to
one logical host array, and restore re-partitions onto the *current*
topology's specs (``sharding.opt_state_specs(zero=...)``) — save on a
(2, 4) mesh, resume on (4, 2) or a different DP width entirely
(asserted in tests/test_zero_parity.py).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "||"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten_into(tree, flat: dict[str, np.ndarray]):
    def rebuild(kp, leaf):
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        arr = flat[key]
        return jnp.asarray(arr.astype(leaf.dtype) if hasattr(leaf, "dtype")
                           else arr)
    return jax.tree_util.tree_map_with_path(rebuild, tree)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._lock = threading.Lock()
        self._pending: threading.Thread | None = None

    # -- discovery ----------------------------------------------------------
    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "OK")):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def manifest(self, step: int) -> dict:
        """The manifest saved alongside a checkpoint — ``extra`` entries
        (e.g. adaptive-controller state) ride here as JSON, so consumers
        can read them *before* building the restore target (controller
        state determines the opt-state shapes)."""
        path = os.path.join(self.dir, f"step_{step}", "manifest.json")
        with open(path) as f:
            return json.load(f)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state: Any, extra: dict | None = None):
        """Synchronous atomic save."""
        final = os.path.join(self.dir, f"step_{step}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "state.npz"), **_flatten(state))
        manifest = {"step": int(step), **(extra or {})}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "OK"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)           # atomic publish
        self._gc()

    def async_save(self, step: int, state: Any, extra: dict | None = None):
        """Device->host copy happens on the caller thread (cheap, required
        for consistency); disk IO on a background thread."""
        flat = _flatten(state)          # snapshot now
        self.wait()

        def _write():
            final = os.path.join(self.dir, f"step_{step}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "state.npz"), **flat)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump({"step": int(step), **(extra or {})}, f)
            with open(os.path.join(tmp, "OK"), "w") as f:
                f.write("ok")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        self._pending = threading.Thread(target=_write, daemon=True)
        self._pending.start()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        with self._lock:
            steps = self.all_steps()
            for s in steps[:-self.keep] if self.keep else []:
                shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                              ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def restore(self, step: int, target: Any, shardings: Any | None = None):
        """Restore into the structure of ``target`` (a pytree of arrays or
        ShapeDtypeStructs). With ``shardings`` (pytree of NamedSharding for
        the *current* mesh), leaves are placed sharded — the saved file is
        mesh-agnostic, so this reshards elastically."""
        path = os.path.join(self.dir, f"step_{step}", "state.npz")
        flat = dict(np.load(path))
        tree = _unflatten_into(target, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree

    def restore_latest(self, target: Any, shardings: Any | None = None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, target, shardings)
