"""Fault-tolerant checkpointing: atomic, verified, keep-k, async.

Format: one directory per step containing a flat .npz of every leaf
(path-keyed) plus a manifest. Writes go to ``<dir>.tmp`` then os.rename —
a crash mid-write can never corrupt the latest checkpoint. Saves are
offloaded to a writer thread (``async_save``) so the train loop never
blocks on storage; ``wait()`` drains before exit/preemption.

Integrity (DESIGN.md §11): the manifest records a per-leaf CRC32 plus
shape/dtype for every array in ``state.npz``. ``restore`` re-checksums
what it loaded and raises :class:`CheckpointCorruptError` on any mismatch
— an ``OK`` marker only proves the *write* completed, not that the bytes
survived the storage layer. ``restore_latest`` walks backwards through
older checkpoints, quarantining (``step_N.corrupt``) anything that fails
verification, so one rotted ``state.npz`` costs a rollback window, not
the run.

Concurrency: the sync and async save paths share one discipline — a
pending writer is always drained before a new save starts, and the
publish (rename + keep-k GC) and every directory scan happen under
``self._lock``, so ``all_steps``/``restore`` never race the writer
thread's GC. Orphaned ``step_*.tmp`` dirs (a writer killed mid-write) are
swept at startup.

Checkpoints are saved *unsharded-logical* (fully addressable host arrays):
restore takes the target mesh/shardings and uses jax.device_put with the
new NamedShardings, so the data-parallel width may change between runs
(elastic restart — DESIGN.md §5).

ZeRO-partitioned optimizer state (DESIGN.md §9) rides the same contract:
``_flatten``'s device_get gathers each row-partitioned moment/EF leaf to
one logical host array, and restore re-partitions onto the *current*
topology's specs (``sharding.opt_state_specs(zero=...)``) — save on a
(2, 4) mesh, resume on (4, 2) or a different DP width entirely
(asserted in tests/test_zero_parity.py).

``fault_hook(stage, step)`` is the chaos seam (train/chaos.py): called at
``"pre_write"`` / ``"mid_write"`` (after state.npz, before OK) /
``"pre_publish"`` / ``"published"``, it lets the fault-injection harness
kill or abort the writer at a precise point, or corrupt a checkpoint the
instant it lands — tests/test_resilience.py drives the whole recovery
path through it.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
import zlib
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

_SEP = "||"


def _ckpt_metrics():
    """Checkpoint-IO instruments (no-ops until ``obs.enable()``)."""
    r = obs.registry()
    return {
        "save_s": r.histogram("ckpt_save_seconds",
                              "write + fsync-equivalent publish of one "
                              "checkpoint (writer-thread time for async)"),
        "restore_s": r.histogram("ckpt_restore_seconds",
                                 "load + verify + rebuild of one "
                                 "checkpoint"),
        "verify_s": r.histogram("ckpt_verify_seconds",
                                "standalone load + CRC verification"),
        "bytes_written": r.counter("ckpt_bytes_written_total",
                                   "uncompressed leaf bytes saved"),
        "bytes_read": r.counter("ckpt_bytes_read_total",
                                "uncompressed leaf bytes loaded on "
                                "restore"),
        "saves": r.counter("ckpt_saves_total", "published checkpoints"),
        "restores": r.counter("ckpt_restores_total",
                              "successful restores"),
        "corrupt": r.counter("ckpt_corruptions_total",
                             "verification failures"),
    }


def _nbytes(flat: dict[str, np.ndarray]) -> int:
    return sum(a.nbytes for a in flat.values())


class CheckpointCorruptError(RuntimeError):
    """A checkpoint failed integrity verification (CRC/shape/dtype/read)."""


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten_into(tree, flat: dict[str, np.ndarray]):
    def rebuild(kp, leaf):
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        arr = flat[key]
        return jnp.asarray(arr.astype(leaf.dtype) if hasattr(leaf, "dtype")
                           else arr)
    return jax.tree_util.tree_map_with_path(rebuild, tree)


def _integrity(flat: dict[str, np.ndarray]) -> dict[str, dict]:
    """Per-leaf CRC32 + shape/dtype — the manifest's verification record."""
    return {
        key: {
            # tobytes() serializes in C order regardless of layout, so the
            # CRC is deterministic across save-time strides
            "crc32": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
        for key, arr in flat.items()
    }


def _check_integrity(step: int, flat: dict[str, np.ndarray],
                     leaves: dict[str, dict]) -> None:
    """Raise CheckpointCorruptError on any CRC/shape/dtype mismatch."""
    missing = sorted(set(leaves) - set(flat))
    if missing:
        raise CheckpointCorruptError(
            f"step {step}: state.npz is missing leaves {missing[:4]}"
            + ("..." if len(missing) > 4 else ""))
    for key, rec in leaves.items():
        arr = flat[key]
        if list(arr.shape) != list(rec["shape"]) \
                or str(arr.dtype) != rec["dtype"]:
            raise CheckpointCorruptError(
                f"step {step}: leaf {key!r} is "
                f"{arr.dtype}{list(arr.shape)}, manifest says "
                f"{rec['dtype']}{rec['shape']}")
        crc = zlib.crc32(arr.tobytes()) & 0xFFFFFFFF
        if crc != rec["crc32"]:
            raise CheckpointCorruptError(
                f"step {step}: leaf {key!r} CRC mismatch "
                f"(got {crc:#010x}, manifest {rec['crc32']:#010x})")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, *,
                 fault_hook: Callable[[str, int], None] | None = None,
                 log: Callable[[str], None] = print):
        self.dir = directory
        self.keep = keep
        self.log = log
        self.fault_hook = fault_hook
        self._m = _ckpt_metrics()
        self._tracer = obs.tracer()
        os.makedirs(directory, exist_ok=True)
        self._lock = threading.Lock()
        self._pending: threading.Thread | None = None
        # a writer killed mid-write leaves step_*.tmp behind; it can never
        # become visible (publish is a rename) but it wastes space and a
        # retried save at the same step must start clean
        for name in os.listdir(directory):
            if re.fullmatch(r"step_\d+\.tmp", name):
                shutil.rmtree(os.path.join(directory, name),
                              ignore_errors=True)

    def _fault(self, stage: str, step: int) -> None:
        if self.fault_hook is not None:
            self.fault_hook(stage, step)

    # -- discovery ----------------------------------------------------------
    def all_steps(self) -> list[int]:
        with self._lock:
            return self._all_steps_locked()

    def _all_steps_locked(self) -> list[int]:
        steps = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "OK")):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def manifest(self, step: int) -> dict:
        """The manifest saved alongside a checkpoint — ``extra`` entries
        (e.g. adaptive-controller state) ride here as JSON, so consumers
        can read them *before* building the restore target (controller
        state determines the opt-state shapes)."""
        path = os.path.join(self.dir, f"step_{step}", "manifest.json")
        with open(path) as f:
            return json.load(f)

    # -- integrity ----------------------------------------------------------
    def _load_verified(self, step: int) -> dict[str, np.ndarray]:
        """Load step's flat arrays and verify them against the manifest.

        Checkpoints written before the integrity format (no ``"leaves"``
        record) load unverified — backward compatible."""
        base = os.path.join(self.dir, f"step_{step}")
        try:
            try:
                manifest = self.manifest(step)
                with np.load(os.path.join(base, "state.npz")) as z:
                    flat = {k: z[k] for k in z.files}
            except CheckpointCorruptError:
                raise
            except Exception as e:        # torn zip, missing file, bad json
                raise CheckpointCorruptError(
                    f"step {step}: unreadable checkpoint "
                    f"({type(e).__name__}: {e})") from e
            leaves = manifest.get("leaves")
            if leaves is not None:
                _check_integrity(step, flat, leaves)
        except CheckpointCorruptError as e:
            self._m["corrupt"].inc()
            self._tracer.instant("ckpt/corrupt", step=step, error=str(e))
            raise
        return flat

    def verify(self, step: int) -> None:
        """Raise :class:`CheckpointCorruptError` unless ``step`` loads and
        matches its manifest's per-leaf CRC32/shape/dtype record."""
        t0 = time.perf_counter()
        with self._tracer.span("ckpt/verify", step=step):
            self._load_verified(step)
        self._m["verify_s"].observe(time.perf_counter() - t0)

    def quarantine(self, step: int) -> str:
        """Move a corrupt checkpoint aside (``step_N.corrupt``) so
        discovery never offers it again; returns the new path."""
        with self._lock:
            src = os.path.join(self.dir, f"step_{step}")
            dst = src + ".corrupt"
            n = 0
            while os.path.exists(dst):
                n += 1
                dst = f"{src}.corrupt{n}"
            os.rename(src, dst)
        self.log(f"[ckpt] quarantined corrupt checkpoint step {step} "
                 f"-> {os.path.basename(dst)}")
        return dst

    def latest_verified_step(self, *, quarantine: bool = True) -> int | None:
        """Newest step that passes verification, walking backwards through
        the retained checkpoints; corrupt ones are quarantined (so the
        next call — or a restarted process — skips straight past them)."""
        for step in reversed(self.all_steps()):
            try:
                self.verify(step)
                return step
            except CheckpointCorruptError as e:
                self.log(f"[ckpt] verification failed: {e}")
                if quarantine:
                    self.quarantine(step)
        return None

    # -- save ---------------------------------------------------------------
    def _write(self, step: int, flat: dict[str, np.ndarray],
               extra: dict | None) -> None:
        t0 = time.perf_counter()
        with self._tracer.span("ckpt/write", step=step,
                               mb=round(_nbytes(flat) / 2**20, 2)):
            self._write_inner(step, flat, extra)
        self._m["save_s"].observe(time.perf_counter() - t0)
        self._m["bytes_written"].inc(_nbytes(flat))
        self._m["saves"].inc()

    def _write_inner(self, step: int, flat: dict[str, np.ndarray],
                     extra: dict | None) -> None:
        final = os.path.join(self.dir, f"step_{step}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        self._fault("pre_write", step)
        np.savez(os.path.join(tmp, "state.npz"), **flat)
        manifest = {"step": int(step), "format": 2,
                    "leaves": _integrity(flat), **(extra or {})}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        self._fault("mid_write", step)
        with open(os.path.join(tmp, "OK"), "w") as f:
            f.write("ok")
        self._fault("pre_publish", step)
        with self._lock:
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)       # atomic publish
            self._gc_locked()
        self._fault("published", step)

    def save(self, step: int, state: Any, extra: dict | None = None):
        """Synchronous atomic save (drains any pending async writer first —
        two writers GC'ing the same directory is the classic torn-keep-k)."""
        self.wait()
        self._write(step, _flatten(state), extra)

    def async_save(self, step: int, state: Any, extra: dict | None = None):
        """Device->host copy happens on the caller thread (cheap, required
        for consistency); disk IO on a background thread."""
        flat = _flatten(state)          # snapshot now
        self.wait()

        def _bg():
            try:
                self._write(step, flat, extra)
            except _WriterInterrupt:
                # chaos harness killed the writer mid-write: the torn
                # step_*.tmp stays behind (startup sweeps it), the
                # published checkpoints are untouched
                pass

        self._pending = threading.Thread(target=_bg, daemon=True)
        self._pending.start()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc_locked(self):
        steps = self._all_steps_locked()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def restore(self, step: int, target: Any, shardings: Any | None = None):
        """Restore into the structure of ``target`` (a pytree of arrays or
        ShapeDtypeStructs), verifying the loaded bytes against the
        manifest's integrity record (:class:`CheckpointCorruptError` on
        mismatch). With ``shardings`` (pytree of NamedSharding for the
        *current* mesh), leaves are placed sharded — the saved file is
        mesh-agnostic, so this reshards elastically."""
        t0 = time.perf_counter()
        with self._tracer.span("ckpt/restore", step=step):
            flat = self._load_verified(step)
            tree = _unflatten_into(target, flat)
            if shardings is not None:
                tree = jax.tree.map(
                    lambda x, s: jax.device_put(x, s), tree, shardings)
        self._m["restore_s"].observe(time.perf_counter() - t0)
        self._m["bytes_read"].inc(_nbytes(flat))
        self._m["restores"].inc()
        return tree

    def restore_latest(self, target: Any, shardings: Any | None = None):
        """Restore the newest checkpoint that passes verification, falling
        back through older ones (corrupt dirs are quarantined). Returns
        ``(None, None)`` when nothing verifiable remains."""
        step = self.latest_verified_step()
        if step is None:
            return None, None
        return step, self.restore(step, target, shardings)


class _WriterInterrupt(BaseException):
    """Raised by a chaos fault hook to kill the async writer mid-write
    (the in-process stand-in for SIGKILL'ing the host at that instant)."""
