"""Deterministic fault injection (chaos harness) for the train substrate.

A :class:`ChaosPlan` is a list of :class:`Fault` records keyed on
``(step, site)`` — fully deterministic, JSON-serializable, replayable —
that the training stack consults at well-defined seams:

========== =================== ==============================================
site       modes               seam
========== =================== ==============================================
grads      nan, inf            in-jit: ``make_train_step(chaos=plan)`` adds
                               the fault value to every gradient leaf on the
                               matching *data* step (traced compare against
                               the ``_chaos_step`` scalar the plan's batch
                               wrapper stamps into each batch)
checkpoint sigkill, abort      ``CheckpointManager.fault_hook``: SIGKILL the
                               process (or, for in-process tests, kill just
                               the writer thread) at a precise write stage
                               — ``arg`` selects ``pre_write`` / ``mid_write``
                               / ``pre_publish`` (default)
checkpoint truncate, bitflip   corrupt the just-published ``state.npz``
                               behind its OK marker (silent storage rot)
data       delay               sleep ``arg`` seconds inside ``batch_fn`` on
                               the matching step (straggler)
========== =================== ==============================================

Faults are keyed on the **data step** (what ``batch_fn`` receives), so the
ladder's recovery semantics compose: a skipped batch or a rolled-back
data window moves past the faulty step instead of replaying it forever —
exactly how a data-dependent NaN behaves in production. ``steps`` may be
a list to model a persistent fault (e.g. NaN on every batch of a window,
which forces the ladder past the skip rung).

Driven by ``launch/train.py --chaos plan.json`` and
``tests/test_resilience.py``; the plan format is documented in
docs/resilience.md.
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
import time
from typing import Any, Callable

import jax.numpy as jnp

from .checkpoint import _WriterInterrupt

_SITES = {
    "grads": ("nan", "inf"),
    "checkpoint": ("sigkill", "abort", "truncate", "bitflip"),
    "data": ("delay",),
}
_STAGES = ("pre_write", "mid_write", "pre_publish", "published")
_CHAOS_KEY = "_chaos_step"


@dataclasses.dataclass(frozen=True)
class Fault:
    """One deterministic fault. ``step`` is the data step (``grads`` /
    ``data`` sites) or the checkpoint step (``checkpoint`` site); ``arg``
    is mode-specific: the write stage for ``sigkill``/``abort``, the sleep
    seconds for ``delay``, ignored otherwise."""

    step: int
    site: str
    mode: str
    arg: Any = None

    def __post_init__(self):
        if self.site not in _SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"have {sorted(_SITES)}")
        if self.mode not in _SITES[self.site]:
            raise ValueError(f"site {self.site!r} has no mode "
                             f"{self.mode!r}; have {_SITES[self.site]}")
        if self.mode in ("sigkill", "abort") and self.arg is not None \
                and self.arg not in _STAGES:
            raise ValueError(f"checkpoint stage {self.arg!r} unknown; "
                             f"have {_STAGES}")


class ChaosPlan:
    """A deterministic fault schedule plus the host bookkeeping (one-shot
    firing for host-side faults; in-jit faults are pure functions of the
    data step, so they need none)."""

    def __init__(self, faults: list[Fault] | None = None, *,
                 log_fn: Callable[[str], None] = print):
        self.faults = list(faults or [])
        self.log = log_fn
        self._fired: set[int] = set()   # host-side one-shot bookkeeping

    # -- construction -------------------------------------------------------
    @classmethod
    def from_spec(cls, spec: list[dict] | dict,
                  log_fn: Callable[[str], None] = print) -> "ChaosPlan":
        """Build from the JSON schema: a list of fault dicts (or
        ``{"faults": [...]}``); each dict's ``step`` may be an int or a
        list of ints (expanded to one fault per step)."""
        if isinstance(spec, dict):
            spec = spec.get("faults", [])
        faults = []
        for rec in spec:
            rec = dict(rec)
            steps = rec.pop("step")
            if not isinstance(steps, (list, tuple)):
                steps = [steps]
            for s in steps:
                faults.append(Fault(step=int(s), **rec))
        return cls(faults, log_fn=log_fn)

    @classmethod
    def load(cls, path: str,
             log_fn: Callable[[str], None] = print) -> "ChaosPlan":
        with open(path) as f:
            return cls.from_spec(json.load(f), log_fn=log_fn)

    def to_spec(self) -> list[dict]:
        return [dataclasses.asdict(f) for f in self.faults]

    def at(self, site: str) -> list[Fault]:
        return [f for f in self.faults if f.site == site]

    # -- in-jit: gradient tampering ----------------------------------------
    def tamper_grads(self, chaos_step, grads):
        """Inside the traced step: add the fault value to every gradient
        leaf when the batch's data step matches. The compare is traced, so
        the compiled step is identical across steps (no retrace); with no
        ``grads`` faults in the plan, the graph is untouched."""
        import jax

        for f in self.at("grads"):
            bad = jnp.float32(jnp.nan if f.mode == "nan" else jnp.inf)
            hit = jnp.equal(chaos_step, f.step)
            grads = jax.tree.map(
                lambda g: g + jnp.where(hit, bad, 0.0).astype(g.dtype),
                grads)
        return grads

    # -- host: batch_fn wrapper --------------------------------------------
    def wrap_batch_fn(self, batch_fn):
        """Stamp ``_chaos_step`` (an int32 scalar of the data step) into
        every batch — the traced key ``tamper_grads`` compares against —
        and serve ``data``-site faults (straggler delays)."""

        def wrapped(step):
            s = int(step)
            for f in self.at("data"):
                if f.step == s and self._fire(f):
                    delay = float(f.arg or 1.0)
                    self.log(f"[chaos] delaying batch {s} by {delay:g}s")
                    time.sleep(delay)
            batch = dict(batch_fn(step))
            batch[_CHAOS_KEY] = jnp.int32(s)
            return batch

        return wrapped

    # -- host: checkpoint faults -------------------------------------------
    def checkpoint_hook(self, stage: str, step: int) -> None:
        """``CheckpointManager.fault_hook`` adapter: write-stage kills and
        post-publish corruption. The manager calls it inline from whichever
        thread is writing, so ``abort`` tears exactly the stage it names."""
        for f in self.at("checkpoint"):
            if f.step != step or not self._matches_stage(f, stage):
                continue
            if not self._fire(f):
                continue
            if f.mode == "sigkill":
                self.log(f"[chaos] SIGKILL at checkpoint step {step} "
                         f"stage {stage}")
                os.kill(os.getpid(), signal.SIGKILL)
            elif f.mode == "abort":
                self.log(f"[chaos] aborting checkpoint writer at step "
                         f"{step} stage {stage}")
                raise _WriterInterrupt()
            elif f.mode in ("truncate", "bitflip"):
                self._corrupt(f, step)

    @staticmethod
    def _matches_stage(f: Fault, stage: str) -> bool:
        if f.mode in ("sigkill", "abort"):
            return stage == (f.arg or "pre_publish")
        return stage == "published"     # corruption hits the landed files

    def _corrupt(self, f: Fault, step: int) -> None:
        # self.dir is unknown here; the hook closure carries it
        raise RuntimeError("corruption faults need a bound directory — "
                           "use bind_checkpoint_dir()")

    def bind_checkpoint_dir(self, directory: str):
        """Return a ``fault_hook`` bound to the checkpoint directory (the
        corruption modes need to know where the published files live)."""
        plan = self

        def _corrupt(f: Fault, step: int) -> None:
            path = os.path.join(directory, f"step_{step}", "state.npz")
            if not os.path.exists(path):            # pragma: no cover
                return
            corrupt_file(path, mode=f.mode)
            plan.log(f"[chaos] {f.mode} applied to {path} (behind OK)")

        def hook(stage: str, step: int) -> None:
            plan._corrupt, orig = _corrupt, plan._corrupt
            try:
                plan.checkpoint_hook(stage, step)
            finally:
                plan._corrupt = orig

        return hook

    def _fire(self, f: Fault) -> bool:
        key = id(f)
        if key in self._fired:
            return False
        self._fired.add(key)
        return True


def corrupt_file(path: str, *, mode: str = "bitflip") -> None:
    """Silent storage rot, concentrated: truncate a file to half, or flip
    one bit in the middle — both keep the OK marker and the manifest
    intact, which is exactly the failure CRC verification exists for."""
    size = os.path.getsize(path)
    if mode == "truncate":
        with open(path, "r+b") as fh:
            fh.truncate(max(size // 2, 1))
    elif mode == "bitflip":
        with open(path, "r+b") as fh:
            fh.seek(size // 2)
            byte = fh.read(1)
            fh.seek(size // 2)
            fh.write(bytes([byte[0] ^ 0x10]))
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")


def strip_chaos_key(batch: dict) -> tuple[dict, Any]:
    """Split the plan's traced step scalar out of a batch (the model must
    never see it). Returns ``(clean_batch, chaos_step_or_None)``."""
    if _CHAOS_KEY not in batch:
        return batch, None
    batch = dict(batch)
    return batch, batch.pop(_CHAOS_KEY)
