"""Logical sharding rules for the (pod, data, model) production mesh.

Model code annotates activations with *logical* axis names; the mapping to
physical mesh axes adapts to whichever mesh is active (single-pod
``(data, model)`` or multi-pod ``(pod, data, model)``), and degrades to
no-ops when no mesh is active (CPU unit tests).

Parameter sharding follows the MaxText FSDP x TP recipe:
  * 2D weights  (d_in, d_out)      -> P(fsdp, tp)   (fsdp = ('pod','data'))
  * stacked     (L, ..., d_in, d_out) -> P(None, ..., fsdp, tp)
  * embeddings  (vocab, d_model)   -> P(tp, fsdp)   (vocab-sharded logits)
  * expert weights (L, E, d, f)    -> P(None, tp, fsdp, None)  (EP on tp axis)
  * 1D params                      -> replicated

The layout policy is a :class:`ShardingPolicy` carried in a
``contextvars.ContextVar`` — scope one with ``use_policy(layout=...)``.
Context variables are per-thread (and per-asyncio-task), so concurrent
dry-runs deriving specs under different layouts cannot race the way the
old module-global ``_LAYOUT`` setter could.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel import compat

DP_AXES = ("pod", "data")   # batch/FSDP axes (present subset is used)
TP_AXIS = "model"

LAYOUTS = ("fsdp_tp", "pure_dp", "decode_tp")


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Explicit layout policy object (replaces the old mutable globals).

    ``layout`` (§Perf iter): "fsdp_tp" (default) shards params FSDP x TP;
    "pure_dp" replicates params and data-parallelizes the batch over EVERY
    mesh axis — the right layout for small archs (whisper/rwkv) where
    256-way model sharding makes shards tiny and collectives dominant;
    "decode_tp" is the decode-time Megatron layout (§Perf iter-6).

    ``seq_parallel`` (§Perf iter-2): shard the residual stream's sequence
    dim over the `model` axis (Megatron-SP style) — activations between
    blocks stay sequence-sharded, so GSPMD stops re-gathering them around
    attention.
    """

    layout: str = "fsdp_tp"
    seq_parallel: bool = False

    def __post_init__(self):
        if self.layout not in LAYOUTS:
            raise ValueError(f"unknown layout {self.layout!r}; "
                             f"allowed: {LAYOUTS}")


_POLICY: contextvars.ContextVar[ShardingPolicy] = contextvars.ContextVar(
    "repro_sharding_policy", default=ShardingPolicy())


def current_policy() -> ShardingPolicy:
    return _POLICY.get()


@contextlib.contextmanager
def use_policy(policy: ShardingPolicy | None = None, **replacements):
    """Scope a layout policy: ``with use_policy(layout="pure_dp"): ...``.

    Either pass a full :class:`ShardingPolicy` or field replacements over
    the current one. Restores the previous policy on exit; per-thread, so
    concurrent derivations under different layouts don't interfere.
    """
    if policy is None:
        policy = dataclasses.replace(current_policy(), **replacements)
    elif replacements:
        raise TypeError("pass either a policy object or field replacements,"
                        " not both")
    token = _POLICY.set(policy)
    try:
        yield policy
    finally:
        _POLICY.reset(token)


def layout_policy() -> str:
    """Current layout name (read-only view of :func:`current_policy`)."""
    return current_policy().layout


def seq_parallel() -> bool:
    """Current sequence-parallel flag (read-only view)."""
    return current_policy().seq_parallel


def active_mesh():
    return compat.get_active_mesh()


def dp_axes(mesh=None) -> tuple[str, ...]:
    mesh = mesh or active_mesh()
    if mesh is None:
        return ()
    return tuple(a for a in DP_AXES if a in mesh.axis_names)


def tp_axis(mesh=None):
    mesh = mesh or active_mesh()
    if mesh is None or TP_AXIS not in mesh.axis_names:
        return None
    return TP_AXIS


def logical_to_spec(axes: tuple, mesh=None) -> P:
    """Map logical names to a PartitionSpec for the active mesh.

    Logical names: 'batch' (DP axes), 'tp' (model axis), 'seq' (sharded over
    DP axes — used for long-context KV), None (replicated). Under the
    'pure_dp' layout, 'batch' spans every mesh axis and 'tp' replicates.
    """
    mesh = mesh or active_mesh()
    policy = current_policy()
    dp = dp_axes(mesh)
    tp = tp_axis(mesh)
    if policy.layout == "pure_dp":
        batch_axes = tuple(a for a in (*dp, tp) if a) or None
        tp = None
    else:
        batch_axes = dp if dp else None
    out = []
    for a in axes:
        if a == "batch" or a == "seq":
            out.append(batch_axes)
        elif a == "tp":
            out.append(tp)
        elif a == "sp":
            out.append(tp if policy.seq_parallel else None)
        elif a is None:
            out.append(None)
        else:
            raise ValueError(f"unknown logical axis {a!r}")
    return P(*out)


def shard(x: jax.Array, *axes) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op without a mesh."""
    mesh = active_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, logical_to_spec(axes, mesh))


# ---------------------------------------------------------------------------
# Parameter partition specs (by path pattern + shape)
# ---------------------------------------------------------------------------
_REPLICATED_HINTS = ("norm", "scale", "bias", "gate", "mu_", "decay",
                     "bonus", "a_log", "d_skip", "conv", "ln_")


def _fit_spec(axes: tuple, shape: tuple[int, ...], mesh) -> P:
    """Drop mesh axes that do not evenly divide their dim (e.g. whisper's
    prime-ish vocab 51866 can't shard 16 ways -> that dim replicates)."""
    out = []
    for a, dim in zip(axes, shape):
        if a is None:
            out.append(None)
        elif dim % _axis_size(mesh, a) == 0:
            out.append(a)
        else:
            out.append(None)
    return P(*out)


def param_spec(path: str, shape: tuple[int, ...], mesh=None,
               policy: ShardingPolicy | None = None) -> P:
    mesh = mesh or active_mesh()
    policy = policy or current_policy()
    if policy.layout == "pure_dp":
        return P()              # params replicated; batch over all axes
    dp = dp_axes(mesh)
    dp = dp if dp else None
    tp = tp_axis(mesh)
    nd = len(shape)
    lpath = path.lower()
    if nd == 0 or nd == 1:
        return P()
    if any(h in lpath for h in _REPLICATED_HINTS):
        # stacked small params (norm scales, biases, ssm constants): the
        # leading dim is layers, the rest are tiny -> replicate
        return P()
    is_row = any(seg in ("wd", "wo", "out_proj")
                 for seg in lpath.split("/"))
    if policy.layout == "decode_tp":
        # §Perf iter-6: decode-time Megatron layout over the COMBINED
        # (dp x tp) axes — every matrix column-parallel (d_out over all
        # chips), down/out projections row-parallel. A decode step then
        # runs shard-local matmuls with one tiny activation psum per
        # block instead of re-gathering weight shards per token.
        allax = tuple(a for a in (*(dp or ()), tp) if a) or None
        lead = (None,) * (nd - 2)
        if "embed" in lpath or "unembed" in lpath or "lm_head" in lpath:
            return _fit_spec((*lead, allax, None), shape, mesh)
        if "expert" in lpath and nd >= 3:
            # experts on tp; expert hidden column/row-parallel on dp
            lead3 = (None,) * (nd - 3)
            if is_row:   # (L, E, f, d)
                return _fit_spec((*lead3, tp, dp, None), shape, mesh)
            return _fit_spec((*lead3, tp, None, dp), shape, mesh)
        if is_row:
            return _fit_spec((*lead, allax, None), shape, mesh)
        return _fit_spec((*lead, None, allax), shape, mesh)
    if "embed" in lpath or "unembed" in lpath or "lm_head" in lpath:
        # (vocab, d) or (L?, vocab, d): vocab on tp, d on fsdp
        lead = (None,) * (nd - 2)
        return _fit_spec((*lead, tp, dp), shape, mesh)
    if "expert" in lpath and nd >= 3:
        # (L, E, d_in, d_out): experts on tp (EP), d_in on fsdp
        lead = (None,) * (nd - 3)
        return _fit_spec((*lead, tp, dp, None), shape, mesh)
    lead = (None,) * (nd - 2)
    if is_row:
        # §Perf iter-3: down/out projections row-parallel (contraction dim
        # on `model`) so the Megatron column->row pair needs one output
        # psum instead of re-gathering the full hidden activation
        return _fit_spec((*lead, tp, dp), shape, mesh)
    if nd >= 2:
        # (L?, d_in, d_out): fsdp x tp
        return _fit_spec((*lead, dp, tp), shape, mesh)
    return P()


def params_specs(params: Any, mesh=None,
                 policy: ShardingPolicy | None = None) -> Any:
    from repro.optim.common import path_str

    policy = policy or current_policy()
    return jax.tree_util.tree_map_with_path(
        lambda kp, p: param_spec(path_str(kp), p.shape, mesh, policy), params
    )


def named_shardings(tree_of_specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Optimizer-state partition specs — derived from the param specs by shape
# matching (DESIGN.md §5): full-size state follows the param; low-rank (…, r)
# keeps the row specs and replicates the rank dim; indices/scalars replicate.
# ---------------------------------------------------------------------------
def _match_state_spec(p_shape, p_spec: P, s_shape) -> P:
    if tuple(s_shape) == tuple(p_shape):
        return p_spec
    # transpose-oriented full-size state (EF buffers are stored oriented)
    if (len(s_shape) == len(p_shape)
            and tuple(s_shape[:-2]) == tuple(p_shape[:-2])
            and (s_shape[-2], s_shape[-1]) == (p_shape[-1], p_shape[-2])):
        sp = list(p_spec) + [None] * (len(p_shape) - len(p_spec))
        sp[-2], sp[-1] = sp[-1], sp[-2]
        return P(*sp)
    # low-rank (..., rows, r): keep leading/row specs, replicate rank dim
    if len(s_shape) == len(p_shape):
        sp = list(p_spec) + [None] * (len(p_shape) - len(p_spec))
        out = []
        for i, (ss, ps) in enumerate(zip(s_shape, p_shape)):
            out.append(sp[i] if ss == ps else None)
        return P(*out)
    if len(s_shape) == len(p_shape) + 1 and tuple(s_shape[:-1]) == tuple(p_shape):
        sp = list(p_spec) + [None] * (len(p_shape) - len(p_spec))
        return P(*sp, None)
    # anything else (indices, scales, scalars): replicate
    return P()


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def batch_specs_tree(batch, mesh,
                     policy: ShardingPolicy | None = None) -> Any:
    """Input batch: leading batch dim over the DP axes (if divisible);
    under 'pure_dp' over every mesh axis, falling back to dp-only when the
    batch doesn't divide the full device count (prefill/decode shapes)."""
    policy = policy or current_policy()
    dp_only = dp_axes(mesh) or None
    if policy.layout == "pure_dp":
        all_axes = tuple(a for a in (*dp_axes(mesh), tp_axis(mesh)) if a) \
            or None
        candidates = (all_axes, dp_only)
    else:
        candidates = (dp_only,)

    def spec(x):
        for axes in candidates:
            if axes and x.shape[0] % _axis_size(mesh, axes) == 0:
                return P(axes, *([None] * (len(x.shape) - 1)))
        return P(*([None] * len(x.shape)))

    return jax.tree.map(spec, batch)


def cache_specs_tree(cache, mesh) -> Any:
    """Decode-cache sharding. Leaves are (repeats, B, ...) stacked.

    Rules (DESIGN.md §5): shard batch over DP when divisible; otherwise
    (long-context B=1) shard the *sequence* axis of attention caches over
    DP. KV heads / channel dims go on `model` when divisible; everything
    else replicates.
    """
    dp = dp_axes(mesh) or None
    tp = tp_axis(mesh)
    dp_n = _axis_size(mesh, dp)
    tp_n = _axis_size(mesh, tp) if tp else 1

    def leaf_spec(kp, x):
        name = str(getattr(kp[-1], "key", kp[-1])) if kp else ""
        shp = x.shape
        out = [None] * len(shp)
        b_ok = len(shp) >= 2 and shp[1] % dp_n == 0 and dp is not None
        if b_ok:
            out[1] = dp
        if name in ("k", "v", "xk", "xv"):            # (R,B,S,H,hd)
            if not b_ok and dp is not None and shp[2] % dp_n == 0:
                out[2] = dp                           # sequence-sharded KV
            if tp and shp[3] % tp_n == 0:
                out[3] = tp
        elif name in ("ckv", "krope"):                # (R,B,S,dim) MLA latent
            if not b_ok and dp is not None and shp[2] % dp_n == 0:
                out[2] = dp
        elif name == "conv":                          # (R,B,K,din)
            if tp and shp[3] % tp_n == 0:
                out[3] = tp
        elif name == "ssm":                           # (R,B,din,st)
            if tp and shp[2] % tp_n == 0:
                out[2] = tp
        elif name == "wkv":                           # (R,B,H,K,V)
            if tp and shp[2] % tp_n == 0:
                out[2] = tp
        return P(*out)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache)


def telemetry_specs(tree: Any) -> Any:
    """PartitionSpecs for telemetry pytrees (per-leaf SubspaceStats under
    ``metrics["telemetry"]``, controller state, sink records).

    Stats are per-leaf scalars or (layers,)-vectors produced by full
    reductions over sharded operands — GSPMD already all-reduces them, so
    every leaf replicates; controller state is host-side JSON mirrored to
    tiny arrays at most. One rule, applied uniformly: replicate.
    """
    return jax.tree.map(lambda _: P(), tree)


def opt_state_specs(opt_state, params, p_specs, *, zero=None, mesh=None):
    """PartitionSpecs for an optimizer state given param specs.

    ``params`` drives the association; each per-param state subtree
    (TrionLeaf / ProjAdamLeaf / FullAdamLeaf / ...) is walked and every array
    gets a spec by shape-matching against its parameter.

    Handles both the legacy ``HarnessState`` (``leaves`` is a params-shaped
    tree of per-leaf states) and the transform-chain ``ChainState``
    (``leaves`` nests combinator state: chain tuples, partition dicts whose
    per-label trees are params-shaped with MaskedNode holes,
    inject-hyperparams records). The walk descends combinator containers
    until a params-shaped subtree matches; anything unmatched (hyperparam
    scalars, empty states) replicates.

    ``zero`` (a :class:`repro.parallel.zero.ZeroConfig`) switches eligible
    projected-Adam leaves to the ZeRO-1 placement (DESIGN.md §9): moments,
    EF payloads and per-row EF scales partition their oriented row dim
    over the config's data axes — matching the shard_map layout the
    distributed step runs with — while index sets and scalars replicate.
    Eligibility is basis-agnostic: any leaf whose projector state is an
    index set into a shared basis (every registered
    :class:`~repro.core.transforms.BasisBackend` kind, plus randperm)
    qualifies; ineligible leaves (dense-basis projector state, rows not
    divisible by the shard count) keep the shape-matched placement.
    """
    zinfo = None
    if zero is not None and zero.active:
        from repro.parallel import zero as zero_mod

        mesh = mesh or active_mesh()
        axes = zero_mod.present_axes(mesh, zero)
        n_shards = _axis_size(mesh, axes) if axes else 1
        if n_shards > 1:
            zinfo = (zero_mod, axes, n_shards)

    def _zero_partitioned(p, leaf_state):
        """Leaves the sharded update path claims (DESIGN.md §9/§14):
        ProjAdamLeaf with index-typed projector state, plus the
        momentum-orthogonalization families (muon/trion/dion — always
        shardable by gather-compute-slice), whose rows split evenly."""
        if zinfo is None:
            return False
        from repro.optim.dion import DionLeaf
        from repro.optim.muon import MuonLeaf
        from repro.optim.projected_adam import ProjAdamLeaf
        from repro.optim.trion import TrionLeaf

        zero_mod, axes, n_shards = zinfo
        if not zero_mod.eligible(p.shape, n_shards):
            return False
        if isinstance(leaf_state, (MuonLeaf, TrionLeaf, DionLeaf)):
            return True
        return (isinstance(leaf_state, ProjAdamLeaf)
                and jnp.issubdtype(leaf_state.proj.dtype, jnp.integer))

    def leaf_specs(p, p_spec, leaf_state):
        if _zero_partitioned(p, leaf_state):
            zero_mod, axes, _ = zinfo
            return zero_mod.state_specs(p.shape, leaf_state, axes)
        return jax.tree.map(
            lambda s: _match_state_spec(p.shape, p_spec, s.shape), leaf_state
        )

    def try_params_shaped(node):
        # structural probe only: does `node` flatten up to the params tree?
        try:
            jax.tree_util.tree_structure(params).flatten_up_to(node)
        except (ValueError, TypeError, KeyError):
            return None
        # it does — a failure deriving specs past this point is a real bug
        # and must raise, not silently degrade to replication
        return jax.tree.map(leaf_specs, params, p_specs, node)

    def walk(node):
        mapped = try_params_shaped(node)
        if mapped is not None:
            return mapped
        if isinstance(node, tuple) and hasattr(node, "_fields"):
            return type(node)(*[walk(c) for c in node])
        if isinstance(node, (tuple, list)):
            return type(node)(walk(c) for c in node)
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        return P()

    return type(opt_state)(
        step=P(),
        key=P(),
        bases=jax.tree.map(lambda _: P(), opt_state.bases),
        leaves=walk(opt_state.leaves),
    )
