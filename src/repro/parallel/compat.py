"""jax version compatibility shims for the mesh/sharding APIs.

The repo targets the current jax mesh API (``jax.set_mesh`` /
``jax.sharding.get_abstract_mesh`` / ``AxisType``); older releases (<= 0.4.x,
the version baked into this container) expose none of those. Every call site
goes through this module so the rest of the codebase can be written against
one API:

  * ``set_mesh(mesh)``          — context manager. New jax: ``jax.set_mesh``
    (installs the abstract mesh). Old jax: the legacy ``with mesh:`` context,
    which installs the physical mesh in ``thread_resources`` — equivalent for
    our purposes (``with_sharding_constraint`` by PartitionSpec, and
    ``active_mesh()`` below reads both).
  * ``get_active_mesh()``       — the mesh installed by ``set_mesh``, or None.
  * ``make_mesh(shape, axes)``  — ``jax.make_mesh`` with ``axis_types`` only
    when the running jax supports it.
"""
from __future__ import annotations

import contextlib

import jax

_HAS_SET_MESH = hasattr(jax, "set_mesh")
_HAS_ABSTRACT_MESH = hasattr(jax.sharding, "get_abstract_mesh")


def set_mesh(mesh):
    """Install ``mesh`` for the duration of a ``with`` block."""
    if _HAS_SET_MESH:
        return jax.set_mesh(mesh)
    # legacy context manager: Mesh.__enter__ sets thread_resources
    return _legacy_mesh_ctx(mesh)


@contextlib.contextmanager
def _legacy_mesh_ctx(mesh):
    with mesh:
        yield mesh


def get_active_mesh():
    """The currently installed mesh (abstract or physical), or None."""
    if _HAS_ABSTRACT_MESH:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and m.axis_names:
            return m
    # legacy thread-resources physical mesh (``with mesh:``)
    try:
        env = jax._src.mesh.thread_resources.env
        pm = env.physical_mesh
    except AttributeError:
        return None
    if pm is None or pm.empty or not pm.axis_names:
        return None
    return pm


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """``jax.shard_map`` with fallback to ``jax.experimental.shard_map``."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as legacy_sm
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return legacy_sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     **kw)


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict.

    Old jax returns a one-element list of per-module dicts; current jax
    returns the dict directly.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """``jax.make_mesh`` with explicit-Auto axis types where supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)
