"""ZeRO-1-style partitioning of low-rank optimizer state (DESIGN.md §9).

The paper's memory claim — rank-independent runtime with up to 25% lower
optimizer memory — compounds with data parallelism: the projected-Adam
state (Adam moments in R^{rows x r}, the int8/fp32 error-feedback buffer in
R^{rows x cols}, per-row EF scales) is *row-parallel*, so it can be
partitioned across the ``('pod', 'data')`` axes and each device can run the
fused select+project+update step on its own row block. Per-device
optimizer-state bytes drop by the DP world size on top of the paper's
low-rank reduction.

Why the row-block decomposition is exact (not an approximation):

* ``S = G @ Q`` is row-parallel — every row of ``S`` is an independent
  contraction of the matching row of ``G`` with the shared basis ``Q``.
* Dynamic column selection needs the *global* column energies
  ``||S[:, j]||^2`` — the only cross-shard quantity in the whole step. Each
  shard reduces its row block and one ``(n,)``-sized ``psum`` over the DP
  axes makes the statistic (and therefore the selected indices, the
  rotation, and the telemetry aggregates) identical on every shard.
* The Adam moment update, bias correction, back-projection
  ``u @ Q_r^T`` and the per-row q8 EF quantization are all elementwise or
  row-parallel, so they run shard-local with zero communication.

The update direction leaves the ``shard_map`` still row-sharded
(``out_specs`` keeps the DP axes on the row dim); the all-gather back to
the parameter's sharding happens lazily where ``apply_updates`` consumes
it, which lets XLA overlap each leaf's gather with the next leaf's
shard-local compute instead of serializing a collective per leaf.

Scope (``MatrixRule.zero_shardable``): rules whose projector state is an
*index set into the shared basis* — any registered basis backend with a
row-decomposable energy statistic (``BasisBackend.zero_shardable``:
dct / dst / hadamard / randortho), plus the identity-basis ``randperm`` —
and, since DESIGN.md §14, the momentum-orthogonalization families
muon / trion / dion. Muon/trion add exactly one new cross-shard term
beyond the psum'd column statistic: the Newton-Schulz all-gather of the
*rank-sized* low-rank factor (NS mixes rows through its Gram matrix, so
it is recomputed identically per shard from the gathered factor and each
shard keeps its own output rows — see ``fused_step.fused_newton_schulz``).
Dion all-gathers the full momentum sum (its ``B^T P`` contraction spans
all rows) and re-slices; its per-layer ``q`` basis comes out replicated
and is placed replicated (``state_specs``). Dense-basis projected-Adam
projectors (svd / power / random) keep a per-matrix ``(n, r)`` basis whose
refresh is not row-decomposable; those leaves — and any leaf whose
oriented row count does not divide the shard count — fall back to the
replicated update path unchanged.
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import PartitionSpec as P

from repro.optim.common import deorient, orient_right
from repro.parallel import compat

ZERO_MODES = ("off", "1")


@dataclasses.dataclass(frozen=True)
class ZeroConfig:
    """Optimizer-state partitioning config.

    ``mode``: "off" (replicated state, the historical behaviour) or "1"
    (ZeRO-1: state + update step partitioned, updates all-gathered).
    ``axes``: mesh axes to partition over; the present subset of the
    active mesh is used (same convention as ``sharding.DP_AXES``).
    """

    mode: str = "off"
    axes: tuple[str, ...] = ("pod", "data")

    def __post_init__(self):
        if self.mode not in ZERO_MODES:
            raise ValueError(f"unknown zero mode {self.mode!r}; "
                             f"allowed: {ZERO_MODES}")
        if isinstance(self.axes, list):
            object.__setattr__(self, "axes", tuple(self.axes))

    @property
    def active(self) -> bool:
        return self.mode != "off"


ZERO_OFF = ZeroConfig()


def parse_zero(flag: str) -> ZeroConfig:
    """CLI helper: ``--zero {off,1}`` -> :class:`ZeroConfig`."""
    return ZeroConfig(mode=flag)


@dataclasses.dataclass(frozen=True)
class ZeroContext:
    """Resolved partitioning info for the active mesh (trace-time)."""

    mesh: object
    axes: tuple[str, ...]
    n_shards: int


def present_axes(mesh, cfg: ZeroConfig) -> tuple[str, ...]:
    if mesh is None:
        return ()
    return tuple(a for a in cfg.axes if a in mesh.axis_names)


def resolve(cfg: ZeroConfig | None) -> ZeroContext | None:
    """Resolve a config against the active mesh; None when inactive
    (mode off, no mesh, configured axes absent, or a 1-wide shard set)."""
    if cfg is None or not cfg.active:
        return None
    mesh = compat.get_active_mesh()
    axes = present_axes(mesh, cfg)
    if not axes:
        return None
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    if n <= 1:
        return None
    return ZeroContext(mesh=mesh, axes=axes, n_shards=n)


# ---------------------------------------------------------------------------
# shard placement policy
# ---------------------------------------------------------------------------
def _oriented_rows(param_shape) -> int:
    """The oriented row count: rules orient matrices so the *projected*
    dimension is last and rows = max of the trailing two dims."""
    return max(param_shape[-2], param_shape[-1])


def eligible(param_shape, n_shards: int) -> bool:
    """A leaf's state partitions iff its oriented row dim splits evenly."""
    if len(param_shape) < 2 or n_shards <= 1:
        return False
    return _oriented_rows(param_shape) % n_shards == 0


def grad_spec(param_shape, axes: tuple[str, ...]) -> P:
    """Spec splitting an *oriented* (rows-at-dim-(-2)) array's row dim.

    Gradients are right-oriented before entering the shard_map (and
    updates deoriented after it) so the split dim is always -2 — deciding
    orientation on a local row block would be wrong, since a block's
    aspect ratio can differ from the global leaf's.
    """
    lead = (None,) * (len(param_shape) - 2)
    return P(*lead, axes, None)


def state_array_spec(param_shape, state_shape, axes: tuple[str, ...]) -> P:
    """Spec for one optimizer-state array of an eligible leaf.

    State arrays are stored *oriented* (rows first of the trailing two
    dims): moments ``(..., rows, r)``, EF payload ``(..., rows, cols)``,
    per-row EF scales ``(..., rows, 1)`` all shard the row dim; index
    sets ``(..., r)``, scalars and anything else replicate.
    """
    rows = _oriented_rows(param_shape)
    if (len(state_shape) == len(param_shape)
            and len(state_shape) >= 2 and state_shape[-2] == rows):
        return P(*([None] * (len(state_shape) - 2)), axes, None)
    return P()


def state_specs(param_shape, state_tree, axes: tuple[str, ...]):
    """Per-array specs for a whole per-leaf state subtree (ProjAdamLeaf,
    including a nested q8 ``QuantizedBuffer``; MuonLeaf/TrionLeaf/DionLeaf).

    Dion's per-layer basis ``q (..., cols, r)`` is special-cased to
    replicate: it is computed from the all-gathered momentum sum (identical
    on every shard), and on *square* leaves its ``cols`` dim would
    otherwise be indistinguishable from a row dim and wrongly sharded.
    """
    from repro.optim.dion import DionLeaf  # lazy: avoids transform cycle

    if isinstance(state_tree, DionLeaf):
        return DionLeaf(
            m=state_array_spec(param_shape, state_tree.m.shape, axes),
            q=P())
    return jax.tree.map(
        lambda s: state_array_spec(param_shape, s.shape, axes), state_tree)


# ---------------------------------------------------------------------------
# the sharded leaf update
# ---------------------------------------------------------------------------
class _CaptureScope:
    """Single-leaf stats buffer used *inside* the shard_map body.

    The real collector lives outside the shard_map trace; recording outer
    tracers from inside would leak. The rule records into this local
    buffer, the stats ride out as a (replicated — every term is psum'd or
    index-derived) shard_map output, and the caller re-records them into
    the outer scope.
    """

    def __init__(self):
        self.stats = None

    def record(self, stats) -> None:
        self.stats = stats


def sharded_leaf_update(rule, g, state, param, ctx, zctx: ZeroContext):
    """Run ``rule.update`` with rows partitioned over ``zctx.axes``.

    Splits the gradient and the row-parallel state arrays across the DP
    shards, runs the (fused or reference) step shard-locally with
    ``ctx.axis`` set so row reductions psum, and returns the update
    direction still row-sharded plus the new (sharded) state. Leaf
    telemetry is computed in-shard from psum'd aggregates and re-recorded
    into the outer collector.
    """
    axes = zctx.axes
    gspec = grad_spec(param.shape, axes)
    sspecs = state_specs(param.shape, state, axes)
    capture = ctx.stats is not None
    # orientation is a *global* property: decide it on the full leaf and
    # hand the shard_map a pre-oriented gradient (ctx.oriented tells the
    # rule not to re-decide on its — possibly differently-shaped — block)
    gf, transposed = orient_right(g)

    def local(g_blk, s_blk, p_blk, step, key, bases):
        cap = _CaptureScope() if capture else None
        inner = dataclasses.replace(ctx, step=step, key=key, bases=bases,
                                    axis=axes, stats=cap, oriented=True)
        d, new_s = rule.update(g_blk, s_blk, p_blk, inner)
        return d, new_s, (cap.stats if capture else None)

    fn = compat.shard_map(
        local, mesh=zctx.mesh,
        in_specs=(gspec, sspecs, P(), P(), P(), P()),
        out_specs=(gspec, sspecs, P()),
        check_vma=False)
    d, new_state, stats = fn(gf, state, param, ctx.step, ctx.key, ctx.bases)
    if capture and stats is not None:
        ctx.record_stats(stats)
    return deorient(d, transposed), new_state

