from .synthetic import SyntheticLM, make_batch_fn
from .pipeline import DataPipeline

__all__ = ["SyntheticLM", "make_batch_fn", "DataPipeline"]
