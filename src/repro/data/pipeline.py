"""Host-side data pipeline with prefetch + straggler mitigation.

Production posture (DESIGN.md §5): data is host-indexed and deterministic
in (seed, step), so any host can recompute any slice — a re-shard or a
restarted worker never loses or duplicates samples. The pipeline
prefetches ``depth`` batches on a thread, and ``get`` has a timeout: if a
batch misses the deadline (straggler / slow storage in a real deployment)
the deterministic generator recomputes it inline, so the step never
stalls behind one slow host.

Failure semantics (DESIGN.md §11): a ``batch_fn`` exception is retried on
the worker with capped exponential backoff (``retries`` attempts —
transient storage hiccups heal invisibly); a persistent failure is
recorded and re-raised from the *caller's* ``get`` instead of silently
killing the prefetch thread and degrading every subsequent step into a
``timeout_s`` stall.
"""
from __future__ import annotations

import queue
import threading
import time


class DataPipeline:
    def __init__(self, batch_fn, start_step: int = 0, depth: int = 2,
                 timeout_s: float = 30.0, retries: int = 2,
                 retry_backoff_s: float = 0.05):
        self._fn = batch_fn
        self._depth = depth
        self._timeout = timeout_s
        self._retries = retries
        self._backoff = retry_backoff_s
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._next = start_step
        self._error: tuple[int, Exception] | None = None
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._next
        while not self._stop.is_set():
            delay = self._backoff
            for attempt in range(self._retries + 1):
                try:
                    batch = self._fn(step)
                    break
                except Exception as e:
                    if attempt == self._retries:
                        # persistent: surface through get(), don't vanish
                        self._error = (step, e)
                        return
                    print(f"[data] batch_fn failed at step {step} "
                          f"(attempt {attempt + 1}/{self._retries + 1}: "
                          f"{type(e).__name__}: {e}); retrying in "
                          f"{delay:.2f}s")
                    if self._stop.wait(delay):
                        return
                    delay = min(delay * 2, 1.0)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.5)
                    break
                except queue.Full:
                    continue
            step += 1

    def _raise_worker_error(self):
        step, exc = self._error
        raise RuntimeError(
            f"data pipeline worker failed permanently at step {step} "
            f"after {self._retries + 1} attempts") from exc

    def get(self, step: int):
        """The batch for ``step``; recomputes deterministically on timeout
        or sequence mismatch (elastic restart); raises if the worker died
        on a persistent ``batch_fn`` error."""
        deadline = time.monotonic() + self._timeout
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                break                   # straggler: recompute inline
            try:
                got_step, batch = self._q.get(
                    timeout=min(0.25, remaining))
            except queue.Empty:
                if self._error is not None:
                    self._raise_worker_error()
                continue
            if got_step == step:
                return batch
            break                       # sequence mismatch: recompute
        if self._error is not None:
            self._raise_worker_error()
        return self._fn(step)           # deterministic fallback

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
