"""Host-side data pipeline with prefetch + straggler mitigation.

Production posture (DESIGN.md §5): data is host-indexed and deterministic
in (seed, step), so any host can recompute any slice — a re-shard or a
restarted worker never loses or duplicates samples. The pipeline
prefetches ``depth`` batches on a thread, and ``get`` has a timeout: if a
batch misses the deadline (straggler / slow storage in a real deployment)
the deterministic generator recomputes it inline, so the step never
stalls behind one slow host.
"""
from __future__ import annotations

import queue
import threading


class DataPipeline:
    def __init__(self, batch_fn, start_step: int = 0, depth: int = 2,
                 timeout_s: float = 30.0):
        self._fn = batch_fn
        self._depth = depth
        self._timeout = timeout_s
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._next = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._next
        while not self._stop.is_set():
            try:
                batch = self._fn(step)
            except Exception:           # pragma: no cover - defensive
                break
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.5)
                    break
                except queue.Full:
                    continue
            step += 1

    def get(self, step: int):
        """The batch for ``step``; recomputes deterministically on timeout
        or sequence mismatch (elastic restart)."""
        try:
            got_step, batch = self._q.get(timeout=self._timeout)
            if got_step == step:
                return batch
        except queue.Empty:
            pass
        return self._fn(step)           # straggler fallback: recompute

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
