"""Deterministic synthetic LM data (no external corpora in this container).

A Zipf-distributed, Markov-flavored token stream that is (a) deterministic
in (seed, step, host) — so restarts and elastic re-shards never lose or
duplicate samples, and (b) *learnable* — next-token depends on the previous
token, so training loss actually decreases and optimizer comparisons
(Trion vs Dion etc.) are meaningful, mirroring the paper's C4 curves in
shape if not in absolute value.

Layout contract: global step -> a disjoint slice of the infinite stream per
(host, microbatch row). ``make_batch_fn`` returns a jit-able pure function
of (step,) so the pipeline can run on-device, overlapping with compute.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.1
    markov_shift: int = 7

    def _zipf_sample(self, key, shape):
        """Inverse-CDF Zipf over [2, vocab) (0/1 reserved: pad/bos)."""
        v = self.vocab_size - 2
        ranks = jnp.arange(1, v + 1, dtype=jnp.float32)
        w = ranks ** (-self.zipf_a)
        cdf = jnp.cumsum(w) / jnp.sum(w)
        u = jax.random.uniform(key, shape)
        idx = jnp.searchsorted(cdf, u)
        return (idx + 2).astype(jnp.int32)

    def batch(self, step: jax.Array) -> dict:
        """(tokens, targets) for one global step; deterministic in step."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        b, s = self.global_batch, self.seq_len
        base = self._zipf_sample(key, (b, s + 1))
        # Markov flavor: token_t depends on token_{t-1} (learnable signal)
        prev = jnp.roll(base, 1, axis=1)
        mixed = jnp.where(
            (prev + base) % 3 == 0,
            (prev * self.markov_shift + 11) % (self.vocab_size - 2) + 2,
            base,
        )
        tokens = mixed[:, :-1]
        targets = mixed[:, 1:]
        return {"tokens": tokens, "targets": targets}


def make_batch_fn(cfg, seq_len: int, global_batch: int, seed: int = 0):
    """Batch function including stub modality frontends (deterministic)."""
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=seq_len,
                     global_batch=global_batch, seed=seed)

    def fn(step):
        batch = ds.batch(step)
        key = jax.random.fold_in(jax.random.PRNGKey(seed + 1), step)
        if cfg.encoder_layers:
            batch["frames"] = 0.02 * jax.random.normal(
                key, (global_batch, cfg.encoder_seq, cfg.d_model),
                jnp.dtype(cfg.compute_dtype))
        if cfg.n_image_tokens:
            batch["image_embeds"] = 0.02 * jax.random.normal(
                key, (global_batch, cfg.n_image_tokens, cfg.d_model),
                jnp.dtype(cfg.compute_dtype))
        return batch

    return fn
