"""In-jit subspace telemetry: the typed aux pytree and its collector.

The two-step dynamic column selection computes, for free, the exact
quantity that tells us how good the low-rank approximation is (§4.1: the
column-norm mass of ``S = G @ Q``). Every term is basis-agnostic — ``Q``
may come from any registered orthogonal-basis backend (DCT/DST/Hadamard/
random-orthogonal, core/transforms.py); orthogonality is all the
captured-energy identity needs. :class:`SubspaceStats` packages that —
plus the index-overlap drift and EF-buffer mass that the adaptive
controllers need — as a per-leaf NamedTuple of small fp32 arrays (leading
dims = stacked layers), emitted *inside* the traced optimizer update.

Collection is out-of-band with respect to the ``Optimizer(init, update)``
signature: a :class:`StatsCollector` is installed with :func:`collect`
around the (traced) ``optimizer.update`` call; the chain runtime
(``as_optimizer``) picks it up via :func:`active_collector` and threads it
through the transform-chain ``Context``; ``lowrank_project`` scopes it to
each leaf's tree path. Because installation happens at trace time, the
recorded values are tracers and ``collector.tree()`` is a valid jit output
(``make_train_step`` returns it under ``metrics["telemetry"]``).

With no collector installed ``Context.stats`` is ``None`` and the rules
skip stat construction entirely — the traced graph is bit-identical to a
telemetry-free build (zero overhead when off; the ≤3 % when on is gated by
``benchmarks/telemetry_overhead.py``).
"""
from __future__ import annotations

import contextlib
from typing import NamedTuple

import jax
import jax.numpy as jnp


class SubspaceStats(NamedTuple):
    """Per-leaf projection-quality statistics (fp32, leading dims = stacked
    layers). All derive from quantities the fused step already computes —
    no extra ``G``-sized passes (DESIGN.md §8)."""

    captured_energy: jax.Array   # ||Q_r^T G||_F^2 / ||G||_F^2 in [0, 1]
    #                              (any orthogonal shared basis Q)
    topr_margin: jax.Array       # (v_r - v_{r+1})/v_1 of column energies;
    #                              -1 on steps where norms aren't resident
    index_overlap: jax.Array     # |idx_new ∩ idx_prev| / r at refresh
    #                              steps; -1 when not a measurement (keep
    #                              steps, basis/non-index projectors) —
    #                              consumers gate on >= 0
    ef_norm: jax.Array           # ||EF||_F written this step (0 if no EF)
    rank_utilization: jax.Array  # participation ratio of the r selected
    #                              column energies, in (0, 1]


def captured_energy(sel_sq: jax.Array, total_sq: jax.Array) -> jax.Array:
    """Energy ratio with a zero-gradient-safe denominator."""
    return sel_sq / jnp.maximum(total_sq, 1e-30)


def rank_utilization(col_energies: jax.Array) -> jax.Array:
    """Participation ratio of the selected column energies, normalized to
    (0, 1]: 1 when energy spreads evenly over the r kept columns, 1/r when
    a single column holds everything. ``col_energies``: (..., r)."""
    r = col_energies.shape[-1]
    s1 = jnp.sum(col_energies, axis=-1)
    s2 = jnp.sum(col_energies * col_energies, axis=-1)
    return (s1 * s1) / (r * jnp.maximum(s2, 1e-30))


class StatsScope(NamedTuple):
    """A collector bound to one leaf's tree path (what rules see as
    ``ctx.stats``)."""

    collector: "StatsCollector"
    path: str

    def record(self, stats: SubspaceStats) -> None:
        self.collector.record(self.path, stats)


class StatsCollector:
    """Accumulates ``{leaf path: SubspaceStats}`` during one update trace."""

    def __init__(self):
        self._stats: dict[str, SubspaceStats] = {}

    def record(self, path: str, stats: SubspaceStats) -> None:
        self._stats[path] = stats

    def scope(self, path: str) -> StatsScope:
        return StatsScope(self, path)

    def tree(self) -> dict[str, SubspaceStats]:
        """The collected aux pytree — a valid jit output (tracers inside)."""
        return dict(self._stats)


_ACTIVE: list[StatsCollector] = []


def active_collector() -> StatsCollector | None:
    """The innermost installed collector (None = telemetry off)."""
    return _ACTIVE[-1] if _ACTIVE else None


@contextlib.contextmanager
def collect():
    """Install a collector around a traced ``optimizer.update`` call."""
    col = StatsCollector()
    _ACTIVE.append(col)
    try:
        yield col
    finally:
        _ACTIVE.pop()


def summarize(stats: SubspaceStats) -> dict[str, float]:
    """Collapse stacked-layer axes to scalar means (controller food).
    Sentinel entries (negative margin/overlap on keep steps) are kept as-is
    — callers filter on them."""
    import numpy as np

    out = {}
    for name, val in stats._asdict().items():
        out[name] = float(np.mean(np.asarray(jax.device_get(val))))
    return out
