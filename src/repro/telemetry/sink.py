"""Host-side telemetry sink: ring buffer + JSONL/CSV writers.

Plugs into the Trainer's structured ``log_metrics(record)`` hook
(train/loop.py). Each record is the per-step metrics dict (device scalars
plus the ``telemetry`` subtree of per-leaf :class:`SubspaceStats`); the
sink converts to host floats, buckets ``every`` consecutive steps into one
aggregated row (mean over the bucket, elementwise for stacked-layer
lists), keeps the last ``ring`` rows in memory for controllers/tests, and
appends each row to a JSONL or CSV file.

Conversion forces a device sync per step — that is a *host*-side cost of
observability, deliberately kept off the jit hot path (the in-jit overhead
is the ≤3 % gated by benchmarks/telemetry_overhead.py). Use a coarser
``every`` if host-side cost ever matters.
"""
from __future__ import annotations

import collections
import csv
import json
import os
from typing import Any

import jax
import numpy as np

FORMATS = ("jsonl", "csv")


def _to_host(val) -> Any:
    """Device scalar/array -> float / nested list (JSON-ready)."""
    arr = np.asarray(jax.device_get(val))
    if arr.ndim == 0:
        return float(arr)
    return arr.astype(np.float64).tolist()


def flatten_record(record: dict, sep: str = "/") -> dict[str, Any]:
    """Nested metrics dict -> flat {dotted key: float | list}.

    NamedTuples (SubspaceStats) flatten by field name; nested dicts (the
    ``telemetry`` subtree) by key, so a stacked-attention leaf's captured
    energy lands under e.g. ``telemetry/block/0/wq/captured_energy``.
    """
    flat: dict[str, Any] = {}

    def walk(prefix: str, node):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(f"{prefix}{sep}{k}" if prefix else str(k), v)
        elif hasattr(node, "_fields"):          # NamedTuple (SubspaceStats)
            for k, v in zip(node._fields, node):
                walk(f"{prefix}{sep}{k}" if prefix else str(k), v)
        elif node is None:
            pass
        elif isinstance(node, (int, float, bool)):
            flat[prefix] = float(node)
        else:
            flat[prefix] = _to_host(node)

    walk("", record)
    return flat


# stat fields whose -1 means "not a measurement" (keep steps, basis
# projectors — see SubspaceStats): averaging a sentinel with real values
# would produce numbers that are neither, so those entries are excluded
# from the bucket mean and a bucket with no valid entries stays -1
_SENTINEL_FIELDS = ("topr_margin", "index_overlap")


def _agg(values: list, *, gated: bool = False) -> Any:
    """Mean over a bucket of rows; elementwise for list-valued entries.
    ``gated=True`` masks out negative (sentinel) entries first."""
    arr = np.asarray(values, np.float64)
    if gated:
        valid = arr >= 0
        s = np.where(valid, arr, 0.0).sum(axis=0)
        n = valid.sum(axis=0)
        out = np.where(n > 0, s / np.maximum(n, 1), -1.0)
    else:
        out = arr.mean(axis=0)
    return out.tolist() if isinstance(values[0], list) else float(out)


class TelemetrySink:
    """Step-bucketed telemetry writer with an in-memory ring buffer.

    ``sink.log_metrics`` is the Trainer hook. Rows aggregate ``every``
    consecutive records; ``history()`` exposes the ring (newest last).
    """

    def __init__(self, path: str | None, *, fmt: str = "jsonl",
                 every: int = 10, ring: int = 512, append: bool = False):
        """``append=True`` preserves existing rows — the right mode for
        checkpoint-resumable runs (a preemption restart must not truncate
        the pre-preemption telemetry; rows carry step numbers, so a
        continued file stays unambiguous)."""
        if fmt not in FORMATS:
            raise ValueError(f"unknown telemetry format {fmt!r}; "
                             f"allowed: {FORMATS}")
        if every < 1:
            raise ValueError(f"every must be >= 1, got {every}")
        self.path = path
        self.fmt = fmt
        self.every = every
        self._bucket: list[dict[str, Any]] = []
        self._ring: collections.deque = collections.deque(maxlen=ring)
        self._file = None
        self._csv_fields: list[str] | None = None
        if path:
            d = os.path.dirname(path)
            if d:
                os.makedirs(d, exist_ok=True)
            resuming = append and os.path.exists(path) \
                and os.path.getsize(path) > 0
            self._file = open(path, "a" if append else "w", newline="")
            if resuming and fmt == "csv":
                # the header already exists; reuse its field set so
                # appended rows stay aligned
                with open(path, newline="") as f:
                    header = f.readline().strip()
                if header:
                    self._csv_fields = header.split(",")
                    self._writer = csv.DictWriter(
                        self._file, self._csv_fields,
                        extrasaction="ignore", restval="")

    # -- ingestion ----------------------------------------------------------
    def log_metrics(self, record: dict) -> None:
        """Trainer hook: one per-step record (step + device scalars +
        per-leaf stats). Emits an aggregated row every ``every`` steps."""
        self._bucket.append(flatten_record(record))
        if len(self._bucket) >= self.every:
            self._emit()

    def _emit(self) -> None:
        if not self._bucket:
            return
        keys: dict[str, None] = {}
        for rec in self._bucket:
            keys.update(dict.fromkeys(rec))     # ordered key union
        row = {}
        for k in keys:
            vals = [rec[k] for rec in self._bucket if k in rec]
            if k == "step":
                row[k] = vals[-1]
            else:
                gated = k.rsplit("/", 1)[-1] in _SENTINEL_FIELDS
                row[k] = _agg(vals, gated=gated)
        self._bucket = []
        self._ring.append(row)
        self._write(row)

    # -- output -------------------------------------------------------------
    def _write(self, row: dict) -> None:
        if self._file is None:
            return
        if self.fmt == "jsonl":
            self._file.write(json.dumps(row) + "\n")
        else:
            # CSV needs scalar cells and a stable header: stacked-layer
            # lists are collapsed to their mean; the first row fixes the
            # field set, later-appearing keys are dropped (JSONL keeps all)
            scal = {k: (float(np.mean(v)) if isinstance(v, list) else v)
                    for k, v in row.items()}
            if self._csv_fields is None:
                self._csv_fields = list(scal)
                self._writer = csv.DictWriter(self._file, self._csv_fields,
                                              extrasaction="ignore",
                                              restval="")
                self._writer.writeheader()
            self._writer.writerow(scal)
        self._file.flush()

    def history(self) -> list[dict]:
        """Aggregated rows currently in the ring buffer (newest last)."""
        return list(self._ring)

    def flush(self) -> None:
        """Emit any partial bucket (end of run / preemption)."""
        self._emit()

    def close(self) -> None:
        self.flush()
        if self._file is not None:
            self._file.close()
            self._file = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
