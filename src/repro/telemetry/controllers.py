"""Closed-loop controllers fed by subspace telemetry (DESIGN.md §8).

Two controllers, both keyed by leaf tree path (the same keys telemetry
emits under and ``lowrank_project(overrides=...)`` consumes):

:class:`RankAllocator`
    Redistributes a global rank budget across layers by captured energy
    (AdaRankGrad's observation: per-layer gradient rank shrinks over
    training, so a fixed global ``r`` wastes memory where energy is
    concentrated and starves layers where it is spread). Bounded
    (``min_rank``/``max_rank``/``quantum``), hysteresis-damped (moves at
    most ``max_step`` quanta per decision, skips moves smaller than one
    quantum), and budget-preserving: the weighted sum of ranks (weights =
    moment elements per rank unit) never exceeds the uniform-rank budget,
    so total optimizer-state memory stays within the fixed-rank footprint.

:class:`RefreshScheduler`
    Stretches/shrinks each leaf's selection ``update_interval`` on a
    power-of-two ladder from measured index-overlap drift (Online Subspace
    Descent: refresh cadence should react to drift, not a fixed T_u).
    Low drift -> refresh less often (cheaper steps); high drift -> refresh
    every step.

Both controllers are plain host-side objects with JSON ``state_dict`` /
``load_state_dict`` so they round-trip through the CheckpointManager
manifest (tests/test_train_substrate.py) and survive preemption.

Rank is a static shape parameter, so adopting a new allocation means
rebuilding the optimizer and migrating its state —
:func:`migrate_opt_state` keeps everything whose shape survived (step,
PRNG key, bases, full-rank Adam moments, EF buffers — EF is rank-
independent by construction) and re-initializes only the changed leaves'
low-rank moments/indices (a subspace reset; the EF buffer carries the
residual history across it).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import numpy as np

from repro import obs


def _controller_metrics():
    """Controller instruments (no-ops until ``obs.enable()``). Each
    adopted proposal also lands on the span tracer as a structured
    instant carrying the full before/after maps."""
    r = obs.registry()
    return {
        "rank_decisions": r.counter(
            "controller_rank_reallocations_total",
            "adopted rank re-allocations"),
        "interval_decisions": r.counter(
            "controller_interval_changes_total",
            "adopted refresh-interval ladder moves"),
        "ranks_changed": r.counter(
            "controller_ranks_changed_total",
            "leaves whose rank moved across all re-allocations"),
        "rank_spread": r.gauge(
            "controller_rank_spread",
            "max - min allocated rank after the last decision"),
    }


# ---------------------------------------------------------------------------
# leaf inventory
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LeafInfo:
    """Static per-leaf facts the controllers need (from param shapes)."""

    rows: int    # total moment rows = prod(shape) / cols (stacked included)
    cols: int    # projected (min oriented) dimension — caps the rank


def leaf_inventory(params, label_fn=None) -> dict[str, LeafInfo]:
    """``{leaf path: LeafInfo}`` for every low-rank-routed matrix leaf.

    Works on concrete arrays or ShapeDtypeStructs (dry-run friendly).
    """
    from repro.optim.common import (default_label_fn, labelled_tree,
                                    oriented_dims, path_str)

    label_fn = label_fn or default_label_fn
    labels = labelled_tree(params, label_fn)
    out: dict[str, LeafInfo] = {}

    def visit(kp, lbl, p):
        if lbl != "lowrank":
            return lbl
        rows, cols = oriented_dims(p.shape)
        total = int(np.prod(p.shape))
        out[path_str(kp)] = LeafInfo(rows=total // cols, cols=cols)
        return lbl

    jax.tree_util.tree_map_with_path(visit, labels, params,
                                     is_leaf=lambda x: isinstance(x, str))
    return out


def _quantize(r: float, q: int) -> int:
    return max(q, int(round(r / q)) * q)


# ---------------------------------------------------------------------------
# rank allocator
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RankAllocatorConfig:
    base_rank: int                  # the uniform rank defining the budget
    min_rank: int = 0               # floor; 0 -> max(quantum, base_rank/4)
    max_rank: int = 0               # cap per leaf; 0 -> 4 * base_rank
    quantum: int = 8                # ranks move in multiples of this
    max_step: int = 4               # max quanta moved per decision per leaf
    decide_every: int = 50          # steps between reallocation decisions
    ema_decay: float = 0.9          # captured-energy EMA smoothing
    deadband: float = 0.02          # min captured-energy spread to act on

    def cap(self) -> int:
        return self.max_rank or 4 * self.base_rank

    def floor(self) -> int:
        return self.min_rank or max(self.quantum, self.base_rank // 4)


class RankAllocator:
    """Per-layer rank allocation by captured energy, budget-preserving.

    Control law (each ``decide_every`` steps): leaves with *low* EMA
    captured energy have under-provisioned subspaces and bid for more
    rank; leaves near 1.0 release it. Targets are the budget-weighted
    water-filling of the deficits ``1 - ema``; each leaf then moves at
    most ``max_step`` quanta toward its target, and a repair pass walks
    rank back off the lowest-deficit leaves until the weighted budget
    constraint holds again.
    """

    def __init__(self, cfg: RankAllocatorConfig,
                 leaves: dict[str, LeafInfo]):
        if not leaves:
            raise ValueError("RankAllocator needs at least one lowrank leaf")
        self.cfg = cfg
        self.leaves = leaves
        r0 = cfg.base_rank
        self.alloc: dict[str, int] = {
            p: min(r0, li.cols) for p, li in leaves.items()}
        # budget in weighted rank units: sum_i rows_i * r_i (elements of ONE
        # moment buffer; m and v scale identically so the ratio is exact)
        self.budget = sum(leaves[p].rows * r for p, r in self.alloc.items())
        self.ema: dict[str, float] = {}
        self.last_decision = 0
        self.n_decisions = 0
        self._m = _controller_metrics()
        self._tracer = obs.tracer()

    # -- telemetry ingestion ------------------------------------------------
    def observe(self, step: int, stats_by_path: dict[str, dict]) -> None:
        """Feed per-leaf stat summaries ({path: {"captured_energy": f, ...}})."""
        d = self.cfg.ema_decay
        for path, st in stats_by_path.items():
            if path not in self.leaves:
                continue
            ce = float(st["captured_energy"])
            if not math.isfinite(ce):
                continue
            prev = self.ema.get(path)
            self.ema[path] = ce if prev is None else d * prev + (1 - d) * ce

    # -- decision -----------------------------------------------------------
    def propose(self, step: int) -> dict[str, int] | None:
        """New allocation, or None when nothing should change."""
        cfg = self.cfg
        if step - self.last_decision < cfg.decide_every:
            return None
        if len(self.ema) < len(self.leaves):
            return None                       # not every leaf observed yet
        self.last_decision = step
        emas = {p: min(max(self.ema[p], 0.0), 1.0) for p in self.leaves}
        if max(emas.values()) - min(emas.values()) < cfg.deadband:
            return None                       # hysteresis: spread too small
        deficits = {p: max(1.0 - e, 1e-3) for p, e in emas.items()}
        w = {p: self.leaves[p].rows for p in self.leaves}
        mean_def = (sum(w[p] * deficits[p] for p in w) / sum(w.values()))

        new: dict[str, int] = {}
        for p, li in self.leaves.items():
            cur = self.alloc[p]
            target = cfg.base_rank * deficits[p] / mean_def
            target = min(max(target, cfg.floor()), cfg.cap(), li.cols)
            delta = max(-cfg.max_step * cfg.quantum,
                        min(cfg.max_step * cfg.quantum, target - cur))
            new[p] = min(_quantize(cur + delta, cfg.quantum), li.cols)

        # repair: shed quanta from the lowest-deficit leaves until the
        # weighted budget constraint holds
        def used(a):
            return sum(self.leaves[p].rows * r for p, r in a.items())

        order = sorted(new, key=lambda p: deficits[p])
        i = 0
        while used(new) > self.budget and i < 10_000:
            p = order[i % len(order)]
            if new[p] - cfg.quantum >= min(cfg.floor(), self.alloc[p]):
                new[p] -= cfg.quantum
            i += 1
        if used(new) > self.budget or new == self.alloc:
            return None
        before = dict(self.alloc)
        self.alloc = new
        self.n_decisions += 1
        moved = {p: (before[p], r) for p, r in new.items()
                 if r != before[p]}
        self._m["rank_decisions"].inc()
        self._m["ranks_changed"].inc(len(moved))
        self._m["rank_spread"].set(max(new.values()) - min(new.values()))
        self._tracer.instant(
            "controller/rank_realloc", step=step,
            changed={p: {"before": b, "after": a}
                     for p, (b, a) in moved.items()},
            budget_used=used(new), budget=self.budget)
        return dict(new)

    # -- persistence --------------------------------------------------------
    def state_dict(self) -> dict:
        return {"alloc": dict(self.alloc), "ema": dict(self.ema),
                "last_decision": self.last_decision,
                "n_decisions": self.n_decisions, "budget": self.budget}

    def load_state_dict(self, d: dict) -> None:
        self.alloc = {str(k): int(v) for k, v in d["alloc"].items()}
        self.ema = {str(k): float(v) for k, v in d["ema"].items()}
        self.last_decision = int(d["last_decision"])
        self.n_decisions = int(d.get("n_decisions", 0))
        self.budget = int(d.get("budget", self.budget))

    def overrides(self) -> dict[str, dict]:
        """Current allocation as lowrank_project override entries (only
        leaves that differ from the uniform base rank)."""
        r0 = self.cfg.base_rank
        return {p: {"rank": r} for p, r in self.alloc.items()
                if r != min(r0, self.leaves[p].cols)}


# ---------------------------------------------------------------------------
# refresh scheduler
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RefreshSchedulerConfig:
    base_interval: int = 1          # starting T_u
    max_interval: int = 64          # ladder cap (powers of two)
    low_drift: float = 0.15         # drift below this -> stretch interval
    high_drift: float = 0.5         # drift above this -> shrink interval
    ema_decay: float = 0.8          # drift EMA smoothing
    cooldown: int = 50              # min steps between changes per leaf
    decide_every: int = 50


class RefreshScheduler:
    """Adapts each leaf's selection refresh interval to measured drift.

    Drift = ``1 - index_overlap`` observed at refresh steps (keep steps
    report overlap 1.0 and are ignored via the topr_margin sentinel).
    Stable subspace -> double the interval (skip redundant selections);
    fast-moving subspace -> halve it, down to every-step refresh. The
    low/high thresholds leave a hysteresis band where nothing changes.
    """

    def __init__(self, cfg: RefreshSchedulerConfig, paths):
        self.cfg = cfg
        self.interval: dict[str, int] = {p: cfg.base_interval for p in paths}
        self.drift_ema: dict[str, float] = {}
        self.last_change: dict[str, int] = {p: 0 for p in paths}
        self.last_decision = 0
        self._m = _controller_metrics()
        self._tracer = obs.tracer()

    def observe(self, step: int, stats_by_path: dict[str, dict]) -> None:
        d = self.cfg.ema_decay
        for path, st in stats_by_path.items():
            if path not in self.interval:
                continue
            # overlap < 0 is the not-a-measurement sentinel: keep steps
            # (no selection happened) and basis/non-index projectors (for
            # which the scheduler is honestly inert — no observations, no
            # proposals). Only refresh-step measurements feed the EMA.
            overlap = float(st["index_overlap"])
            if overlap < 0:
                continue
            drift = 1.0 - overlap
            if not math.isfinite(drift):
                continue
            prev = self.drift_ema.get(path)
            self.drift_ema[path] = (drift if prev is None
                                    else d * prev + (1 - d) * drift)

    def propose(self, step: int) -> dict[str, int] | None:
        cfg = self.cfg
        if step - self.last_decision < cfg.decide_every:
            return None
        self.last_decision = step
        moved: dict[str, tuple[int, int]] = {}
        for p, ema in self.drift_ema.items():
            if step - self.last_change[p] < cfg.cooldown:
                continue
            cur = self.interval[p]
            if ema < cfg.low_drift and cur < cfg.max_interval:
                self.interval[p] = cur * 2
            elif ema > cfg.high_drift and cur > 1:
                self.interval[p] = max(1, cur // 2)
            else:
                continue
            self.last_change[p] = step
            moved[p] = (cur, self.interval[p])
        if not moved:
            return None
        self._m["interval_decisions"].inc()
        self._tracer.instant(
            "controller/interval_change", step=step,
            changed={p: {"before": b, "after": a, "drift":
                         round(self.drift_ema[p], 4)}
                     for p, (b, a) in moved.items()})
        return dict(self.interval)

    def state_dict(self) -> dict:
        return {"interval": dict(self.interval),
                "drift_ema": dict(self.drift_ema),
                "last_change": dict(self.last_change),
                "last_decision": self.last_decision}

    def load_state_dict(self, d: dict) -> None:
        self.interval = {str(k): int(v) for k, v in d["interval"].items()}
        self.drift_ema = {str(k): float(v)
                          for k, v in d["drift_ema"].items()}
        self.last_change = {str(k): int(v)
                            for k, v in d["last_change"].items()}
        self.last_decision = int(d["last_decision"])

    def overrides(self) -> dict[str, dict]:
        return {p: {"update_interval": t} for p, t in self.interval.items()
                if t != self.cfg.base_interval}


# ---------------------------------------------------------------------------
# state migration across an optimizer rebuild
# ---------------------------------------------------------------------------
def merge_overrides(*maps: dict[str, dict] | None) -> dict[str, dict]:
    """Union per-leaf override maps (later maps win on field collisions)."""
    out: dict[str, dict] = {}
    for m in maps:
        for path, fields in (m or {}).items():
            out.setdefault(path, {}).update(fields)
    return out


def migrate_opt_state(old_state, fresh_state):
    """Carry optimizer state across a rank-reallocation rebuild.

    ``old_state`` and ``fresh_state`` have identical pytree *structure*
    (same params, same combinator nesting) but low-rank arrays of changed
    leaves differ in shape. Per array: keep the old value when shape and
    dtype survived, else take the freshly initialized one. Per
    ``ProjAdamLeaf`` whose rank changed, the whole moment/index/inner-step
    set is reset together (fresh) while the rank-independent EF buffer is
    carried over — a subspace reset whose residual history survives in EF.
    """
    from repro.optim.projected_adam import ProjAdamLeaf

    def keep_or_fresh(fresh, old):
        if (hasattr(old, "shape") and hasattr(fresh, "shape")
                and old.shape == fresh.shape and old.dtype == fresh.dtype):
            return old
        return fresh

    def leaf(fresh, old):
        if isinstance(fresh, ProjAdamLeaf):
            if old.m.shape == fresh.m.shape:
                return old
            # rank changed: fresh moments/indices/inner_step, EF carried
            return ProjAdamLeaf(
                m=fresh.m, v=fresh.v, proj=fresh.proj,
                ef=jax.tree.map(keep_or_fresh, fresh.ef, old.ef),
                inner_step=fresh.inner_step)
        return keep_or_fresh(fresh, old)

    return jax.tree.map(leaf, fresh_state, old_state,
                        is_leaf=lambda x: isinstance(x, ProjAdamLeaf))
