"""Runtime glue for the closed-loop controllers (DESIGN.md §8).

Rank and refresh interval are *static* parameters of the traced optimizer
(rank shapes the moment buffers), so a controller decision means:
rebuild the optimizer with the merged per-leaf overrides, re-jit the train
step, and migrate the optimizer state (``migrate_opt_state`` — everything
rank-independent survives, changed leaves get a subspace reset whose
residual history is carried by the EF buffer). Decisions are hysteresis-
damped and quantized by the controllers, so rebuilds are rare — a bounded
number of retraces over a run, amortized to noise.

:class:`AdaptiveOptimizerManager` owns that cycle and presents the three
callables the Trainer consumes: ``init_state`` / ``step`` /
``control_hook``, plus ``state_dict``/``load_state_dict`` so controller
state rides the checkpoint manifest (Trainer ``extra_state``).
"""
from __future__ import annotations

from typing import Any, Callable

from .controllers import RankAllocator, RefreshScheduler, merge_overrides
from .stats import summarize


class AdaptiveOptimizerManager:
    """Owns the optimizer rebuild cycle driven by telemetry.

    Parameters
    ----------
    make_optimizer:
        ``overrides -> Optimizer`` factory (e.g. a ``get_optimizer``
        closure forwarding ``overrides=``).
    make_step:
        ``optimizer -> jitted (TrainState, batch) -> (TrainState, metrics)``
        factory; called again after every adopted decision.
    make_train_state:
        ``optimizer -> TrainState`` initializer (fresh params + opt state).
    rank_allocator / refresh_scheduler:
        either may be None (rank-only / refresh-only operation).
    log_fn:
        decision log sink (default print).
    """

    def __init__(self, *, make_optimizer: Callable[[dict | None], Any],
                 make_step: Callable[[Any], Any],
                 make_train_state: Callable[[Any], Any],
                 rank_allocator: RankAllocator | None = None,
                 refresh_scheduler: RefreshScheduler | None = None,
                 log_fn: Callable[[str], None] = print):
        self.make_optimizer = make_optimizer
        self.make_step = make_step
        self.make_train_state = make_train_state
        self.rank_allocator = rank_allocator
        self.refresh_scheduler = refresh_scheduler
        self.log = log_fn
        self.n_rebuilds = 0
        self._build()

    # -- build/rebuild ------------------------------------------------------
    def current_overrides(self) -> dict[str, dict]:
        return merge_overrides(
            self.rank_allocator.overrides() if self.rank_allocator else None,
            self.refresh_scheduler.overrides()
            if self.refresh_scheduler else None)

    def _build(self) -> None:
        ov = self.current_overrides()
        self.optimizer = self.make_optimizer(ov or None)
        self._step_fn = self.make_step(self.optimizer)

    def _rebuild(self, state):
        from repro.core.transforms import basis_cache
        from repro.telemetry.controllers import migrate_opt_state

        self._build()
        self.n_rebuilds += 1
        # re-initing the optimizer serves the shared n×n bases from the
        # process-wide BasisCache instead of recomputing them per rebuild
        fresh_opt_state = self.optimizer.init(state.params)
        cs = basis_cache().stats()
        self.log(f"[adaptive] rebuild #{self.n_rebuilds}: basis cache "
                 f"{cs['hits']} hits / {cs['misses']} misses "
                 f"({cs['entries']} bases resident)")
        migrated = migrate_opt_state(state.opt_state, fresh_opt_state)
        return state._replace(opt_state=migrated)

    # -- Trainer plumbing ---------------------------------------------------
    def init_state(self):
        return self.make_train_state(self.optimizer)

    def step(self, state, batch):
        """Stable callable for the Trainer; indirects to the current jit."""
        return self._step_fn(state, batch)

    def control_hook(self, step: int, state, metrics):
        """Trainer hook: feed telemetry, maybe adopt a decision.

        Returns a migrated TrainState when the optimizer was rebuilt,
        else None. Controllers gate their own cadence (``decide_every``),
        so this is cheap to call every step.
        """
        tel = metrics.get("telemetry")
        if not tel:
            return None
        stats_by_path = {path: summarize(st) for path, st in tel.items()}
        proposals = False
        if self.rank_allocator is not None:
            self.rank_allocator.observe(step, stats_by_path)
            if self.rank_allocator.propose(step) is not None:
                proposals = True
                self.log(f"[adaptive] step {step}: rank reallocation "
                         f"#{self.rank_allocator.n_decisions} -> "
                         f"{self.rank_allocator.alloc}")
        if self.refresh_scheduler is not None:
            self.refresh_scheduler.observe(step, stats_by_path)
            if self.refresh_scheduler.propose(step) is not None:
                proposals = True
                self.log(f"[adaptive] step {step}: refresh intervals -> "
                         f"{self.refresh_scheduler.interval}")
        if not proposals:
            return None
        return self._rebuild(state)

    # -- persistence (Trainer extra_state protocol) -------------------------
    def state_dict(self) -> dict:
        out: dict[str, Any] = {"n_rebuilds": self.n_rebuilds}
        if self.rank_allocator is not None:
            out["rank_allocator"] = self.rank_allocator.state_dict()
        if self.refresh_scheduler is not None:
            out["refresh_scheduler"] = self.refresh_scheduler.state_dict()
        return out

    def load_state_dict(self, d: dict) -> None:
        """Restore controller state, then rebuild so the optimizer (and the
        opt-state shapes ``init_state`` produces) match the restored
        allocation — call BEFORE restoring the checkpointed train state."""
        self.n_rebuilds = int(d.get("n_rebuilds", 0))
        if self.rank_allocator is not None and "rank_allocator" in d:
            self.rank_allocator.load_state_dict(d["rank_allocator"])
        if self.refresh_scheduler is not None and "refresh_scheduler" in d:
            self.refresh_scheduler.load_state_dict(d["refresh_scheduler"])
        self._build()
