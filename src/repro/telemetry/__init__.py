"""Subspace telemetry + adaptive control subsystem (DESIGN.md §8).

Layers (host-side pieces import lazily — the in-jit layer depends only on
jax, so the optimizer stack never pulls in file writers or controllers):

  stats.py        in-jit metrics: :class:`SubspaceStats` emitted per leaf by
                  the projected-Adam rules, collected through the
                  transform-chain ``Context`` with near-zero overhead.
  sink.py         host-side sink: ring buffer + JSONL/CSV writers with
                  step-bucketed aggregation; plugs into the Trainer's
                  structured ``log_metrics`` hook.
  controllers.py  closed-loop controllers: per-layer rank allocator and
                  adaptive refresh scheduler, both checkpointable.
  adaptive.py     runtime glue: rebuilds the optimizer with per-leaf
                  overrides when a controller moves, migrating state.
"""
from .stats import (  # noqa: F401
    StatsCollector,
    SubspaceStats,
    active_collector,
    collect,
)
