"""Mamba-1 selective SSM mixer (Jamba's sequence mixer).

Training uses a chunked scan: an outer `lax.scan` over sequence chunks
carrying the (B, d_inner, state) SSM state, with a parallel
`lax.associative_scan` inside each chunk. The (B, chunk, d_inner, state)
intermediates exist only per-chunk, and the elementwise-diagonal recurrence
``h' = a * h + b`` composes stably (a = exp(dt*A) <= 1).

Decode is the exact single-step recurrence with a (conv_cache, ssm_state)
cache.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard

from .layers import dense_init


def init_mamba(key, cfg) -> dict:
    d, din, st = cfg.d_model, cfg.mamba_d_inner, cfg.mamba_state
    dtr, ck = cfg.dt_rank, cfg.mamba_conv
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    a_init = jnp.tile(jnp.arange(1, st + 1, dtype=jnp.float32)[None, :], (din, 1))
    return {
        "in_proj": {"kernel": dense_init(ks[0], d, 2 * din, dt)},
        "conv": {"kernel": (jax.random.normal(ks[1], (ck, din)) /
                            math.sqrt(ck)).astype(dt),
                 "bias": jnp.zeros((din,), dt)},
        "x_proj": {"kernel": dense_init(ks[2], din, dtr + 2 * st, dt)},
        "dt_proj": {"kernel": dense_init(ks[3], dtr, din, dt),
                    "bias": jnp.full((din,), -4.6, dt)},  # softplus^-1(0.01)
        "a_log": jnp.log(a_init),                          # (din, st) fp32
        "d_skip": jnp.ones((din,), jnp.float32),
        "out_proj": {"kernel": dense_init(ks[4], din, d, dt)},
    }


def _causal_conv(x, kernel, bias):
    """Depthwise causal conv. x: (B, S, din); kernel: (K, din)."""
    k = kernel.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * kernel[i][None, None, :]
              for i in range(k))
    return out + bias


def _ssm_chunk(carry, inputs):
    """One chunk. carry h0: (B, din, st); inputs per-chunk arrays."""
    h0, = carry
    a, bx, c = inputs           # a,bx: (B, c, din, st); c: (B, c, st)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    a_cum, h_in = jax.lax.associative_scan(combine, (a, bx), axis=1)
    h = a_cum * h0[:, None] + h_in                       # (B, c, din, st)
    y = jnp.einsum("bcds,bcs->bcd", h, c)
    return (h[:, -1],), y


def mamba_mix(params, x, cfg, chunk: int = 128, return_state: bool = False):
    """(B, S, d) -> (B, S, d); with ``return_state`` also the decode cache
    {'conv': last K-1 pre-conv activations, 'ssm': final SSM state}."""
    b, s, d = x.shape
    din, st = cfg.mamba_d_inner, cfg.mamba_state
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)

    xz = x @ params["in_proj"]["kernel"]
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = shard(xs, "batch", None, "tp")
    conv_tail = xs[:, -(cfg.mamba_conv - 1):, :]          # decode conv cache
    xs = jax.nn.silu(_causal_conv(xs, params["conv"]["kernel"],
                                  params["conv"]["bias"]))

    dbc = xs @ params["x_proj"]["kernel"]
    dt_r, b_ssm, c_ssm = jnp.split(
        dbc, [cfg.dt_rank, cfg.dt_rank + st], axis=-1)
    dt = jax.nn.softplus(dt_r @ params["dt_proj"]["kernel"]
                         + params["dt_proj"]["bias"])     # (B, S, din)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))     # (din, st)

    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf[..., None] * a)                   # (B,S,din,st)
    drive = (dtf * xs.astype(jnp.float32))[..., None] * \
        b_ssm.astype(jnp.float32)[:, :, None, :]          # (B,S,din,st)

    nc = s // chunk
    resh = lambda t: t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)
    h0 = jnp.zeros((b, din, st), jnp.float32)
    (h_last, ), ys = jax.lax.scan(
        _ssm_chunk, (h0,),
        (resh(decay), resh(drive), resh(c_ssm.astype(jnp.float32))))
    y = ys.swapaxes(0, 1).reshape(b, s, din)
    y = y + xs.astype(jnp.float32) * params["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = shard(y, "batch", None, "tp")
    out = y @ params["out_proj"]["kernel"]
    if return_state:
        return out, {"conv": conv_tail, "ssm": h_last}
    return out


def init_mamba_cache(cfg, batch: int, dtype):
    return {
        "conv": jnp.zeros((batch, cfg.mamba_conv - 1, cfg.mamba_d_inner), dtype),
        "ssm": jnp.zeros((batch, cfg.mamba_d_inner, cfg.mamba_state),
                         jnp.float32),
    }


def mamba_step(params, x_t, cache, cfg):
    """x_t: (B, d) one token. Returns (y_t, new_cache)."""
    b, d = x_t.shape
    st = cfg.mamba_state
    xz = x_t @ params["in_proj"]["kernel"]
    xs, z = jnp.split(xz, 2, axis=-1)

    conv_in = jnp.concatenate([cache["conv"], xs[:, None, :]], axis=1)
    kern = params["conv"]["kernel"]                       # (K, din)
    xs = jax.nn.silu(jnp.einsum("bkd,kd->bd", conv_in, kern)
                     + params["conv"]["bias"])
    new_conv = conv_in[:, 1:]

    dbc = xs @ params["x_proj"]["kernel"]
    dt_r, b_ssm, c_ssm = jnp.split(dbc, [cfg.dt_rank, cfg.dt_rank + st], -1)
    dt = jax.nn.softplus(dt_r @ params["dt_proj"]["kernel"]
                         + params["dt_proj"]["bias"])
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt.astype(jnp.float32)[..., None] * a)  # (B,din,st)
    drive = (dt * xs).astype(jnp.float32)[..., None] * \
        b_ssm.astype(jnp.float32)[:, None, :]
    h = decay * cache["ssm"] + drive
    y = jnp.einsum("bds,bs->bd", h, c_ssm.astype(jnp.float32))
    y = y + xs.astype(jnp.float32) * params["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x_t.dtype)
    return y @ params["out_proj"]["kernel"], {"conv": new_conv, "ssm": h}
