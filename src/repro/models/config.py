"""ModelConfig: one dataclass describing every assigned architecture.

``schedule`` expresses the layer layout as segments of repeating
"super-blocks": ``((pattern, repeats), ...)`` where ``pattern`` is a tuple of
block kinds. Each segment is `lax.scan`ned over its repeats (HLO size stays
O(pattern), not O(layers)); interleavings (gemma3 5 local : 1 global, jamba
1 attn : 7 mamba with MoE every other layer) are expressed inside the
pattern, exactly as deployed.

Block kinds:
  attn        causal GQA self-attention + dense SwiGLU
  local       as `attn` but sliding-window
  attn_moe    causal GQA self-attention + MoE FFN
  mla_dense   DeepSeek MLA attention + dense SwiGLU
  mla_moe     DeepSeek MLA attention + (shared + routed) MoE
  mamba_dense Mamba SSM mixer + dense SwiGLU
  mamba_moe   Mamba SSM mixer + MoE FFN
  rwkv        RWKV-6 time-mix + channel-mix
  cross       cross-attention to stub image embeddings + dense SwiGLU (VLM)
  enc         bidirectional attention + GELU MLP (whisper encoder)
  dec         causal self-attn + cross-attn(encoder) + GELU MLP
"""
from __future__ import annotations

import dataclasses
from typing import Optional

Schedule = tuple[tuple[tuple[str, ...], int], ...]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|moe|hybrid|ssm|encdec|vlm
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    schedule: Schedule
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    use_qk_norm: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    sliding_window: int = 4096       # for 'local' blocks
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0                # routed expert hidden size
    shared_d_ff: int = 0             # shared expert hidden size
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # MLA (deepseek-v3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    mtp: bool = False                # multi-token-prediction extra head
    # Mamba (jamba)
    mamba_expand: int = 2
    mamba_state: int = 16
    mamba_conv: int = 4
    mamba_dt_rank: int = 0           # 0 -> d_model // 16
    # RWKV-6
    rwkv_head_size: int = 64
    rwkv_decay_lora: int = 64
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 1500          # fixed audio-frame count (stub frontend)
    # VLM
    n_image_tokens: int = 0
    # precision
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # attention chunking (blockwise flash-style)
    q_chunk: int = 512
    kv_chunk: int = 1024
    remat: bool = True
    # gradient-accumulation microbatch (rows of the global batch per inner
    # step; 0 = whole batch in one shot). Chosen per arch so activations fit.
    train_microbatch: int = 0
    # sequence-parallel attention over the `model` axis (shard_map; §Perf
    # iter-1). Wins when head counts don't divide tp (qwen 40q/8kv);
    # loses when they do (deepseek 128) — set per arch from measurements.
    attn_sp: bool = False
    # parameter layout policy: "fsdp_tp" | "pure_dp" (§Perf iter-5 —
    # sub-2B archs replicate params and data-parallelize all 256 chips)
    layout: str = "fsdp_tp"
    # decode-shape layout: "decode_tp" (§Perf iter-6) puts every matrix
    # column/row-parallel over the combined (dp x tp) axes so a decode
    # step does shard-local matmuls + one activation psum per block
    # instead of re-gathering FSDP weight shards per token
    decode_layout: str = "fsdp_tp"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_layers(self) -> int:
        return sum(len(p) * r for p, r in self.schedule)

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.mamba_dt_rank or max(1, self.d_model // 16)

    @property
    def rwkv_n_heads(self) -> int:
        return self.d_model // self.rwkv_head_size

    def block_kinds(self) -> tuple[str, ...]:
        out = []
        for pattern, _ in self.schedule:
            out.extend(pattern)
        return tuple(dict.fromkeys(out))

    @property
    def is_attention_free(self) -> bool:
        return all(k in ("rwkv", "mamba_dense", "mamba_moe")
                   for k in self.block_kinds())

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic archs: SSM / hybrid / sliding-window-dominated."""
        kinds = self.block_kinds()
        if any(k in ("rwkv", "mamba_dense", "mamba_moe") for k in kinds):
            return True
        return "local" in kinds           # gemma3-style 5:1 local:global

    def reduced(self, **overrides) -> "ModelConfig":
        """A small same-family config for CPU smoke tests."""
        def shrink_schedule(sched: Schedule) -> Schedule:
            return tuple((pattern, min(r, 1)) for pattern, r in sched)

        base = dict(
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            schedule=shrink_schedule(self.schedule),
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            moe_top_k=min(self.moe_top_k, 2) if self.moe_top_k else 0,
            moe_d_ff=64 if self.moe_d_ff else 0,
            shared_d_ff=64 if self.shared_d_ff else 0,
            q_lora_rank=64 if self.q_lora_rank else 0,
            kv_lora_rank=64 if self.kv_lora_rank else 0,
            qk_nope_dim=32 if self.qk_nope_dim else 0,
            qk_rope_dim=16 if self.qk_rope_dim else 0,
            v_head_dim=32 if self.v_head_dim else 0,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=16 if self.encoder_layers else self.encoder_seq,
            n_image_tokens=8 if self.n_image_tokens else 0,
            capacity_factor=8.0,   # drop-free routing: smoke tests compare
                                   # forward vs prefill+decode exactly
            sliding_window=8,
            q_chunk=8,
            kv_chunk=8,
            param_dtype="float32",
            compute_dtype="float32",
            remat=False,
            mamba_dt_rank=8 if "mamba_dense" in self.block_kinds()
                          or "mamba_moe" in self.block_kinds() else 0,
            rwkv_head_size=32,
            rwkv_decay_lora=8,
        )
        base.update(overrides)
        return dataclasses.replace(self, **base)
