"""Model primitives: norms, RoPE, blockwise (flash-style) attention, MLPs.

Pure-functional: params are nested dicts of arrays; every apply function is
shape-polymorphic over leading batch dims where possible. Activations are
annotated with *logical* sharding (repro.parallel.sharding.shard) so the same
code runs on CPU tests and the 512-chip mesh.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.parallel.compat import shard_map
from repro.parallel.sharding import shard


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rms_norm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale + bias).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_table(seq_len: int, head_dim: int, theta: float = 1e4,
               offset: int = 0, dtype=jnp.float32):
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    pos = jnp.arange(offset, offset + seq_len, dtype=jnp.float32)
    ang = pos[:, None] * freqs[None, :]
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x, cos, sin):
    """x: (..., S, H, hd); tables (S, hd/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1
                           ).astype(x.dtype)


def rope_at(pos, head_dim: int, theta: float = 1e4):
    """Per-position rope tables for decode. pos: (B,) int32 -> (B, 1, half)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.cos(ang)[:, None, :], jnp.sin(ang)[:, None, :]


def rope_tables_at(positions, head_dim: int, theta: float = 1e4,
                   dtype=jnp.float32):
    """``rope_table`` for a *traced* position vector (chunked prefill:
    the chunk's absolute start is a runtime scalar, so the static
    ``offset`` of ``rope_table`` can't express it). positions: (S,)
    int32 -> ((S, half), (S, half)) for ``apply_rope``."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


# ---------------------------------------------------------------------------
# Blockwise (flash-style) causal attention — pure JAX online softmax.
# Memory: O(S * chunk) instead of O(S^2); the fully-masked block pairs are
# still *computed* (mask applied) — removing them is a §Perf iteration.
# ---------------------------------------------------------------------------
NEG_INF = -1e30


def _attn_scores(qg, k, mask, hd):
    """qg: (B,Hkv,G,qc,hd); k: (B,Hkv,kc,hd) -> scores (B,Hkv,G,qc,kc).
    bf16 inputs, fp32 accumulation — no fp32 copies of K blocks."""
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(k.dtype), k,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    return jnp.where(mask, s, NEG_INF)


def blockwise_attention(q, k, v, *, causal: bool, window: int | None = None,
                        q_chunk: int = 512, kv_chunk: int = 512,
                        q_offset: int = 0):
    """Online-softmax attention.

    q: (B, Sq, Hq, hd); k, v: (B, Skv, Hkv, hd). ``q_offset`` is the absolute
    position of q[0] (prefill continuation). Returns (B, Sq, Hq, hd).
    """
    b, sq, hq, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    vd = v.shape[-1]          # value dim may differ from qk dim (MLA)
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    if sq % q_chunk:
        q_chunk = sq       # odd lengths (tests): one chunk
    if skv % kv_chunk:
        kv_chunk = skv
    nq, nk = sq // q_chunk, skv // kv_chunk
    group = hq // hkv

    qt = q.transpose(0, 2, 1, 3).reshape(b, hq, nq, q_chunk, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(b, hkv, nk, kv_chunk, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(b, hkv, nk, kv_chunk, vd)

    q_pos = (q_offset + jnp.arange(sq)).reshape(nq, q_chunk)
    k_pos = jnp.arange(skv).reshape(nk, kv_chunk)

    def q_step(qi):
        qb = qt[:, :, qi].reshape(b, hkv, group, q_chunk, hd)
        qp = q_pos[qi]                                    # (qc,)

        def kv_step(carry, ki):
            acc, m, denom = carry
            kb, vb = kt[:, :, ki], vt[:, :, ki]
            kp = k_pos[ki]
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= qp[:, None] >= kp[None, :]
            if window is not None:
                mask &= qp[:, None] - kp[None, :] < window
            s = _attn_scores(qb, kb, mask, hd)            # (B,Hkv,G,qc,kc)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            denom = denom * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(vb.dtype), vb,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (acc, m_new, denom), None

        acc0 = jnp.zeros((b, hkv, group, q_chunk, vd), jnp.float32)
        m0 = jnp.full((b, hkv, group, q_chunk), NEG_INF, jnp.float32)
        d0 = jnp.zeros((b, hkv, group, q_chunk), jnp.float32)
        (acc, m, denom), _ = jax.lax.scan(kv_step, (acc0, m0, d0),
                                          jnp.arange(nk))
        out = acc / jnp.maximum(denom[..., None], 1e-30)
        return out.reshape(b, hq, q_chunk, vd)

    outs = jax.lax.map(q_step, jnp.arange(nq))            # (nq,B,Hq,qc,hd)
    out = outs.transpose(1, 2, 0, 3, 4).reshape(b, hq, sq, vd)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def sp_blockwise_attention(q, k, v, *, causal: bool, window=None,
                           q_chunk: int = 512, kv_chunk: int = 512):
    """Sequence-parallel attention (§Perf iter-1, beyond-paper).

    Shards the *query sequence* over the `model` axis inside a shard_map:
    each chip runs blockwise attention for its S/tp query slice against
    the full K/V (gathered ONCE per layer at the shard_map boundary).
    Without this, GSPMD re-gathers operands inside every (q-chunk,
    kv-chunk) loop iteration — the dominant collective in the train
    baseline. Head counts never need to divide tp (qwen's 40/8 heads).
    Falls back to the plain path when no mesh / not divisible.
    """
    from jax.sharding import PartitionSpec as P
    from repro.parallel.sharding import active_mesh, dp_axes, tp_axis

    mesh = active_mesh()
    tp = tp_axis(mesh)
    b, s, hq, hd = q.shape
    if mesh is None or tp is None:
        return blockwise_attention(q, k, v, causal=causal, window=window,
                                   q_chunk=q_chunk, kv_chunk=kv_chunk)
    tp_n = mesh.shape[tp]
    dp = dp_axes(mesh)
    dp_n = 1
    for a in dp:
        dp_n *= mesh.shape[a]
    if s % tp_n or (s // tp_n) < 64 or (dp and b % dp_n):
        return blockwise_attention(q, k, v, causal=causal, window=window,
                                   q_chunk=q_chunk, kv_chunk=kv_chunk)
    s_loc = s // tp_n
    dps = dp if dp else None

    def local(qs, ks, vs):
        off = jax.lax.axis_index(tp) * s_loc
        return blockwise_attention(qs, ks, vs, causal=causal, window=window,
                                   q_chunk=min(q_chunk, s_loc),
                                   kv_chunk=kv_chunk, q_offset=off)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(dps, tp, None, None), P(dps, None, None, None),
                  P(dps, None, None, None)),
        out_specs=P(dps, tp, None, None),
        check_vma=False,   # scan carries start unvarying (zeros init)
    )(q, k, v)


def decode_attention(q, k_cache, v_cache, *, length=None, window=None,
                     mask=None, scale=None):
    """Single-token attention against a (B, S, Hkv, hd) cache.

    q: (B, Hq, hd). ``length``: (B,) valid cache length (entries >= length
    masked). ``mask``: explicit (B, S) bool validity (ring buffers) —
    overrides length/window. Returns (B, Hq, vd)."""
    b, hq, hd = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    group = hq // hkv
    # bf16 x bf16 -> fp32-accumulated dots (MXU path); never materialize an
    # fp32 copy of the cache (perf iter-0, EXPERIMENTS.md §Perf)
    qg = q.reshape(b, hkv, group, hd).astype(k_cache.dtype)
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    if mask is None:
        pos = jnp.arange(s)[None, :]
        mask = jnp.ones((b, s), bool)
        if length is not None:
            mask &= pos < length[:, None]
        if window is not None and length is not None:
            mask &= pos >= (length[:, None] - window)
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, hq, -1).astype(q.dtype)


def chunk_attention(q, k_cache, v_cache, mask, *, scale=None):
    """Multi-token attention against a cache (chunked paged prefill).

    q: (B, C, Hq, hd) — the prompt chunk's queries; k/v_cache:
    (B, S, Hkv, hd) — the prefill scratch holding every position written
    so far (including this chunk's); mask: (C, S) or (B, C, S) bool
    validity (causal-with-offset, sliding window). Returns (B, C, Hq,
    hd) in q.dtype. Same bf16-dot/fp32-accumulate discipline as
    ``decode_attention``."""
    b, c, hq, hd = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    group = hq // hkv
    qg = q.reshape(b, c, hkv, group, hd).astype(k_cache.dtype)
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bckgd,bskd->bkcgs", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None, :, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkcgs,bskd->bckgd", p, v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, c, hq, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def _shard_hidden(h):
    """Constrain (B, ..., f) activations: batch x tp normally; under
    sequence parallelism (§Perf iter-2) batch x seq@tp x replicated —
    keeping the hidden dim whole avoids resharding between the
    sequence-sharded residual stream and each MLP."""
    from repro.parallel.sharding import seq_parallel
    if seq_parallel() and h.ndim >= 3:
        axes = ("batch", "sp") + (None,) * (h.ndim - 2)
    else:
        axes = ("batch",) + (None,) * (h.ndim - 2) + ("tp",)
    return shard(h, *axes)


def swiglu(x, wg, wu, wd):
    h = jax.nn.silu(x @ wg) * (x @ wu)
    h = _shard_hidden(h)
    return h @ wd


def gelu_mlp(x, wi, bi, wo, bo):
    h = jax.nn.gelu(x @ wi + bi, approximate=True)
    h = _shard_hidden(h)
    return h @ wo + bo
