"""RWKV-6 ("Finch") blocks: time-mix with data-dependent decay + channel-mix.

The WKV recurrence per head (K = V = head_size):
    state'[k, v] = w_t[k] * state[k, v] + kv_t[k] * v_t[v]
    out_t[v]     = sum_k r_t[k] * (state[k, v] + u[k] * kv_t[k] * v_t[v])
with the data-dependent per-channel decay ``w_t = exp(-exp(w0 + lora(x_t)))``
(the Finch signature feature). Training runs an exact `lax.scan` over the
sequence — the state is tiny ((B, H, K, V) = (B, d/64, 64, 64)) so the scan's
HLO is one compact loop; the TPU-production alternative (chunked log-space
parallel form as a Pallas kernel) is noted in DESIGN.md as future kernel
work. Decode reuses the identical single-step update.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import dense_init


def init_rwkv(key, cfg) -> dict:
    d, hs = cfg.d_model, cfg.rwkv_head_size
    h = cfg.rwkv_n_heads
    lo = cfg.rwkv_decay_lora
    ks = jax.random.split(key, 10)
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "tm": {  # time mix
            "mu_r": jnp.full((d,), 0.5, dt), "mu_k": jnp.full((d,), 0.5, dt),
            "mu_v": jnp.full((d,), 0.5, dt), "mu_g": jnp.full((d,), 0.5, dt),
            "mu_w": jnp.full((d,), 0.5, dt),
            "wr": {"kernel": dense_init(ks[0], d, d, dt)},
            "wk": {"kernel": dense_init(ks[1], d, d, dt)},
            "wv": {"kernel": dense_init(ks[2], d, d, dt)},
            "wg": {"kernel": dense_init(ks[3], d, d, dt)},
            "wo": {"kernel": dense_init(ks[4], d, d, dt)},
            "decay_w0": jnp.full((d,), -2.0, jnp.float32),
            "decay_a": dense_init(ks[5], d, lo, jnp.float32),
            "decay_b": dense_init(ks[6], lo, d, jnp.float32, scale=0.01),
            "bonus_u": jnp.zeros((h, hs), jnp.float32),
            "ln_scale": jnp.ones((d,), jnp.float32),  # group-norm on heads
        },
        "cm": {  # channel mix
            "mu_c": jnp.full((d,), 0.5, dt),
            "ck": {"kernel": dense_init(ks[7], d, cfg.d_ff, dt)},
            "cv": {"kernel": dense_init(ks[8], cfg.d_ff, d, dt)},
            "cr": {"kernel": dense_init(ks[9], d, d, dt)},
        },
    }
    return p


def _decay(tm, xw):
    """Data-dependent per-channel decay in (0, 1)."""
    lora = jnp.tanh(xw.astype(jnp.float32) @ tm["decay_a"]) @ tm["decay_b"]
    return jnp.exp(-jnp.exp(tm["decay_w0"] + lora))


def _wkv_step(state, rkvw, u):
    """state: (B,H,K,V); r,k,v: (B,H,K|V); w: (B,H,K)."""
    r, k, v, w = rkvw
    kv = k[..., :, None] * v[..., None, :]               # (B,H,K,V)
    out = jnp.einsum("bhk,bhkv->bhv", r, state + u[..., None] * kv)
    new_state = w[..., None] * state + kv
    return new_state, out


def _heads(x, h, hs):
    return x.reshape(*x.shape[:-1], h, hs)


def _group_norm(x, scale, h, hs, eps=1e-5):
    """Per-head layernorm of the wkv output. x: (..., d)."""
    xh = x.reshape(*x.shape[:-1], h, hs).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(*x.shape) * scale).astype(x.dtype)


def time_mix(tm, x, x_prev, state, cfg):
    """x: (B, S, d); x_prev: (B, d) last token of the previous segment;
    state: (B, H, K, V). Returns (out, last_x, new_state)."""
    b, s, d = x.shape
    h, hs = cfg.rwkv_n_heads, cfg.rwkv_head_size
    xx = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)

    def mixed(mu):
        return x + mu * (xx - x)

    r = _heads(mixed(tm["mu_r"]) @ tm["wr"]["kernel"], h, hs)
    k = _heads(mixed(tm["mu_k"]) @ tm["wk"]["kernel"], h, hs)
    v = _heads(mixed(tm["mu_v"]) @ tm["wv"]["kernel"], h, hs)
    g = jax.nn.silu(mixed(tm["mu_g"]) @ tm["wg"]["kernel"])
    w = _heads(_decay(tm, mixed(tm["mu_w"])), h, hs)     # (B,S,H,K)

    rs, ks_, vs, ws = (t.swapaxes(0, 1).astype(jnp.float32)
                       for t in (r, k, v, w))            # (S,B,H,·)
    u = tm["bonus_u"]

    def step(st, inp):
        return _wkv_step(st, inp, u)

    state, outs = jax.lax.scan(step, state.astype(jnp.float32),
                               (rs, ks_, vs, ws))
    out = outs.swapaxes(0, 1).reshape(b, s, d)           # (B,S,d)
    out = _group_norm(out, tm["ln_scale"], h, hs)
    out = (out * g.astype(out.dtype)) @ tm["wo"]["kernel"]
    return out, x[:, -1, :], state


def channel_mix(cm, x, x_prev):
    xx = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    xm = x + cm["mu_c"] * (xx - x)
    k = jnp.square(jax.nn.relu(xm @ cm["ck"]["kernel"]))
    return jax.nn.sigmoid(xm @ cm["cr"]["kernel"]) * (k @ cm["cv"]["kernel"]), \
        x[:, -1, :]


def time_mix_step(tm, x_t, x_prev, state, cfg):
    """Single-token decode. x_t: (B, d)."""
    h, hs = cfg.rwkv_n_heads, cfg.rwkv_head_size

    def mixed(mu):
        return x_t + mu * (x_prev - x_t)

    r = _heads(mixed(tm["mu_r"]) @ tm["wr"]["kernel"], h, hs)
    k = _heads(mixed(tm["mu_k"]) @ tm["wk"]["kernel"], h, hs)
    v = _heads(mixed(tm["mu_v"]) @ tm["wv"]["kernel"], h, hs)
    g = jax.nn.silu(mixed(tm["mu_g"]) @ tm["wg"]["kernel"])
    w = _heads(_decay(tm, mixed(tm["mu_w"])), h, hs)
    new_state, out = _wkv_step(
        state.astype(jnp.float32),
        (r.astype(jnp.float32), k.astype(jnp.float32),
         v.astype(jnp.float32), w), tm["bonus_u"])
    out = out.reshape(x_t.shape).astype(x_t.dtype)
    out = _group_norm(out, tm["ln_scale"], h, hs)
    out = (out * g.astype(out.dtype)) @ tm["wo"]["kernel"]
    return out, x_t, new_state


def channel_mix_step(cm, x_t, x_prev):
    xm = x_t + cm["mu_c"] * (x_prev - x_t)
    k = jnp.square(jax.nn.relu(xm @ cm["ck"]["kernel"]))
    return jax.nn.sigmoid(xm @ cm["cr"]["kernel"]) * (k @ cm["cv"]["kernel"]), x_t
