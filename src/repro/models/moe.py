"""Mixture-of-Experts FFN with expert parallelism over the `model` mesh axis.

Design (DESIGN.md §5): tokens enter the block replicated across the `model`
axis (the same layout dense TP uses between blocks). Each device routes all
its tokens, keeps only those destined for its local expert shard
(E_loc = E / tp), runs the expert FFNs on a capacity-bounded (E_loc, C, d)
buffer, scatters results back token-space, and the cross-device combine is a
single psum over `model` — the identical communication pattern as a dense TP
MLP's output all-reduce, so EP costs no extra collective class.

Without an active mesh (CPU unit tests) the same code runs with tp=1.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.compat import shard_map
from repro.parallel.sharding import active_mesh, dp_axes, tp_axis

from .layers import dense_init


class MoEParams(NamedTuple):
    router: jax.Array        # (d, E)
    wg: jax.Array            # (E, d, f) gate   ("experts" in path -> EP spec)
    wu: jax.Array            # (E, d, f) up
    wd: jax.Array            # (E, f, d) down


def init_moe(key, cfg) -> dict:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    scale_d = 1.0 / math.sqrt(d)
    scale_f = 1.0 / math.sqrt(f)
    p = {
        "router": {"kernel": dense_init(ks[0], d, e, jnp.float32)},
        "experts": {
            "wg": (jax.random.normal(ks[1], (e, d, f)) * scale_d).astype(dt),
            "wu": (jax.random.normal(ks[2], (e, d, f)) * scale_d).astype(dt),
            "wd": (jax.random.normal(ks[3], (e, f, d)) * scale_f).astype(dt),
        },
    }
    if cfg.n_shared_experts:
        kk = jax.random.split(ks[0], 3)
        fs = cfg.shared_d_ff or cfg.moe_d_ff * cfg.n_shared_experts
        p["shared"] = {
            "wg": dense_init(kk[0], d, fs, dt),
            "wu": dense_init(kk[1], d, fs, dt),
            "wd": dense_init(kk[2], fs, d, dt),
        }
    return p


def _local_moe(x, router_w, wg, wu, wd, *, cfg, tp_index, tp_size):
    """Per-device MoE body. x: (B_loc, S, d) (replicated over tp); expert
    weights are the local shard (E_loc, ...). Returns partial output that
    must be psum'd over tp."""
    b, s, d = x.shape
    e_loc = wg.shape[0]
    e = e_loc * tp_size
    k = cfg.moe_top_k
    t = b * s

    xf = x.reshape(t, d)
    logits = (xf.astype(jnp.float32) @ router_w.astype(jnp.float32))  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_e = jax.lax.top_k(probs, k)                          # (T, k)
    gate_w = gate_w / jnp.clip(gate_w.sum(-1, keepdims=True), 1e-9)

    # ---- aux load-balance loss (computed on full router; identical on all
    # tp shards so the psum-combine divides it back out) -------------------
    me = probs.mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[gate_e.reshape(-1)].add(
        jnp.ones((t * k,), jnp.float32)) / (t * k)
    aux = e * jnp.sum(me * ce)

    # ---- capacity-bounded dispatch to local experts ----------------------
    cap = int(math.ceil(t * k / e * cfg.capacity_factor))
    cap = max(8, -(-cap // 8) * 8)
    flat_e = gate_e.reshape(-1)                                       # (T*k,)
    flat_w = gate_w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), k)

    first = tp_index * e_loc
    local = (flat_e >= first) & (flat_e < first + e_loc)
    leid = jnp.where(local, flat_e - first, e_loc)                    # e_loc = drop
    # position of each (token, expert) pair within its expert's capacity
    onehot = jax.nn.one_hot(leid, e_loc, dtype=jnp.int32)             # (T*k, E_loc)
    pos = jnp.cumsum(onehot, axis=0) - 1
    pos = jnp.sum(pos * onehot, axis=1)                               # (T*k,)
    keep = local & (pos < cap)
    slot = jnp.where(keep, leid * cap + pos, e_loc * cap)             # overflow slot

    buf = jnp.zeros((e_loc * cap + 1, d), x.dtype)
    buf = buf.at[slot].set(xf[flat_tok])
    buf = buf[:-1].reshape(e_loc, cap, d)

    h = jnp.einsum("ecd,edf->ecf", buf, wg)
    u = jnp.einsum("ecd,edf->ecf", buf, wu)
    h = jax.nn.silu(h) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, wd).reshape(e_loc * cap, d)
    out_buf = jnp.concatenate([out_buf, jnp.zeros((1, d), out_buf.dtype)], 0)

    contrib = out_buf[slot] * flat_w[:, None].astype(out_buf.dtype)
    out = jnp.zeros((t, d), x.dtype).at[flat_tok].add(
        jnp.where(keep[:, None], contrib, 0))
    return out.reshape(b, s, d), aux


def moe_ffn(params: dict, x: jax.Array, cfg):
    """(B, S, d) -> (B, S, d), aux-loss scalar. Runs expert-parallel over the
    `model` axis when a mesh is active."""
    mesh = active_mesh()
    tp = tp_axis(mesh)
    router_w = params["router"]["kernel"]
    ex = params["experts"]

    if tp is None:
        out, aux = _local_moe(x, router_w, ex["wg"], ex["wu"], ex["wd"],
                              cfg=cfg, tp_index=0, tp_size=1)
    else:
        dp = dp_axes(mesh)
        tp_size = mesh.shape[tp]
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        # decode / tiny batches can't shard over dp -> replicate tokens,
        # keep experts sharded over tp (each chip runs all tokens against
        # its local expert shard; psum combines)
        batch_sharded = dp and x.shape[0] % max(dp_size, 1) == 0
        from repro.parallel.sharding import layout_policy
        decode_tp = layout_policy() == "decode_tp"
        if decode_tp:
            batch_sharded = False       # tokens replicated; weights f-sharded
        x_spec = P(dp, None, None) if batch_sharded else P(None, None, None)
        # decode_tp (§Perf iter-6): expert hidden column/row-parallel over
        # dp — wg/wu f-sliced, wd f-sliced on its contraction dim; the
        # down-projection partials psum over dp (tiny: one (T, d) vector)
        up_spec = P(tp, None, dp) if decode_tp else P(tp, None, None)
        dn_spec = P(tp, dp, None) if decode_tp else P(tp, None, None)

        def body(xl, rw, wg, wu, wd):
            idx = jax.lax.axis_index(tp)
            out, aux = _local_moe(xl, rw, wg, wu, wd, cfg=cfg,
                                  tp_index=idx, tp_size=tp_size)
            aux = jax.lax.psum(aux, tp) / jnp.float32(tp_size)
            if batch_sharded:
                aux = jax.lax.pmean(aux, dp)   # global load-balance loss
            out = jax.lax.psum(out, tp)
            if decode_tp and dp:
                out = jax.lax.psum(out, dp)    # combine f-partials
            return out, aux

        out, aux = shard_map(
            body,
            mesh=mesh,
            in_specs=(x_spec, P(None, None), up_spec, up_spec, dn_spec),
            out_specs=(x_spec, P()),
            check_vma=False,
        )(x, router_w, ex["wg"], ex["wu"], ex["wd"])

    if "shared" in params:
        sh = params["shared"]
        from .layers import swiglu

        out = out + swiglu(x, sh["wg"], sh["wu"], sh["wd"])
    return out, aux * cfg.router_aux_weight
