"""Unified schedule-driven model builder for every assigned architecture.

One code path builds dense GQA transformers (qwen/phi3/command-r/llama),
sliding-window interleaves (gemma3), MoE (deepseek-moe), MLA+MoE
(deepseek-v3 incl. MTP head), hybrid Mamba+attention+MoE (jamba), RWKV-6,
encoder-decoder audio (whisper — conv frontend stubbed to precomputed frame
embeddings), and cross-attention VLM (llama-3.2-vision — vision tower
stubbed to precomputed patch embeddings).

The layer layout comes from ``cfg.schedule``: segments of repeating
super-block patterns, each `lax.scan`ned over its repeats with stacked
params — HLO stays O(pattern), not O(layers). The same structure is reused
for the decode cache, so decode scans too.

Three entry points:
  forward(params, batch, cfg)               -> (logits, aux)     train/eval
  prefill(params, batch, cfg)               -> (logits, cache)   inference
  decode_step(params, cache, token, pos, cfg)-> (logits, cache)  1 new token
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard

from .layers import (
    apply_rope,
    blockwise_attention,
    chunk_attention,
    decode_attention,
    dense_init,
    embed_init,
    gelu_mlp,
    layer_norm,
    rms_norm,
    rope_at,
    rope_table,
    rope_tables_at,
    sp_blockwise_attention,
    swiglu,
)
from .mamba import init_mamba, init_mamba_cache, mamba_mix, mamba_step
from .moe import init_moe, moe_ffn
from .rwkv import (
    channel_mix,
    channel_mix_step,
    init_rwkv,
    time_mix,
    time_mix_step,
)

ATTN_KINDS = ("attn", "local", "attn_moe", "enc", "dec", "cross")
MLA_KINDS = ("mla_dense", "mla_moe")
MOE_KINDS = ("attn_moe", "mla_moe", "mamba_moe")


# ===========================================================================
# Parameter init
# ===========================================================================
def _init_gqa(key, cfg, *, bidirectional=False, bias=False):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "wq": {"kernel": dense_init(ks[0], d, hq * hd, dt)},
        "wk": {"kernel": dense_init(ks[1], d, hkv * hd, dt)},
        "wv": {"kernel": dense_init(ks[2], d, hkv * hd, dt)},
        "wo": {"kernel": dense_init(ks[3], hq * hd, d, dt)},
    }
    if bias or cfg.qkv_bias:
        p["wq"]["bias"] = jnp.zeros((hq * hd,), dt)
        p["wk"]["bias"] = jnp.zeros((hkv * hd,), dt)
        p["wv"]["bias"] = jnp.zeros((hkv * hd,), dt)
        if bias:
            p["wo"]["bias"] = jnp.zeros((d,), dt)
    if cfg.use_qk_norm:
        p["q_norm_scale"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm_scale"] = jnp.zeros((hd,), jnp.float32)
    return p


def _init_mla(key, cfg):
    d = cfg.d_model
    h = cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    ks = jax.random.split(key, 5)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "wq_a": {"kernel": dense_init(ks[0], d, cfg.q_lora_rank, dt)},
        "q_norm_scale": jnp.zeros((cfg.q_lora_rank,), jnp.float32),
        "wq_b": {"kernel": dense_init(ks[1], cfg.q_lora_rank, h * qk, dt)},
        "wkv_a": {"kernel": dense_init(
            ks[2], d, cfg.kv_lora_rank + cfg.qk_rope_dim, dt)},
        "kv_norm_scale": jnp.zeros((cfg.kv_lora_rank,), jnp.float32),
        "wkv_b": {"kernel": dense_init(
            ks[3], cfg.kv_lora_rank,
            h * (cfg.qk_nope_dim + cfg.v_head_dim), dt)},
        "wo": {"kernel": dense_init(ks[4], h * cfg.v_head_dim, d, dt)},
    }


def _init_swiglu(key, cfg, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "wg": {"kernel": dense_init(ks[0], d, f, dt)},
        "wu": {"kernel": dense_init(ks[1], d, f, dt)},
        "wd": {"kernel": dense_init(ks[2], f, d, dt)},
    }


def _init_gelu_mlp(key, cfg):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 2)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "wi": {"kernel": dense_init(ks[0], d, f, dt),
               "bias": jnp.zeros((f,), dt)},
        "wo": {"kernel": dense_init(ks[1], f, d, dt),
               "bias": jnp.zeros((d,), dt)},
    }


def _ln(cfg, with_bias=False):
    d = cfg.d_model
    if with_bias:
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.zeros((d,), jnp.float32)}  # rms (1 + scale)


def init_block(key, kind: str, cfg) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if kind in ("attn", "local"):
        return {"ln1": _ln(cfg), "attn": _init_gqa(k1, cfg),
                "ln2": _ln(cfg), "mlp": _init_swiglu(k2, cfg)}
    if kind == "attn_moe":
        return {"ln1": _ln(cfg), "attn": _init_gqa(k1, cfg),
                "ln2": _ln(cfg), "moe": init_moe(k2, cfg)}
    if kind == "mla_dense":
        return {"ln1": _ln(cfg), "attn": _init_mla(k1, cfg),
                "ln2": _ln(cfg), "mlp": _init_swiglu(k2, cfg)}
    if kind == "mla_moe":
        return {"ln1": _ln(cfg), "attn": _init_mla(k1, cfg),
                "ln2": _ln(cfg), "moe": init_moe(k2, cfg)}
    if kind == "mamba_dense":
        return {"ln1": _ln(cfg), "mamba": init_mamba(k1, cfg),
                "ln2": _ln(cfg), "mlp": _init_swiglu(k2, cfg)}
    if kind == "mamba_moe":
        return {"ln1": _ln(cfg), "mamba": init_mamba(k1, cfg),
                "ln2": _ln(cfg), "moe": init_moe(k2, cfg)}
    if kind == "rwkv":
        p = init_rwkv(k1, cfg)
        p["ln1"] = _ln(cfg, with_bias=True)
        p["ln2"] = _ln(cfg, with_bias=True)
        return p
    if kind == "cross":
        # llama-3.2-vision style gated cross-attention block
        return {"ln1": _ln(cfg), "xattn": _init_gqa(k1, cfg),
                "gate_attn": jnp.zeros((), jnp.float32),
                "ln2": _ln(cfg), "mlp": _init_swiglu(k2, cfg),
                "gate_mlp": jnp.zeros((), jnp.float32)}
    if kind == "enc":
        return {"ln1": _ln(cfg, True),
                "attn": _init_gqa(k1, cfg, bidirectional=True, bias=True),
                "ln2": _ln(cfg, True), "mlp": _init_gelu_mlp(k2, cfg)}
    if kind == "dec":
        return {"ln1": _ln(cfg, True), "attn": _init_gqa(k1, cfg, bias=True),
                "ln2": _ln(cfg, True), "xattn": _init_gqa(k3, cfg, bias=True),
                "ln3": _ln(cfg, True), "mlp": _init_gelu_mlp(k4, cfg)}
    raise ValueError(f"unknown block kind {kind!r}")


def init_params(cfg, key) -> dict:
    """Full parameter tree. Segment i, pattern position j lives at
    params['segments'][i][f'p{j}'] with leading stacked axis = repeats."""
    keys = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.param_dtype)
    params: dict[str, Any] = {
        "embed": {"kernel": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dt)},
        "final_norm": _ln(cfg, with_bias=(cfg.family == "encdec")),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = {"kernel": embed_init(
            keys[1], cfg.vocab_size, cfg.d_model, dt)}

    segs = []
    seg_keys = jax.random.split(keys[2], len(cfg.schedule))
    for (pattern, repeats), sk in zip(cfg.schedule, seg_keys):
        pos_keys = jax.random.split(sk, len(pattern))
        seg = {}
        for j, (kind, pk) in enumerate(zip(pattern, pos_keys)):
            layer_keys = jax.random.split(pk, repeats)
            seg[f"p{j}"] = jax.vmap(lambda k: init_block(k, kind, cfg))(
                layer_keys)
        segs.append(seg)
    params["segments"] = segs

    if cfg.encoder_layers:
        enc_keys = jax.random.split(keys[3], cfg.encoder_layers)
        params["encoder"] = {
            "blocks": jax.vmap(lambda k: init_block(k, "enc", cfg))(enc_keys),
            "ln_post": _ln(cfg, True),
        }
    if cfg.mtp:
        params["mtp"] = {
            "norm": _ln(cfg),
            "proj": {"kernel": dense_init(keys[4], 2 * cfg.d_model,
                                          cfg.d_model, dt)},
        }
    return params


def param_count(params) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))


_PRECISION_CRITICAL = ("norm", "ln", "scale", "bias", "a_log", "d_skip",
                       "decay", "bonus", "gate", "mu_")


def cast_params(params, cfg):
    """Mixed precision: weights cast to compute dtype at use (bf16 MXU
    path); small precision-critical leaves (norms, ssm decay constants,
    gates) stay in their stored dtype."""
    cdt = jnp.dtype(cfg.compute_dtype)

    def cast(kp, p):
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp).lower()
        if any(h in path for h in _PRECISION_CRITICAL):
            return p
        if jnp.issubdtype(p.dtype, jnp.floating):
            return p.astype(cdt)
        return p

    return jax.tree_util.tree_map_with_path(cast, params)


# ===========================================================================
# Attention application (train / prefill path)
# ===========================================================================
def _qk_norm(x, scale):
    return rms_norm(x, scale)


def _gqa_apply(p, x, cfg, *, causal, window=None, kv_src=None, rope=True,
               q_offset=0, return_kv=False):
    """x: (B,S,d); kv_src (B,Skv,d) for cross-attention (no rope on kv)."""
    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    src = kv_src if kv_src is not None else x

    def proj(w, t, h):
        y = t @ w["kernel"]
        if "bias" in w:
            y = y + w["bias"]
        return y.reshape(*t.shape[:-1], h, hd)

    q = proj(p["wq"], x, hq)
    k = proj(p["wk"], src, hkv)
    v = proj(p["wv"], src, hkv)
    if cfg.use_qk_norm:
        q = _qk_norm(q, p["q_norm_scale"])
        k = _qk_norm(k, p["k_norm_scale"])
    if rope and kv_src is None:
        cos, sin = rope_table(s, hd, cfg.rope_theta, offset=q_offset)
        q = apply_rope(q, cos, sin)
        cos_k, sin_k = rope_table(src.shape[1], hd, cfg.rope_theta)
        k = apply_rope(k, cos_k, sin_k)
    if cfg.attn_sp:
        q = shard(q, "batch", "sp", None, None)
        k = shard(k, "batch", None, None, None)
        v = shard(v, "batch", None, None, None)
        out = sp_blockwise_attention(q, k, v, causal=causal, window=window,
                                     q_chunk=cfg.q_chunk,
                                     kv_chunk=cfg.kv_chunk)
        out = shard(out, "batch", "sp", None, None)
    else:
        q = shard(q, "batch", None, "tp", None)
        out = blockwise_attention(q, k, v, causal=causal, window=window,
                                  q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                                  q_offset=q_offset)
    out = out.reshape(b, s, hq * hd)
    y = out @ p["wo"]["kernel"]
    if "bias" in p["wo"]:
        y = y + p["wo"]["bias"]
    if return_kv:
        return y, (k, v)
    return y, None


def _mla_apply(p, x, cfg, *, return_kv=False):
    """DeepSeek MLA, non-absorbed (train/prefill) form."""
    b, s, d = x.shape
    h = cfg.n_heads
    nope, rope_d, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim

    cq = rms_norm(x @ p["wq_a"]["kernel"], p["q_norm_scale"])
    q = (cq @ p["wq_b"]["kernel"]).reshape(b, s, h, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]

    ckv = x @ p["wkv_a"]["kernel"]
    c_kv, k_rope = ckv[..., :cfg.kv_lora_rank], ckv[..., cfg.kv_lora_rank:]
    c_kv = rms_norm(c_kv, p["kv_norm_scale"])
    kv = (c_kv @ p["wkv_b"]["kernel"]).reshape(b, s, h, nope + vd)
    k_nope, v = kv[..., :nope], kv[..., nope:]

    cos, sin = rope_table(s, rope_d, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)     # (B,S,1,rope)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, h, rope_d))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    if cfg.attn_sp:
        out = sp_blockwise_attention(q, k, v, causal=True,
                                     q_chunk=cfg.q_chunk,
                                     kv_chunk=cfg.kv_chunk)
    else:
        out = blockwise_attention(q, k, v, causal=True,
                                  q_chunk=cfg.q_chunk,
                                  kv_chunk=cfg.kv_chunk)
    y = out.reshape(b, s, h * vd) @ p["wo"]["kernel"]
    if return_kv:
        # decode cache stores the *latent* (c_kv) + roped shared k_rope
        return y, (c_kv, k_rope[:, :, 0, :])
    return y, None


# ===========================================================================
# Block application (train / prefill)
# ===========================================================================
def block_apply(kind: str, p, x, cfg, ctx, *, return_kv=False):
    """Returns (x_out, aux_scalar, cache_entry_or_None)."""
    aux = jnp.zeros((), jnp.float32)
    kv = None
    if kind in ("attn", "local", "attn_moe"):
        h = rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
        window = cfg.sliding_window if kind == "local" else None
        a, kv = _gqa_apply(p["attn"], h, cfg, causal=True, window=window,
                           return_kv=return_kv)
        x = x + a
        h = rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
        if kind == "attn_moe":
            m, aux = moe_ffn(p["moe"], h, cfg)
        else:
            m = swiglu(h, p["mlp"]["wg"]["kernel"], p["mlp"]["wu"]["kernel"],
                       p["mlp"]["wd"]["kernel"])
        x = x + m
    elif kind in MLA_KINDS:
        h = rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
        a, kv = _mla_apply(p["attn"], h, cfg, return_kv=return_kv)
        x = x + a
        h = rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
        if kind == "mla_moe":
            m, aux = moe_ffn(p["moe"], h, cfg)
        else:
            m = swiglu(h, p["mlp"]["wg"]["kernel"], p["mlp"]["wu"]["kernel"],
                       p["mlp"]["wd"]["kernel"])
        x = x + m
    elif kind in ("mamba_dense", "mamba_moe"):
        h = rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
        if return_kv:
            mx, kv = mamba_mix(p["mamba"], h, cfg, return_state=True)
        else:
            mx = mamba_mix(p["mamba"], h, cfg)
        x = x + mx
        h = rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
        if kind == "mamba_moe":
            m, aux = moe_ffn(p["moe"], h, cfg)
        else:
            m = swiglu(h, p["mlp"]["wg"]["kernel"], p["mlp"]["wu"]["kernel"],
                       p["mlp"]["wd"]["kernel"])
        x = x + m
    elif kind == "rwkv":
        b, s, d = x.shape
        hh, hs = cfg.rwkv_n_heads, cfg.rwkv_head_size
        h = layer_norm(x, p["ln1"]["scale"], p["ln1"]["bias"], cfg.norm_eps)
        x_prev0 = jnp.zeros((b, d), h.dtype)
        st0 = jnp.zeros((b, hh, hs, hs), jnp.float32)
        tm_out, last_x, st = time_mix(p["tm"], h, x_prev0, st0, cfg)
        x = x + tm_out
        h2 = layer_norm(x, p["ln2"]["scale"], p["ln2"]["bias"], cfg.norm_eps)
        cm_out, last_cm = channel_mix(p["cm"], h2, jnp.zeros((b, d), h2.dtype))
        x = x + cm_out
        if return_kv:
            kv = {"x_prev_tm": last_x, "x_prev_cm": last_cm, "wkv": st}
    elif kind == "cross":
        h = rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
        a, kv = _gqa_apply(p["xattn"], h, cfg, causal=False,
                           kv_src=ctx["image_embeds"], rope=False,
                           return_kv=return_kv)
        x = x + jnp.tanh(p["gate_attn"]) * a
        h = rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
        m = swiglu(h, p["mlp"]["wg"]["kernel"], p["mlp"]["wu"]["kernel"],
                   p["mlp"]["wd"]["kernel"])
        x = x + jnp.tanh(p["gate_mlp"]) * m
    elif kind == "enc":
        h = layer_norm(x, p["ln1"]["scale"], p["ln1"]["bias"], cfg.norm_eps)
        a, _ = _gqa_apply(p["attn"], h, cfg, causal=False, rope=False)
        x = x + a
        h = layer_norm(x, p["ln2"]["scale"], p["ln2"]["bias"], cfg.norm_eps)
        x = x + gelu_mlp(h, p["mlp"]["wi"]["kernel"], p["mlp"]["wi"]["bias"],
                         p["mlp"]["wo"]["kernel"], p["mlp"]["wo"]["bias"])
    elif kind == "dec":
        h = layer_norm(x, p["ln1"]["scale"], p["ln1"]["bias"], cfg.norm_eps)
        a, kv_self = _gqa_apply(p["attn"], h, cfg, causal=True,
                                return_kv=return_kv)
        x = x + a
        h = layer_norm(x, p["ln2"]["scale"], p["ln2"]["bias"], cfg.norm_eps)
        a, kv_cross = _gqa_apply(p["xattn"], h, cfg, causal=False,
                                 kv_src=ctx["enc_out"], rope=False,
                                 return_kv=return_kv)
        x = x + a
        h = layer_norm(x, p["ln3"]["scale"], p["ln3"]["bias"], cfg.norm_eps)
        x = x + gelu_mlp(h, p["mlp"]["wi"]["kernel"], p["mlp"]["wi"]["bias"],
                         p["mlp"]["wo"]["kernel"], p["mlp"]["wo"]["bias"])
        kv = (kv_self, kv_cross) if return_kv else None
    else:
        raise ValueError(kind)
    return x, aux, kv


# ===========================================================================
# Encoder (whisper) — stub frontend: input is (B, enc_seq, d) frame embeds
# ===========================================================================
def _sinusoid(seq, d, dtype):
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / (half - 1))
    ang = jnp.arange(seq)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


def encode(params, frames, cfg):
    """frames: (B, enc_seq, d_model) precomputed (conv frontend stub)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = frames.astype(cdt)
    x = x + _sinusoid(x.shape[1], cfg.d_model, x.dtype)[None]

    def body(x, p):
        x, _, _ = block_apply("enc", p, x, cfg, {})
        return x.astype(cdt), None     # pin carry dtype

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"])
    ln = params["encoder"]["ln_post"]
    return layer_norm(x, ln["scale"], ln["bias"], cfg.norm_eps)


# ===========================================================================
# Forward (train / eval / prefill)
# ===========================================================================
def forward(params, batch, cfg, *, return_cache: bool = False):
    """batch: {'tokens': (B,S) int32, 'frames': (B,enc_seq,d)?,
    'image_embeds': (B,n_img,d)?}. Returns (logits, aux) or, with
    return_cache, (logits, aux, cache)."""
    tokens = batch["tokens"]
    cdt = jnp.dtype(cfg.compute_dtype)
    params = cast_params(params, cfg)
    x = params["embed"]["kernel"][tokens]
    x = shard(x, "batch", "sp", None)

    ctx = {}
    if cfg.encoder_layers:
        ctx["enc_out"] = encode(params, batch["frames"], cfg)
    if cfg.n_image_tokens:
        ctx["image_embeds"] = batch["image_embeds"].astype(cdt)

    aux_total = jnp.zeros((), jnp.float32)
    caches = []
    for (pattern, repeats), seg in zip(cfg.schedule, params["segments"]):

        def body(carry, layer_p):
            x, aux = carry
            entries = {}
            for j, kind in enumerate(pattern):
                x, a, kv = block_apply(kind, layer_p[f"p{j}"], x, cfg, ctx,
                                       return_kv=return_cache)
                x = x.astype(cdt)      # pin residual-stream dtype (carry)
                aux = aux + a
                if return_cache:
                    entries[f"p{j}"] = kv
            return (x, aux), (entries if return_cache else None)

        if cfg.remat:
            body = jax.checkpoint(body)
        (x, aux_total), seg_cache = jax.lax.scan(body, (x, aux_total), seg)
        caches.append(seg_cache)

    if cfg.family == "encdec":
        fn = params["final_norm"]
        x = layer_norm(x, fn["scale"], fn["bias"], cfg.norm_eps)
    else:
        x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)

    unemb = (params["embed"] if cfg.tie_embeddings else params["unembed"])
    logits = x @ unemb["kernel"].astype(cdt).T
    from repro.parallel.sharding import seq_parallel as _seq_par
    if _seq_par():
        logits = shard(logits, "batch", "sp", None)
    else:
        logits = shard(logits, "batch", None, "tp")

    aux = {"moe_aux": aux_total, "mtp_logits": None}
    if cfg.mtp and "mtp" in params:
        # DeepSeek-style multi-token prediction: predict t+2 from
        # [h_t ; embed(token_{t+1})]. Full-length with a roll (position S-1
        # is masked in the loss) so the gather keeps the (B, S) sharding —
        # a [:, 1:] slice makes S odd and forces SPMD to replicate the
        # embedding table (XLA "involuntary full rematerialization").
        emb_next = params["embed"]["kernel"][jnp.roll(tokens, -1, axis=1)]
        h_mtp = jnp.concatenate([x, emb_next], axis=-1)
        h_mtp = h_mtp @ params["mtp"]["proj"]["kernel"].astype(cdt)
        h_mtp = rms_norm(h_mtp, params["mtp"]["norm"]["scale"], cfg.norm_eps)
        aux["mtp_logits"] = h_mtp @ unemb["kernel"].astype(cdt).T

    if return_cache:
        return logits, aux, caches, ctx
    return logits, aux


# ===========================================================================
# Decode cache
# ===========================================================================
def _cache_layout(kind: str, cfg, batch: int, max_len: int, cdt):
    """Zeros cache entry for one layer of ``kind`` (unstacked)."""
    hkv, hd = cfg.n_kv_heads, cfg.hd
    if kind in ("attn", "attn_moe", "dec"):
        kv = {"k": jnp.zeros((batch, max_len, hkv, hd), cdt),
              "v": jnp.zeros((batch, max_len, hkv, hd), cdt)}
        if kind == "dec":
            es = cfg.encoder_seq
            kv["xk"] = jnp.zeros((batch, es, hkv, hd), cdt)
            kv["xv"] = jnp.zeros((batch, es, hkv, hd), cdt)
        return kv
    if kind == "local":
        w = min(cfg.sliding_window, max_len)
        return {"k": jnp.zeros((batch, w, hkv, hd), cdt),
                "v": jnp.zeros((batch, w, hkv, hd), cdt)}
    if kind in MLA_KINDS:
        return {"ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), cdt),
                "krope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), cdt)}
    if kind in ("mamba_dense", "mamba_moe"):
        return init_mamba_cache(cfg, batch, cdt)
    if kind == "rwkv":
        d = cfg.d_model
        hh, hs = cfg.rwkv_n_heads, cfg.rwkv_head_size
        return {"x_prev_tm": jnp.zeros((batch, d), cdt),
                "x_prev_cm": jnp.zeros((batch, d), cdt),
                "wkv": jnp.zeros((batch, hh, hs, hs), jnp.float32)}
    if kind == "cross":
        n = cfg.n_image_tokens
        return {"xk": jnp.zeros((batch, n, hkv, hd), cdt),
                "xv": jnp.zeros((batch, n, hkv, hd), cdt)}
    raise ValueError(kind)


def init_cache(cfg, batch: int, max_len: int) -> list:
    """Zeroed decode cache matching the segment/scan structure."""
    cdt = jnp.dtype(cfg.compute_dtype)
    caches = []
    for pattern, repeats in cfg.schedule:
        seg = {}
        for j, kind in enumerate(pattern):
            one = _cache_layout(kind, cfg, batch, max_len, cdt)
            seg[f"p{j}"] = jax.tree.map(
                lambda t: jnp.zeros((repeats, *t.shape), t.dtype), one)
        caches.append(seg)
    return caches


# ===========================================================================
# Decode step (single new token against the cache)
# ===========================================================================
def _rope_decode(x, cos, sin):
    """x: (B, H, hd); tables (B, 1, half) — broadcast over the head axis."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1).astype(x.dtype)


def _gqa_decode(p, x_t, cache, pos, cfg, *, window=None):
    """x_t: (B, d); cache {'k','v'}: (B, S|w, Hkv, hd); pos: (B,) int32
    per-sequence positions. Returns (y, cache)."""
    b, d = x_t.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    def proj(w, t, h):
        y = t @ w["kernel"]
        if "bias" in w:
            y = y + w["bias"]
        return y.reshape(b, h, hd)

    q = proj(p["wq"], x_t, hq)
    k = proj(p["wk"], x_t, hkv)
    v = proj(p["wv"], x_t, hkv)
    if cfg.use_qk_norm:
        q = _qk_norm(q, p["q_norm_scale"])
        k = _qk_norm(k, p["k_norm_scale"])
    cos, sin = rope_at(pos, hd, cfg.rope_theta)    # (B, 1, half)
    q = _rope_decode(q, cos, sin)                  # broadcast over heads
    k = _rope_decode(k, cos, sin)

    s = cache["k"].shape[1]
    k = k.astype(cache["k"].dtype)
    v = v.astype(cache["v"].dtype)
    rows = jnp.arange(b)
    if window is not None:
        slot = pos % s                             # per-row ring slot
        new_k = cache["k"].at[rows, slot].set(k)
        new_v = cache["v"].at[rows, slot].set(v)
        idx = jnp.arange(s)[None, :]
        posc = pos[:, None]
        entry_pos = posc - ((posc - idx) % s)
        mask = (entry_pos >= 0) & (entry_pos >= posc - window + 1)
        out = decode_attention(q, new_k, new_v, mask=mask)
    else:
        new_k = cache["k"].at[rows, pos].set(k)
        new_v = cache["v"].at[rows, pos].set(v)
        out = decode_attention(q, new_k, new_v, length=pos + 1)
    y = out.reshape(b, hq * hd) @ p["wo"]["kernel"]
    if "bias" in p["wo"]:
        y = y + p["wo"]["bias"]
    cache = dict(cache)
    cache["k"], cache["v"] = new_k, new_v
    return y, cache


def _cross_decode(p, x_t, xk, xv, cfg):
    b, d = x_t.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    def proj(w, t, h):
        y = t @ w["kernel"]
        if "bias" in w:
            y = y + w["bias"]
        return y.reshape(b, h, hd)

    q = proj(p["wq"], x_t, hq)
    out = decode_attention(q, xk, xv)
    y = out.reshape(b, hq * hd) @ p["wo"]["kernel"]
    if "bias" in p["wo"]:
        y = y + p["wo"]["bias"]
    return y


def _mla_decode(p, x_t, cache, pos, cfg):
    """Absorbed-form MLA decode: attention runs in the latent space, the
    per-head up-projections are folded into q and the output (DeepSeek-V3
    inference trick) — the cache is (B, S, kv_rank + rope)."""
    b, d = x_t.shape
    h = cfg.n_heads
    nope, rope_d, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank

    cq = rms_norm(x_t @ p["wq_a"]["kernel"], p["q_norm_scale"])
    q = (cq @ p["wq_b"]["kernel"]).reshape(b, h, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]

    ckv = x_t @ p["wkv_a"]["kernel"]
    c_kv, k_rope = ckv[..., :kvr], ckv[..., kvr:]
    c_kv = rms_norm(c_kv, p["kv_norm_scale"])

    cos, sin = rope_at(pos, rope_d, cfg.rope_theta)
    q_rope = _rope_decode(q_rope, cos, sin)
    k_rope = _rope_decode(k_rope[:, None, :], cos, sin)[:, 0]

    wkv_b = p["wkv_b"]["kernel"].reshape(kvr, h, nope + vd)
    w_uk, w_uv = wkv_b[..., :nope], wkv_b[..., nope:]

    # absorb W_uk into q: q_lat (B, H, kvr)
    q_lat = jnp.einsum("bhn,khn->bhk", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))

    rows = jnp.arange(b)
    new_ckv = cache["ckv"].at[rows, pos].set(
        c_kv.astype(cache["ckv"].dtype))
    new_kr = cache["krope"].at[rows, pos].set(
        k_rope.astype(cache["krope"].dtype))

    s = new_ckv.shape[1]
    cdt = new_ckv.dtype
    # bf16 dots with fp32 accumulation — no fp32 copy of the latent cache
    scores = (jnp.einsum("bhk,bsk->bhs", q_lat.astype(cdt), new_ckv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bhr,bsr->bhs", q_rope.astype(cdt), new_kr,
                           preferred_element_type=jnp.float32))
    scores = scores / math.sqrt(nope + rope_d)
    mask = jnp.arange(s)[None] <= pos[:, None]
    scores = jnp.where(mask[:, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(cdt)
    ctx_lat = jnp.einsum("bhs,bsk->bhk", probs, new_ckv,
                         preferred_element_type=jnp.float32)
    v = jnp.einsum("bhk,khv->bhv", ctx_lat, w_uv.astype(jnp.float32))
    y = v.reshape(b, h * vd).astype(x_t.dtype) @ p["wo"]["kernel"]
    return y, {"ckv": new_ckv, "krope": new_kr}


def block_decode(kind: str, p, x_t, cache, pos, cfg):
    """x_t: (B, d); pos: (B,) int32 per-sequence positions. Returns
    (x_t, new_cache_entry)."""
    if kind in ("attn", "local", "attn_moe"):
        h = rms_norm(x_t, p["ln1"]["scale"], cfg.norm_eps)
        window = cfg.sliding_window if kind == "local" else None
        a, cache = _gqa_decode(p["attn"], h, cache, pos, cfg, window=window)
        x_t = x_t + a
        h = rms_norm(x_t, p["ln2"]["scale"], cfg.norm_eps)
        if kind == "attn_moe":
            m, _ = moe_ffn(p["moe"], h[:, None, :], cfg)
            m = m[:, 0]
        else:
            m = swiglu(h, p["mlp"]["wg"]["kernel"], p["mlp"]["wu"]["kernel"],
                       p["mlp"]["wd"]["kernel"])
        return x_t + m, cache
    if kind in MLA_KINDS:
        h = rms_norm(x_t, p["ln1"]["scale"], cfg.norm_eps)
        a, cache = _mla_decode(p["attn"], h, cache, pos, cfg)
        x_t = x_t + a
        h = rms_norm(x_t, p["ln2"]["scale"], cfg.norm_eps)
        if kind == "mla_moe":
            m, _ = moe_ffn(p["moe"], h[:, None, :], cfg)
            m = m[:, 0]
        else:
            m = swiglu(h, p["mlp"]["wg"]["kernel"], p["mlp"]["wu"]["kernel"],
                       p["mlp"]["wd"]["kernel"])
        return x_t + m, cache
    if kind in ("mamba_dense", "mamba_moe"):
        h = rms_norm(x_t, p["ln1"]["scale"], cfg.norm_eps)
        a, new_mc = mamba_step(p["mamba"], h, cache, cfg)
        x_t = x_t + a
        h = rms_norm(x_t, p["ln2"]["scale"], cfg.norm_eps)
        if kind == "mamba_moe":
            m, _ = moe_ffn(p["moe"], h[:, None, :], cfg)
            m = m[:, 0]
        else:
            m = swiglu(h, p["mlp"]["wg"]["kernel"], p["mlp"]["wu"]["kernel"],
                       p["mlp"]["wd"]["kernel"])
        return x_t + m, new_mc
    if kind == "rwkv":
        h = layer_norm(x_t, p["ln1"]["scale"], p["ln1"]["bias"], cfg.norm_eps)
        tm_out, new_xp, new_st = time_mix_step(
            p["tm"], h, cache["x_prev_tm"].astype(h.dtype), cache["wkv"], cfg)
        x_t = x_t + tm_out
        h2 = layer_norm(x_t, p["ln2"]["scale"], p["ln2"]["bias"], cfg.norm_eps)
        cm_out, new_xp_cm = channel_mix_step(
            p["cm"], h2, cache["x_prev_cm"].astype(h2.dtype))
        x_t = x_t + cm_out
        return x_t, {"x_prev_tm": new_xp.astype(cache["x_prev_tm"].dtype),
                     "x_prev_cm": new_xp_cm.astype(cache["x_prev_cm"].dtype),
                     "wkv": new_st}
    if kind == "cross":
        h = rms_norm(x_t, p["ln1"]["scale"], cfg.norm_eps)
        a = _cross_decode(p["xattn"], h, cache["xk"], cache["xv"], cfg)
        x_t = x_t + jnp.tanh(p["gate_attn"]) * a
        h = rms_norm(x_t, p["ln2"]["scale"], cfg.norm_eps)
        m = swiglu(h, p["mlp"]["wg"]["kernel"], p["mlp"]["wu"]["kernel"],
                   p["mlp"]["wd"]["kernel"])
        return x_t + jnp.tanh(p["gate_mlp"]) * m, cache
    if kind == "dec":
        h = layer_norm(x_t, p["ln1"]["scale"], p["ln1"]["bias"], cfg.norm_eps)
        a, cache = _gqa_decode(p["attn"], h, cache, pos, cfg)
        x_t = x_t + a
        h = layer_norm(x_t, p["ln2"]["scale"], p["ln2"]["bias"], cfg.norm_eps)
        a = _cross_decode(p["xattn"], h, cache["xk"], cache["xv"], cfg)
        x_t = x_t + a
        h = layer_norm(x_t, p["ln3"]["scale"], p["ln3"]["bias"], cfg.norm_eps)
        m = gelu_mlp(h, p["mlp"]["wi"]["kernel"], p["mlp"]["wi"]["bias"],
                     p["mlp"]["wo"]["kernel"], p["mlp"]["wo"]["bias"])
        return x_t + m, cache
    raise ValueError(kind)


def _lm_head(x_t, params, cfg):
    """Final norm + unembedding shared by every decode entry point.
    x_t: (..., d) -> logits (..., vocab)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    if cfg.family == "encdec":
        fn = params["final_norm"]
        x_t = layer_norm(x_t, fn["scale"], fn["bias"], cfg.norm_eps)
    else:
        x_t = rms_norm(x_t, params["final_norm"]["scale"], cfg.norm_eps)
    unemb = (params["embed"] if cfg.tie_embeddings else params["unembed"])
    return x_t @ unemb["kernel"].astype(cdt).T


def decode_step(params, cache, token, pos, cfg):
    """token: (B,) int32; pos: scalar int32 or (B,) int32 per-sequence
    positions of this token. Returns (logits (B, vocab), new_cache)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    params = cast_params(params, cfg)
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.full((token.shape[0],), pos, jnp.int32)
    x_t = params["embed"]["kernel"][token]
    x_t = shard(x_t, "batch", None)

    new_caches = []
    for (pattern, repeats), seg_p, seg_c in zip(
            cfg.schedule, params["segments"], cache):

        def body(x_t, sc):
            layer_p, layer_c = sc
            new_entries = {}
            for j, kind in enumerate(pattern):
                x_t, new_entries[f"p{j}"] = block_decode(
                    kind, layer_p[f"p{j}"], x_t, layer_c[f"p{j}"], pos, cfg)
                x_t = x_t.astype(cdt)   # pin carry dtype
            return x_t, new_entries

        x_t, new_seg = jax.lax.scan(body, x_t, (seg_p, seg_c))
        new_caches.append(new_seg)

    return _lm_head(x_t, params, cfg), new_caches


# ===========================================================================
# Prefill: forward with cache emission, then reshape into decode layout
# ===========================================================================
def prefill(params, batch, cfg, max_len: int | None = None):
    """Run the full prompt, build the decode cache. Returns (last_logits,
    cache, n_prompt). The emitted per-layer K/V are padded to ``max_len``."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    max_len = max_len or s
    logits, aux, raw_caches, ctx = forward(params, batch, cfg,
                                           return_cache=True)
    cdt = jnp.dtype(cfg.compute_dtype)

    caches = []
    for (pattern, repeats), seg_cache in zip(cfg.schedule, raw_caches):
        seg = {}
        for j, kind in enumerate(pattern):
            kv = seg_cache[f"p{j}"]
            seg[f"p{j}"] = _prefill_entry(kind, kv, cfg, b, s, max_len, cdt,
                                          ctx)
        caches.append(seg)
    return logits[:, -1], caches, s


def _pad_seq(x, max_len):
    """(R, B, S, ...) -> (R, B, max_len, ...) zero-padded."""
    pad = max_len - x.shape[2]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[2] = (0, pad)
    return jnp.pad(x, widths)


def _prefill_entry(kind, kv, cfg, b, s, max_len, cdt, ctx):
    if kind in ("attn", "attn_moe"):
        k, v = kv
        return {"k": _pad_seq(k.astype(cdt), max_len),
                "v": _pad_seq(v.astype(cdt), max_len)}
    if kind == "local":
        k, v = kv
        w = min(cfg.sliding_window, max_len)
        if s >= w:
            # keep the last `w` positions, laid out ring-buffer style:
            # position p lives at slot p % w
            tail_k, tail_v = k[:, :, -w:], v[:, :, -w:]
            slots = (jnp.arange(s - w, s)) % w
            order = jnp.argsort(slots)
            return {"k": tail_k[:, :, order].astype(cdt),
                    "v": tail_v[:, :, order].astype(cdt)}
        return {"k": _pad_seq(k.astype(cdt), w),
                "v": _pad_seq(v.astype(cdt), w)}
    if kind in MLA_KINDS:
        ckv, krope = kv
        return {"ckv": _pad_seq(ckv.astype(cdt), max_len),
                "krope": _pad_seq(krope.astype(cdt), max_len)}
    if kind == "cross":
        xk, xv = kv
        return {"xk": xk.astype(cdt), "xv": xv.astype(cdt)}
    if kind == "dec":
        (k, v), (xk, xv) = kv
        return {"k": _pad_seq(k.astype(cdt), max_len),
                "v": _pad_seq(v.astype(cdt), max_len),
                "xk": xk.astype(cdt), "xv": xv.astype(cdt)}
    if kind in ("mamba_dense", "mamba_moe"):
        return {"conv": kv["conv"].astype(cdt), "ssm": kv["ssm"]}
    if kind == "rwkv":
        return {"x_prev_tm": kv["x_prev_tm"].astype(cdt),
                "x_prev_cm": kv["x_prev_cm"].astype(cdt),
                "wkv": kv["wkv"]}
    raise ValueError(f"no prefill cache layout for block kind {kind!r}")


# ===========================================================================
# Paged serving path (DESIGN.md §12)
#
# The dense decode cache above charges every slot for max_len tokens.
# The serving engine replaces it with a global pool of fixed-size token
# blocks (serve/kv_cache.py) addressed through per-slot block tables;
# attention runs in the Pallas flash-decode kernel which gathers K/V
# straight through the table. Three entry points:
#
#   init_paged_pools(cfg, NB, bs)                  zeroed per-layer pools
#   prefill_chunk(params, scratch, tokens, ...)    one prompt chunk into a
#                                                  dense prefill scratch
#   write_prefill_to_pools(pools, scratch, ...)    scatter scratch -> blocks
#   decode_step_paged(params, pools, ...)          one token for every slot
#
# Only pure-attention schedules (attn / local / attn_moe) have a paged
# layout; recurrent-state families (Mamba, RWKV), MLA latents and
# encoder-decoder keep the dense engine.
# ===========================================================================
PAGED_KINDS = ("attn", "local", "attn_moe")


def paged_supported(cfg) -> bool:
    """True when every block in ``cfg.schedule`` has a paged layout."""
    return all(kind in PAGED_KINDS
               for pattern, _ in cfg.schedule for kind in pattern)


def _check_paged(cfg):
    if not paged_supported(cfg):
        bad = sorted({k for pattern, _ in cfg.schedule for k in pattern
                      if k not in PAGED_KINDS})
        raise ValueError(
            f"paged serving supports kinds {PAGED_KINDS}; {cfg.name!r} "
            f"has {bad} — use the dense ServeEngine for this family")


def init_paged_pools(cfg, num_blocks: int, block_size: int) -> list:
    """Zeroed paged K/V pools matching the segment/scan structure:
    ``pools[seg]['p{j}'] = {'k','v': (R, NB, bs, Hkv, hd)}``. Block ids
    are shared across layers — entry ``i`` of a block table addresses
    block ``i`` of every layer's pool."""
    _check_paged(cfg)
    cdt = jnp.dtype(cfg.compute_dtype)
    hkv, hd = cfg.n_kv_heads, cfg.hd
    pools = []
    for pattern, repeats in cfg.schedule:
        seg = {}
        for j, kind in enumerate(pattern):
            seg[f"p{j}"] = {
                "k": jnp.zeros((repeats, num_blocks, block_size, hkv, hd),
                               cdt),
                "v": jnp.zeros((repeats, num_blocks, block_size, hkv, hd),
                               cdt),
            }
        pools.append(seg)
    return pools


def init_prefill_scratch(cfg, max_prefill_len: int) -> list:
    """Dense per-layer K/V scratch used while chunk-prefilling ONE
    sequence; scattered into the paged pools afterwards. Unlike the
    decode cache, ``local`` layers get the full length here (the window
    is enforced by masks, not a ring buffer, so the scatter into blocks
    stays position-indexed)."""
    _check_paged(cfg)
    cdt = jnp.dtype(cfg.compute_dtype)
    hkv, hd = cfg.n_kv_heads, cfg.hd
    scratch = []
    for pattern, repeats in cfg.schedule:
        seg = {}
        for j, kind in enumerate(pattern):
            seg[f"p{j}"] = {
                "k": jnp.zeros((repeats, 1, max_prefill_len, hkv, hd), cdt),
                "v": jnp.zeros((repeats, 1, max_prefill_len, hkv, hd), cdt),
            }
        scratch.append(seg)
    return scratch


def _paged_ffn(kind, p, x_t, cfg):
    """Post-attention half of a paged block: norm + SwiGLU or MoE.
    x_t: (B, d) (decode) or (B, C, d) (prefill chunk)."""
    h = rms_norm(x_t, p["ln2"]["scale"], cfg.norm_eps)
    if kind == "attn_moe":
        squeeze = h.ndim == 2
        m, _ = moe_ffn(p["moe"], h[:, None, :] if squeeze else h, cfg)
        return x_t + (m[:, 0] if squeeze else m)
    m = swiglu(h, p["mlp"]["wg"]["kernel"], p["mlp"]["wu"]["kernel"],
               p["mlp"]["wd"]["kernel"])
    return x_t + m


def _paged_gqa_decode(p, x_t, pool, block_table, pos, active, cfg, *,
                      window, num_splits):
    """One token of paged GQA attention. x_t: (B, d); pool {'k','v'}:
    (NB, bs, Hkv, hd); block_table: (B, MAXB); pos/active: (B,). The
    new K/V are scattered into each slot's current block (inactive
    slots scatter out-of-range and are dropped), then the flash-decode
    kernel attends through the table."""
    from repro.kernels.ops import flash_decode_op

    b, d = x_t.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    nb, bs = pool["k"].shape[0], pool["k"].shape[1]

    def proj(w, t, h):
        y = t @ w["kernel"]
        if "bias" in w:
            y = y + w["bias"]
        return y.reshape(b, h, hd)

    q = proj(p["wq"], x_t, hq)
    k = proj(p["wk"], x_t, hkv)
    v = proj(p["wv"], x_t, hkv)
    if cfg.use_qk_norm:
        q = _qk_norm(q, p["q_norm_scale"])
        k = _qk_norm(k, p["k_norm_scale"])
    cos, sin = rope_at(pos, hd, cfg.rope_theta)
    q = _rope_decode(q, cos, sin)
    k = _rope_decode(k, cos, sin)

    rows = jnp.arange(b)
    blk = block_table[rows, pos // bs]
    dest = jnp.where(active, blk, nb)              # OOB -> dropped
    off = pos % bs
    new_k = pool["k"].at[dest, off].set(k.astype(pool["k"].dtype),
                                        mode="drop")
    new_v = pool["v"].at[dest, off].set(v.astype(pool["v"].dtype),
                                        mode="drop")
    lengths = jnp.where(active, pos + 1, 0)
    out = flash_decode_op(q, new_k, new_v, block_table, lengths,
                          window=window, num_splits=num_splits)
    y = out.reshape(b, hq * hd) @ p["wo"]["kernel"]
    if "bias" in p["wo"]:
        y = y + p["wo"]["bias"]
    return y, {"k": new_k, "v": new_v}


def decode_step_paged(params, pools, token, pos, block_table, active, cfg,
                      *, num_splits: int = 1):
    """One decode token for every scheduler slot against the paged pools.

    token/pos/active: (B,) — per-slot lanes (B = slot capacity, fixed);
    block_table: (B, MAXB) int32. Inactive slots cost compute but write
    nothing and read length-0 caches (zero attention output), so batch
    composition can churn without retracing. Returns (logits (B, vocab),
    new_pools)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    params = cast_params(params, cfg)
    pos = jnp.asarray(pos, jnp.int32)
    active = jnp.asarray(active, bool)
    x_t = params["embed"]["kernel"][token]

    new_pools = []
    for (pattern, repeats), seg_p, seg_pool in zip(
            cfg.schedule, params["segments"], pools):

        def body(x_t, sc):
            layer_p, layer_pool = sc
            new_entries = {}
            for j, kind in enumerate(pattern):
                p, pool = layer_p[f"p{j}"], layer_pool[f"p{j}"]
                window = cfg.sliding_window if kind == "local" else None
                h = rms_norm(x_t, p["ln1"]["scale"], cfg.norm_eps)
                a, new_entries[f"p{j}"] = _paged_gqa_decode(
                    p["attn"], h, pool, block_table, pos, active, cfg,
                    window=window, num_splits=num_splits)
                x_t = _paged_ffn(kind, p, x_t + a, cfg).astype(cdt)
            return x_t, new_entries

        x_t, new_seg = jax.lax.scan(body, x_t, (seg_p, seg_pool))
        new_pools.append(new_seg)

    return _lm_head(x_t, params, cfg), new_pools


def prefill_chunk(params, scratch, tokens, start, take_idx, cfg):
    """Run one prompt chunk through the model, extending the prefill
    scratch. tokens: (1, C) (right-padded garbage is fine — causal
    masking keeps it out of valid positions); start: scalar int32
    absolute position of tokens[:, 0]; take_idx: scalar int32 chunk-
    local index whose logits to return (the prompt's last token on the
    final chunk; ignored otherwise). Returns (logits (1, vocab),
    new_scratch)."""
    _check_paged(cfg)
    cdt = jnp.dtype(cfg.compute_dtype)
    params = cast_params(params, cfg)
    b, c = tokens.shape
    start = jnp.asarray(start, jnp.int32)
    x = params["embed"]["kernel"][tokens]          # (1, C, d)
    hkv, hd = cfg.n_kv_heads, cfg.hd

    def attn_chunk(p, h, scr, window):
        q = (h @ p["wq"]["kernel"])
        k = (h @ p["wk"]["kernel"])
        v = (h @ p["wv"]["kernel"])
        if "bias" in p["wq"]:
            q, k, v = (q + p["wq"]["bias"], k + p["wk"]["bias"],
                       v + p["wv"]["bias"])
        q = q.reshape(b, c, cfg.n_heads, hd)
        k = k.reshape(b, c, hkv, hd)
        v = v.reshape(b, c, hkv, hd)
        if cfg.use_qk_norm:
            q = _qk_norm(q, p["q_norm_scale"])
            k = _qk_norm(k, p["k_norm_scale"])
        qpos = start + jnp.arange(c, dtype=jnp.int32)
        cos, sin = rope_tables_at(qpos, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        new_k = jax.lax.dynamic_update_slice(
            scr["k"], k.astype(scr["k"].dtype), (0, start, 0, 0))
        new_v = jax.lax.dynamic_update_slice(
            scr["v"], v.astype(scr["v"].dtype), (0, start, 0, 0))
        s = scr["k"].shape[1]
        kpos = jnp.arange(s, dtype=jnp.int32)[None, :]
        mask = kpos <= qpos[:, None]               # causal w/ offset
        if window is not None:
            mask &= kpos >= qpos[:, None] - window + 1
        out = chunk_attention(q, new_k, new_v, mask)
        y = out.reshape(b, c, cfg.n_heads * hd) @ p["wo"]["kernel"]
        if "bias" in p["wo"]:
            y = y + p["wo"]["bias"]
        return y, {"k": new_k, "v": new_v}

    new_scratch = []
    for (pattern, repeats), seg_p, seg_scr in zip(
            cfg.schedule, params["segments"], scratch):

        def body(x, sc):
            layer_p, layer_scr = sc
            new_entries = {}
            for j, kind in enumerate(pattern):
                p = layer_p[f"p{j}"]
                window = cfg.sliding_window if kind == "local" else None
                h = rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
                a, new_entries[f"p{j}"] = attn_chunk(
                    p["attn"], h, layer_scr[f"p{j}"], window)
                x = _paged_ffn(kind, p, x + a, cfg).astype(cdt)
            return x, new_entries

        x, new_seg = jax.lax.scan(body, x, (seg_p, seg_scr))
        new_scratch.append(new_seg)

    take_idx = jnp.asarray(take_idx, jnp.int32)
    x_last = jnp.take_along_axis(x, take_idx.reshape(1, 1, 1), axis=1)[:, 0]
    return _lm_head(x_last, params, cfg), new_scratch


def write_prefill_to_pools(pools, scratch, block_ids, length,
                           block_size: int):
    """Scatter a finished prefill scratch into the paged pools.

    block_ids: (MAXB,) int32 — the sequence's block table (padded);
    length: scalar int32 valid tokens. Whole blocks are written (the
    tail of the last block holds garbage that stays masked by
    ``length``); entries past ``ceil(length / bs)`` scatter out of
    range and are dropped."""
    length = jnp.asarray(length, jnp.int32)
    nblocks = (length + block_size - 1) // block_size

    def write(pool, scr):
        r, nb_pool, bs = pool.shape[0], pool.shape[1], pool.shape[2]
        s = scr.shape[2]
        ncols = s // bs
        blocks = scr.reshape(r, ncols, bs, *scr.shape[3:])
        dest = jnp.where(jnp.arange(ncols) < nblocks,
                         block_ids[:ncols].astype(jnp.int32), nb_pool)
        return pool.at[:, dest].set(blocks.astype(pool.dtype), mode="drop")

    return jax.tree.map(write, pools, scratch)
