"""Fused column-gather back-projection kernel: ``O = b @ Q[:, idx]^T``.

Trion / DCT-AdamW back-project the low-rank factor ``b (m, r)`` through the
selected DCT columns: ``O = b @ Q_r^T`` where ``Q_r^T = Q^T[idx, :] (r, n)``
is a *row* gather of the transposed shared basis. This kernel never
materializes the gathered matrix in HBM: the selected rows are gathered
VMEM->VMEM from a resident column stripe of ``Q^T``, driven by the
scalar-prefetched index vector.

Two entry points (DESIGN.md §3):

  * ``colgather_matmul(b, qt, idx)``            — one back-projection.
  * ``colgather_matmul_dual(b1, b2, qt, idx)``  — the projected-Adam step's
    descent direction ``u @ Q_r^T`` AND residual reconstruction
    ``g_low @ Q_r^T`` from ONE gather: the ``(r, bn)`` scratch is built once
    per column stripe and feeds both matmuls, so ``Q`` is read once instead
    of twice.

Both accept leading stacked-layer axes on ``b``/``idx`` — collapsed into a
leading batch grid dimension with per-layer index vectors (the shapes every
scan-stacked config produces).

Grid ``(nb, nj, ni)`` — ``j`` after batch so the ``(n, bn)`` stripe of
``Q^T`` and its gathered ``(r, bn)`` scratch are built once per ``(b, j)``
and reused across all row blocks ``i``.

``block=None`` (the default) resolves through the process-wide
:class:`~repro.tune.cache.TuningCache` — tuned block on a hit, the
hardcoded ``DEFAULT_BLOCK`` on a miss (the bit-identical untuned path).

``compute_dtype`` in {"fp32", "bf16", "int8"} selects the matmul precision
(DESIGN.md §15). Because the gather selects *rows* of ``Q^T``, a
per-column scale of the gathered matrix would depend on ``idx``; instead
``Q^T`` is int8-quantized per-row pre-gather and those row scales are
folded into ``b`` before ``b``'s own per-row quantization (kernels/lowp.py
derivation), leaving one per-row epilogue scale — and an int8 gather
scratch, 4x smaller in VMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.tune.cache import resolve_block

from .lowp import check_compute_dtype, quant_rows

DEFAULT_BLOCK = (512, 256)  # (bm rows of b, bn output columns)


def _build_gather(idx_ref, bi, qt_ref, gather_ref, r: int):
    def body(k, _):
        row = idx_ref[bi, k]
        gather_ref[pl.ds(k, 1), :] = qt_ref[pl.ds(row, 1), :]
        return ()

    jax.lax.fori_loop(0, r, body, ())


def _kernel(idx_ref, b_ref, qt_ref, out_ref, gather_ref, *, r: int,
            cast=jnp.float32):
    bi = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _gather():
        _build_gather(idx_ref, bi, qt_ref, gather_ref, r)

    qr = gather_ref[...].astype(cast)
    out_ref[0] = jnp.dot(
        b_ref[0].astype(cast), qr, preferred_element_type=jnp.float32
    ).astype(out_ref.dtype)


def _kernel_dual(idx_ref, b1_ref, b2_ref, qt_ref, o1_ref, o2_ref, gather_ref,
                 *, r: int, cast=jnp.float32):
    bi = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _gather():
        _build_gather(idx_ref, bi, qt_ref, gather_ref, r)

    qr = gather_ref[...].astype(cast)
    o1_ref[0] = jnp.dot(
        b1_ref[0].astype(cast), qr, preferred_element_type=jnp.float32
    ).astype(o1_ref.dtype)
    o2_ref[0] = jnp.dot(
        b2_ref[0].astype(cast), qr, preferred_element_type=jnp.float32
    ).astype(o2_ref.dtype)


def _kernel_q8(idx_ref, b_ref, sb_ref, qt_ref, out_ref, gather_ref, *,
               r: int):
    """int8: gathered rows stay int8, exact int32 dot, per-row epilogue."""
    bi = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _gather():
        _build_gather(idx_ref, bi, qt_ref, gather_ref, r)

    acc = jnp.dot(b_ref[0], gather_ref[...],
                  preferred_element_type=jnp.int32)
    out_ref[0] = (acc.astype(jnp.float32) * sb_ref[0]).astype(out_ref.dtype)


def _kernel_dual_q8(idx_ref, b1_ref, s1_ref, b2_ref, s2_ref, qt_ref,
                    o1_ref, o2_ref, gather_ref, *, r: int):
    bi = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _gather():
        _build_gather(idx_ref, bi, qt_ref, gather_ref, r)

    qr = gather_ref[...]
    a1 = jnp.dot(b1_ref[0], qr, preferred_element_type=jnp.int32)
    o1_ref[0] = (a1.astype(jnp.float32) * s1_ref[0]).astype(o1_ref.dtype)
    a2 = jnp.dot(b2_ref[0], qr, preferred_element_type=jnp.int32)
    o2_ref[0] = (a2.astype(jnp.float32) * s2_ref[0]).astype(o2_ref.dtype)


def _norm_operands(bs: tuple[jax.Array, ...], qt: jax.Array, idx: jax.Array):
    """Collapse leading axes; validate shapes. Returns (batched bs, idx2d,
    batch_shape, m, r, n)."""
    *batch, m, r = bs[0].shape
    n = qt.shape[1]
    assert qt.shape[0] == n, (qt.shape,)
    for b in bs[1:]:
        assert b.shape == bs[0].shape, (b.shape, bs[0].shape)
    assert idx.shape == (*batch, r), (idx.shape, bs[0].shape)
    bb = tuple(b.reshape((-1, m, r)) for b in bs)
    idx2 = idx.reshape((-1, r)).astype(jnp.int32)
    return bb, idx2, tuple(batch), m, r, n


def _call(bs, qt, idx, *, block, interpret, out_dtype, compute_dtype):
    bb, idx2, batch, m, r, n = _norm_operands(bs, qt, idx)
    nb = bb[0].shape[0]
    out_dtype = out_dtype or bs[0].dtype
    bm, bn = block
    mp, np_ = (-m % bm), (-n % bn)
    mm, nn = m + mp, n + np_
    ni, nj = mm // bm, nn // bn
    nops = len(bs)
    out_shape = [jax.ShapeDtypeStruct((nb, mm, nn), out_dtype)] * nops
    out_specs = [
        pl.BlockSpec((1, bm, bn), lambda b, j, i, idx_ref: (b, i, j))
    ] * nops

    if compute_dtype == "int8":
        qt_q, s_qt = quant_rows(qt)                   # (n, n) i8, (n, 1)
        s_sel = jnp.take(s_qt[:, 0], idx2, axis=0)    # (nb, r)
        ops_in, in_specs = [], []
        for b in bb:
            bq, sb = quant_rows(b.astype(jnp.float32) * s_sel[:, None, :])
            if mp:
                bq = jnp.pad(bq, ((0, 0), (0, mp), (0, 0)))
                sb = jnp.pad(sb, ((0, 0), (0, mp), (0, 0)),
                             constant_values=1.0)
            ops_in += [bq, sb]
            in_specs += [
                pl.BlockSpec((1, bm, r), lambda b, j, i, idx_ref: (b, i, 0)),
                pl.BlockSpec((1, bm, 1), lambda b, j, i, idx_ref: (b, i, 0)),
            ]
        qtp = jnp.pad(qt_q, ((0, 0), (0, np_))) if np_ else qt_q
        in_specs.append(
            pl.BlockSpec((n, bn), lambda b, j, i, idx_ref: (0, j)))
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nb, nj, ni),
            in_specs=in_specs,
            out_specs=out_specs,
            scratch_shapes=[pltpu.VMEM((r, bn), jnp.int8)],
        )
        kernel = _kernel_q8 if nops == 1 else _kernel_dual_q8
        outs = pl.pallas_call(
            functools.partial(kernel, r=r),
            grid_spec=grid_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(idx2, *ops_in, qtp)
    else:
        cast = jnp.float32 if compute_dtype == "fp32" else jnp.bfloat16
        bp = tuple(jnp.pad(b, ((0, 0), (0, mp), (0, 0))) if mp else b
                   for b in bb)
        qtp = jnp.pad(qt, ((0, 0), (0, np_))) if np_ else qt
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nb, nj, ni),
            in_specs=[
                *([pl.BlockSpec((1, bm, r),
                                lambda b, j, i, idx_ref: (b, i, 0))] * nops),
                pl.BlockSpec((qt.shape[0], bn),
                             lambda b, j, i, idx_ref: (0, j)),
            ],
            out_specs=out_specs,
            scratch_shapes=[pltpu.VMEM((r, bn), qt.dtype)],
        )
        kernel = _kernel if nops == 1 else _kernel_dual
        outs = pl.pallas_call(
            functools.partial(kernel, r=r, cast=cast),
            grid_spec=grid_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(idx2, *bp, qtp)
    return tuple(o[:, :m, :n].reshape((*batch, m, n)) for o in outs)


def _resolve(kernel: str, b: jax.Array, n: int, block):
    if block is not None:
        return tuple(block)
    *batch, m, r = b.shape
    return tuple(resolve_block(kernel, (math.prod(batch), m, n), r,
                               b.dtype, DEFAULT_BLOCK))


@functools.partial(jax.jit, static_argnames=("block", "interpret", "out_dtype",
                                             "compute_dtype"))
def _colgather_matmul(b, qt, idx, *, block, interpret, out_dtype,
                      compute_dtype):
    (out,) = _call((b,), qt, idx, block=block, interpret=interpret,
                   out_dtype=out_dtype, compute_dtype=compute_dtype)
    return out


@functools.partial(jax.jit, static_argnames=("block", "interpret", "out_dtype",
                                             "compute_dtype"))
def _colgather_matmul_dual(b1, b2, qt, idx, *, block, interpret, out_dtype,
                           compute_dtype):
    return _call((b1, b2), qt, idx, block=block, interpret=interpret,
                 out_dtype=out_dtype, compute_dtype=compute_dtype)


def colgather_matmul(
    b: jax.Array,
    qt: jax.Array,
    idx: jax.Array,
    *,
    block: tuple[int, int] | None = None,
    interpret: bool = False,
    out_dtype=None,
    compute_dtype: str = "fp32",
) -> jax.Array:
    """``O[..., m, n] = b[..., m, r] @ qt[idx, :]``; ``qt`` is ``Q^T`` (n, n),
    ``idx`` (..., r) int32 per-layer. Output dtype defaults to ``b.dtype``.
    ``block=None`` resolves TuningCache -> ``DEFAULT_BLOCK``;
    ``compute_dtype`` in {"fp32", "bf16", "int8"}."""
    check_compute_dtype(compute_dtype)
    block = _resolve("colgather_matmul", b, qt.shape[1], block)
    return _colgather_matmul(b, qt, idx, block=block, interpret=interpret,
                             out_dtype=out_dtype, compute_dtype=compute_dtype)


def colgather_matmul_dual(
    b1: jax.Array,
    b2: jax.Array,
    qt: jax.Array,
    idx: jax.Array,
    *,
    block: tuple[int, int] | None = None,
    interpret: bool = False,
    out_dtype=None,
    compute_dtype: str = "fp32",
) -> tuple[jax.Array, jax.Array]:
    """``(b1 @ qt[idx, :], b2 @ qt[idx, :])`` sharing one index gather."""
    check_compute_dtype(compute_dtype)
    block = _resolve("colgather_matmul_dual", b1, qt.shape[1], block)
    return _colgather_matmul_dual(b1, b2, qt, idx, block=block,
                                  interpret=interpret, out_dtype=out_dtype,
                                  compute_dtype=compute_dtype)
