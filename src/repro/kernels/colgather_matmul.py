"""Fused column-gather back-projection kernel: ``O = b @ Q[:, idx]^T``.

Trion / DCT-AdamW back-project the low-rank factor ``b (m, r)`` through the
selected DCT columns: ``O = b @ Q_r^T`` where ``Q_r^T = Q^T[idx, :] (r, n)``
is a *row* gather of the transposed shared basis. This kernel never
materializes the gathered matrix in HBM: the selected rows are gathered
VMEM->VMEM from a resident column stripe of ``Q^T``, driven by the
scalar-prefetched index vector.

Grid ``(nj, ni)`` — ``j`` outermost so the ``(n, bn)`` stripe of ``Q^T`` and
its gathered ``(r, bn)`` scratch are built once per column block and reused
across all row blocks ``i``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = (512, 256)  # (bm rows of b, bn output columns)


def _kernel(idx_ref, b_ref, qt_ref, out_ref, gather_ref, *, r: int):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _build_gather():
        def body(k, _):
            row = idx_ref[k]
            gather_ref[pl.ds(k, 1), :] = qt_ref[pl.ds(row, 1), :]
            return ()

        jax.lax.fori_loop(0, r, body, ())

    out_ref[...] = jnp.dot(
        b_ref[...].astype(jnp.float32),
        gather_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret", "out_dtype"))
def colgather_matmul(
    b: jax.Array,
    qt: jax.Array,
    idx: jax.Array,
    *,
    block: tuple[int, int] = DEFAULT_BLOCK,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """``O[m, n] = b[m, r] @ qt[idx, :][r, n]``; ``qt`` is ``Q^T`` (n, n),
    ``idx`` (r,) int32. Output dtype defaults to ``b.dtype``."""
    m, r = b.shape
    n = qt.shape[1]
    assert qt.shape[0] == n and idx.shape == (r,), (b.shape, qt.shape, idx.shape)
    out_dtype = out_dtype or b.dtype
    bm, bn = block
    mp, np_ = (-m % bm), (-n % bn)
    bp = jnp.pad(b, ((0, mp), (0, 0))) if mp else b
    qtp = jnp.pad(qt, ((0, 0), (0, np_))) if np_ else qt
    mm, nn = m + mp, n + np_
    ni, nj = mm // bm, nn // bn

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nj, ni),
        in_specs=[
            pl.BlockSpec((bm, r), lambda j, i, idx_ref: (i, 0)),
            pl.BlockSpec((qt.shape[0], bn), lambda j, i, idx_ref: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda j, i, idx_ref: (i, j)),
        scratch_shapes=[pltpu.VMEM((r, bn), qt.dtype)],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, r=r),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((mm, nn), out_dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), bp, qtp)
    return out[:m, :n]
