"""Pallas TPU kernels for the paper's compute hot-spots.

  dct_project      — fused S = G @ Q + column-norm ranking statistic
  colgather_matmul — fused back-projection b @ Q[:, idx]^T (scalar-prefetch
                     driven gather, never materializes Q_r); the _dual
                     variant back-projects two factors from one gather
  newton_schulz    — NS5 on the low-rank factor (r-sized Gram in VMEM)
  quant_ef         — int8 error-feedback quantize / fused dequant-add
  flash_attention  — online-softmax attention, GQA/causal/window, VMEM-
                     resident softmax state (the train/prefill memory-term
                     fix identified in EXPERIMENTS.md §Roofline)
  flash_decode     — paged single-query decode attention: K/V gathered
                     through the serve block table via scalar-prefetched
                     index maps, split-KV parallel over cache blocks with
                     an online-softmax merge (serve/kv_cache.py is the
                     pool; DESIGN.md §12)

dct_project / colgather_matmul / quant_ef accept leading stacked-layer axes
(collapsed into a batch grid dimension), so the scan-stacked ``(layers, m,
n)`` leaves every production config emits run on the kernel path; the fused
projected-Adam step that drives them is core/fused_step.py (DESIGN.md §3).

Each has a pure-jnp oracle in ref.py; tests sweep shapes/dtypes against it in
interpret mode (this container is CPU-only; TPU v5e is the target).
"""
from . import ops, ref
from .colgather_matmul import colgather_matmul, colgather_matmul_dual
from .dct_project import dct_project
from .flash_attention import flash_attention
from .flash_decode import flash_decode
from .newton_schulz import newton_schulz_pallas, ns_iteration
from .quant_ef import dequant_add_ef, quantize_ef

__all__ = [
    "ops", "ref", "colgather_matmul", "colgather_matmul_dual", "dct_project",
    "flash_attention", "flash_decode", "newton_schulz_pallas", "ns_iteration",
    "dequant_add_ef", "quantize_ef",
]
