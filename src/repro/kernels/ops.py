"""Jit'd public wrappers over the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites work in CPU
tests and on real hardware (`repro.kernels.ops.ON_TPU`).

The ``**kw`` passthrough is load-bearing for DESIGN.md §15: callers
(fused_step) forward ``compute_dtype`` here, and an omitted ``block``
leaves the kernels' ``block=None`` default in place, which resolves
against the process-wide TuningCache at trace time (repro.tune).
"""
from __future__ import annotations

import jax

from .colgather_matmul import colgather_matmul, colgather_matmul_dual
from .dct_project import dct_project
from .flash_attention import flash_attention
from .flash_decode import flash_decode
from .newton_schulz import newton_schulz_pallas, ns_iteration
from .quant_ef import dequant_add_ef, quantize_ef

ON_TPU = jax.default_backend() == "tpu"
_INTERPRET = not ON_TPU


def dct_project_op(g, q, **kw):
    kw.setdefault("interpret", _INTERPRET)
    return dct_project(g, q, **kw)


def colgather_matmul_op(b, qt, idx, **kw):
    kw.setdefault("interpret", _INTERPRET)
    return colgather_matmul(b, qt, idx, **kw)


def colgather_matmul_dual_op(b1, b2, qt, idx, **kw):
    kw.setdefault("interpret", _INTERPRET)
    return colgather_matmul_dual(b1, b2, qt, idx, **kw)


def newton_schulz_op(x, **kw):
    kw.setdefault("interpret", _INTERPRET)
    return newton_schulz_pallas(x, **kw)


def ns_iteration_op(x, **kw):
    kw.setdefault("interpret", _INTERPRET)
    return ns_iteration(x, **kw)


def flash_attention_op(q, k, v, **kw):
    kw.setdefault("interpret", _INTERPRET)
    return flash_attention(q, k, v, **kw)


def flash_decode_op(q, k_pool, v_pool, block_table, lengths, **kw):
    kw.setdefault("interpret", _INTERPRET)
    return flash_decode(q, k_pool, v_pool, block_table, lengths, **kw)


def quantize_ef_op(x, **kw):
    kw.setdefault("interpret", _INTERPRET)
    return quantize_ef(x, **kw)


def dequant_add_ef_op(g, q, scale, **kw):
    kw.setdefault("interpret", _INTERPRET)
    return dequant_add_ef(g, q, scale, **kw)
