"""Pallas Newton-Schulz for the low-rank factor (Trion's hot loop).

One NS5 iteration on the wide-oriented factor ``X (r, m)`` (``r <= m``) is
    A = X X^T            (r x r Gram)
    P = b A + c A A      (r x r polynomial)
    X = a X + P X

Two kernels per iteration:
  * ``gram``  — grid over column blocks of ``X``, accumulating the (r, r)
    Gram matrix in a VMEM scratch (single pass over X).
  * ``apply`` — grid over column blocks, computing ``a X + P X`` with the
    (r, r) polynomial matrix resident in VMEM (second pass over X).

The r x r polynomial between the two passes is a trivial jnp matmul (r <= 512
-> <= 1 MB, negligible). HBM traffic per iteration: 2 reads + 1 write of X —
vs 3 full-size matmuls of Muon's full-rank NS; this is the kernel-level
realisation of the paper's "Newton-Schulz on the low-rank factor" claim.

Inputs may carry arbitrary leading stacked-layer axes — ``(layers, r, m)``
from scan-stacked models — which collapse into one leading *grid* dimension
(same layout as kernels/dct_project.py), so every layer's iteration runs
from a single kernel launch. This is what lets the subspace-fused
muon/trion path (optim/muon.py, optim/trion.py via
core/fused_step.fused_newton_schulz) orthogonalize stacked low-rank
factors without a vmap wrapper around the pallas_call.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.newton_schulz import NS_COEFFS
from repro.tune.cache import resolve_block

DEFAULT_BM = 512  # column-block of the wide factor


def _resolve_bm(shape, bm):
    """``bm=None`` -> TuningCache -> ``DEFAULT_BM``; keyed on the
    wide-oriented factor signature ``(nb, r, m)`` with rank ``r``."""
    if bm is not None:
        return int(bm)
    *batch, r, m = shape
    return int(resolve_block("newton_schulz", (math.prod(batch), r, m), r,
                             "float32", DEFAULT_BM))


def _gram_kernel(x_ref, out_ref, acc_ref, *, nk: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)
    acc_ref[...] += jnp.dot(x, x.T, preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _out():
        out_ref[0] = acc_ref[...]


def _apply_kernel(x_ref, p_ref, out_ref, *, a: float):
    x = x_ref[0].astype(jnp.float32)
    out_ref[0] = (
        a * x + jnp.dot(p_ref[0], x, preferred_element_type=jnp.float32)
    ).astype(out_ref.dtype)


def _pad_cols(x, bm):
    pad = -x.shape[-1] % bm
    return (jnp.pad(x, ((0, 0), (0, 0), (0, pad))) if pad else x), \
        x.shape[-1] + pad


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def _ns_iteration(x: jax.Array, *, bm: int, interpret: bool) -> jax.Array:
    a, b, c = NS_COEFFS
    *batch, r, m = x.shape
    xb = x.reshape((-1, r, m))
    nb = xb.shape[0]
    xp, mm = _pad_cols(xb, bm)
    nk = mm // bm

    gram = pl.pallas_call(
        functools.partial(_gram_kernel, nk=nk),
        grid=(nb, nk),
        in_specs=[pl.BlockSpec((1, r, bm), lambda bi, k: (bi, 0, k))],
        out_specs=pl.BlockSpec((1, r, r), lambda bi, k: (bi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, r, r), jnp.float32),
        scratch_shapes=[pltpu.VMEM((r, r), jnp.float32)],
        interpret=interpret,
    )(xp)

    poly = b * gram + c * jnp.einsum("brs,bst->brt", gram, gram,
                                     preferred_element_type=jnp.float32)

    y = pl.pallas_call(
        functools.partial(_apply_kernel, a=a),
        grid=(nb, nk),
        in_specs=[
            pl.BlockSpec((1, r, bm), lambda bi, k: (bi, 0, k)),
            pl.BlockSpec((1, r, r), lambda bi, k: (bi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, r, bm), lambda bi, k: (bi, 0, k)),
        out_shape=jax.ShapeDtypeStruct((nb, r, mm), x.dtype),
        interpret=interpret,
    )(xp, poly)
    return y[:, :, :m].reshape((*batch, r, m))


def ns_iteration(x: jax.Array, *, bm: int | None = None,
                 interpret: bool = False) -> jax.Array:
    """One fused NS5 iteration on wide ``x (..., r, m)``, r <= m.

    Leading axes (stacked layers) become the kernel's batch grid dim; the
    (r, r) polynomial between the two passes is a batched jnp matmul.
    ``bm=None`` resolves TuningCache -> ``DEFAULT_BM``.
    """
    return _ns_iteration(x, bm=_resolve_bm(x.shape, bm), interpret=interpret)


@functools.partial(jax.jit, static_argnames=("steps", "bm", "interpret", "eps"))
def _newton_schulz_pallas(x: jax.Array, *, steps: int, bm: int, eps: float,
                          interpret: bool) -> jax.Array:
    wide = x.shape[-2] <= x.shape[-1]
    xw = x if wide else jnp.swapaxes(x, -1, -2)
    xf = xw.astype(jnp.float32)
    xf = xf / (jnp.linalg.norm(xf, axis=(-2, -1), keepdims=True) + eps)
    for _ in range(steps):
        xf = _ns_iteration(xf, bm=bm, interpret=interpret)
    out = xf.astype(x.dtype)
    return out if wide else jnp.swapaxes(out, -1, -2)


def newton_schulz_pallas(x: jax.Array, *, steps: int = 5,
                         bm: int | None = None, eps: float = 1e-7,
                         interpret: bool = False) -> jax.Array:
    """Full NS orthogonalization of ``x (..., p, q)`` via the fused iteration.

    Orientation is decided on the trailing two dims (global for the whole
    stack — every layer of a stacked leaf shares the shape); normalization
    is per-matrix Frobenius, matching core/newton_schulz.newton_schulz.
    ``bm=None`` resolves TuningCache (keyed on the wide-oriented shape) ->
    ``DEFAULT_BM``.
    """
    wide_shape = x.shape if x.shape[-2] <= x.shape[-1] else \
        (*x.shape[:-2], x.shape[-1], x.shape[-2])
    return _newton_schulz_pallas(x, steps=steps,
                                 bm=_resolve_bm(wide_shape, bm), eps=eps,
                                 interpret=interpret)
