"""Low-precision compute helpers for the projection matmuls (DESIGN.md §15).

Shared by the Pallas kernels (dct_project / colgather_matmul grow a
``compute_dtype`` argument) and the jnp mirrors that serve the "off"/"fft"
fused modes, so every dispatch mode quantizes with one formula.

int8 epilogue math. The projection ``S = G @ Q`` runs as

    S[i, j] ~= (sum_k Gq[i, k] * Qq[k, j]) * s_g[i] * s_q[j]

with ``Gq = round(G / s_g)`` per-row and ``Qq = round(Q / s_q)`` per-column
— the quant_ef idiom (symmetric linear, amax/127) applied to both operands.
The int8 x int8 dot accumulates exactly in int32 (|sum| <= 127^2 * k < 2^31
for every supported width), so the kernel and the jnp mirror produce
bit-identical products; only the fp32 epilogue multiply rounds.

The back-projection ``O = b @ Q^T[idx, :]`` gathers *rows* of ``Q^T``, so a
per-column scale of the gathered matrix would depend on ``idx``. Instead
``Q^T`` is quantized per-row once (pre-gather), and the row scales are
folded into ``b`` before ``b``'s own per-row quantization:

    O[i, j] = sum_k (b[i, k] * s_qt[idx[k]]) * Qtq[idx[k], j]
            ~= (sum_k bq[i, k] * Qtq[idx[k], j]) * s_b[i]

which leaves a single per-row epilogue scale — and the kernel gathers int8
rows, shrinking the VMEM gather scratch 4x.

Zero/subnormal rows: ``q8_scale`` clamps the scale at the smallest normal
fp32 (`max(amax/127, tiny)`). An exactly-zero row quantizes to zeros either
way; the clamp exists because a *subnormal* row makes ``amax/127``
underflow to 0.0 and ``x / 0`` poison the payload with NaNs. All three EF
quantizers (kernels/quant_ef.py, kernels/ref.py, core/error_feedback.py)
use this same guard so the fused off/on/fft paths stay in lockstep.

``LOWP_ERROR_BOUNDS`` are the documented relative-Frobenius error bounds of
each compute path against fp32 — pinned by tests/test_tuning.py and gated
on a real gradient stream in benchmarks/projection_errors.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

COMPUTE_DTYPES = ("fp32", "bf16", "int8")

#: relative Frobenius error ||lowp - fp32||_F / ||fp32||_F the compute paths
#: stay within (measured headroom >= 2x on random + real gradient streams)
LOWP_ERROR_BOUNDS = {"fp32": 0.0, "bf16": 0.01, "int8": 0.02}

#: smallest normal fp32 — the per-row scale clamp
F32_TINY = float(jnp.finfo(jnp.float32).tiny)


def check_compute_dtype(compute_dtype: str) -> str:
    if compute_dtype not in COMPUTE_DTYPES:
        raise ValueError(f"unknown compute_dtype {compute_dtype!r}; "
                         f"allowed: {COMPUTE_DTYPES}")
    return compute_dtype


def q8_scale(amax: jax.Array) -> jax.Array:
    """amax -> symmetric int8 scale, clamped away from zero/subnormal."""
    return jnp.maximum(amax / 127.0, F32_TINY)


def quant_rows(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-row (last axis) symmetric int8: (..., m, n) -> int8 + (..., m, 1)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = q8_scale(amax)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def quant_cols(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-column symmetric int8: (..., k, n) -> int8 + (..., 1, n)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-2, keepdims=True)
    scale = q8_scale(amax)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


# ---------------------------------------------------------------------------
# jnp mirrors — the "off"/"fft" fused modes run these so compute_dtype means
# the same thing under every dispatch mode
# ---------------------------------------------------------------------------
def lowp_matmul(a: jax.Array, b: jax.Array, compute_dtype: str) -> jax.Array:
    """``a (..., m, k) @ b (k, n)`` in the requested compute precision,
    fp32 result. int8 matches the kernel path bit-for-bit on the integer
    accumulation (int32 is exact)."""
    check_compute_dtype(compute_dtype)
    if compute_dtype == "fp32":
        return jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))
    if compute_dtype == "bf16":
        return jnp.matmul(a.astype(jnp.bfloat16), b.astype(jnp.bfloat16),
                          preferred_element_type=jnp.float32)
    qa, sa = quant_rows(a)
    qb, sb = quant_cols(b)
    acc = jnp.matmul(qa.astype(jnp.int32), qb.astype(jnp.int32))
    return acc.astype(jnp.float32) * sa * sb


def lowp_gather_matmul(bs: tuple[jax.Array, ...], qt: jax.Array,
                       idx: jax.Array, compute_dtype: str
                       ) -> tuple[jax.Array, ...]:
    """``(b @ qt[idx, :] for b in bs)`` sharing one gather, in the requested
    compute precision; fp32 results. ``bs``: (..., m, r); ``qt``: (n, n);
    ``idx``: (..., r)."""
    check_compute_dtype(compute_dtype)
    if compute_dtype != "int8":
        cast = jnp.float32 if compute_dtype == "fp32" else jnp.bfloat16
        gathered = jnp.take(qt, idx, axis=0).astype(cast)
        return tuple(jnp.matmul(b.astype(cast), gathered,
                                preferred_element_type=jnp.float32)
                     for b in bs)
    qt_q, s_qt = quant_rows(qt)                       # (n, n) i8, (n, 1)
    gathered = jnp.take(qt_q, idx, axis=0)            # (..., r, n) i8
    s_sel = jnp.take(s_qt[:, 0], idx, axis=0)         # (..., r)
    outs = []
    for b in bs:
        bq, sb = quant_rows(b.astype(jnp.float32) * s_sel[..., None, :])
        acc = jnp.matmul(bq.astype(jnp.int32), gathered.astype(jnp.int32))
        outs.append(acc.astype(jnp.float32) * sb)
    return tuple(outs)
