"""Fused basis-projection kernel: ``S = G @ Q`` + per-column squared norms.

The TPU-native replacement for the paper's Makhoul FFT fast path (DESIGN.md
§2): one MXU-tiled matmul pass over ``G`` that simultaneously accumulates the
column ranking statistic ``norms[j] = sum_i S[i, j]^2``, so the dynamic column
selection needs no second read of ``S`` from HBM.

The kernel is parameterized by the basis matrix ``Q`` — nothing in it is
DCT-specific, so every predefined-basis backend (DCT/DST/Hadamard/
random-orthogonal, core/transforms.py) dispatches through the same
``pallas_call`` under fused mode "on" (the step-microbench dispatch spy
pins that per kind).

Inputs may carry arbitrary leading stacked-layer axes — ``(layers, m, n)`` or
``(layers, experts, m, n)`` from scan-stacked models. They are collapsed into
one leading *grid* dimension, so every layer's projection runs from the same
kernel launch against the single shared basis ``Q`` (DESIGN.md §3).

Grid layout ``(nb, nj, ni, nk)`` — batch outermost; then ``j`` (output column
blocks) so the ``norms`` block for a given ``(b, j)`` stays resident in VMEM
across the whole ``(i, k)`` sweep; ``k`` innermost for the standard
accumulator pattern.

``block=None`` (the default) resolves through the process-wide
:class:`~repro.tune.cache.TuningCache` — tuned block on a hit, the
hardcoded ``DEFAULT_BLOCK`` on a miss, so an untuned process is
bit-identical to the pre-autotuner repo. Block shapes are multiples of the
(8, 128) fp32 tile; the default 256^3 keeps the working set (G + Q + S
tiles + fp32 acc + norms) around 1 MB of VMEM.

``compute_dtype`` selects the matmul precision (DESIGN.md §15): "fp32"
(the bit-identical default), "bf16" (operands cast, fp32 accumulation), or
"int8" — per-row scales on ``G`` and per-column scales on ``Q`` (the
quant_ef idiom, kernels/lowp.py), int8 MXU dot with exact int32
accumulation, scales folded into the fp32 epilogue. The column norms are
computed on the dequantized ``S``, so ranking sees the same values the
selection slices.

Under ZeRO-1 (DESIGN.md §9) the kernel is invoked *inside* a shard_map on a
per-device row block ``(rows / N_dp, n)`` — row-blocking only shrinks the
``i`` grid dimension, and the ``norms`` output is then a row-partial
statistic that the caller (core/fused_step.select_and_project) completes
with one ``(n,)``-sized psum over the data axes. The kernel itself never
communicates.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.tune.cache import resolve_block

from .lowp import check_compute_dtype, quant_cols, quant_rows

DEFAULT_BLOCK = (256, 256, 256)  # (bm, bn, bk)


def _kernel(g_ref, q_ref, s_ref, norms_ref, acc_ref, *, nk: int, out_dtype,
            cast=jnp.float32):
    i = pl.program_id(2)
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        g_ref[0].astype(cast),
        q_ref[...].astype(cast),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _finalize():
        acc = acc_ref[...]
        s_ref[0] = acc.astype(out_dtype)
        col = jnp.sum(acc * acc, axis=0, keepdims=True)

        @pl.when(i == 0)
        def _first():
            norms_ref[0] = col

        @pl.when(i > 0)
        def _rest():
            norms_ref[0] += col


def _kernel_q8(g_ref, q_ref, sg_ref, sq_ref, s_ref, norms_ref, acc_ref, *,
               nk: int, out_dtype):
    """int8 variant: exact int32 accumulation, scales folded in finalize."""
    i = pl.program_id(2)
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(g_ref[0], q_ref[...],
                            preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _finalize():
        # (bm, bn) = int32 acc * (bm, 1) row scales * (1, bn) column scales
        acc = acc_ref[...].astype(jnp.float32) * sg_ref[0] * sq_ref[...]
        s_ref[0] = acc.astype(out_dtype)
        col = jnp.sum(acc * acc, axis=0, keepdims=True)

        @pl.when(i == 0)
        def _first():
            norms_ref[0] = col

        @pl.when(i > 0)
        def _rest():
            norms_ref[0] += col


@functools.partial(jax.jit, static_argnames=("block", "interpret", "out_dtype",
                                             "compute_dtype"))
def _dct_project(
    g: jax.Array,
    q: jax.Array,
    *,
    block: tuple[int, int, int],
    interpret: bool,
    out_dtype,
    compute_dtype: str,
) -> tuple[jax.Array, jax.Array]:
    *batch, m, n = g.shape
    assert q.shape == (n, n), (g.shape, q.shape)
    out_dtype = out_dtype or g.dtype
    gb = g.reshape((-1, m, n))
    nb = gb.shape[0]
    bm, bn, bk = block
    mp, np_, kp = (-m % bm), (-n % bn), (-n % bk)
    mm, nn, kk = m + mp, n + np_, n + kp
    ni, nj, nk = mm // bm, nn // bn, kk // bk
    grid = (nb, nj, ni, nk)
    out_shape = [
        jax.ShapeDtypeStruct((nb, mm, nn), out_dtype),
        jax.ShapeDtypeStruct((nb, 1, nn), jnp.float32),
    ]
    out_specs = [
        pl.BlockSpec((1, bm, bn), lambda b, j, i, k: (b, i, j)),
        pl.BlockSpec((1, 1, bn), lambda b, j, i, k: (b, 0, j)),
    ]

    if compute_dtype == "int8":
        # quantize on the unpadded operands (exact full-row/column amax);
        # int8 zero padding contributes 0 to the exact int32 accumulation
        gq, sg = quant_rows(gb)                       # (nb, m, n), (nb, m, 1)
        qq, sq = quant_cols(q)                        # (n, n), (1, n)
        gp = jnp.pad(gq, ((0, 0), (0, mp), (0, kp))) if mp or kp else gq
        qp = jnp.pad(qq, ((0, kp), (0, np_))) if kp or np_ else qq
        sgp = jnp.pad(sg, ((0, 0), (0, mp), (0, 0)),
                      constant_values=1.0) if mp else sg
        sqp = jnp.pad(sq, ((0, 0), (0, np_)),
                      constant_values=1.0) if np_ else sq
        s, norms = pl.pallas_call(
            functools.partial(_kernel_q8, nk=nk, out_dtype=out_dtype),
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bm, bk), lambda b, j, i, k: (b, i, k)),
                pl.BlockSpec((bk, bn), lambda b, j, i, k: (k, j)),
                pl.BlockSpec((1, bm, 1), lambda b, j, i, k: (b, i, 0)),
                pl.BlockSpec((1, bn), lambda b, j, i, k: (0, j)),
            ],
            out_specs=out_specs,
            out_shape=out_shape,
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
            interpret=interpret,
        )(gp, qp, sgp, sqp)
    else:
        cast = jnp.float32 if compute_dtype == "fp32" else jnp.bfloat16
        gp = jnp.pad(gb, ((0, 0), (0, mp), (0, kp))) if mp or kp else gb
        qp = jnp.pad(q, ((0, kp), (0, np_))) if kp or np_ else q
        s, norms = pl.pallas_call(
            functools.partial(_kernel, nk=nk, out_dtype=out_dtype, cast=cast),
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bm, bk), lambda b, j, i, k: (b, i, k)),
                pl.BlockSpec((bk, bn), lambda b, j, i, k: (k, j)),
            ],
            out_specs=out_specs,
            out_shape=out_shape,
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
            interpret=interpret,
        )(gp, qp)
    s = s[:, :m, :n].reshape((*batch, m, n))
    norms = norms[:, 0, :n].reshape((*batch, n))
    return s, norms


def dct_project(
    g: jax.Array,
    q: jax.Array,
    *,
    block: tuple[int, int, int] | None = None,
    interpret: bool = False,
    out_dtype=None,
    compute_dtype: str = "fp32",
) -> tuple[jax.Array, jax.Array]:
    """Returns ``(S, norms)``: ``S = G @ Q`` and fp32 squared-l2 column norms.

    ``g``: (..., m, n); ``q``: (n, n) shared basis. Leading axes become the
    kernel's batch grid dimension. Arbitrary shapes are zero-padded up to
    block multiples (padded columns yield norm 0 and are sliced away).
    ``block=None`` resolves TuningCache -> ``DEFAULT_BLOCK`` (trace-time);
    ``compute_dtype`` in {"fp32", "bf16", "int8"} selects matmul precision.
    Returns ``S (..., m, n)`` and ``norms (..., n)``.
    """
    check_compute_dtype(compute_dtype)
    if block is None:
        *batch, m, n = g.shape
        block = resolve_block("dct_project", (math.prod(batch), m, n), 0,
                              g.dtype, DEFAULT_BLOCK)
    return _dct_project(g, q, block=tuple(block), interpret=interpret,
                        out_dtype=out_dtype, compute_dtype=compute_dtype)
