"""Fused basis-projection kernel: ``S = G @ Q`` + per-column squared norms.

The TPU-native replacement for the paper's Makhoul FFT fast path (DESIGN.md
§2): one MXU-tiled matmul pass over ``G`` that simultaneously accumulates the
column ranking statistic ``norms[j] = sum_i S[i, j]^2``, so the dynamic column
selection needs no second read of ``S`` from HBM.

The kernel is parameterized by the basis matrix ``Q`` — nothing in it is
DCT-specific, so every predefined-basis backend (DCT/DST/Hadamard/
random-orthogonal, core/transforms.py) dispatches through the same
``pallas_call`` under fused mode "on" (the step-microbench dispatch spy
pins that per kind).

Inputs may carry arbitrary leading stacked-layer axes — ``(layers, m, n)`` or
``(layers, experts, m, n)`` from scan-stacked models. They are collapsed into
one leading *grid* dimension, so every layer's projection runs from the same
kernel launch against the single shared basis ``Q`` (DESIGN.md §3).

Grid layout ``(nb, nj, ni, nk)`` — batch outermost; then ``j`` (output column
blocks) so the ``norms`` block for a given ``(b, j)`` stays resident in VMEM
across the whole ``(i, k)`` sweep; ``k`` innermost for the standard
accumulator pattern.

Block shapes are multiples of the (8, 128) fp32 tile; the default 256^3 keeps
the working set (G + Q + S tiles + fp32 acc + norms) around 1 MB of VMEM.

Under ZeRO-1 (DESIGN.md §9) the kernel is invoked *inside* a shard_map on a
per-device row block ``(rows / N_dp, n)`` — row-blocking only shrinks the
``i`` grid dimension, and the ``norms`` output is then a row-partial
statistic that the caller (core/fused_step.select_and_project) completes
with one ``(n,)``-sized psum over the data axes. The kernel itself never
communicates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = (256, 256, 256)  # (bm, bn, bk)


def _kernel(g_ref, q_ref, s_ref, norms_ref, acc_ref, *, nk: int, out_dtype):
    i = pl.program_id(2)
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        g_ref[0].astype(jnp.float32),
        q_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _finalize():
        acc = acc_ref[...]
        s_ref[0] = acc.astype(out_dtype)
        col = jnp.sum(acc * acc, axis=0, keepdims=True)

        @pl.when(i == 0)
        def _first():
            norms_ref[0] = col

        @pl.when(i > 0)
        def _rest():
            norms_ref[0] += col


@functools.partial(jax.jit, static_argnames=("block", "interpret", "out_dtype"))
def dct_project(
    g: jax.Array,
    q: jax.Array,
    *,
    block: tuple[int, int, int] = DEFAULT_BLOCK,
    interpret: bool = False,
    out_dtype=None,
) -> tuple[jax.Array, jax.Array]:
    """Returns ``(S, norms)``: ``S = G @ Q`` and fp32 squared-l2 column norms.

    ``g``: (..., m, n); ``q``: (n, n) shared basis. Leading axes become the
    kernel's batch grid dimension. Arbitrary shapes are zero-padded up to
    block multiples (padded columns yield norm 0 and are sliced away).
    Returns ``S (..., m, n)`` and ``norms (..., n)``.
    """
    *batch, m, n = g.shape
    assert q.shape == (n, n), (g.shape, q.shape)
    out_dtype = out_dtype or g.dtype
    gb = g.reshape((-1, m, n))
    nb = gb.shape[0]
    bm, bn, bk = block
    mp, np_, kp = (-m % bm), (-n % bn), (-n % bk)
    gp = jnp.pad(gb, ((0, 0), (0, mp), (0, kp))) if mp or kp else gb
    qp = jnp.pad(q, ((0, kp), (0, np_))) if kp or np_ else q
    mm, nn, kk = m + mp, n + np_, n + kp
    ni, nj, nk = mm // bm, nn // bn, kk // bk

    s, norms = pl.pallas_call(
        functools.partial(_kernel, nk=nk, out_dtype=out_dtype),
        grid=(nb, nj, ni, nk),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda b, j, i, k: (b, i, k)),
            pl.BlockSpec((bk, bn), lambda b, j, i, k: (k, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, bm, bn), lambda b, j, i, k: (b, i, j)),
            pl.BlockSpec((1, 1, bn), lambda b, j, i, k: (b, 0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, mm, nn), out_dtype),
            jax.ShapeDtypeStruct((nb, 1, nn), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(gp, qp)
    s = s[:, :m, :n].reshape((*batch, m, n))
    norms = norms[:, 0, :n].reshape((*batch, n))
    return s, norms
