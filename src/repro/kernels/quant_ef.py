"""Bandwidth-bound int8 error-feedback kernels.

Two fused passes used by DCT-AdamW's quantized EF (paper §2.4):
  * ``quantize_ef``     — residual (m, n) fp -> (int8 payload, per-row fp32
    scale) in a single HBM read + int8 write (4x HBM write reduction vs fp32).
  * ``dequant_add_ef``  — ``G + q * scale`` fused so the dequantized fp32 EF
    buffer never exists in HBM.

Rows are processed in full width per grid step so the per-row amax reduction
and the scaling stay in registers/VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 256  # rows per grid step


def _quant_kernel(x_ref, q_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    scale_ref[...] = scale


def _dequant_add_kernel(g_ref, q_ref, scale_ref, out_ref):
    out_ref[...] = (
        g_ref[...].astype(jnp.float32)
        + q_ref[...].astype(jnp.float32) * scale_ref[...]
    ).astype(out_ref.dtype)


def _pad_rows(x, bm):
    pad = -x.shape[0] % bm
    return (jnp.pad(x, ((0, pad), (0, 0))) if pad else x), x.shape[0] + pad


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def quantize_ef(x: jax.Array, *, bm: int = DEFAULT_BM,
                interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """(m, n) fp -> ((m, n) int8, (m, 1) fp32 row scales)."""
    m, n = x.shape
    xp, mm = _pad_rows(x, bm)
    q, scale = pl.pallas_call(
        _quant_kernel,
        grid=(mm // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mm, n), jnp.int8),
            jax.ShapeDtypeStruct((mm, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xp)
    return q[:m], scale[:m]


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def dequant_add_ef(g: jax.Array, q: jax.Array, scale: jax.Array, *,
                   bm: int = DEFAULT_BM, interpret: bool = False) -> jax.Array:
    """``G + dequant(q, scale)`` fused; output dtype follows ``G``."""
    m, n = g.shape
    gp, mm = _pad_rows(g, bm)
    qp, _ = _pad_rows(q, bm)
    sp, _ = _pad_rows(scale, bm)
    out = pl.pallas_call(
        _dequant_add_kernel,
        grid=(mm // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mm, n), g.dtype),
        interpret=interpret,
    )(gp, qp, sp)
    return out[:m]
