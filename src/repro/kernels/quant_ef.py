"""Bandwidth-bound int8 error-feedback kernels.

Two fused passes used by DCT-AdamW's quantized EF (paper §2.4):
  * ``quantize_ef``     — residual (..., m, n) fp -> (int8 payload, per-row
    fp32 scale) in a single HBM read + int8 write (4x HBM write reduction vs
    fp32).
  * ``dequant_add_ef``  — ``G + q * scale`` fused so the dequantized fp32 EF
    buffer never exists in HBM (the projected-Adam step reads the EF payload
    straight into the gradient accumulation, DESIGN.md §3).

Leading stacked-layer axes are collapsed into a leading batch grid dimension
(scan-stacked ``(layers, m, n)`` leaves run in one launch). Rows are
processed in full width per grid step so the per-row amax reduction and the
scaling stay in registers/VMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.tune.cache import resolve_block

from .lowp import q8_scale

DEFAULT_BM = 256  # rows per grid step


def _quant_kernel(x_ref, q_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    # max(amax/127, tiny): an all-zero row quantizes to zeros under any
    # positive scale, but a *subnormal* row underflows amax/127 to 0.0 and
    # x / 0 would poison the int8 payload with NaNs (kernels/lowp.py; the
    # jnp quantizers in kernels/ref.py + core/error_feedback.py match)
    scale = q8_scale(amax)
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    scale_ref[...] = scale


def _dequant_add_kernel(g_ref, q_ref, scale_ref, out_ref):
    out_ref[...] = (
        g_ref[...].astype(jnp.float32)
        + q_ref[...].astype(jnp.float32) * scale_ref[...]
    ).astype(out_ref.dtype)


def _batch_rows(x, bm):
    """(..., m, n) -> row-padded (nb, mm, n) + original dims."""
    *batch, m, n = x.shape
    xb = x.reshape((-1, m, n))
    pad = -m % bm
    if pad:
        xb = jnp.pad(xb, ((0, 0), (0, pad), (0, 0)))
    return xb, tuple(batch), m, m + pad, n


def _resolve_bm(x: jax.Array, bm):
    """``bm=None`` -> TuningCache -> ``DEFAULT_BM`` (both EF kernels share
    the one "quant_ef" cache family)."""
    if bm is not None:
        return int(bm)
    *batch, m, n = x.shape
    return int(resolve_block("quant_ef", (math.prod(batch), m, n), 0,
                             x.dtype, DEFAULT_BM))


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def _quantize_ef(x: jax.Array, *, bm: int,
                 interpret: bool) -> tuple[jax.Array, jax.Array]:
    xp, batch, m, mm, n = _batch_rows(x, bm)
    nb = xp.shape[0]
    q, scale = pl.pallas_call(
        _quant_kernel,
        grid=(nb, mm // bm),
        in_specs=[pl.BlockSpec((1, bm, n), lambda b, i: (b, i, 0))],
        out_specs=[
            pl.BlockSpec((1, bm, n), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bm, 1), lambda b, i: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, mm, n), jnp.int8),
            jax.ShapeDtypeStruct((nb, mm, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xp)
    return (q[:, :m].reshape((*batch, m, n)),
            scale[:, :m].reshape((*batch, m, 1)))


def quantize_ef(x: jax.Array, *, bm: int | None = None,
                interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """(..., m, n) fp -> ((..., m, n) int8, (..., m, 1) fp32 row scales).
    ``bm=None`` resolves TuningCache -> ``DEFAULT_BM``."""
    return _quantize_ef(x, bm=_resolve_bm(x, bm), interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def _dequant_add_ef(g: jax.Array, q: jax.Array, scale: jax.Array, *,
                    bm: int, interpret: bool) -> jax.Array:
    gp, batch, m, mm, n = _batch_rows(g, bm)
    qp, *_ = _batch_rows(q, bm)
    sp, *_ = _batch_rows(scale, bm)
    nb = gp.shape[0]
    out = pl.pallas_call(
        _dequant_add_kernel,
        grid=(nb, mm // bm),
        in_specs=[
            pl.BlockSpec((1, bm, n), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bm, n), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bm, 1), lambda b, i: (b, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, n), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, mm, n), g.dtype),
        interpret=interpret,
    )(gp, qp, sp)
    return out[:, :m].reshape((*batch, m, n))


def dequant_add_ef(g: jax.Array, q: jax.Array, scale: jax.Array, *,
                   bm: int | None = None, interpret: bool = False
                   ) -> jax.Array:
    """``G + dequant(q, scale)`` fused; output dtype follows ``G``.
    ``bm=None`` resolves TuningCache -> ``DEFAULT_BM``."""
    return _dequant_add_ef(g, q, scale, bm=_resolve_bm(g, bm),
                           interpret=interpret)
