"""Bandwidth-bound int8 error-feedback kernels.

Two fused passes used by DCT-AdamW's quantized EF (paper §2.4):
  * ``quantize_ef``     — residual (..., m, n) fp -> (int8 payload, per-row
    fp32 scale) in a single HBM read + int8 write (4x HBM write reduction vs
    fp32).
  * ``dequant_add_ef``  — ``G + q * scale`` fused so the dequantized fp32 EF
    buffer never exists in HBM (the projected-Adam step reads the EF payload
    straight into the gradient accumulation, DESIGN.md §3).

Leading stacked-layer axes are collapsed into a leading batch grid dimension
(scan-stacked ``(layers, m, n)`` leaves run in one launch). Rows are
processed in full width per grid step so the per-row amax reduction and the
scaling stay in registers/VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 256  # rows per grid step


def _quant_kernel(x_ref, q_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    scale_ref[...] = scale


def _dequant_add_kernel(g_ref, q_ref, scale_ref, out_ref):
    out_ref[...] = (
        g_ref[...].astype(jnp.float32)
        + q_ref[...].astype(jnp.float32) * scale_ref[...]
    ).astype(out_ref.dtype)


def _batch_rows(x, bm):
    """(..., m, n) -> row-padded (nb, mm, n) + original dims."""
    *batch, m, n = x.shape
    xb = x.reshape((-1, m, n))
    pad = -m % bm
    if pad:
        xb = jnp.pad(xb, ((0, 0), (0, pad), (0, 0)))
    return xb, tuple(batch), m, m + pad, n


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def quantize_ef(x: jax.Array, *, bm: int = DEFAULT_BM,
                interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """(..., m, n) fp -> ((..., m, n) int8, (..., m, 1) fp32 row scales)."""
    xp, batch, m, mm, n = _batch_rows(x, bm)
    nb = xp.shape[0]
    q, scale = pl.pallas_call(
        _quant_kernel,
        grid=(nb, mm // bm),
        in_specs=[pl.BlockSpec((1, bm, n), lambda b, i: (b, i, 0))],
        out_specs=[
            pl.BlockSpec((1, bm, n), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bm, 1), lambda b, i: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, mm, n), jnp.int8),
            jax.ShapeDtypeStruct((nb, mm, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xp)
    return (q[:, :m].reshape((*batch, m, n)),
            scale[:, :m].reshape((*batch, m, 1)))


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def dequant_add_ef(g: jax.Array, q: jax.Array, scale: jax.Array, *,
                   bm: int = DEFAULT_BM, interpret: bool = False) -> jax.Array:
    """``G + dequant(q, scale)`` fused; output dtype follows ``G``."""
    gp, batch, m, mm, n = _batch_rows(g, bm)
    qp, *_ = _batch_rows(q, bm)
    sp, *_ = _batch_rows(scale, bm)
    nb = gp.shape[0]
    out = pl.pallas_call(
        _dequant_add_kernel,
        grid=(nb, mm // bm),
        in_specs=[
            pl.BlockSpec((1, bm, n), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bm, n), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bm, 1), lambda b, i: (b, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, n), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, mm, n), g.dtype),
        interpret=interpret,
    )(gp, qp, sp)
    return out[:, :m].reshape((*batch, m, n))
