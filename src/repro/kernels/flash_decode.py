"""Paged flash-decode Pallas kernel (TPU target).

Single-query attention for continuous-batching decode: each sequence's
K/V live scattered across fixed-size blocks of a global pool
(serve/kv_cache.py), addressed by a per-slot block table. The kernel
gathers K/V *through the table* via the BlockSpec index maps — the
scalar-prefetched ``block_table`` is available before the body runs, so
each grid step DMAs exactly one pool block into VMEM; the paged cache
is never densified in HBM.

Structure (mirrors ``flash_attention.py``):

  * GQA head-grouping — q is laid out ``(B*Hkv, group, hd)`` so every
    grid row loads one K/V block once and attends all ``group`` query
    heads of that kv head against it (the same ``q_head // group``
    folding as the prefill kernel, moved into the row layout because
    decode's q is a single token).
  * Split-KV parallelism — the block-table walk is split into
    ``num_splits`` *parallel* grid rows, each producing an unnormalized
    partial ``(acc, m, l)`` online-softmax state over its share of the
    cache blocks; a tiny jnp epilogue merges the splits with the
    standard max-shift algebra. Within a split the walk is the
    innermost (sequential) grid dimension with the accumulator resident
    in VMEM, exactly like the prefill kernel's KV sweep.
  * Blocks entirely past a slot's ``length`` (or entirely outside its
    sliding window) are skipped with ``pl.when`` — no DMA'd garbage is
    ever computed on, which is also what makes a slot's output
    bit-independent of whatever other sequences occupy the pool.

``lengths[b] == 0`` (an inactive scheduler slot) produces a zero output
row rather than NaN: the merge guards the empty-softmax case.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(table_ref, lengths_ref, q_ref, k_ref, v_ref,
            o_ref, m_ref, l_ref, acc_ref, ms_ref, ls_ref, *,
            hkv: int, bps: int, bs: int, group: int,
            window: int | None, scale: float):
    bh = pl.program_id(1)
    j = pl.program_id(2)
    b = bh // hkv

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        ms_ref[...] = jnp.full_like(ms_ref, NEG_INF)
        ls_ref[...] = jnp.zeros_like(ls_ref)

    blk = pl.program_id(0) * bps + j        # global block-table column
    start = blk * bs
    length = lengths_ref[b]
    run = start < length
    if window is not None:
        run = jnp.logical_and(run, start + bs - 1 >= length - window)

    @pl.when(run)
    def _block():
        q = q_ref[0].astype(jnp.float32)                 # (group, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)           # (bs, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        k_pos = start + jax.lax.broadcasted_iota(jnp.int32, (group, bs), 1)
        mask = k_pos < length
        if window is not None:
            mask = jnp.logical_and(mask, k_pos >= length - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = ms_ref[...]                             # (group, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        ls_ref[...] = ls_ref[...] * corr + p.sum(axis=1, keepdims=True)
        v = v_ref[0, :, 0].astype(jnp.float32)           # (bs, hd)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        ms_ref[...] = m_new

    @pl.when(j == bps - 1)
    def _finalize():
        o_ref[0, 0] = acc_ref[...]
        m_ref[0, 0] = ms_ref[...]
        l_ref[0, 0] = ls_ref[...]


@functools.partial(jax.jit, static_argnames=(
    "window", "num_splits", "interpret"))
def flash_decode(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                 block_table: jax.Array, lengths: jax.Array, *,
                 window: int | None = None, num_splits: int = 1,
                 interpret: bool = False) -> jax.Array:
    """Paged single-query attention.

    q: (B, Hq, hd); k_pool/v_pool: (NB, bs, Hkv, hd); block_table:
    (B, MAXB) int32 pool-block ids (unused entries must be in-range,
    conventionally 0); lengths: (B,) int32 valid tokens per slot
    (0 = inactive slot -> zero output). ``Hq % Hkv == 0``. Splits the
    MAXB-entry table walk into ``num_splits`` parallel partials (MAXB
    is right-padded to a multiple). Returns (B, Hq, hd) in q.dtype.
    """
    b, hq, hd = q.shape
    nb, bs, hkv, hd_k = k_pool.shape
    assert hd_k == hd and v_pool.shape == k_pool.shape, (q.shape, k_pool.shape)
    assert hq % hkv == 0, (hq, hkv)
    assert block_table.shape[0] == b and lengths.shape == (b,)
    group = hq // hkv
    maxb = block_table.shape[1]
    num_splits = max(1, min(num_splits, maxb))
    bps = -(-maxb // num_splits)             # table columns per split
    pad = num_splits * bps - maxb
    table = block_table.astype(jnp.int32)
    if pad:
        table = jnp.pad(table, ((0, 0), (0, pad)))
    lengths = lengths.astype(jnp.int32)
    scale = 1.0 / math.sqrt(hd)

    # (B, Hkv, group, hd) -> (B*Hkv, group, hd): row r serves kv head
    # r % Hkv of batch r // Hkv
    qf = q.reshape(b, hkv, group, hd).reshape(b * hkv, group, hd)

    def kv_index(s, bh, j, table_ref, lengths_ref):
        return (table_ref[bh // hkv, s * bps + j], 0, bh % hkv, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(num_splits, b * hkv, bps),
        in_specs=[
            pl.BlockSpec((1, group, hd), lambda s, bh, j, t, ln: (bh, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd), kv_index),
            pl.BlockSpec((1, bs, 1, hd), kv_index),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, group, hd), lambda s, bh, j, t, ln: (s, bh, 0, 0)),
            pl.BlockSpec((1, 1, group, 1), lambda s, bh, j, t, ln: (s, bh, 0, 0)),
            pl.BlockSpec((1, 1, group, 1), lambda s, bh, j, t, ln: (s, bh, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((group, hd), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
    )
    o_part, m_part, l_part = pl.pallas_call(
        functools.partial(_kernel, hkv=hkv, bps=bps, bs=bs, group=group,
                          window=window, scale=scale),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((num_splits, b * hkv, group, hd), jnp.float32),
            jax.ShapeDtypeStruct((num_splits, b * hkv, group, 1), jnp.float32),
            jax.ShapeDtypeStruct((num_splits, b * hkv, group, 1), jnp.float32),
        ],
        interpret=interpret,
    )(table, lengths, qf, k_pool, v_pool)

    # online-softmax merge across splits (all-empty slots stay zero)
    m_star = jnp.max(m_part, axis=0, keepdims=True)      # (1, BH, g, 1)
    alpha = jnp.exp(m_part - jnp.maximum(m_star, NEG_INF / 2))
    l_tot = jnp.sum(alpha * l_part, axis=0)              # (BH, g, 1)
    acc = jnp.sum(alpha * o_part, axis=0)                # (BH, g, hd)
    out = acc / jnp.maximum(l_tot, 1e-30)
    return out.reshape(b, hkv, group, hd).reshape(b, hq, hd).astype(q.dtype)
