"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.newton_schulz import NS_COEFFS

from .lowp import q8_scale


def dct_project_ref(g: jax.Array, q: jax.Array, out_dtype=None):
    """``g``: (..., m, n); ``q``: (n, n). Returns (S, per-column sq-norms)."""
    s32 = g.astype(jnp.float32) @ q.astype(jnp.float32)
    norms = jnp.sum(s32 * s32, axis=-2)
    return s32.astype(out_dtype or g.dtype), norms


def colgather_matmul_ref(b: jax.Array, qt: jax.Array, idx: jax.Array,
                         out_dtype=None):
    """``b``: (..., m, r); ``qt``: (n, n); ``idx``: (..., r) per-layer."""
    gathered = jnp.take(qt, idx, axis=0).astype(jnp.float32)  # (..., r, n)
    out = b.astype(jnp.float32) @ gathered
    return out.astype(out_dtype or b.dtype)


def colgather_matmul_dual_ref(b1, b2, qt, idx, out_dtype=None):
    gathered = jnp.take(qt, idx, axis=0).astype(jnp.float32)
    o1 = b1.astype(jnp.float32) @ gathered
    o2 = b2.astype(jnp.float32) @ gathered
    dt = out_dtype or b1.dtype
    return o1.astype(dt), o2.astype(dt)


def ns_iteration_ref(x: jax.Array) -> jax.Array:
    a, b, c = NS_COEFFS
    xf = x.astype(jnp.float32)
    gram = xf @ xf.T
    poly = b * gram + c * gram @ gram
    return (a * xf + poly @ xf).astype(x.dtype)


def newton_schulz_ref(x: jax.Array, steps: int = 5, eps: float = 1e-7):
    wide = x.shape[0] <= x.shape[1]
    xw = (x if wide else x.T).astype(jnp.float32)
    xw = xw / (jnp.linalg.norm(xw) + eps)
    for _ in range(steps):
        xw = ns_iteration_ref(xw)
    out = xw.astype(x.dtype)
    return out if wide else out.T


def quantize_ef_ref(x: jax.Array):
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = q8_scale(amax)   # max(amax/127, tiny) — lockstep with the kernel
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequant_add_ef_ref(g: jax.Array, q: jax.Array, scale: jax.Array):
    return (g.astype(jnp.float32) + q.astype(jnp.float32) * scale).astype(g.dtype)


def flash_decode_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                     block_table: jax.Array, lengths: jax.Array, *,
                     window: int | None = None):
    """Dense-attention oracle for the paged flash-decode kernel.

    Gathers each slot's blocks into a dense (B, S, Hkv, hd) cache
    through the block table, then runs plain fp32 masked softmax
    attention. q: (B, Hq, hd); pools: (NB, bs, Hkv, hd); block_table:
    (B, MAXB) int32; lengths: (B,). Returns (B, Hq, hd) in q.dtype.
    """
    b, hq, hd = q.shape
    nb, bs, hkv, _ = k_pool.shape
    group = hq // hkv
    # densify: (B, MAXB, bs, Hkv, hd) -> (B, S, Hkv, hd)
    k = jnp.take(k_pool, block_table, axis=0).reshape(b, -1, hkv, hd)
    v = jnp.take(v_pool, block_table, axis=0).reshape(b, -1, hkv, hd)
    s = k.shape[1]
    qg = q.reshape(b, hkv, group, hd).astype(jnp.float32)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.float32(hd))
    pos = jnp.arange(s)[None, :]
    mask = pos < lengths[:, None]
    if window is not None:
        mask &= pos >= (lengths[:, None] - window)
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(mask[:, None, None, :], probs, 0.0)  # length-0 slots
    out = jnp.einsum("bhgs,bshd->bhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, hq, hd).astype(q.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int | None = None):
    """Plain softmax attention oracle. q: (B,S,Hq,hd); k,v: (B,S,Hkv,hd)."""
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    kx = jnp.repeat(k, group, axis=2)
    vx = jnp.repeat(v, group, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        kx.astype(jnp.float32)) / jnp.sqrt(float(hd))
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= qp >= kp
    if window is not None:
        mask &= qp - kp < window
    scores = jnp.where(mask[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vx.astype(jnp.float32))
    return out.astype(q.dtype)
