"""Flash attention Pallas kernel (TPU target) — GQA + causal + window.

The §Perf/§Roofline analysis shows the train/prefill memory term is
dominated by blockwise-attention score traffic: the pure-JAX path
materializes (bq, bk) score tiles in HBM every chunk. This kernel keeps
the online-softmax state (acc, running max m, running sum l) resident in
VMEM across the whole KV sweep, so HBM sees only Q/K/V/O — the classic
flash-attention data movement, tiled for the MXU.

Grid ``(B*Hq, nq, nk)`` with the KV dimension innermost (sequential on
TPU, accumulator pattern). GQA is handled in the K/V index maps
(kv_head = q_head // group) — no K/V expansion in HBM. Fully-masked
causal/window blocks are skipped with ``pl.when`` (no MXU work), matching
the causal ~2x flop saving the pure-JAX path lacks.

Validated against ``ref.flash_attention_ref`` in interpret mode
(tests/test_kernels.py sweeps shapes/dtypes/causal/window).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            nk: int, bq: int, bk: int, causal: bool, window: int | None,
            scale: float, out_dtype):
    i = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = i * bq
    k_start = kb * bk
    # block-level skip: fully above the diagonal (causal) or fully outside
    # the sliding window
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + bq - 1)
    if window is not None:
        run = jnp.logical_and(run, k_start + bk - 1 >= q_start - window + 1)

    @pl.when(run)
    def _block():
        q = q_ref[0].astype(jnp.float32)              # (bq, hd)
        k = k_ref[0].astype(jnp.float32)              # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, q_pos >= k_pos)
        if window is not None:
            mask = jnp.logical_and(mask, q_pos - k_pos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                           # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)                # (bq, 1)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)              # (bk, hd)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kb == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: (B, S, Hq, hd); k, v: (B, S, Hkv, hd) with Hq % Hkv == 0.
    Returns (B, S, Hq, hd) in q.dtype. S must divide by the blocks
    (production shapes are powers of two; pad otherwise)."""
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    assert hq % hkv == 0 and k.shape == v.shape == (b, s, hkv, hd)
    group = hq // hkv
    bq = min(block_q, s)
    bk = min(block_k, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    nq, nk = s // bq, s // bk
    scale = 1.0 / math.sqrt(hd)

    # (B*H, S, hd) layout so the grid's first axis walks batch x heads
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, s, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, hd)

    def kv_index(h, i, kb):
        # q-head h -> kv row (batch * hkv + q_head // group)
        return ((h // hq) * hkv + (h % hq) // group, kb, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, bq=bq, bk=bk, causal=causal,
                          window=window, scale=scale, out_dtype=q.dtype),
        grid=(b * hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda h, i, kb: (h, i, 0)),
            pl.BlockSpec((1, bk, hd), kv_index),
            pl.BlockSpec((1, bk, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda h, i, kb: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, s, hd).transpose(0, 2, 1, 3)
