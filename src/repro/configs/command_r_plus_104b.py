"""command-r-plus-104b [dense] — GQA, no biases.

64 layers, d_model=12288, 96 heads (GQA kv=8), d_ff=33792, vocab=256000
[hf:CohereForAI/c4ai-command-r-plus]. The widest d_model of the assigned
pool — the DCT basis here is 12288x12288 (one per device, bf16 = 302 MB,
still far below Dion-style per-layer projections; see DESIGN.md §7.3).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab_size=256000,
    schedule=((("attn",), 64),),
    rope_theta=75_000_000.0,
    param_dtype="bfloat16",
    train_microbatch=64,     # §Perf iter-4
    attn_sp=True,            # §Perf iter-1: kv=8 doesn't divide tp
    decode_layout="decode_tp",  # §Perf iter-6
)

SMOKE = CONFIG.reduced()
