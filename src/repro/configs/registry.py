"""``--arch <id>`` lookup for every assigned architecture (+ paper models)."""
from __future__ import annotations

from . import (
    command_r_plus_104b,
    deepseek_moe_16b,
    deepseek_v3_671b,
    gemma3_27b,
    jamba15_large_398b,
    llama32_vision_90b,
    llama_paper,
    phi3_mini_3p8b,
    qwen25_32b,
    rwkv6_1p6b,
    whisper_large_v3,
)

_MODULES = {
    "whisper-large-v3": whisper_large_v3,
    "llama-3.2-vision-90b": llama32_vision_90b,
    "deepseek-v3-671b": deepseek_v3_671b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "jamba-1.5-large-398b": jamba15_large_398b,
    "rwkv6-1.6b": rwkv6_1p6b,
    "gemma3-27b": gemma3_27b,
    "qwen2.5-32b": qwen25_32b,
    "phi3-mini-3.8b": phi3_mini_3p8b,
    "command-r-plus-104b": command_r_plus_104b,
}

ARCHS = {name: mod.CONFIG for name, mod in _MODULES.items()}
SMOKES = {name: mod.SMOKE for name, mod in _MODULES.items()}

# the paper's own models, addressable the same way
ARCHS["llama-30m"] = llama_paper.LLAMA_30M
ARCHS["llama-350m"] = llama_paper.LLAMA_350M
ARCHS["llama-800m"] = llama_paper.LLAMA_800M
ARCHS["llama-1.3b"] = llama_paper.LLAMA_1_3B

ASSIGNED = tuple(_MODULES)          # the 10 graded architectures


def get_config(arch: str, smoke: bool = False):
    table = SMOKES if smoke else ARCHS
    if arch not in table:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(table)}")
    return table[arch]


def list_archs() -> list[str]:
    return sorted(ARCHS)
