"""llama-3.2-vision-90b [vlm] — cross-attention image layers every 5th.

100 layers, d_model=8192, 64 heads (GQA kv=8), d_ff=28672, vocab=128256
[hf:meta-llama/Llama-3.2-*-Vision]. The vision tower is stubbed: the input
spec supplies precomputed (B, n_image_tokens, d_model) patch embeddings.
Every 5th layer is a gated cross-attention block (tanh-gated attn + MLP,
the Llama-3.2 adapter recipe).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    schedule=((("attn", "attn", "attn", "attn", "cross"), 20),),
    n_image_tokens=6400,            # 4 tiles x 1600 patches (stub frontend)
    rope_theta=500000.0,
    param_dtype="bfloat16",
    train_microbatch=64,     # §Perf iter-4
    attn_sp=True,            # §Perf iter-1: kv=8 doesn't divide tp
    decode_layout="decode_tp",  # §Perf iter-6
)

SMOKE = CONFIG.reduced()
