"""gemma3-27b [dense] — 5:1 local:global attention, 128k context.

62 layers, d_model=5376, 32 heads (GQA kv=16), d_ff=21504, vocab=262144
[hf:google/gemma-3-27b]. Sliding window 1024 on local layers; qk-norm; tied
embeddings. 62 = 10 x (5 local + 1 global) + 2 local. The 5/6 local share
makes long_500k decode near-linear (only 10 global layers touch the full
cache), which is why this arch runs the long-context cell.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    schedule=(
        (("local", "local", "local", "local", "local", "attn"), 10),
        (("local", "local"), 1),
    ),
    sliding_window=1024,
    use_qk_norm=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    param_dtype="bfloat16",
    train_microbatch=32,
    # decode_layout stays fsdp_tp: iter-6 REFUTED here (+419% — kv16
    # divides tp, baseline decode was already shard-local; EXPERIMENTS §Perf)
)

SMOKE = CONFIG.reduced(sliding_window=8)
