"""whisper-large-v3 [audio] — encoder-decoder, conv frontend stubbed.

32 encoder + 32 decoder layers, d_model=1280, 20 heads (kv=20), d_ff=5120,
vocab=51866 [arXiv:2212.04356]. ``input_specs`` supplies precomputed
(B, 1500, d) frame embeddings in place of the mel+conv frontend (stub per
brief). Decoder positions use RoPE instead of Whisper's learned absolute
embeddings so the decoder is shape-polymorphic to the 32k decode shape
(deviation recorded in DESIGN.md).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    schedule=((("dec",), 32),),
    encoder_layers=32,
    encoder_seq=1500,
    norm_eps=1e-5,
    param_dtype="float32",
    train_microbatch=64,
    layout="pure_dp",        # §Perf iter-5: 1.5B fits replicated
)

SMOKE = CONFIG.reduced(schedule=((("dec",), 2),))
