"""Architecture config registry: ``get_config(arch_id)`` / ``--arch <id>``."""
from __future__ import annotations

from .registry import ARCHS, get_config, list_archs
from .shapes import SHAPES, cell_applicable, input_specs, skip_reason

__all__ = ["ARCHS", "get_config", "list_archs", "SHAPES", "input_specs",
           "cell_applicable", "skip_reason"]
