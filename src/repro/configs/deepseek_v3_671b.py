"""deepseek-v3-671b [moe] — MLA + 1 shared + 256 routed top-8 + MTP.

61 layers (first 3 dense), d_model=7168, 128 MLA heads, vocab=129280, MoE
256 experts top-8 with expert hidden 2048 [arXiv:2412.19437]. The brief's
``d_ff=2048`` is the routed-expert hidden size; the three dense layers use
the model's published dense d_ff=18432. MLA dims are the published ones
(q_lora 1536, kv_lora 512, nope 128, rope 64, v 128). MTP enabled.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,                     # dense layers (3)
    vocab_size=129280,
    schedule=((("mla_dense",), 3), (("mla_moe",), 58)),
    n_experts=256,
    n_shared_experts=1,
    moe_top_k=8,
    moe_d_ff=2048,                  # per the brief: routed expert hidden
    shared_d_ff=2048,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    mtp=True,
    param_dtype="bfloat16",
    train_microbatch=64,     # §Perf iter-4: halves FSDP regather/grad-AR
    decode_layout="decode_tp",  # §Perf iter-6
)

SMOKE = CONFIG.reduced(schedule=((("mla_dense",), 1), (("mla_moe",), 1)))
