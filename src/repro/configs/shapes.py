"""Assigned input shapes x applicability + ShapeDtypeStruct input specs.

The four LM shapes from the brief. ``input_specs(cfg, shape)`` returns the
exact pytree of jax.ShapeDtypeStruct the corresponding step function is
lowered with — weak-type-correct, shardable, zero allocation. Modality
frontends are stubs: whisper gets precomputed (B, 1500, d) frame
embeddings; the VLM gets (B, n_image_tokens, d) patch embeddings.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def skip_reason(cfg, shape_name: str) -> str | None:
    """None if the (arch, shape) cell runs; otherwise why it is skipped
    (recorded in DESIGN.md / EXPERIMENTS.md per the brief)."""
    spec = SHAPES[shape_name]
    if spec.name == "long_500k" and not cfg.supports_long_context:
        return ("pure full-attention arch: no sub-quadratic path at 524k "
                "context (skip noted in DESIGN.md §6)")
    return None


def cell_applicable(cfg, shape_name: str) -> bool:
    return skip_reason(cfg, shape_name) is None


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _modality_extras(cfg, batch: int) -> dict:
    extras = {}
    if cfg.encoder_layers:
        extras["frames"] = _sds((batch, cfg.encoder_seq, cfg.d_model),
                                jnp.dtype(cfg.compute_dtype))
    if cfg.n_image_tokens:
        extras["image_embeds"] = _sds((batch, cfg.n_image_tokens, cfg.d_model),
                                      jnp.dtype(cfg.compute_dtype))
    return extras


def batch_specs(cfg, shape_name: str, *, with_targets: bool = True) -> dict:
    """Input batch pytree for train/prefill entry points."""
    spec = SHAPES[shape_name]
    b, s = spec.global_batch, spec.seq_len
    out = {"tokens": _sds((b, s), jnp.int32)}
    if with_targets and spec.kind == "train":
        out["targets"] = _sds((b, s), jnp.int32)
    out.update(_modality_extras(cfg, b))
    return out


def decode_specs(cfg, shape_name: str) -> dict:
    """Inputs for serve_step: one new token against a seq_len cache."""
    from repro.models.transformer import init_cache

    spec = SHAPES[shape_name]
    b, s = spec.global_batch, spec.seq_len
    cache = jax.eval_shape(lambda: init_cache(cfg, b, s))
    return {
        "token": _sds((b,), jnp.int32),
        "pos": _sds((), jnp.int32),
        "cache": cache,
    }


def input_specs(cfg, shape_name: str) -> dict:
    spec = SHAPES[shape_name]
    if spec.kind == "decode":
        return decode_specs(cfg, shape_name)
    return batch_specs(cfg, shape_name)
