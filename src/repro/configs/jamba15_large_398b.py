"""jamba-1.5-large-398b [hybrid] — Mamba + attention 1:7, MoE every other.

72 layers = 9 super-blocks of 8, d_model=8192, 64 heads (kv=8), d_ff=24576,
vocab=65536, MoE 16 experts top-2 [arXiv:2403.19887]. Each super-block has
one attention layer (index 4) and seven Mamba layers; MoE replaces the MLP
on every odd layer.
"""
from repro.models.config import ModelConfig

_PATTERN = ("mamba_dense", "mamba_moe", "mamba_dense", "mamba_moe",
            "attn", "mamba_moe", "mamba_dense", "mamba_moe")

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    schedule=((_PATTERN, 9),),
    n_experts=16,
    moe_top_k=2,
    moe_d_ff=24576,
    mamba_expand=2,
    mamba_state=16,
    mamba_conv=4,
    param_dtype="bfloat16",
    train_microbatch=64,     # §Perf iter-4
    attn_sp=True,            # §Perf iter-1: kv=8 doesn't divide tp
    decode_layout="decode_tp",  # §Perf iter-6
)

SMOKE = CONFIG.reduced()
