"""phi3-mini-3.8b [dense] — RoPE + SwiGLU + (degenerate) GQA.

32 layers, d_model=3072, 32 heads (kv=32 — plain MHA), d_ff=8192,
vocab=32064 [arXiv:2404.14219].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    schedule=((("attn",), 32),),
    param_dtype="float32",
    train_microbatch=64,
)

SMOKE = CONFIG.reduced()
