"""qwen2.5-32b [dense] — GQA with QKV bias.

64 layers, d_model=5120, 40 heads (GQA kv=8), d_ff=27648, vocab=152064
[hf:Qwen/Qwen2.5-32B].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab_size=152064,
    schedule=((("attn",), 64),),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    param_dtype="bfloat16",
    train_microbatch=32,
    attn_sp=True,            # §Perf iter-1: 40q/8kv heads don't divide tp
    decode_layout="decode_tp",  # §Perf iter-6
)

SMOKE = CONFIG.reduced()
