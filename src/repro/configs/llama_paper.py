"""The paper's own pre-training models: Llama 30M / 350M / 800M / 1.3B.

Sized to the paper's reported (params, d_model) pairs — §3: 350M (d=1024),
800M (d=2048), 1.3B (d=2048), plus the 30M (d=640) model used for the
projection-error study (App. F). Sequence length 512, C4-style next-token
objective (synthetic deterministic data in this repo).
"""
from repro.models.config import ModelConfig


def _llama(name, layers, d, heads, d_ff, vocab=32000):
    return ModelConfig(
        name=name,
        family="dense",
        d_model=d,
        n_heads=heads,
        n_kv_heads=heads,
        d_ff=d_ff,
        vocab_size=vocab,
        schedule=((("attn",), layers),),
        rope_theta=1e4,
        param_dtype="float32",
        q_chunk=512,
        kv_chunk=512,
    )


LLAMA_30M = _llama("llama-30m", 6, 640, 10, 1728)
LLAMA_350M = _llama("llama-350m", 24, 1024, 16, 2816)
LLAMA_800M = _llama("llama-800m", 16, 2048, 16, 5504)
LLAMA_1_3B = _llama("llama-1.3b", 24, 2048, 16, 5504)

CONFIG = LLAMA_350M
SMOKE = CONFIG.reduced()
