"""deepseek-moe-16b [moe] — fine-grained experts, 2 shared + 64 routed top-6.

28 layers (first dense), d_model=2048, 16 heads (kv=16), expert hidden 1408,
vocab=102400 [arXiv:2401.06066]. The first layer is the published dense
layer (d_ff=10944); shared experts total 2x1408=2816 hidden.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,                     # dense layer 0
    vocab_size=102400,
    schedule=((("attn",), 1), (("attn_moe",), 27)),
    n_experts=64,
    n_shared_experts=2,
    moe_top_k=6,
    moe_d_ff=1408,
    shared_d_ff=2816,
    param_dtype="float32",
    train_microbatch=64,
)

SMOKE = CONFIG.reduced(schedule=((("attn",), 1), (("attn_moe",), 1)))
