"""rwkv6-1.6b "Finch" [ssm] — attention-free, data-dependent decay.

24 layers, d_model=2048, d_ff=7168, vocab=65536 [arXiv:2404.05892].
Head size 64 (32 WKV heads), decay LoRA rank 64. Constant-size state makes
every decode shape (incl. long_500k) O(1) per token.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    d_model=2048,
    n_heads=32,                     # = d_model / rwkv_head_size
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    schedule=((("rwkv",), 24),),
    rwkv_head_size=64,
    rwkv_decay_lora=64,
    norm_eps=1e-5,
    param_dtype="float32",
    train_microbatch=64,
    layout="pure_dp",        # §Perf iter-5: 1.6B fits replicated
)

SMOKE = CONFIG.reduced(schedule=((("rwkv",), 2),))
