"""ServeEngine end-to-end generation across model families.

The decode-path unit tests check one-step logits parity; these check the
full prefill -> N-token autoregressive loop per family, including the
modality stubs (whisper frames, VLM patch embeddings), ring-buffer local
caches (gemma), SSM/RWKV recurrent caches, and MLA latent caches.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import SMOKES
from repro.models import transformer as T
from repro.serve.engine import ServeEngine

FAMS = ["whisper-large-v3", "llama-3.2-vision-90b", "deepseek-v3-671b",
        "gemma3-27b", "rwkv6-1.6b", "jamba-1.5-large-398b"]


def _batch(cfg, b, s, rng):
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if cfg.encoder_layers:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.encoder_seq, cfg.d_model)),
            jnp.float32)
    if cfg.n_image_tokens:
        batch["image_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_image_tokens, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", FAMS)
def test_generate_matches_stepwise_forward(arch):
    """Greedy generation == argmax over repeated full forwards (the
    strongest cache-correctness check: every generated token feeds back)."""
    cfg = SMOKES[arch]
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b, s, new = 2, 6, 4
    batch = _batch(cfg, b, s, rng)

    eng = ServeEngine(cfg, params, max_len=s + new)
    got = np.asarray(eng.generate(batch, max_new_tokens=new))

    # oracle: grow the sequence with full forwards
    toks = batch["tokens"]
    for _ in range(new):
        fb = dict(batch)
        fb["tokens"] = toks
        logits, _ = T.forward(params, fb, cfg)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        toks = jnp.concatenate([toks, nxt[:, None]], axis=1)
    want = np.asarray(toks[:, s:])
    np.testing.assert_array_equal(got, want)


def test_generate_eos_early_exit():
    cfg = SMOKES["qwen2.5-32b"]
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    batch = _batch(cfg, 2, 4, rng)
    eng = ServeEngine(cfg, params, max_len=32)
    out = eng.generate(batch, max_new_tokens=8, eos_id=0)
    assert out.shape[0] == 2 and 1 <= out.shape[1] <= 8
