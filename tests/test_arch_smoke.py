"""Per-architecture smoke tests (brief deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED config
of the same family, run one forward and one train step on CPU, assert
output shapes and no NaNs; check prefill+decode agrees with the full
forward (cache correctness) where the family supports decode.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ASSIGNED, SMOKES
from repro.models import transformer as T


def _batch(cfg, b, s, rng):
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if cfg.encoder_layers:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.encoder_seq, cfg.d_model)),
            jnp.float32)
    if cfg.n_image_tokens:
        batch["image_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_image_tokens, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_shapes_no_nan(arch):
    cfg = SMOKES[arch]
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b, s = 2, 16
    logits, aux = T.forward(params, _batch(cfg, b, s, rng), cfg)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert jnp.isfinite(aux["moe_aux"])


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_no_nan(arch):
    cfg = SMOKES[arch]
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    b, s = 2, 8
    batch = _batch(cfg, b, s + 1, rng)
    inputs = dict(batch)
    inputs["tokens"] = batch["tokens"][:, :-1]
    targets = batch["tokens"][:, 1:]

    def loss_fn(p):
        logits, aux = T.forward(p, inputs, cfg)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(logp, targets[..., None], -1).mean()
        return nll + aux["moe_aux"]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert jnp.isfinite(loss)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat)
    # at least one grad actually nonzero
    assert any(float(jnp.abs(g).max()) > 0 for g in flat)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_decode_matches_forward(arch):
    cfg = SMOKES[arch]
    params = T.init_params(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(2)
    b, s = 2, 8
    batch = _batch(cfg, b, s + 1, rng)
    toks = batch["tokens"]
    full_logits, _ = T.forward(params, batch, cfg)

    pb = dict(batch)
    pb["tokens"] = toks[:, :s]
    last_logits, cache, _ = T.prefill(params, pb, cfg, max_len=s + 4)
    np.testing.assert_allclose(np.asarray(last_logits),
                               np.asarray(full_logits[:, s - 1]),
                               atol=2e-3, rtol=1e-3)
    lg, cache = T.decode_step(params, cache, toks[:, s], jnp.int32(s), cfg)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full_logits[:, s]),
                               atol=2e-3, rtol=1e-3)


def test_full_configs_have_exact_dims():
    """The FULL configs carry the exact dims from the brief (they are only
    lowered via ShapeDtypeStructs, never allocated, in the dry-run)."""
    from repro.configs.registry import ARCHS

    expect = {
        "whisper-large-v3": (1280, 20, 20, 5120, 51866, 32),
        "llama-3.2-vision-90b": (8192, 64, 8, 28672, 128256, 100),
        "deepseek-v3-671b": (7168, 128, 128, 18432, 129280, 61),
        "deepseek-moe-16b": (2048, 16, 16, 10944, 102400, 28),
        "jamba-1.5-large-398b": (8192, 64, 8, 24576, 65536, 72),
        "rwkv6-1.6b": (2048, 32, 32, 7168, 65536, 24),
        "gemma3-27b": (5376, 32, 16, 21504, 262144, 62),
        "qwen2.5-32b": (5120, 40, 8, 27648, 152064, 64),
        "phi3-mini-3.8b": (3072, 32, 32, 8192, 32064, 32),
        "command-r-plus-104b": (12288, 96, 8, 33792, 256000, 64),
    }
    for arch, (d, h, kv, ff, vocab, layers) in expect.items():
        cfg = ARCHS[arch]
        assert cfg.d_model == d, arch
        assert cfg.n_heads == h, arch
        assert cfg.n_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == vocab, arch
        assert cfg.n_layers == layers, arch
    # MoE dims per the brief
    from repro.configs.registry import ARCHS as A
    assert (A["deepseek-v3-671b"].n_experts, A["deepseek-v3-671b"].moe_top_k,
            A["deepseek-v3-671b"].moe_d_ff) == (256, 8, 2048)
    assert (A["deepseek-moe-16b"].n_experts, A["deepseek-moe-16b"].moe_top_k,
            A["deepseek-moe-16b"].moe_d_ff) == (64, 6, 1408)
    assert (A["jamba-1.5-large-398b"].n_experts,
            A["jamba-1.5-large-398b"].moe_top_k) == (16, 2)
