"""End-to-end dry-run smoke: lower + compile real cells on the production
mesh in a subprocess (512 forced host devices). Covers the deliverable-(e)
path continuously — sharding or lowering regressions fail here, not in the
overnight sweep."""
import json
import os
import subprocess
import sys
import textwrap

_SCRIPT = textwrap.dedent("""
    import json
    from repro.launch.dryrun import run_cell
    recs = [
        run_cell("phi3-mini-3.8b", "decode_32k", verbose=False),
        run_cell("rwkv6-1.6b", "train_4k", verbose=False),
        run_cell("rwkv6-1.6b", "long_500k", verbose=False),
        run_cell("phi3-mini-3.8b", "long_500k", verbose=False),  # skip path
    ]
    print("JSON" + json.dumps(recs))
""")


def test_dryrun_cells_compile():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-4000:]
    payload = [l for l in proc.stdout.splitlines() if l.startswith("JSON")]
    assert payload, proc.stdout
    recs = json.loads(payload[0][4:])
    ok = {(r["arch"], r["shape"]): r["status"] for r in recs}
    assert ok[("phi3-mini-3.8b", "decode_32k")] == "ok"
    assert ok[("rwkv6-1.6b", "train_4k")] == "ok"
    assert ok[("rwkv6-1.6b", "long_500k")] == "ok"
    # pure-full-attention arch skips the 524k cell, per the brief
    assert ok[("phi3-mini-3.8b", "long_500k")] == "skip"
    # roofline terms present and positive for the train cell
    train = next(r for r in recs
                 if (r["arch"], r["shape"]) == ("rwkv6-1.6b", "train_4k"))
    assert train["compute_s"] > 0 and train["bytes_per_device"] > 0
    assert train["mesh"] == "pod1x16x16" and train["n_devices"] == 256
