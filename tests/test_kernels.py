"""Per-kernel allclose tests: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dct import dct2_matrix
from repro.kernels import ref
from repro.kernels.colgather_matmul import colgather_matmul
from repro.kernels.dct_project import dct_project
from repro.kernels.newton_schulz import newton_schulz_pallas, ns_iteration
from repro.kernels.quant_ef import dequant_add_ef, quantize_ef


def _rand(shape, dtype, seed=0, scale=1.0):
    x = np.random.default_rng(seed).standard_normal(shape) * scale
    return jnp.asarray(x.astype(np.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# dct_project: S = G @ Q fused with column norms
# ---------------------------------------------------------------------------
DCT_SHAPES = [(32, 64), (128, 128), (100, 96), (257, 130), (64, 512)]


@pytest.mark.parametrize("shape", DCT_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dct_project_matches_ref(shape, dtype):
    m, n = shape
    g = _rand((m, n), dtype, seed=m + n)
    q = dct2_matrix(n, dtype)
    s, norms = dct_project(g, q, block=(32, 64, 32), interpret=True)
    s_ref, norms_ref = ref.dct_project_ref(g, q)
    tol = 1e-5 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(np.asarray(s, np.float32),
                               np.asarray(s_ref, np.float32),
                               atol=tol * np.sqrt(n), rtol=tol)
    np.testing.assert_allclose(np.asarray(norms), np.asarray(norms_ref),
                               rtol=2e-5 if dtype == jnp.float32 else 0.1,
                               atol=1e-4)


def test_dct_project_padded_columns_rank_last():
    """Zero-padded columns must produce zero norms (never selected)."""
    g = _rand((40, 48), jnp.float32, seed=7)
    q = dct2_matrix(48)
    _, norms = dct_project(g, q, block=(32, 64, 32), interpret=True)
    assert norms.shape == (48,)
    assert float(norms.min()) > 0  # all real columns have positive energy


# ---------------------------------------------------------------------------
# colgather_matmul: O = b @ Q^T[idx, :]
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,n,r", [(64, 64, 8), (128, 96, 16), (50, 130, 10)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_colgather_matmul_matches_ref(m, n, r, dtype):
    b = _rand((m, r), dtype, seed=m)
    qt = jnp.asarray(np.asarray(dct2_matrix(n)).T).astype(dtype)
    idx = jnp.asarray(np.sort(np.random.default_rng(r).choice(n, r, replace=False))
                      ).astype(jnp.int32)
    out = colgather_matmul(b, qt, idx, block=(32, 64), interpret=True)
    out_ref = ref.colgather_matmul_ref(b, qt, idx)
    tol = 1e-5 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(out_ref, np.float32),
                               atol=tol * r, rtol=tol)


# ---------------------------------------------------------------------------
# batched (stacked-layer) kernel paths + fused dual back-projection
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("batch", [(3,), (2, 2)])
def test_dct_project_batched_matches_per_layer(batch):
    g = _rand((*batch, 40, 48), jnp.float32, seed=11)
    q = dct2_matrix(48)
    s, norms = dct_project(g, q, block=(32, 32, 32), interpret=True)
    assert s.shape == g.shape and norms.shape == (*batch, 48)
    gs = g.reshape((-1, 40, 48))
    for li in range(gs.shape[0]):
        s_l, n_l = dct_project(gs[li], q, block=(32, 32, 32), interpret=True)
        np.testing.assert_allclose(np.asarray(s.reshape((-1, 40, 48))[li]),
                                   np.asarray(s_l), atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(norms.reshape((-1, 48))[li]),
                                   np.asarray(n_l), rtol=2e-5, atol=1e-4)


def test_colgather_matmul_batched_per_layer_indices():
    L, m, n, r = 3, 50, 64, 8
    b = _rand((L, m, r), jnp.float32, seed=5)
    qt = jnp.asarray(np.asarray(dct2_matrix(n)).T)
    rng = np.random.default_rng(0)
    idx = jnp.asarray(np.stack([np.sort(rng.choice(n, r, replace=False))
                                for _ in range(L)])).astype(jnp.int32)
    out = colgather_matmul(b, qt, idx, block=(32, 32), interpret=True)
    out_ref = ref.colgather_matmul_ref(b, qt, idx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("shape", [(64, 96), (3, 50, 96)])
def test_colgather_matmul_dual_matches_two_singles(shape):
    from repro.kernels.colgather_matmul import colgather_matmul_dual

    *batch, m, n = shape
    r = 8
    b1 = _rand((*batch, m, r), jnp.float32, seed=1)
    b2 = _rand((*batch, m, r), jnp.float32, seed=2)
    qt = jnp.asarray(np.asarray(dct2_matrix(n)).T)
    rng = np.random.default_rng(3)
    idx = jnp.asarray(np.sort(rng.choice(n, (*batch, r), replace=True),
                              axis=-1)).astype(jnp.int32)
    o1, o2 = colgather_matmul_dual(b1, b2, qt, idx, block=(32, 32),
                                   interpret=True)
    s1 = colgather_matmul(b1, qt, idx, block=(32, 32), interpret=True)
    s2 = colgather_matmul(b2, qt, idx, block=(32, 32), interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(s1), atol=1e-6)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(s2), atol=1e-6)


def test_quant_ef_batched_roundtrip():
    x = _rand((3, 40, 32), jnp.float32, seed=13, scale=4.0)
    q, scale = quantize_ef(x, bm=16, interpret=True)
    assert q.shape == x.shape and scale.shape == (3, 40, 1)
    q_ref, scale_ref = ref.quantize_ef_ref(x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
    np.testing.assert_allclose(np.asarray(scale), np.asarray(scale_ref),
                               rtol=1e-6)
    g = _rand((3, 40, 32), jnp.float32, seed=14)
    out = dequant_add_ef(g, q, scale, bm=16, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.dequant_add_ef_ref(g, q, scale)),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# newton_schulz: fused iteration + full orthogonalization
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("r,m", [(8, 64), (16, 128), (16, 100)])
def test_ns_iteration_matches_ref(r, m):
    x = _rand((r, m), jnp.float32, seed=r * m, scale=0.1)
    y = ns_iteration(x, bm=32, interpret=True)
    y_ref = ref.ns_iteration_ref(x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("shape", [(64, 8), (8, 64), (100, 12)])
def test_newton_schulz_pallas_matches_ref(shape):
    x = _rand(shape, jnp.float32, seed=sum(shape))
    y = newton_schulz_pallas(x, steps=5, bm=32, interpret=True)
    y_ref = ref.newton_schulz_ref(x, steps=5)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-3, rtol=1e-3)


def test_newton_schulz_pallas_orthogonalizes():
    x = _rand((128, 16), jnp.float32, seed=3)
    y = np.asarray(newton_schulz_pallas(x, steps=10, bm=64, interpret=True),
                   dtype=np.float64)
    sv = np.linalg.svd(y, compute_uv=False)
    assert sv.max() < 1.35 and sv.min() > 0.3


# ---------------------------------------------------------------------------
# quant_ef
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(32, 64), (100, 48), (257, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_ef_matches_ref(shape, dtype):
    x = _rand(shape, dtype, seed=shape[0], scale=3.0)
    q, scale = quantize_ef(x, bm=32, interpret=True)
    q_ref, scale_ref = ref.quantize_ef_ref(x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
    np.testing.assert_allclose(np.asarray(scale), np.asarray(scale_ref),
                               rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dequant_add_matches_ref(dtype):
    g = _rand((64, 32), dtype, seed=1)
    resid = _rand((64, 32), jnp.float32, seed=2, scale=0.5)
    q, scale = ref.quantize_ef_ref(resid)
    out = dequant_add_ef(g, q, scale, bm=32, interpret=True)
    out_ref = ref.dequant_add_ef_ref(g, q, scale)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(out_ref, np.float32),
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-6)


def test_quant_roundtrip_bound():
    x = _rand((48, 96), jnp.float32, seed=9, scale=10.0)
    q, scale = quantize_ef(x, bm=16, interpret=True)
    y = np.asarray(q, np.float32) * np.asarray(scale)
    bound = np.abs(np.asarray(x)).max(axis=1, keepdims=True) / 254.0
    assert (np.abs(np.asarray(x) - y) <= bound * 1.01).all()


# ---------------------------------------------------------------------------
# integration: pallas pipeline == optimizer-core pipeline
# ---------------------------------------------------------------------------
def test_kernel_pipeline_matches_core_trion_math():
    """dct_project + top-r + colgather == core dct2/selection/back_project."""
    from repro.core.selection import back_project, dynamic_column_selection

    m, n, r = 96, 64, 8
    g = _rand((m, n), jnp.float32, seed=42)
    q = dct2_matrix(n)

    s_k, norms_k = dct_project(g, q, block=(32, 32, 32), interpret=True)
    idx_k = jnp.sort(jax.lax.top_k(norms_k, r)[1]).astype(jnp.int32)
    b_k = jnp.take(s_k, idx_k, axis=1)
    out_k = colgather_matmul(b_k, q.T, idx_k, block=(32, 32), interpret=True)

    s = g @ q
    idx, b = dynamic_column_selection(s, r)
    out = back_project(b, q, idx)

    np.testing.assert_array_equal(np.asarray(idx_k), np.asarray(idx))
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# flash attention kernel (GQA / causal / sliding-window)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,s,hq,hkv,hd,causal,window,dtype", [
    (2, 256, 4, 2, 64, True, None, jnp.float32),
    (1, 512, 8, 8, 128, True, None, jnp.bfloat16),
    (2, 256, 4, 1, 64, False, None, jnp.float32),
    (1, 512, 4, 2, 64, True, 128, jnp.float32),
    (1, 256, 2, 2, 32, True, 64, jnp.bfloat16),
    (3, 128, 6, 3, 64, True, None, jnp.float32),
])
def test_flash_attention_matches_ref(b, s, hq, hkv, hd, causal, window,
                                     dtype):
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ref import flash_attention_ref

    rng = np.random.default_rng(hash((b, s, hq)) % 2**31)
    q = jnp.asarray(rng.standard_normal((b, s, hq, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=128, block_k=128, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_flash_attention_matches_blockwise_model_path():
    """The kernel agrees with the pure-JAX model attention (same oracle)."""
    from repro.kernels.flash_attention import flash_attention
    from repro.models.layers import blockwise_attention

    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.standard_normal((2, 256, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 256, 2, 64)), jnp.float32)
    a = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                        interpret=True)
    b = blockwise_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)
