"""Property suite for Newton–Schulz orthogonalization (DESIGN.md §14).

Pins the contract the subspace-fused muon/trion/dion paths rely on:

- U^T U ≈ I on the small dimension for steps ∈ {3, 5}, across tall, wide,
  odd, stacked, and r>rows ("r > n slice") shapes.  The quintic NS5
  polynomial *bands* singular values rather than converging them, so the
  identity check splits into an off-diagonal bound (directional
  orthogonality, tight) and a singular-value band (the documented
  [0.3, 1.35] envelope shared with test_kernels / test_core_ns_ef).
- The Pallas batch-grid kernel matches the pure-jnp oracle in kernels/ref.py
  and the core implementation bitwise-close in interpret mode.
- fused_step.fused_newton_schulz is the identity composition when no ZeRO
  gather axes are given, and its "off" mode equals core newton_schulz.
- Near-singular inputs (rank-deficient, duplicated columns, tiny scales)
  stay finite and inside the singular-value envelope — the normalization
  eps must prevent NaN blowups on degenerate momentum factors.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fused_step
from repro.core.newton_schulz import NS_COEFFS, newton_schulz
from repro.kernels import ref
from repro.kernels.newton_schulz import newton_schulz_pallas, ns_iteration

# Shapes the optimizer families actually feed NS: tall low-rank factors
# (rows, r), wide orientation, scan-stacked leaves, odd dims, and the
# r > rows case (subspace rank exceeding the oriented row count, where
# the internal wide-orientation transpose must kick in).
SHAPES = [
    (64, 16),       # tall factor, the trion/muon-subspace common case
    (16, 64),       # wide (full-space muon on a wide oriented leaf)
    (3, 64, 16),    # scan-stacked
    (33, 80),       # odd dims
    (100, 12),      # tall, rows not a multiple of any block
    (8, 64),        # r > rows slice
]

# NS5 bands singular values instead of driving them to 1 (measured worst
# case over SHAPES x 5 seeds: offdiag <= 0.30, sv in [0.68, 1.14]).
OFFDIAG_TOL = 0.35
SV_LO, SV_HI = 0.3, 1.35


def _rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)


def _small_gram(y: np.ndarray) -> np.ndarray:
    """U^T U (or U U^T for wide y) over the small trailing dim, float64."""
    y = y.astype(np.float64)
    if y.shape[-2] >= y.shape[-1]:
        return np.einsum("...ki,...kj->...ij", y, y)
    return np.einsum("...ik,...jk->...ij", y, y)


def _singular_values(y: np.ndarray) -> np.ndarray:
    return np.linalg.svd(y.reshape(-1, *y.shape[-2:]).astype(np.float64),
                         compute_uv=False)


@pytest.mark.parametrize("steps", [3, 5])
@pytest.mark.parametrize("shape", SHAPES)
def test_gram_near_identity(shape, steps):
    y = np.asarray(newton_schulz(_rand(shape, seed=sum(shape)), steps=steps))
    g = _small_gram(y)
    off = np.abs(g * (1.0 - np.eye(g.shape[-1]))).max()
    assert off < OFFDIAG_TOL, (shape, steps, off)
    sv = _singular_values(y)
    assert SV_LO < sv.min() and sv.max() < SV_HI, (shape, steps,
                                                  sv.min(), sv.max())


@pytest.mark.parametrize("steps", [3, 5])
@pytest.mark.parametrize("shape", SHAPES)
def test_pallas_matches_core_and_ref(shape, steps):
    x = _rand(shape, seed=sum(shape) + steps)
    y_pl = np.asarray(newton_schulz_pallas(x, steps=steps, bm=32,
                                           interpret=True))
    y_core = np.asarray(newton_schulz(x, steps=steps))
    np.testing.assert_allclose(y_pl, y_core, atol=1e-3, rtol=1e-3)
    # ref.py oracle is 2D-only; vmap over stacked leaves
    f = lambda m: ref.newton_schulz_ref(m, steps=steps)
    for _ in range(x.ndim - 2):
        f = jax.vmap(f)
    np.testing.assert_allclose(y_pl, np.asarray(f(x)), atol=1e-3, rtol=1e-3)


def test_ns_iteration_matches_polynomial():
    """One fused Pallas iteration == a*X + (b*G + c*G^2) X literally."""
    a, b, c = NS_COEFFS
    x = _rand((16, 96), seed=7, scale=0.1)
    g = np.asarray(x, np.float64) @ np.asarray(x, np.float64).T
    want = a * np.asarray(x, np.float64) + (b * g + c * g @ g) @ np.asarray(
        x, np.float64)
    got = np.asarray(ns_iteration(x, bm=32, interpret=True))
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


def test_fused_newton_schulz_identity_without_axes():
    """gather_axes=None => plain core NS (the replicated/non-ZeRO path)."""
    x = _rand((3, 64, 16), seed=11)
    got = fused_step.fused_newton_schulz(x, steps=5, mode="off",
                                         gather_axes=None)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(newton_schulz(x, steps=5)))


@pytest.mark.parametrize("kind", ["rank_deficient", "dup_columns", "tiny"])
def test_near_singular_inputs_stay_finite(kind):
    x = _rand((64, 16), seed=3)
    if kind == "rank_deficient":
        x = x.at[:, 8:].set(0.0)
    elif kind == "dup_columns":
        x = x.at[:, 1].set(x[:, 0])
    else:
        x = x * 1e-20
    for steps in (3, 5):
        y = np.asarray(newton_schulz(x, steps=steps), np.float64)
        assert np.isfinite(y).all(), (kind, steps)
        sv = _singular_values(y)
        # zero directions must stay (near) zero, live ones inside the band
        assert sv.max() < SV_HI, (kind, steps, sv.max())
        if kind != "tiny":
            live = sv[sv > 1e-3]
            assert live.size and live.min() > SV_LO, (kind, steps)


def test_near_singular_hypothesis():
    """Property-based: any matrix with one direction scaled toward zero
    keeps finite output and banded live singular values."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(seed=st.integers(0, 2**16),
               log_scale=st.floats(-12.0, 0.0),
               steps=st.sampled_from([3, 5]))
    @hyp.settings(max_examples=25, deadline=None)
    def check(seed, log_scale, steps):
        x = _rand((32, 8), seed=seed)
        x = x.at[:, 0].set(x[:, 0] * 10.0 ** log_scale)
        y = np.asarray(newton_schulz(x, steps=steps), np.float64)
        assert np.isfinite(y).all()
        assert _singular_values(y).max() < SV_HI

    check()
