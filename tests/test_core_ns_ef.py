"""Tests for Newton-Schulz orthogonalization and quantized error feedback."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.error_feedback import dequantize_q8, quantize_q8, zeros_q8
from repro.core.newton_schulz import newton_schulz


@pytest.mark.parametrize("shape", [(8, 8), (32, 8), (8, 32), (3, 16, 4)])
def test_ns_singular_values_near_one(shape):
    rng = np.random.default_rng(0)
    x = rng.standard_normal(shape).astype(np.float32)
    y = np.asarray(newton_schulz(jnp.asarray(x), steps=10), dtype=np.float64)
    sv = np.linalg.svd(y, compute_uv=False)
    # NS5 converges to ~[0.7, 1.3] band quickly; 10 steps should tighten it
    assert sv.max() < 1.35
    assert sv.min() > 0.3


def test_ns_matches_uv_transpose():
    """For well-conditioned input, NS approximates U V^T of the SVD."""
    rng = np.random.default_rng(1)
    # construct matrix with singular values in [0.5, 1.5] (well-conditioned)
    u, _ = np.linalg.qr(rng.standard_normal((16, 16)))
    v, _ = np.linalg.qr(rng.standard_normal((8, 8)))
    s = np.diag(np.linspace(0.5, 1.5, 8))
    x = (u[:, :8] @ s @ v.T).astype(np.float32)
    y = np.asarray(newton_schulz(jnp.asarray(x), steps=12), dtype=np.float64)
    target = u[:, :8] @ v.T
    # KJ's quintic trades exactness for speed: singular values land in a
    # ~[0.7, 1.3] band, so compare up to that band, not exactly.
    assert np.abs(y - target).max() < 0.25
    # direction alignment: <y, target> / (|y||target|) should be ~1
    cos = (y * target).sum() / (np.linalg.norm(y) * np.linalg.norm(target))
    assert cos > 0.98


def test_ns_preserves_shape_and_dtype():
    x = jnp.ones((4, 12, 3), dtype=jnp.bfloat16)
    y = newton_schulz(x, steps=5)
    assert y.shape == x.shape and y.dtype == x.dtype


def test_ns_low_rank_orientation():
    """Trion's case: tall (m, r) factor — gram matrices must be r-sized and the
    result orthogonal-ish on the thin side."""
    rng = np.random.default_rng(2)
    x = rng.standard_normal((256, 16)).astype(np.float32)
    y = np.asarray(newton_schulz(jnp.asarray(x), steps=10), dtype=np.float64)
    gram = y.T @ y
    np.testing.assert_allclose(gram, np.eye(16), atol=0.35)


@settings(max_examples=30, deadline=None)
@given(m=st.integers(1, 16), n=st.integers(1, 32), seed=st.integers(0, 2**31 - 1),
       scale=st.floats(1e-3, 1e3))
def test_q8_roundtrip_error_bound(m, n, seed, scale):
    x = np.random.default_rng(seed).standard_normal((m, n)).astype(np.float32) * scale
    buf = quantize_q8(jnp.asarray(x))
    y = np.asarray(dequantize_q8(buf))
    # symmetric q8: |err| <= scale/2 = max|row|/254 per row
    row_bound = np.abs(x).max(axis=-1, keepdims=True) / 254.0 + 1e-12
    assert (np.abs(x - y) <= row_bound * 1.01).all()


def test_q8_zeros_and_zero_rows():
    buf = zeros_q8((4, 8))
    assert np.asarray(dequantize_q8(buf)).sum() == 0
    x = jnp.zeros((3, 5))
    buf = quantize_q8(x)
    np.testing.assert_array_equal(np.asarray(dequantize_q8(buf)), np.zeros((3, 5)))


def test_q8_batched():
    x = np.random.default_rng(3).standard_normal((2, 3, 4, 8)).astype(np.float32)
    buf = quantize_q8(jnp.asarray(x))
    assert buf.q.shape == x.shape and buf.scale.shape == (2, 3, 4, 1)
    y = np.asarray(dequantize_q8(buf))
    assert np.abs(x - y).max() < np.abs(x).max() / 100.0
