"""Behavioural tests for the full optimizer zoo.

A tiny two-layer MLP regression problem: every optimizer must drive the loss
down; the low-rank family must keep per-leaf state shapes consistent with the
paper's memory claims.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import OPTIMIZERS, apply_updates, get_optimizer
from repro.optim.projected_adam import ProjAdamLeaf
from repro.optim.trion import TrionLeaf

D_IN, D_H, D_OUT = 16, 32, 4


def _leaf(state, label, *path):
    """Per-leaf state in a matrix preset's ChainState: the presets are
    chain(partition({lowrank, full}), lr, decay), so member 0 holds the
    partition dict of params-shaped (holey) state trees."""
    node = state.leaves[0][label]
    for k in path:
        node = node[k]
    return node


def _init_params(key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "layer1": {"kernel": jax.random.normal(k1, (D_IN, D_H)) * 0.3},
        "layer2": {"kernel": jax.random.normal(k2, (D_H, D_OUT)) * 0.3},
        "out_bias": jnp.zeros((D_OUT,)),
        "stacked": {"kernel": jax.random.normal(k3, (3, D_H, D_H)) * 0.1},
    }


def _forward(params, x):
    h = jnp.tanh(x @ params["layer1"]["kernel"])
    for i in range(3):
        h = jnp.tanh(h @ params["stacked"]["kernel"][i] + h)
    return h @ params["layer2"]["kernel"] + params["out_bias"]


def _loss(params, x, y):
    return jnp.mean((_forward(params, x) - y) ** 2)


def _make_problem(seed=0):
    key = jax.random.PRNGKey(seed)
    kp, kx, kt = jax.random.split(key, 3)
    params = _init_params(kp)
    x = jax.random.normal(kx, (64, D_IN))
    target_params = _init_params(kt)
    y = _forward(target_params, x)
    return params, x, y


OPT_KW = {
    "adamw": {},
    "muon": {},
    "dion": {"rank": 8},
    "trion": {"rank": 8},
    "dct_adamw": {"rank": 8},
    "ldadamw": {"rank": 8},
    "galore": {"rank": 8, "update_interval": 5},
    "frugal": {"rank": 8, "update_interval": 5},
    "fira": {"rank": 8, "update_interval": 5},
}


@pytest.mark.parametrize("name", sorted(OPTIMIZERS))
def test_loss_decreases(name):
    params, x, y = _make_problem()
    opt = get_optimizer(name, lr=2e-2, weight_decay=0.0, **OPT_KW[name])
    state = opt.init(params)
    loss0 = float(_loss(params, x, y))

    @jax.jit
    def step(params, state):
        loss, grads = jax.value_and_grad(_loss)(params, x, y)
        updates, state = opt.update(grads, state, params)
        return apply_updates(params, updates), state, loss

    for _ in range(60):
        params, state, loss = step(params, state)
    final = float(_loss(params, x, y))
    assert np.isfinite(final)
    assert final < 0.5 * loss0, f"{name}: {loss0} -> {final}"


@pytest.mark.parametrize("name", sorted(OPTIMIZERS))
def test_state_structures_stable_under_jit(name):
    """update must be jit-stable: state_out structure == state_in structure."""
    params, x, y = _make_problem(1)
    opt = get_optimizer(name, lr=1e-2, **OPT_KW[name])
    state = opt.init(params)
    grads = jax.grad(_loss)(params, x, y)
    _, state2 = jax.jit(opt.update)(grads, state, params)
    assert (jax.tree_util.tree_structure(state)
            == jax.tree_util.tree_structure(state2))
    s1, s2 = jax.tree.leaves(state), jax.tree.leaves(state2)
    assert all(a.shape == b.shape and a.dtype == b.dtype for a, b in zip(s1, s2))


def test_trion_state_has_no_projection_matrices():
    """Paper claim: Trion stores momentum only — no per-layer basis."""
    params, *_ = _make_problem()
    opt = get_optimizer("trion", lr=1e-2, rank=8)
    state = opt.init(params)
    leaf = _leaf(state, "lowrank", "layer1", "kernel")
    assert isinstance(leaf, TrionLeaf)
    # momentum stored oriented (projected dim last) so ZeRO can row-shard it
    assert leaf.m.shape == (D_H, D_IN)
    # shared DCT basis stored once per distinct projected width; layer2's
    # (32, 4) min-dim is below the low-rank threshold -> full path, no basis
    assert set(state.bases) == {str(D_IN), str(D_H)}


def test_dct_adamw_state_is_lowrank_plus_indices():
    """Paper claim: m, v are (rows, r); per-layer extras are r int32 indices
    and an int8 EF buffer."""
    params, *_ = _make_problem()
    r = 8
    opt = get_optimizer("dct_adamw", lr=1e-2, rank=r)
    state = opt.init(params)
    leaf = _leaf(state, "lowrank", "layer1", "kernel")
    assert isinstance(leaf, ProjAdamLeaf)
    assert leaf.m.shape == (D_H, r) and leaf.v.shape == (D_H, r)  # oriented
    assert leaf.proj.dtype == jnp.int32 and leaf.proj.shape == (r,)
    assert leaf.ef.q.dtype == jnp.int8


def test_dion_stores_per_layer_basis():
    """Contrast: Dion must store a per-layer (cols, r) projection matrix."""
    params, *_ = _make_problem()
    opt = get_optimizer("dion", lr=1e-2, rank=8)
    state = opt.init(params)
    leaf = _leaf(state, "lowrank", "layer1", "kernel")
    assert leaf.q.shape == (D_IN, 8)  # oriented: min dim is D_IN


def test_stacked_leaf_gets_per_layer_indices():
    params, *_ = _make_problem()
    opt = get_optimizer("dct_adamw", lr=1e-2, rank=8)
    state = opt.init(params)
    leaf = _leaf(state, "lowrank", "stacked", "kernel")
    assert leaf.proj.shape == (3, 8)       # per stacked layer indices
    assert leaf.m.shape == (3, D_H, 8)


def test_bias_uses_full_adam_path():
    params, *_ = _make_problem()
    opt = get_optimizer("trion", lr=1e-2, rank=8)
    state = opt.init(params)
    from repro.optim.common import FullAdamLeaf
    assert isinstance(_leaf(state, "full", "out_bias"), FullAdamLeaf)


def test_trion_fft_matches_matmul_path():
    """Makhoul-projected Trion step == matmul-projected Trion step."""
    params, x, y = _make_problem(3)
    grads = jax.grad(_loss)(params, x, y)
    outs = []
    for method in ("matmul", "fft"):
        opt = get_optimizer("trion", lr=1e-2, rank=8, dct_method=method)
        state = opt.init(params)
        upd, _ = jax.jit(opt.update)(grads, state, params)
        outs.append(upd)
    a, b = jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])
    for u, v in zip(a, b):
        np.testing.assert_allclose(np.asarray(u), np.asarray(v),
                                   atol=2e-5, rtol=1e-4)


def test_dct_adamw_exact_rotation_flag_equivalent():
    """Permutation rotation == paper-literal matmul rotation.

    The matmul R has ~1e-7 off-diagonal leakage that Adam's 1/sqrt(v)
    amplifies over steps, so equivalence is asserted tightly on a single
    rotation application and loosely end-to-end."""
    params, x, y = _make_problem(4)
    results = []
    for exact in (False, True):
        p = jax.tree.map(lambda a: a, params)
        opt = get_optimizer("dct_adamw", lr=5e-2, rank=6, error_feedback=False,
                            exact_rotation_matmul=exact)
        state = opt.init(p)
        for _ in range(2):
            grads = jax.grad(_loss)(p, x, y)
            upd, state = jax.jit(opt.update)(grads, state, p)
            p = apply_updates(p, upd)
        results.append((p, state))
    # atol calibrated against the observed leakage amplification: a handful
    # of entries land at ~4e-3 after two steps at lr=5e-2
    for u, v in zip(jax.tree.leaves(results[0][0]), jax.tree.leaves(results[1][0])):
        np.testing.assert_allclose(np.asarray(u), np.asarray(v),
                                   atol=5e-3, rtol=2e-2)
    # first moments agree tightly (no 1/sqrt(v) amplification)
    m0 = _leaf(results[0][1], "lowrank", "layer1", "kernel").m
    m1 = _leaf(results[1][1], "lowrank", "layer1", "kernel").m
    np.testing.assert_allclose(np.asarray(m0), np.asarray(m1), atol=1e-5)


def test_galore_refresh_interval():
    """GaLore's projector state must change only at refresh steps."""
    params, x, y = _make_problem(5)
    opt = get_optimizer("galore", lr=1e-2, rank=4, update_interval=3)
    state = opt.init(params)
    bases = []
    p = params
    for _ in range(4):
        grads = jax.grad(_loss)(p, x, y)
        upd, state = jax.jit(opt.update)(grads, state, p)
        p = apply_updates(p, upd)
        bases.append(np.asarray(_leaf(state, "lowrank", "layer1", "kernel").proj))
    # refresh at steps 1 and 4 (t % 3 == 1); constant in between
    assert np.allclose(bases[0], bases[1]) and np.allclose(bases[1], bases[2])
    assert not np.allclose(bases[2], bases[3])


def test_frugal_dct_variant_runs():
    params, x, y = _make_problem(6)
    opt = get_optimizer("frugal", lr=1e-2, rank=4, projector="dct")
    state = opt.init(params)
    grads = jax.grad(_loss)(params, x, y)
    upd, state = jax.jit(opt.update)(grads, state, params)
    assert all(np.isfinite(np.asarray(u)).all() for u in jax.tree.leaves(upd))


@pytest.mark.parametrize("projector", ["svd", "dct", "random", "randperm"])
def test_frugal_all_projectors(projector):
    params, x, y = _make_problem(7)
    opt = get_optimizer("frugal", lr=1e-2, rank=4, projector=projector)
    state = opt.init(params)
    for _ in range(3):
        grads = jax.grad(_loss)(params, x, y)
        upd, state = jax.jit(opt.update)(grads, state, params)
        params = apply_updates(params, upd)
    assert all(np.isfinite(np.asarray(u)).all() for u in jax.tree.leaves(params))
