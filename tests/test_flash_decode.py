"""Paged flash-decode kernel vs the dense reference (interpret mode).

The kernel gathers K/V through the block table via scalar-prefetched
index maps and merges split-KV partials with online-softmax algebra;
the reference densifies the pool and runs plain softmax attention.
Sweeps the axes the serve engine exercises: GQA group sizes (incl.
MHA), odd head dims, partially-filled final blocks, caches longer than
one KV split, sliding windows, and inactive (length-0) rows.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode import flash_decode
from repro.kernels.ref import flash_decode_ref


def _case(rng, *, b, hq, hkv, hd, num_blocks, bs, maxb, lengths):
    q = jnp.asarray(rng.standard_normal((b, hq, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((num_blocks, bs, hkv, hd)),
                    jnp.float32)
    v = jnp.asarray(rng.standard_normal((num_blocks, bs, hkv, hd)),
                    jnp.float32)
    # distinct blocks per row, padded with zeros past each row's need
    table = np.zeros((b, maxb), np.int32)
    free = list(rng.permutation(num_blocks))
    for i, ln in enumerate(lengths):
        need = -(-ln // bs)
        table[i, :need] = [free.pop() for _ in range(need)]
    return q, k, v, jnp.asarray(table), jnp.asarray(lengths, jnp.int32)


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1), (6, 3)])
def test_gqa_group_sizes(hq, hkv):
    rng = np.random.default_rng(0)
    args = _case(rng, b=3, hq=hq, hkv=hkv, hd=16, num_blocks=24, bs=8,
                 maxb=4, lengths=[17, 32, 9])
    got = flash_decode(*args, interpret=True)
    want = flash_decode_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-6, rtol=2e-6)


@pytest.mark.parametrize("hd", [17, 31])
def test_odd_head_dims(hd):
    rng = np.random.default_rng(1)
    args = _case(rng, b=2, hq=4, hkv=2, hd=hd, num_blocks=16, bs=8,
                 maxb=3, lengths=[11, 24])
    got = flash_decode(*args, interpret=True)
    want = flash_decode_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-6, rtol=2e-6)


@pytest.mark.parametrize("length", [1, 7, 8, 9, 15, 16])
def test_partial_final_blocks(length):
    """Every fill level of the last block, incl. exactly-full."""
    rng = np.random.default_rng(2)
    args = _case(rng, b=1, hq=4, hkv=2, hd=16, num_blocks=8, bs=8,
                 maxb=2, lengths=[length])
    got = flash_decode(*args, interpret=True)
    want = flash_decode_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-6, rtol=2e-6)


@pytest.mark.parametrize("num_splits", [1, 2, 3, 6])
def test_split_kv_merge(num_splits):
    """Cache spanning several KV splits; the online-softmax merge of
    unnormalized partials must match the single-pass softmax."""
    rng = np.random.default_rng(3)
    args = _case(rng, b=2, hq=4, hkv=2, hd=16, num_blocks=16, bs=4,
                 maxb=6, lengths=[23, 10])
    want = flash_decode_ref(*args)
    got = flash_decode(*args, num_splits=num_splits, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-6, rtol=2e-6)


def test_sliding_window():
    rng = np.random.default_rng(4)
    args = _case(rng, b=2, hq=4, hkv=4, hd=16, num_blocks=12, bs=4,
                 maxb=5, lengths=[19, 6])
    for w in (4, 8):
        got = flash_decode(*args, window=w, interpret=True)
        want = flash_decode_ref(*args, window=w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-6, rtol=2e-6)


def test_inactive_rows_zero_and_isolated():
    """length-0 rows produce exactly zero, and their (stale) table
    entries never leak into other rows' outputs."""
    rng = np.random.default_rng(5)
    q, k, v, table, lengths = _case(
        rng, b=3, hq=4, hkv=2, hd=16, num_blocks=16, bs=8, maxb=3,
        lengths=[13, 0, 21])
    got = flash_decode(q, k, v, table, lengths, interpret=True)
    assert not np.asarray(got[1]).any()
    # poison the inactive row's table: active rows must be unchanged
    poisoned = table.at[1].set(jnp.asarray([5, 6, 7], jnp.int32))
    got2 = flash_decode(q, k, v, poisoned, lengths, interpret=True)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(got2[0]))
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(got2[2]))


def test_matches_dense_decode_attention_order():
    """Single-split path follows the dense op order closely enough for
    the fp32 parity bar the serving tests rely on."""
    rng = np.random.default_rng(6)
    args = _case(rng, b=4, hq=8, hkv=4, hd=32, num_blocks=32, bs=8,
                 maxb=4, lengths=[32, 1, 17, 25])
    got = flash_decode(*args, interpret=True)
    want = flash_decode_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-6, rtol=2e-6)
