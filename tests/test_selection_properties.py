"""Property tests (hypothesis) for dynamic column selection — paper §4.1.

Invariants under test:
  1. Energy identity:  ||G - Q_r Q_r^T' G||_F^2 = ||G||_F^2 - sum_sel ||G q_i||^2.
  2. Contractiveness:  top-r selection gives error <= (1 - r/n) ||G||_F^2.
  3. Optimality:       no other column subset of the same size beats top-r (l2).
  4. Exactness at full rank: r == n reconstructs G.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.dct import dct2_matrix, dct_basis_np
from repro.core.selection import (
    back_project,
    column_norms,
    dynamic_column_selection,
    reconstruction_error_sq,
    select_top_r,
)

matrix_shapes = st.tuples(st.integers(2, 24), st.integers(2, 24))


def _rand_g(shape, seed):
    return np.random.default_rng(seed).standard_normal(shape).astype(np.float32)


@settings(max_examples=40, deadline=None)
@given(shape=matrix_shapes, seed=st.integers(0, 2**31 - 1), frac=st.floats(0.1, 1.0))
def test_energy_identity_and_contractive(shape, seed, frac):
    m, n = shape
    r = max(1, min(n, int(round(frac * n))))
    g = _rand_g((m, n), seed)
    q = np.asarray(dct2_matrix(n), dtype=np.float64)
    s = g.astype(np.float64) @ q
    idx = np.asarray(select_top_r(jnp.asarray(column_norms(jnp.asarray(s))), r))
    # explicit reconstruction
    qr = q[:, idx]
    rec = g.astype(np.float64) @ qr @ qr.T
    err_explicit = np.linalg.norm(g - rec) ** 2
    err_identity = float(
        reconstruction_error_sq(jnp.asarray(g), jnp.asarray(q, dtype=jnp.float32),
                                jnp.asarray(idx))
    )
    tol = 1e-4 * max(1.0, np.linalg.norm(g) ** 2)
    assert abs(err_explicit - err_identity) < tol
    # contractive with factor (1 - r/n)
    bound = (1.0 - r / n) * np.linalg.norm(g) ** 2
    assert err_explicit <= bound + tol


@settings(max_examples=25, deadline=None)
@given(shape=st.tuples(st.integers(2, 10), st.integers(2, 8)),
       seed=st.integers(0, 2**31 - 1))
def test_topr_is_optimal_subset(shape, seed):
    """Exhaustively check: among all size-r column subsets, top-r by column
    l2 norm of S minimizes reconstruction error (paper §4.1)."""
    import itertools

    m, n = shape
    r = max(1, n // 2)
    g = _rand_g((m, n), seed).astype(np.float64)
    q = dct_basis_np(n).T  # DCT-II matrix, float64
    s = g @ q
    norms = (s**2).sum(axis=0)
    top = set(np.argsort(-norms)[:r].tolist())

    def err(subset):
        qr = q[:, list(subset)]
        return np.linalg.norm(g - g @ qr @ qr.T) ** 2

    best = min(err(c) for c in itertools.combinations(range(n), r))
    assert err(top) <= best + 1e-9 * max(1.0, np.linalg.norm(g) ** 2)


@settings(max_examples=20, deadline=None)
@given(shape=matrix_shapes, seed=st.integers(0, 2**31 - 1))
def test_full_rank_exact(shape, seed):
    m, n = shape
    g = _rand_g((m, n), seed)
    q = dct2_matrix(n)
    idx, b = dynamic_column_selection(jnp.asarray(g) @ q, n)
    rec = np.asarray(back_project(b, q, idx))
    np.testing.assert_allclose(rec, g, atol=1e-4 * max(1.0, np.abs(g).max() * n))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), l=st.integers(1, 4))
def test_batched_selection_matches_per_matrix(seed, l):
    """Stacked-layer (vmapped) selection == per-layer selection."""
    m, n, r = 12, 10, 4
    g = _rand_g((l, m, n), seed)
    q = dct2_matrix(n)
    s = jnp.asarray(g) @ q
    idx_b, b_b = dynamic_column_selection(s, r)
    for i in range(l):
        idx_i, b_i = dynamic_column_selection(s[i], r)
        np.testing.assert_array_equal(np.asarray(idx_b[i]), np.asarray(idx_i))
        np.testing.assert_allclose(np.asarray(b_b[i]), np.asarray(b_i), rtol=1e-6)


def test_l1_norm_ranking_runs():
    g = _rand_g((6, 8), 0)
    q = dct2_matrix(8)
    norms = column_norms(jnp.asarray(g) @ q, ord="l1")
    idx = select_top_r(norms, 3)
    assert idx.shape == (3,)
    assert len(set(np.asarray(idx).tolist())) == 3
