"""Property tests (hypothesis) for dynamic column selection — paper §4.1.

Invariants under test:
  1. Energy identity:  ||G - Q_r Q_r^T' G||_F^2 = ||G||_F^2 - sum_sel ||G q_i||^2.
  2. Contractiveness:  top-r selection gives error <= (1 - r/n) ||G||_F^2.
  3. Optimality:       no other column subset of the same size beats top-r (l2).
  4. Exactness at full rank: r == n reconstructs G.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.dct import dct2_matrix, dct_basis_np
from repro.core.selection import (
    back_project,
    column_norms,
    dynamic_column_selection,
    reconstruction_error_sq,
    select_top_r,
)

matrix_shapes = st.tuples(st.integers(2, 24), st.integers(2, 24))


def _rand_g(shape, seed):
    return np.random.default_rng(seed).standard_normal(shape).astype(np.float32)


@settings(max_examples=40, deadline=None)
@given(shape=matrix_shapes, seed=st.integers(0, 2**31 - 1), frac=st.floats(0.1, 1.0))
def test_energy_identity_and_contractive(shape, seed, frac):
    m, n = shape
    r = max(1, min(n, int(round(frac * n))))
    g = _rand_g((m, n), seed)
    q = np.asarray(dct2_matrix(n), dtype=np.float64)
    s = g.astype(np.float64) @ q
    idx = np.asarray(select_top_r(jnp.asarray(column_norms(jnp.asarray(s))), r))
    # explicit reconstruction
    qr = q[:, idx]
    rec = g.astype(np.float64) @ qr @ qr.T
    err_explicit = np.linalg.norm(g - rec) ** 2
    err_identity = float(
        reconstruction_error_sq(jnp.asarray(g), jnp.asarray(q, dtype=jnp.float32),
                                jnp.asarray(idx))
    )
    tol = 1e-4 * max(1.0, np.linalg.norm(g) ** 2)
    assert abs(err_explicit - err_identity) < tol
    # contractive with factor (1 - r/n)
    bound = (1.0 - r / n) * np.linalg.norm(g) ** 2
    assert err_explicit <= bound + tol


@settings(max_examples=25, deadline=None)
@given(shape=st.tuples(st.integers(2, 10), st.integers(2, 8)),
       seed=st.integers(0, 2**31 - 1))
def test_topr_is_optimal_subset(shape, seed):
    """Exhaustively check: among all size-r column subsets, top-r by column
    l2 norm of S minimizes reconstruction error (paper §4.1)."""
    import itertools

    m, n = shape
    r = max(1, n // 2)
    g = _rand_g((m, n), seed).astype(np.float64)
    q = dct_basis_np(n).T  # DCT-II matrix, float64
    s = g @ q
    norms = (s**2).sum(axis=0)
    top = set(np.argsort(-norms)[:r].tolist())

    def err(subset):
        qr = q[:, list(subset)]
        return np.linalg.norm(g - g @ qr @ qr.T) ** 2

    best = min(err(c) for c in itertools.combinations(range(n), r))
    assert err(top) <= best + 1e-9 * max(1.0, np.linalg.norm(g) ** 2)


@settings(max_examples=20, deadline=None)
@given(shape=matrix_shapes, seed=st.integers(0, 2**31 - 1))
def test_full_rank_exact(shape, seed):
    m, n = shape
    g = _rand_g((m, n), seed)
    q = dct2_matrix(n)
    idx, b = dynamic_column_selection(jnp.asarray(g) @ q, n)
    rec = np.asarray(back_project(b, q, idx))
    np.testing.assert_allclose(rec, g, atol=1e-4 * max(1.0, np.abs(g).max() * n))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), l=st.integers(1, 4))
def test_batched_selection_matches_per_matrix(seed, l):
    """Stacked-layer (vmapped) selection == per-layer selection."""
    m, n, r = 12, 10, 4
    g = _rand_g((l, m, n), seed)
    q = dct2_matrix(n)
    s = jnp.asarray(g) @ q
    idx_b, b_b = dynamic_column_selection(s, r)
    for i in range(l):
        idx_i, b_i = dynamic_column_selection(s[i], r)
        np.testing.assert_array_equal(np.asarray(idx_b[i]), np.asarray(idx_i))
        np.testing.assert_allclose(np.asarray(b_b[i]), np.asarray(b_i), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(shape=st.one_of(
           st.tuples(st.integers(4, 24), st.integers(4, 24)),
           st.tuples(st.integers(1, 3), st.integers(4, 24),
                     st.integers(4, 24))),
       seed=st.integers(0, 2**31 - 1), frac=st.floats(0.15, 1.0),
       fused=st.sampled_from(["off", "fft"]))
def test_reported_captured_energy_contract(shape, seed, frac, fused):
    """The telemetry layer's reported captured-energy ratio (DESIGN.md §8)
    obeys the §4.1 contraction bound — residual <= (1 - r/n) ||G||_F^2,
    i.e. captured >= r/n — and equals the direct jnp reference on stacked
    and odd shapes, through both the unfused and the fused (Makhoul fft)
    execution layers."""
    import dataclasses

    import jax
    from repro.optim.common import Context
    from repro.optim.projected_adam import ProjectedAdamRule
    from repro.telemetry.stats import collect

    *batch, d1, d2 = shape
    # the rule orients so the projected dim is the smallest; build the test
    # matrix pre-oriented so the jnp reference below matches exactly
    m, n = max(d1, d2), min(d1, d2)
    shape = (*batch, m, n)
    r = max(1, min(n, int(round(frac * n))))
    g = jnp.asarray(_rand_g(tuple(shape), seed))
    base = ProjectedAdamRule(rank=r, projector="dct", residual="ef",
                             ef_dtype="fp32", fused=fused)
    q32 = dct2_matrix(n)
    with collect() as col:
        state = base.init(tuple(shape), jnp.float32)
        ctx = Context(step=jnp.int32(1), bases={str(n): q32},
                      key=jax.random.PRNGKey(0), stats=col.scope("w"))
        base.update(g, state, jnp.zeros_like(g), ctx)
    captured = np.asarray(col.tree()["w"].captured_energy, np.float64)

    # jnp reference: selected column energy over total, same G (EF = 0 at
    # step 1 so the rule projects exactly G)
    s = np.asarray(g, np.float64) @ np.asarray(q32, np.float64)
    norms = (s**2).sum(axis=-2)
    idx = np.argsort(-norms, axis=-1)[..., :r]
    sel = np.take_along_axis(norms, idx, axis=-1).sum(axis=-1)
    total = (np.asarray(g, np.float64)**2).sum(axis=(-2, -1))
    ref = sel / np.maximum(total, 1e-30)
    np.testing.assert_allclose(captured, ref, rtol=5e-4, atol=5e-5)

    # §4.1 contraction: residual <= (1 - r/n)||G||^2 <=> captured >= r/n
    assert np.all(captured >= r / n - 1e-4), (captured, r / n)


def test_l1_norm_ranking_runs():
    g = _rand_g((6, 8), 0)
    q = dct2_matrix(8)
    norms = column_norms(jnp.asarray(g) @ q, ord="l1")
    idx = select_top_r(norms, 3)
    assert idx.shape == (3,)
    assert len(set(np.asarray(idx).tolist())) == 3
