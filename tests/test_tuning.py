"""Autotuner + low-precision compute-path tests (DESIGN.md §15).

Pins the PR's two contracts:

1. **Untuned is bit-identical.** ``block=None`` with an empty TuningCache
   resolves to exactly the hardcoded defaults, per kernel family; the JSON
   file format round-trips losslessly; the roofline pruner (not wall-clock
   sweeps) is what cuts the measurement grid.
2. **Low precision is bounded.** ``compute_dtype`` in {"bf16", "int8"}
   stays inside ``LOWP_ERROR_BOUNDS`` vs fp32 across stacked / odd-shaped
   / transposed leaves, in every fused mode, and the Pallas int8 kernels
   match their jnp mirrors to float-epilogue tolerance (int32 accumulation
   is exact; XLA may reassociate the two scale multiplies, so the
   comparison is allclose at ~1e-5, not equality). The q8 scale guard
   keeps all-zero and subnormal rows NaN-free through the fused EF path.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dct import dct2_matrix
from repro.kernels.lowp import LOWP_ERROR_BOUNDS, lowp_matmul
from repro.roofline import hw
from repro.roofline.analysis import RooflineReport
from repro.tune import (KERNELS, TuningCache, make_key, resolve_block,
                        tuning_cache)
from repro.tune.prune import candidate_blocks, prune


@pytest.fixture(autouse=True)
def _clean_global_cache():
    """Tests mutate the process-wide cache; never leak entries (a stale
    entry would change other tests' Pallas block sizes and break their
    bit-exactness pins)."""
    tuning_cache().clear()
    yield
    tuning_cache().clear()


def _rand(shape, dtype=jnp.float32, seed=0):
    x = np.random.default_rng(seed).standard_normal(shape)
    return jnp.asarray(x.astype(np.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# cache: keys, counters, persistence
# ---------------------------------------------------------------------------
def test_make_key_normalizes():
    k = make_key("dct_project", [2, jnp.int32(64), 64], 0, jnp.float32,
                 "cpu")
    assert k == ("dct_project", (2, 64, 64), 0, "float32", "cpu")
    assert hash(k)  # fully hashable/static
    # platform defaults to the active jax backend
    assert make_key("quant_ef", (1, 8, 8), 0, "float32")[-1] \
        == jax.default_backend()


def test_cache_hit_miss_counters():
    c = TuningCache()
    key = make_key("dct_project", (1, 64, 64), 0, "float32", "cpu")
    assert c.lookup(key) is None and c.misses == 1 and c.hits == 0
    c.store(key, (128, 128, 128))
    assert c.lookup(key) == (128, 128, 128)
    assert (c.hits, c.misses) == (1, 1)
    assert key in c and len(c) == 1


def test_cache_json_round_trip_stable(tmp_path):
    c = TuningCache()
    c.store(make_key("dct_project", (1, 64, 64), 0, "float32", "cpu"),
            (128, 128, 128))
    c.store(make_key("quant_ef", (2, 64, 64), 0, "float32", "cpu"), 128)
    p1, p2 = tmp_path / "a.json", tmp_path / "b.json"
    c.save(str(p1))
    c2 = TuningCache()
    assert c2.load(str(p1)) == 2
    assert c2.entries() == c.entries()
    # tuple vs bare-int block values survive the round trip typed
    key_q = make_key("quant_ef", (2, 64, 64), 0, "float32", "cpu")
    assert isinstance(c2.entries()[key_q], int)
    # byte-stable: save -> load -> save is the identical file
    c2.save(str(p2))
    assert p1.read_text() == p2.read_text()


def test_cache_version_check(tmp_path):
    with pytest.raises(ValueError, match="version"):
        TuningCache().from_json({"version": 99, "entries": []})


def test_resolve_block_miss_returns_default():
    before = tuning_cache().misses
    assert resolve_block("dct_project", (1, 64, 64), 0, "float32",
                         (256, 256, 256)) == (256, 256, 256)
    assert tuning_cache().misses == before + 1


# ---------------------------------------------------------------------------
# pruning: roofline predictions drive the cut
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kernel,shape,rank", [
    ("dct_project", (1, 1024, 1024), 0),
    ("colgather_matmul", (2, 512, 1024), 128),
    ("quant_ef", (1, 1024, 1024), 0),
    ("newton_schulz", (1, 128, 1024), 128),
])
def test_prune_uses_roofline(kernel, shape, rank):
    keep = 4
    survivors = prune(kernel, shape, rank, "float32", arch="v5e", keep=keep)
    grid = candidate_blocks(kernel, shape, rank)
    assert 1 <= len(survivors) <= keep < len(grid)  # it actually pruned
    spec = hw.get_arch("v5e")
    preds = [c.predicted_s for c in survivors]
    assert preds == sorted(preds)  # ranked by predicted step time
    for c in survivors:
        # the prediction is a real roofline report priced at the arch
        assert isinstance(c.report, RooflineReport)
        assert c.report.device_arch == "v5e"
        assert c.predicted_s == c.report.step_s
        assert c.bound in ("compute", "memory")
        assert c.vmem_bytes <= spec.vmem_bytes * 0.9  # fits the envelope
        assert c.block in grid


def test_prune_bound_classification_tracks_arch():
    # quantize/dequant streams bytes: memory-bound on any real accelerator
    assert all(c.bound == "memory"
               for c in prune("quant_ef", (2, 1024, 1024), 0, arch="v5e"))
    # a big projection matmul on the bandwidth-rich cpu-est table flips to
    # compute-bound; on v5e's HBM it stays memory-bound at this size
    big = ("dct_project", (1, 4096, 4096), 0)
    assert any(c.bound == "compute"
               for c in prune(*big, "float32", arch="cpu-est"))


def test_prune_vmem_fallback():
    # every candidate of the colgather family at n=4096 carries the full
    # (n, bn) Q^T stripe; with a deliberately tiny VMEM nothing fits and
    # the pruner must still return the smallest-footprint candidates
    survivors = prune("colgather_matmul", (1, 4096, 4096), 256,
                      arch="v5e", keep=3, vmem_frac=1e-6)
    assert len(survivors) == 3
    foots = [c.vmem_bytes for c in survivors]
    all_foots = sorted(c.vmem_bytes for c in (
        prune("colgather_matmul", (1, 4096, 4096), 256, arch="v5e",
              keep=100, vmem_frac=1e9)))
    assert max(foots) <= all_foots[2]


# ---------------------------------------------------------------------------
# block=None: bit-identical fallback + tuned-block dispatch
# ---------------------------------------------------------------------------
def test_block_none_bit_identical_untuned():
    import importlib

    from repro.kernels import (colgather_matmul, colgather_matmul_dual,
                               dct_project, dequant_add_ef, ns_iteration,
                               quantize_ef)
    # attribute access on repro.kernels returns the re-exported functions,
    # so the defining modules come via importlib
    dp_mod = importlib.import_module("repro.kernels.dct_project")
    cg_mod = importlib.import_module("repro.kernels.colgather_matmul")
    q8_mod = importlib.import_module("repro.kernels.quant_ef")
    ns_mod = importlib.import_module("repro.kernels.newton_schulz")

    g = _rand((2, 65, 48), seed=1)
    q = dct2_matrix(48)
    s0, n0 = dct_project(g, q, interpret=True)
    s1, n1 = dct_project(g, q, block=dp_mod.DEFAULT_BLOCK, interpret=True)
    assert np.array_equal(np.asarray(s0), np.asarray(s1))
    assert np.array_equal(np.asarray(n0), np.asarray(n1))

    b = _rand((2, 65, 8), seed=2)
    qt = jnp.swapaxes(q, -1, -2)
    idx = jnp.tile(jnp.arange(8, dtype=jnp.int32)[None], (2, 1))
    o0 = colgather_matmul(b, qt, idx, interpret=True)
    o1 = colgather_matmul(b, qt, idx, block=cg_mod.DEFAULT_BLOCK,
                          interpret=True)
    assert np.array_equal(np.asarray(o0), np.asarray(o1))
    d0 = colgather_matmul_dual(b, b, qt, idx, interpret=True)
    d1 = colgather_matmul_dual(b, b, qt, idx, block=cg_mod.DEFAULT_BLOCK,
                               interpret=True)
    assert all(np.array_equal(np.asarray(a), np.asarray(x))
               for a, x in zip(d0, d1))

    x = _rand((2, 33, 48), seed=3)
    qv0, sc0 = quantize_ef(x, interpret=True)
    qv1, sc1 = quantize_ef(x, bm=q8_mod.DEFAULT_BM, interpret=True)
    assert np.array_equal(np.asarray(qv0), np.asarray(qv1))
    assert np.array_equal(np.asarray(sc0), np.asarray(sc1))
    y0 = dequant_add_ef(x, qv0, sc0, interpret=True)
    y1 = dequant_add_ef(x, qv0, sc0, bm=q8_mod.DEFAULT_BM, interpret=True)
    assert np.array_equal(np.asarray(y0), np.asarray(y1))

    w = _rand((1, 16, 40), seed=4)
    z0 = ns_iteration(w, interpret=True)
    z1 = ns_iteration(w, bm=ns_mod.DEFAULT_BM, interpret=True)
    assert np.array_equal(np.asarray(z0), np.asarray(z1))


def test_tuned_block_reaches_kernel_dispatch(monkeypatch):
    """A stored cache entry must change what the jitted kernel is traced
    with — the CI tune job's dispatch-spy contract, in-tree."""
    import importlib
    dp_mod = importlib.import_module("repro.kernels.dct_project")
    from repro.kernels import dct_project

    g = _rand((1, 64, 64), seed=5)
    q = dct2_matrix(64)
    tuned = (128, 64, 64)
    tuning_cache().store(make_key("dct_project", (1, 64, 64), 0, "float32"),
                         tuned)

    seen = []
    orig = dp_mod._dct_project

    def spy(g, q, **kw):
        seen.append(kw["block"])
        return orig(g, q, **kw)

    monkeypatch.setattr(dp_mod, "_dct_project", spy)
    hits = tuning_cache().hits
    s_tuned, n_tuned = dct_project(g, q, interpret=True)
    assert seen == [tuned]
    assert tuning_cache().hits == hits + 1
    # a tuned block changes scheduling, never semantics
    s_dflt, n_dflt = dct_project(g, q, block=dp_mod.DEFAULT_BLOCK,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(s_tuned), np.asarray(s_dflt),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(n_tuned), np.asarray(n_dflt),
                               rtol=1e-5, atol=1e-5)


def test_tune_kernel_stores_winner_and_record(tmp_path):
    from repro.tune import tune_kernel

    cache = TuningCache()
    rec = tune_kernel("quant_ef", (1, 64, 64), 0, "float32", keep=2,
                      interpret=True, iters=1, warmup=1, cache=cache)
    assert len(cache) == 1
    key = make_key("quant_ef", (1, 64, 64), 0, "float32")
    assert cache.lookup(key) is not None
    for field in ("kernel", "shape", "grid_size", "survivors", "timings_s",
                  "default_block", "default_s", "best_block", "best_s",
                  "speedup", "bound", "platform"):
        assert field in rec, field
    # the default was measured even if pruned out, and the winner's timing
    # can never exceed it (ties break toward the default)
    assert rec["default_block"] in rec["timings_s"]
    assert rec["best_s"] <= rec["default_s"]
    # the record round-trips through the BENCH json layer
    (tmp_path / "rec.json").write_text(json.dumps(rec))


# ---------------------------------------------------------------------------
# low-precision compute path
# ---------------------------------------------------------------------------
LEAF_SHAPES = [
    ((3, 64, 48), 48),    # stacked
    ((33, 40), 40),       # odd, non-multiple of any block
    ((48, 64), 64),       # transposed orientation (m < n)
]


@pytest.mark.parametrize("gshape,n", LEAF_SHAPES)
@pytest.mark.parametrize("dt", ["bf16", "int8"])
def test_lowp_matmul_within_bounds(gshape, n, dt):
    g = _rand(gshape, seed=sum(gshape))
    q = dct2_matrix(n)
    ref = g @ q
    out = lowp_matmul(g, q, dt)
    rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
    assert rel <= LOWP_ERROR_BOUNDS[dt], (dt, rel)


@pytest.mark.parametrize("gshape,n", LEAF_SHAPES)
@pytest.mark.parametrize("mode", ["off", "on", "fft"])
@pytest.mark.parametrize("dt", ["bf16", "int8"])
def test_select_and_project_lowp_bounded_all_modes(gshape, n, mode, dt):
    from repro.core import fused_step

    g = _rand(gshape, seed=sum(gshape) + 7)
    q = dct2_matrix(n)
    r = 8
    idx_ref, low_ref = fused_step.select_and_project(g, q, r, mode=mode)
    idx_dt, low_dt = fused_step.select_and_project(g, q, r, mode=mode,
                                                   compute_dtype=dt)
    # selection overlap: the ranking statistic survives the quantization
    ref_set = set(np.asarray(idx_ref).reshape(-1).tolist())
    got_set = set(np.asarray(idx_dt).reshape(-1).tolist())
    assert len(ref_set & got_set) / len(ref_set) >= 0.75, (mode, dt)
    # projected factor error vs the fp32 transform, on the common columns
    s_ref = np.asarray(g @ q, np.float64)
    s_dt = np.asarray(lowp_matmul(g, q, dt), np.float64)
    rel = np.linalg.norm(s_dt - s_ref) / np.linalg.norm(s_ref)
    assert rel <= LOWP_ERROR_BOUNDS[dt], (mode, dt, rel)


def test_fp32_mode_paths_unchanged():
    """compute_dtype="fp32" must leave every dispatch mode's fp32 math
    untouched (the pre-PR pin): fft mode still runs the fast transform,
    off mode the reference selection."""
    from repro.core import fused_step
    from repro.core.dct import makhoul_dct2
    from repro.core.selection import dynamic_column_selection

    g = _rand((2, 32, 48), seed=11)
    q = dct2_matrix(48)
    idx, low = fused_step.select_and_project(g, q, 8, mode="fft",
                                             compute_dtype="fp32")
    s = makhoul_dct2(g)
    idx_ref, low_ref = dynamic_column_selection(s, 8)
    assert np.array_equal(np.asarray(idx), np.asarray(idx_ref))
    assert np.array_equal(np.asarray(low), np.asarray(low_ref))


@pytest.mark.parametrize("gshape,n", LEAF_SHAPES)
def test_int8_kernel_matches_mirror(gshape, n):
    """Pallas int8 dct_project vs the jnp mirror: same quantization, same
    int32 accumulation; only the float epilogue may reassociate."""
    from repro.kernels import dct_project

    g = _rand(gshape, seed=sum(gshape) + 13)
    q = dct2_matrix(n)
    s_k, norms_k = dct_project(g, q, block=(32, 32, 32), interpret=True,
                               compute_dtype="int8")
    s_m = lowp_matmul(g, q, "int8")
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_m),
                               rtol=1e-5, atol=1e-5)
    norms_m = jnp.sum(jnp.square(s_m), axis=-2)  # per-batch column energy
    np.testing.assert_allclose(np.asarray(norms_k), np.asarray(norms_m),
                               rtol=1e-4, atol=1e-4)


def test_int8_colgather_matches_mirror():
    from repro.kernels import colgather_matmul, colgather_matmul_dual
    from repro.kernels.lowp import lowp_gather_matmul

    b = _rand((2, 40, 8), seed=17)
    q = dct2_matrix(48)
    qt = jnp.swapaxes(q, -1, -2)
    idx = jnp.stack([jnp.arange(8), jnp.arange(8) * 3 % 48]).astype(jnp.int32)
    out_k = colgather_matmul(b, qt, idx, block=(32, 32), interpret=True,
                             compute_dtype="int8")
    (out_m,) = lowp_gather_matmul((b,), qt, idx, "int8")
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_m),
                               rtol=1e-5, atol=1e-5)
    b2 = _rand((2, 40, 8), seed=19)
    d_k = colgather_matmul_dual(b, b2, qt, idx, block=(32, 32),
                                interpret=True, compute_dtype="int8")
    d_m = lowp_gather_matmul((b, b2), qt, idx, "int8")
    for got, want in zip(d_k, d_m):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
    # and the fp32 back-projection ground truth stays within the int8 bound
    ref = jnp.einsum("bmr,brn->bmn", b, jnp.take(qt, idx, axis=0))
    rel = float(jnp.linalg.norm(out_k - ref) / jnp.linalg.norm(ref))
    assert rel <= LOWP_ERROR_BOUNDS["int8"]


@pytest.mark.parametrize("dt", ["bf16", "int8"])
def test_rule_level_lowp_close_to_fp32(dt):
    """One full ProjectedAdamRule update in low precision stays close to
    the fp32 update — the end-to-end plumbing test for compute_dtype."""
    import dataclasses

    from repro.optim.projected_adam import ProjectedAdamRule
    from repro.optim.transform import matrix_optimizer

    shape = (2, 48, 64)
    params = {"w": jnp.zeros(shape, jnp.float32)}
    grads = {"w": _rand(shape, seed=23)}
    base = ProjectedAdamRule(rank=8, projector="dct", residual="ef",
                             ef_dtype="fp32", fused="fft")
    outs = {}
    for cdt in ("fp32", dt):
        rule = dataclasses.replace(base, compute_dtype=cdt)
        opt = matrix_optimizer(rule, 1e-3)
        state = opt.init(params)
        d, _ = opt.update(grads, state, params)
        outs[cdt] = np.asarray(d["w"], np.float64)
    denom = np.linalg.norm(outs["fp32"]) or 1.0
    rel = np.linalg.norm(outs[dt] - outs["fp32"]) / denom
    # Adam normalizes per-coordinate, so amplification over the matmul
    # bound is expected; 10x the bound still separates real regressions
    # (a wrong scale fold is O(1) off) from quantization noise
    assert rel <= 10 * LOWP_ERROR_BOUNDS[dt], (dt, rel)
    # and a strictly positive difference: bit-identity to fp32 would mean
    # compute_dtype silently fell off the dispatch path
    assert rel > 0, dt


def test_lowp_refuses_reference_path():
    """A non-fp32 compute_dtype must fail loudly, never silently run fp32:
    eagerly for fused="off", at trace time when fused="auto" resolves to
    the reference path (the off-TPU default) or the projector is
    dense-basis."""
    import dataclasses

    from repro.core import fused_step
    from repro.optim.projected_adam import ProjectedAdamRule
    from repro.optim.transform import matrix_optimizer

    with pytest.raises(ValueError, match="compute_dtype"):
        ProjectedAdamRule(rank=8, fused="off", compute_dtype="int8")

    params = {"w": jnp.zeros((16, 16), jnp.float32)}
    grads = {"w": _rand((16, 16), seed=7)}
    if fused_step.resolve("auto") == "off":      # true on every CI backend
        rule = ProjectedAdamRule(rank=8, fused="auto", compute_dtype="int8")
        opt = matrix_optimizer(rule, 1e-3)
        state = opt.init(params)
        with pytest.raises(ValueError, match="fused"):
            opt.update(grads, state, params)
    # dense-basis projector: no fused dataflow regardless of mode
    rule = ProjectedAdamRule(rank=8, projector="svd", fused="fft",
                             compute_dtype="int8")
    opt = matrix_optimizer(rule, 1e-3)
    state = opt.init(params)
    with pytest.raises(ValueError, match="fused"):
        opt.update(grads, state, params)


# ---------------------------------------------------------------------------
# q8 scale guard: zero + subnormal rows through the fused EF path
# ---------------------------------------------------------------------------
def test_q8_zero_and_subnormal_rows_finite():
    from repro.core.error_feedback import dequantize_q8, quantize_q8
    from repro.kernels import quantize_ef
    from repro.kernels.lowp import F32_TINY
    from repro.kernels.ref import quantize_ef_ref

    x = np.zeros((4, 16), np.float32)
    x[1] = 2e-45            # subnormal row: amax/127 underflows to 0.0
    x[2] = np.linspace(-1, 1, 16)
    x = jnp.asarray(x)
    for name, (qv, scale) in {
            "kernel": quantize_ef(x, bm=2, interpret=True),
            "ref": quantize_ef_ref(x),
            "core": quantize_q8(x)}.items():
        qn, sn = np.asarray(qv, np.int32), np.asarray(scale)
        assert np.isfinite(sn).all(), name
        assert (sn >= F32_TINY).all(), name            # the guard
        assert np.isfinite(qn.astype(np.float32) * sn).all(), name
        # zero/subnormal rows dequantize to exactly zero payload
        assert (qn[0] == 0).all() and (qn[1] == 0).all(), name
    buf = quantize_q8(x)
    assert np.isfinite(np.asarray(dequantize_q8(buf))).all()


def test_q8_guard_through_fused_ef_rule():
    """A gradient with an all-zero row must survive a full q8-EF fused
    update without NaNs (the regression the scale guard exists for)."""
    from repro.optim.projected_adam import ProjectedAdamRule
    from repro.optim.transform import matrix_optimizer

    g = np.array(_rand((2, 32, 48), seed=29))
    g[0, 5, :] = 0.0
    g[1, 7, :] = 2e-45
    grads = {"w": jnp.asarray(g)}
    params = {"w": jnp.zeros((2, 32, 48), jnp.float32)}
    for fused in ("off", "on", "fft"):
        rule = ProjectedAdamRule(rank=8, projector="dct", residual="ef",
                                 ef_dtype="q8", fused=fused)
        opt = matrix_optimizer(rule, 1e-3)
        state = opt.init(params)
        d, new_state = opt.update(grads, state, params)
        d, new_state = opt.update(grads, new_state, params)  # EF consumed
        assert np.isfinite(np.asarray(d["w"])).all(), fused


def test_kernels_iterate_cache_families():
    """Every family the cache claims to key is a real tunable entry point
    with a default + candidate grid."""
    from repro.tune.autotune import default_block

    for k in KERNELS:
        assert candidate_blocks(k, (1, 128, 128), 32)
        assert default_block(k) is not None
