"""Observability layer (src/repro/obs/, DESIGN.md §13).

Covers the metrics registry (bucket edges, quantiles, labeled series,
registration conflicts, enable/disable), the span tracer (nesting, ring
wraparound, Chrome-trace validity, sink export), the exporters
(Prometheus text format, JSONL), and the three instrumented layers:

  * serving — TTFT/ITL/queue-wait/E2E histograms must agree exactly with
    the per-request timestamps on the GenerationHandles (same clock, same
    emission points), and the per-step ``step_stats`` dict must be
    populated with pool utilization/fragmentation even with obs disabled;
  * training — phase histograms count every step, the sampled full-state
    sync fires on its cadence, ladder/controller decisions land as
    structured events;
  * checkpointing — save/restore/verify durations and byte counters.

The disabled-mode contract is pinned two ways: instruments record
nothing while disabled, and the lowered HLO of a jitted train step is
*bit-identical* with obs enabled vs disabled (the instrumentation is
host-side only and can never alter a traced graph).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.obs.metrics import MetricsRegistry
from repro.obs.tracing import SpanTracer


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts and ends with the process-wide obs state clean
    (disabled, empty series/ring) — obs is global by design."""
    obs.disable()
    obs.reset()
    yield
    obs.disable()
    obs.reset()


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------
def test_histogram_bucket_edges_inclusive():
    r = MetricsRegistry()
    h = r.histogram("h_edges", edges=(0.1, 1.0, 10.0))
    for v in (0.05, 0.1):           # at-or-below the first edge
        h.observe(v)
    h.observe(0.5)                  # (0.1, 1.0]
    h.observe(1.0)                  # edge value lands in its own bucket
    h.observe(99.0)                 # overflow
    s = h.snapshot()["series"][()]
    assert s["buckets"] == [2, 2, 0, 1]
    assert s["count"] == 5
    assert s["min"] == 0.05 and s["max"] == 99.0
    assert s["sum"] == pytest.approx(0.05 + 0.1 + 0.5 + 1.0 + 99.0)


def test_histogram_quantiles():
    r = MetricsRegistry()
    h = r.histogram("h_q", edges=(1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 1.5, 3.0):
        h.observe(v)
    # p100 == observed max; p0 clamps to observed min
    assert h.quantile(1.0) == 3.0
    assert h.quantile(0.0) == 0.5
    # median falls inside the (1, 2] bucket, between its two entries
    assert 1.0 <= h.quantile(0.5) <= 2.0
    h.observe(50.0)                 # overflow bucket reports observed max
    assert h.quantile(0.99) == 50.0
    assert h.mean() == pytest.approx((0.5 + 1.5 + 1.5 + 3.0 + 50.0) / 5)
    with pytest.raises(ValueError):
        h.quantile(1.5)


def test_labeled_series_tuple_keyed():
    r = MetricsRegistry()
    c = r.counter("c_lbl", labels=("reason",))
    c.inc(1, ("eos",))
    c.inc(2, ("eos",))
    c.inc(1, ("length",))
    assert c.value(("eos",)) == 3
    assert c.value(("length",)) == 1
    assert c.value(("cancelled",)) == 0
    g = r.gauge("g_lbl", labels=("k",))
    g.set(2.0, ("a",))
    g.add(0.5, ("a",))
    assert g.value(("a",)) == 2.5


def test_registration_conflicts_raise():
    r = MetricsRegistry()
    r.counter("m1", labels=("a",))
    assert r.counter("m1", labels=("a",)) is r.get("m1")  # get-or-create
    with pytest.raises(ValueError):
        r.gauge("m1")                           # kind mismatch
    with pytest.raises(ValueError):
        r.counter("m1", labels=("b",))          # label mismatch
    r.histogram("m2", edges=(1.0, 2.0))
    with pytest.raises(ValueError):
        r.histogram("m2", edges=(1.0, 3.0))     # edge mismatch
    with pytest.raises(ValueError):
        r.histogram("m3", edges=(2.0, 1.0))     # non-ascending edges


def test_disabled_registry_records_nothing():
    r = MetricsRegistry(enabled=False)
    c, h = r.counter("c"), r.histogram("h")
    c.inc()
    h.observe(1.0)
    assert c.value() == 0 and h.count() == 0
    r.enable()
    c.inc()
    h.observe(1.0)
    assert c.value() == 1 and h.count() == 1
    r.disable()
    c.inc()
    assert c.value() == 1


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------
def test_span_nesting_depth():
    tr = SpanTracer(capacity=16)
    with tr.span("outer", step=1):
        with tr.span("inner", step=1):
            pass
    recs = tr.records()
    by_name = {r["name"]: r for r in recs}
    assert by_name["outer"]["depth"] == 0
    assert by_name["inner"]["depth"] == 1
    # inner closes first, so it lands in the ring first
    assert [r["name"] for r in recs] == ["inner", "outer"]
    assert all(r["dur"] >= 0 for r in recs)


def test_tracer_ring_wraparound_oldest_first():
    tr = SpanTracer(capacity=4)
    for i in range(7):
        tr.instant("e", step=i)
    assert tr.dropped == 3
    steps = [r["step"] for r in tr.records()]
    assert steps == [3, 4, 5, 6]                # oldest first, newest last
    tr.clear()
    assert tr.records() == [] and tr.dropped == 0


def test_disabled_tracer_is_noop():
    tr = SpanTracer(capacity=4, enabled=False)
    with tr.span("s"):
        pass
    tr.instant("e")
    assert tr.records() == []


def test_chrome_trace_valid():
    tr = SpanTracer(capacity=16)
    with tr.span("phase", step=3, n=2):
        tr.instant("tick", step=3)
    trace = json.loads(json.dumps(tr.chrome_trace()))   # JSON round-trip
    evs = trace["traceEvents"]
    assert len(evs) == 2
    for ev in evs:
        assert ev["ph"] in ("X", "i")
        assert isinstance(ev["ts"], float) and "pid" in ev and "tid" in ev
    x = next(e for e in evs if e["ph"] == "X")
    i = next(e for e in evs if e["ph"] == "i")
    assert x["dur"] >= 0 and x["args"] == {"n": 2, "step": 3}
    assert i["s"] == "t" and i["args"]["step"] == 3


def test_tracer_to_sink_buckets_by_step(tmp_path):
    from repro.telemetry.sink import TelemetrySink

    tr = SpanTracer(capacity=32)
    for step in (1, 2):
        with tr.span("work", step=step):
            pass
    tr.instant("trip", step=2)
    with tr.span("unstepped"):                  # no step -> not exported
        pass
    sink = TelemetrySink(str(tmp_path / "t.jsonl"), every=1)
    assert tr.to_sink(sink) == 3
    sink.close()
    rows = sink.history()
    assert [r["step"] for r in rows] == [1, 2, 2]
    assert "span/work" in rows[0]
    assert rows[2]["event/trip"] == 1.0


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------
def test_prometheus_exposition_format():
    from repro.obs.exporters import prometheus_exposition

    r = MetricsRegistry()
    r.counter("req_total", "requests", labels=("reason",)).inc(3, ("eos",))
    r.gauge("depth", "queue depth").set(2)
    h = r.histogram("lat_seconds", "latency", edges=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    text = prometheus_exposition(r)
    lines = text.splitlines()
    assert "# TYPE req_total counter" in lines
    assert '# HELP req_total requests' in lines
    assert 'req_total{reason="eos"} 3' in lines
    assert "depth 2" in lines
    assert "# TYPE lat_seconds histogram" in lines
    # cumulative le buckets ending at +Inf; final bucket == _count
    assert 'lat_seconds_bucket{le="0.1"} 1' in lines
    assert 'lat_seconds_bucket{le="1"} 2' in lines
    assert 'lat_seconds_bucket{le="+Inf"} 3' in lines
    assert "lat_seconds_count 3" in lines
    assert any(line.startswith("lat_seconds_sum ") for line in lines)
    assert text.endswith("\n")


def test_prometheus_rejects_bad_metric_name():
    from repro.obs.exporters import prometheus_exposition

    r = MetricsRegistry()
    r.counter("bad-name")
    with pytest.raises(ValueError):
        prometheus_exposition(r)


def test_prometheus_exporter_atomic_write(tmp_path):
    from repro.obs.exporters import PrometheusExporter

    r = MetricsRegistry()
    r.counter("c_total").inc(5)
    path = tmp_path / "snap" / "metrics.prom"
    out = PrometheusExporter(r, str(path)).write()
    assert out == str(path)
    assert "c_total 5" in path.read_text()
    assert not path.with_suffix(".prom.tmp").exists()


def test_jsonl_exporter_appends_snapshots(tmp_path):
    from repro.obs.exporters import JSONLExporter

    r = MetricsRegistry()
    h = r.histogram("h_seconds", edges=(1.0, 2.0))
    h.observe(0.5, ())
    exp = JSONLExporter(r, str(tmp_path / "m.jsonl"))
    exp.write(step=10)
    h.observe(1.5)
    exp.write(step=20)
    lines = [json.loads(ln) for ln in
             (tmp_path / "m.jsonl").read_text().splitlines()]
    assert [ln["step"] for ln in lines] == [10, 20]
    series = lines[1]["metrics"]["h_seconds"]["series"][""]
    assert series["count"] == 2 and "p50" in series and "p99" in series


# ---------------------------------------------------------------------------
# telemetry sink: ring wraparound + bucket flush ordering (satellite)
# ---------------------------------------------------------------------------
def test_sink_ring_wraparound(tmp_path):
    from repro.telemetry.sink import TelemetrySink

    sink = TelemetrySink(str(tmp_path / "s.jsonl"), every=1, ring=4)
    for i in range(1, 11):
        sink.log_metrics({"step": i, "loss": float(i)})
    sink.close()
    rows = sink.history()
    assert len(rows) == 4                       # ring capacity
    assert [r["step"] for r in rows] == [7, 8, 9, 10]   # newest last
    # the file keeps everything the ring dropped
    on_disk = [json.loads(ln) for ln in
               (tmp_path / "s.jsonl").read_text().splitlines()]
    assert [r["step"] for r in on_disk] == list(range(1, 11))


def test_sink_bucket_flush_ordering(tmp_path):
    from repro.telemetry.sink import TelemetrySink

    sink = TelemetrySink(str(tmp_path / "s.jsonl"), every=3)
    for i in range(1, 8):                       # 7 records, every=3
        sink.log_metrics({"step": i, "loss": float(i)})
    sink.flush()                                # partial bucket (step 7)
    sink.flush()                                # idempotent: no empty row
    sink.close()
    rows = sink.history()
    # buckets [1..3], [4..6], [7]: step takes the bucket's last value,
    # values aggregate by mean, ordering is strictly by step
    assert [r["step"] for r in rows] == [3, 6, 7]
    assert [r["loss"] for r in rows] == [2.0, 5.0, 7.0]


# ---------------------------------------------------------------------------
# disabled-mode graph bit-identity
# ---------------------------------------------------------------------------
def _tiny_cfg():
    from repro.models.config import ModelConfig

    return ModelConfig(
        name="llama-obs-tiny", family="dense", d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=96, vocab_size=64,
        schedule=((("attn",), 2),), param_dtype="float32",
        compute_dtype="float32", remat=False, q_chunk=16, kv_chunk=16)


def test_obs_toggle_keeps_train_step_hlo_bit_identical():
    """Enabling obs must not alter any traced graph: the instrumentation
    is host-side only. Pinned by lowering the same train step with obs
    disabled and enabled and comparing the HLO text byte-for-byte."""
    from repro.models import transformer as T
    from repro.optim.api import get_optimizer
    from repro.train.steps import TrainState, make_train_step

    cfg = _tiny_cfg()
    opt = get_optimizer("dct_adamw", lr=1e-3, rank=8)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    state = TrainState(jnp.zeros((), jnp.int32), params, opt.init(params))
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
             "targets": jnp.zeros((2, 16), jnp.int32)}
    step = make_train_step(cfg, opt)

    obs.disable()
    hlo_off = jax.jit(step).lower(state, batch).as_text()
    obs.enable()
    hlo_on = jax.jit(step).lower(state, batch).as_text()
    assert hlo_off == hlo_on


# ---------------------------------------------------------------------------
# serving instrumentation
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def paged_setup():
    from repro.configs.registry import SMOKES
    from repro.models import transformer as T

    cfg = SMOKES["qwen2.5-32b"]
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _churn(cfg, params, *, cancel_one: bool = False):
    from repro.serve import PagedServeEngine, Session

    eng = PagedServeEngine(cfg, params, block_size=4, num_blocks=32,
                           max_blocks_per_seq=6, num_slots=2,
                           max_prefill_len=16, prefill_chunk=8,
                           num_splits=2)
    sess = Session(eng, "obs")
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (n,))
               for n in (9, 5, 11, 7, 10)]
    budgets = [6, 3, 5, 4, 4]
    hs = [sess.submit(prompts[0], max_new_tokens=budgets[0]),
          sess.submit(prompts[1], max_new_tokens=budgets[1])]
    eng.step(); eng.step()
    hs.append(sess.submit(prompts[2], max_new_tokens=budgets[2]))
    hs.append(sess.submit(prompts[3], max_new_tokens=budgets[3]))
    if cancel_one:
        # a 5th request queues behind the two busy slots while hs[2] is
        # cancelled before it was ever admitted
        hs.append(sess.submit(prompts[4], max_new_tokens=budgets[4]))
        hs[2].cancel()
    eng.run()
    return eng, hs


def test_serve_histograms_match_handle_timestamps(paged_setup):
    """The acceptance invariant: TTFT/ITL/queue-wait/E2E histograms from
    a churn run agree with the per-request timestamps on the handles —
    same count, same sum (the engine emits both from the same perf_counter
    stamps at the same step boundaries, quantized to whole decode steps)."""
    cfg, params = paged_setup
    obs.enable()
    eng, hs = _churn(cfg, params)
    assert all(h.done for h in hs)
    r = obs.registry()

    ttfts = [h.ttft for h in hs]
    itls = [g for h in hs for g in h.inter_token_latencies()]
    e2es = [h.e2e for h in hs]
    qw = [h.queue_wait for h in hs]
    for name, vals in (("serve_ttft_seconds", ttfts),
                       ("serve_itl_seconds", itls),
                       ("serve_queue_wait_seconds", qw),
                       ("serve_e2e_seconds", e2es)):
        hist = r.get(name)
        assert hist.count() == len(vals), name
        assert hist.sum() == pytest.approx(sum(vals), rel=1e-9), name
    # every inter-token gap is a whole number of decode steps: positive,
    # and bounded by the run's wall time
    assert all(g > 0 for g in itls)
    for h in hs:
        assert h.ttft >= h.queue_wait > 0
        assert h.e2e >= h.token_times[-1] - h.t_submit
    assert r.get("serve_tokens_total").value() == \
        sum(len(h.tokens) for h in hs)
    assert r.get("serve_requests_submitted_total").value() == 4
    assert r.get("serve_requests_finished_total").value(("length",)) == 4


def test_serve_step_stats_without_obs(paged_setup):
    """Satellite: allocator utilization/fragmentation ride the engine's
    per-step stats dict with obs fully disabled."""
    cfg, params = paged_setup
    assert not obs.enabled()
    eng, hs = _churn(cfg, params)
    st = eng.step_stats
    for key in ("step", "running", "pending", "tokens_emitted",
                "used_blocks", "free_blocks", "utilization",
                "fragmentation"):
        assert key in st, key
    assert st["running"] == 0 and st["pending"] == 0
    assert st["free_blocks"] == 32 and st["used_blocks"] == 0
    assert st["tokens_emitted"] == sum(len(h.tokens) for h in hs)
    assert eng.stats()["tokens_emitted"] == st["tokens_emitted"]
    # and nothing leaked into the disabled registry
    assert obs.registry().get("serve_tokens_total").value() == 0


def test_serve_cancel_and_backpressure_counters(paged_setup):
    cfg, params = paged_setup
    obs.enable()
    eng, hs = _churn(cfg, params, cancel_one=True)
    r = obs.registry()
    cancels = r.get("serve_cancellations_total")
    assert cancels.value(("queued",)) + cancels.value(("running",)) == 1
    fin = r.get("serve_requests_finished_total")
    assert fin.value(("cancelled",)) == 1
    assert fin.value(("length",)) == 4
    # 5 submissions through 2 slots -> someone waited on a slot at least
    # one step boundary
    assert r.get("serve_backpressure_steps_total").value(("slots",)) \
        + r.get("serve_backpressure_steps_total").value(("blocks",)) > 0
    # gauges settle at drained-pool values
    assert r.get("serve_slots_active").value() == 0
    assert r.get("serve_pool_free_blocks").value() == 32
    assert r.get("serve_pool_utilization").value() == 0.0
    # spans from admit/decode are in the ring with step tags
    names = {rec["name"] for rec in obs.tracer().records()}
    assert "serve/admit" in names and "serve/decode_step" in names


# ---------------------------------------------------------------------------
# training instrumentation
# ---------------------------------------------------------------------------
def test_trainer_phase_metrics_and_sampled_sync(tmp_path):
    from repro.data.synthetic import SyntheticLM
    from repro.models import transformer as T
    from repro.optim.api import get_optimizer
    from repro.train.loop import Trainer
    from repro.train.steps import TrainState, make_train_step

    cfg = _tiny_cfg()
    opt = get_optimizer("adamw", lr=1e-3)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16,
                     global_batch=2, seed=0)
    obs.enable()
    trainer = Trainer(
        train_step=jax.jit(make_train_step(cfg, opt)),
        init_state_fn=lambda: TrainState(jnp.zeros((), jnp.int32), params,
                                         opt.init(params)),
        batch_fn=lambda i: ds.batch(jnp.int32(i)),
        log_fn=lambda s: None, sync_sample_every=2)
    trainer.run(5, resume=False)
    r = obs.registry()
    for name in ("train_data_wait_seconds", "train_dispatch_seconds",
                 "train_host_sync_seconds", "train_step_seconds"):
        assert r.get(name).count() == 5, name
    assert r.get("train_full_sync_seconds").count() == 2   # steps 2, 4
    assert r.get("train_steps_total").value(("committed",)) == 5
    assert r.get("train_full_sync_seconds").sum() > 0
    names = [rec["name"] for rec in obs.tracer().records()]
    for span in ("train/data_wait", "train/dispatch", "train/host_sync",
                 "train/full_sync"):
        assert span in names, span


def test_resilience_ladder_events():
    from repro.train.resilience import ResilienceConfig, ResilienceManager

    obs.enable()
    rm = ResilienceManager(ResilienceConfig(max_skips=1, max_rollbacks=1),
                           log_fn=lambda s: None)
    assert rm.observe(1, 1.0, True).kind == "ok"
    assert rm.observe(2, float("nan"), False).kind == "skip"
    assert rm.observe(3, float("nan"), False).kind == "rollback"
    rm.rolled_back(from_step=3, to_step=0)
    assert rm.observe(4, float("nan"), False).kind == "skip"
    assert rm.observe(5, float("nan"), False).kind == "halt"
    r = obs.registry()
    assert r.get("resilience_guard_trips_total").value() == 4
    acts = r.get("resilience_actions_total")
    assert acts.value(("skip",)) == 2
    assert acts.value(("rollback",)) == 1
    assert acts.value(("halt",)) == 1
    names = [rec["name"] for rec in obs.tracer().records()]
    assert names.count("resilience/guard_trip") == 4
    assert "resilience/rollback" in names and "resilience/halt" in names
    halt = next(rec for rec in obs.tracer().records()
                if rec["name"] == "resilience/halt")
    assert "reason" in halt["args"] and halt["args"]["rollbacks"] == 2


def test_controller_events_carry_before_after():
    from repro.telemetry.controllers import (LeafInfo, RankAllocator,
                                             RankAllocatorConfig,
                                             RefreshScheduler,
                                             RefreshSchedulerConfig)

    obs.enable()
    leaves = {"a": LeafInfo(rows=64, cols=64),
              "b": LeafInfo(rows=64, cols=64)}
    ra = RankAllocator(RankAllocatorConfig(base_rank=16, quantum=8,
                                           decide_every=1), leaves)
    ra.observe(1, {"a": {"captured_energy": 0.99},
                   "b": {"captured_energy": 0.30}})
    new = ra.propose(2)
    assert new is not None and new["b"] > new["a"]
    r = obs.registry()
    assert r.get("controller_rank_reallocations_total").value() == 1
    assert r.get("controller_ranks_changed_total").value() == \
        sum(1 for p in new if new[p] != min(16, leaves[p].cols))
    ev = next(rec for rec in obs.tracer().records()
              if rec["name"] == "controller/rank_realloc")
    changed = ev["args"]["changed"]
    assert all({"before", "after"} <= set(v) for v in changed.values())

    rs = RefreshScheduler(RefreshSchedulerConfig(decide_every=1,
                                                 cooldown=0), ["a"])
    rs.observe(1, {"a": {"index_overlap": 0.99}})    # low drift -> stretch
    assert rs.propose(2) == {"a": 2}
    assert r.get("controller_interval_changes_total").value() == 1
    ev = next(rec for rec in obs.tracer().records()
              if rec["name"] == "controller/interval_change")
    assert ev["args"]["changed"]["a"]["before"] == 1
    assert ev["args"]["changed"]["a"]["after"] == 2


# ---------------------------------------------------------------------------
# checkpoint instrumentation
# ---------------------------------------------------------------------------
def test_checkpoint_durations_and_bytes(tmp_path):
    from repro.train.checkpoint import (CheckpointCorruptError,
                                        CheckpointManager)

    obs.enable()
    state = {"w": jnp.arange(64, dtype=jnp.float32),
             "b": jnp.ones((8,), jnp.float32)}
    nbytes = 64 * 4 + 8 * 4
    mgr = CheckpointManager(str(tmp_path / "ckpt"), log=lambda s: None)
    mgr.save(1, state)
    mgr.verify(1)
    restored = mgr.restore(1, state)
    assert jnp.array_equal(restored["w"], state["w"])
    r = obs.registry()
    assert r.get("ckpt_saves_total").value() == 1
    assert r.get("ckpt_restores_total").value() == 1
    assert r.get("ckpt_bytes_written_total").value() == nbytes
    assert r.get("ckpt_bytes_read_total").value() == nbytes
    assert r.get("ckpt_save_seconds").count() == 1
    assert r.get("ckpt_verify_seconds").count() == 1
    assert r.get("ckpt_restore_seconds").count() == 1
    assert r.get("ckpt_save_seconds").sum() > 0

    # corruption: flip bytes in state.npz -> verify raises + counter
    p = tmp_path / "ckpt" / "step_1" / "state.npz"
    raw = bytearray(p.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    p.write_bytes(bytes(raw))
    with pytest.raises(CheckpointCorruptError):
        mgr.verify(1)
    assert r.get("ckpt_corruptions_total").value() == 1
    names = {rec["name"] for rec in obs.tracer().records()}
    assert {"ckpt/write", "ckpt/verify", "ckpt/restore",
            "ckpt/corrupt"} <= names
