"""Train substrate: data determinism, checkpoint atomicity/keep-k/elastic
restore, trainer resume, schedules, serve engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataPipeline
from repro.data.synthetic import SyntheticLM
from repro.models import transformer as T
from repro.optim.api import get_optimizer
from repro.serve.engine import ServeEngine
from repro.train.checkpoint import CheckpointManager
from repro.train.loop import Trainer
from repro.train.schedule import cosine_warmup, linear_warmup
from repro.train.steps import TrainState, init_state, make_train_step

from repro.models.config import ModelConfig


def _tiny():
    return ModelConfig(
        name="tiny", family="dense", d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=128, schedule=((("attn",), 2),),
        param_dtype="float32", compute_dtype="float32", remat=False,
        q_chunk=32, kv_chunk=32)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------
def test_synthetic_deterministic():
    ds = SyntheticLM(vocab_size=100, seq_len=16, global_batch=4, seed=3)
    a = ds.batch(jnp.int32(7))
    b = ds.batch(jnp.int32(7))
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = ds.batch(jnp.int32(8))
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(c["tokens"]))
    # targets are next-token shifted
    assert a["tokens"].shape == a["targets"].shape == (4, 16)


def test_synthetic_learnable_signal():
    """Markov structure: next-token entropy < unigram entropy."""
    ds = SyntheticLM(vocab_size=64, seq_len=512, global_batch=4)
    b = np.asarray(ds.batch(jnp.int32(0))["tokens"]).reshape(-1)
    pairs = {}
    for x, y in zip(b[:-1], b[1:]):
        pairs.setdefault(int(x), []).append(int(y))
    # for the most frequent predecessor, the successor dist is peaked
    x = max(pairs, key=lambda k: len(pairs[k]))
    ys = pairs[x]
    top = max(np.bincount(ys)) / len(ys)
    assert top > 2.0 / 64, top


def test_pipeline_prefetch_and_straggler_fallback():
    calls = []

    def fn(step):
        calls.append(step)
        return {"step": step}

    p = DataPipeline(fn, start_step=0, depth=2, timeout_s=2.0)
    try:
        for s in range(4):
            out = p.get(s)
            assert out["step"] == s
    finally:
        p.close()


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_keep_k(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    state = {"w": jnp.arange(6.0).reshape(2, 3), "step": jnp.int32(5),
             "nested": {"b": jnp.ones((4,))}}
    for s in (10, 20, 30):
        cm.save(s, state)
    assert cm.all_steps() == [20, 30]          # keep-k GC
    restored = cm.restore(30, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state))
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.asarray(state["w"]))


def test_checkpoint_async_and_atomic(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=3)
    state = {"w": jnp.ones((8, 8))}
    cm.async_save(1, state)
    cm.wait()
    assert cm.latest_step() == 1
    # a stale .tmp dir must not be visible as a checkpoint
    os.makedirs(os.path.join(str(tmp_path), "step_99.tmp"), exist_ok=True)
    assert cm.latest_step() == 1


def test_trainer_resume(tmp_path):
    cfg = _tiny()
    opt = get_optimizer("trion", lr=1e-3, rank=8)
    step_fn = jax.jit(make_train_step(cfg, opt))
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, global_batch=2)

    def mk():
        return Trainer(train_step=step_fn,
                       init_state_fn=lambda: init_state(
                           cfg, opt, jax.random.PRNGKey(0)),
                       batch_fn=lambda s: ds.batch(jnp.int32(s)),
                       ckpt_dir=str(tmp_path), ckpt_every=2, log_every=100)

    s1 = mk().run(total_steps=4)
    assert int(s1.step) == 4
    # "crash" and resume: a fresh trainer continues from step 4
    s2 = mk().run(total_steps=6)
    assert int(s2.step) == 6


# ---------------------------------------------------------------------------
# controller state: checkpoint round-trip + preemption (DESIGN.md §8)
# ---------------------------------------------------------------------------
def _make_manager(tmp_path, cfg, *, rank=8):
    """Adaptive manager over the tiny model with aggressive decisions."""
    from repro.telemetry.adaptive import AdaptiveOptimizerManager
    from repro.telemetry.controllers import (RankAllocator,
                                             RankAllocatorConfig,
                                             RefreshScheduler,
                                             RefreshSchedulerConfig,
                                             leaf_inventory)

    params_sds = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    leaves = leaf_inventory(params_sds)
    allocator = RankAllocator(
        RankAllocatorConfig(base_rank=rank, quantum=2, max_step=2,
                            decide_every=2, deadband=0.0, ema_decay=0.5),
        leaves)
    scheduler = RefreshScheduler(
        RefreshSchedulerConfig(decide_every=2, cooldown=2, low_drift=0.99,
                               max_interval=4), leaves)
    return AdaptiveOptimizerManager(
        make_optimizer=lambda ov=None: get_optimizer(
            "dct_adamw", lr=1e-3, rank=rank, fused="fft", overrides=ov),
        make_step=lambda opt: jax.jit(
            make_train_step(cfg, opt, telemetry=True)),
        make_train_state=lambda opt: init_state(cfg, opt,
                                                jax.random.PRNGKey(0)),
        rank_allocator=allocator, refresh_scheduler=scheduler,
        log_fn=lambda s: None)


def test_controller_state_checkpoint_roundtrip(tmp_path):
    """Rank-allocator and refresh-scheduler state survive a
    CheckpointManager save/restore round-trip via the manifest."""
    cfg = _tiny()
    mgr = _make_manager(tmp_path, cfg)
    # give the controllers non-trivial state
    mgr.rank_allocator.ema = {p: 0.1 * i for i, p in
                              enumerate(mgr.rank_allocator.leaves)}
    mgr.rank_allocator.alloc = {p: (6 if i % 2 else 10) for i, p in
                                enumerate(mgr.rank_allocator.leaves)}
    mgr.rank_allocator.last_decision = 7
    mgr.refresh_scheduler.interval = {
        p: 2 for p in mgr.refresh_scheduler.interval}
    mgr.refresh_scheduler.drift_ema = {
        p: 0.25 for p in mgr.refresh_scheduler.interval}

    cm = CheckpointManager(str(tmp_path), keep=2)
    state = {"w": jnp.ones((4, 4))}
    cm.save(5, state, extra={"extra_state": mgr.state_dict()})

    mgr2 = _make_manager(tmp_path, cfg)
    extra = cm.manifest(5)["extra_state"]
    mgr2.load_state_dict(extra)
    assert mgr2.rank_allocator.state_dict() == \
        mgr.rank_allocator.state_dict()
    assert mgr2.refresh_scheduler.state_dict() == \
        mgr.refresh_scheduler.state_dict()
    # the rebuilt optimizer reflects the restored (non-uniform) allocation:
    # init_state produces moment buffers with the restored per-leaf ranks
    st = mgr2.init_state()
    ranks = {leaf.m.shape[-1]
             for leaf in jax.tree.leaves(
                 st.opt_state.leaves,
                 is_leaf=lambda x: type(x).__name__ == "ProjAdamLeaf")
             if type(leaf).__name__ == "ProjAdamLeaf"}
    assert ranks == {6, 10}


def test_adaptive_trainer_sigterm_preemption_resume(tmp_path):
    """Simulated SIGTERM mid-run: the trainer checkpoints (controller
    state in the manifest) and exits; a fresh trainer+manager resumes with
    the same allocation and finishes."""
    import signal as _signal

    cfg = _tiny()
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, global_batch=2)

    def make_trainer(mgr, fire_at=None):
        fired = []

        def maybe_fire(record):
            if fire_at is not None and record["step"] == fire_at \
                    and not fired:
                fired.append(True)
                _signal.raise_signal(_signal.SIGTERM)   # preemption notice

        return Trainer(train_step=mgr.step, init_state_fn=mgr.init_state,
                       batch_fn=lambda s: ds.batch(jnp.int32(s)),
                       ckpt_dir=str(tmp_path), ckpt_every=100,
                       log_every=100, log_metrics=maybe_fire,
                       control_hook=mgr.control_hook, extra_state=mgr)

    mgr1 = _make_manager(tmp_path, cfg)
    state = make_trainer(mgr1, fire_at=6).run(total_steps=20)
    assert int(state.step) == 6                      # preempted mid-run
    cm = CheckpointManager(str(tmp_path))
    assert cm.latest_step() == 6                     # SIGTERM checkpointed
    saved = cm.manifest(6)["extra_state"]
    assert saved["rank_allocator"]["ema"]            # controllers had state

    # fresh process: controller state loads BEFORE the restore target is
    # built, so a restored non-uniform allocation shapes the opt state
    mgr2 = _make_manager(tmp_path, cfg)
    state = make_trainer(mgr2).run(total_steps=10)
    assert int(state.step) == 10
    assert mgr2.rank_allocator.state_dict()["ema"].keys() == \
        saved["rank_allocator"]["ema"].keys()


# ---------------------------------------------------------------------------
# structured log_metrics hook (telemetry sink + console both plug in)
# ---------------------------------------------------------------------------
def test_trainer_log_metrics_hook_and_console(tmp_path):
    cfg = _tiny()
    opt = get_optimizer("trion", lr=1e-3, rank=8)
    step_fn = jax.jit(make_train_step(cfg, opt))
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, global_batch=2)
    records, lines = [], []
    trainer = Trainer(train_step=step_fn,
                      init_state_fn=lambda: init_state(
                          cfg, opt, jax.random.PRNGKey(0)),
                      batch_fn=lambda s: ds.batch(jnp.int32(s)),
                      log_every=2, log_fn=lines.append,
                      log_metrics=records.append)
    trainer.run(total_steps=4)
    # hook sees every step, structured
    assert [r["step"] for r in records] == [1, 2, 3, 4]
    assert all("loss" in r and "s_per_step" in r for r in records)
    # the historic console line still appears at the historic cadence
    assert len(lines) == 2
    assert lines[0].startswith("[trainer] step 2 loss ")
    assert "ms/step" in lines[0]


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------
def test_schedules():
    s = linear_warmup(1.0, 10)
    assert float(s(jnp.int32(5))) == pytest.approx(0.5)
    c = cosine_warmup(1.0, 10, 110, final_frac=0.1)
    assert float(c(jnp.int32(110))) == pytest.approx(0.1, abs=1e-3)
    assert float(c(jnp.int32(10))) == pytest.approx(1.0, abs=1e-2)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------
def test_serve_engine_greedy_matches_forward():
    cfg = _tiny()
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, max_len=32)
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 6)), jnp.int32)
    out = eng.generate({"tokens": prompt}, max_new_tokens=4)
    assert out.shape == (2, 4)
    # the first generated token equals the argmax of the full forward
    logits, _ = T.forward(params, {"tokens": prompt}, cfg)
    np.testing.assert_array_equal(np.asarray(out[:, 0]),
                                  np.asarray(jnp.argmax(logits[:, -1], -1)))


def test_microbatch_grad_accumulation_equivalence():
    """Accumulated microbatch grads == full-batch grads (Adam at step 1
    turns fp noise into sign flips, so compare the gradients directly)."""
    from repro.train.steps import _split_micro, grad_fn
    cfg = _tiny()
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8)
    batch = ds.batch(jnp.int32(0))
    params = T.init_params(cfg, jax.random.PRNGKey(0))

    full, _ = grad_fn(params, batch, cfg)

    n_micro = 4
    micro = _split_micro(batch, n_micro)
    acc = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    for i in range(n_micro):
        mb = jax.tree.map(lambda x: x[i], micro)
        g, _ = grad_fn(params, mb, cfg)
        acc = jax.tree.map(lambda a, gi: a + gi / n_micro, acc, g)

    for a, b in zip(jax.tree.leaves(full), jax.tree.leaves(acc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-3)
