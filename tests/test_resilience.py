"""Resilient training (DESIGN.md §11): in-jit anomaly guard, escalation
ladder, verified checkpoints with rollback/quarantine, chaos harness,
progress-aware supervisor, and data-pipeline error propagation."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataPipeline
from repro.data.synthetic import SyntheticLM
from repro.models.config import ModelConfig
from repro.optim.api import get_optimizer
from repro.train.chaos import ChaosPlan, Fault, corrupt_file
from repro.train.checkpoint import CheckpointCorruptError, CheckpointManager
from repro.train.loop import Trainer
from repro.train.resilience import (
    HALT_EXIT_CODE,
    Action,
    ResilienceConfig,
    ResilienceManager,
    TrainingHalted,
    all_finite_tree,
    scale_hyperparam,
    select_tree,
)
from repro.train.steps import init_state, make_train_step


def _tiny():
    return ModelConfig(
        name="tiny", family="dense", d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=128, schedule=((("attn",), 2),),
        param_dtype="float32", compute_dtype="float32", remat=False,
        q_chunk=32, kv_chunk=32)


# ---------------------------------------------------------------------------
# in-jit guard primitives
# ---------------------------------------------------------------------------
def test_all_finite_tree():
    good = {"a": jnp.ones((3,)), "b": {"c": jnp.zeros((2, 2))},
            "i": jnp.arange(3)}                    # int leaves ignored
    assert bool(all_finite_tree(good))
    bad = dict(good, b={"c": jnp.array([[1.0, jnp.nan], [0.0, 0.0]])})
    assert not bool(all_finite_tree(bad))
    inf = dict(good, a=jnp.array([1.0, jnp.inf, 0.0]))
    assert not bool(all_finite_tree(inf))


def test_select_tree():
    new = {"w": jnp.ones((2,)), "s": jnp.int32(5)}
    old = {"w": jnp.zeros((2,)), "s": jnp.int32(4)}
    keep = select_tree(jnp.asarray(False), new, old)
    np.testing.assert_array_equal(np.asarray(keep["w"]), [0.0, 0.0])
    assert int(keep["s"]) == 4
    take = select_tree(jnp.asarray(True), new, old)
    np.testing.assert_array_equal(np.asarray(take["w"]), [1.0, 1.0])


def test_guarded_step_refuses_nonfinite_update():
    """A NaN-poisoned batch must leave the (donated) state untouched and
    report all_finite=False; a clean batch advances as usual."""
    cfg = _tiny()
    opt = get_optimizer("dct_adamw", lr=1e-3, rank=8, lr_scale=True)
    plan = ChaosPlan([Fault(step=1, site="grads", mode="nan")],
                     log_fn=lambda s: None)
    step_fn = jax.jit(make_train_step(cfg, opt, guard=True, chaos=plan),
                      donate_argnums=0)
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, global_batch=2)
    batch_fn = plan.wrap_batch_fn(lambda s: ds.batch(jnp.int32(s)))

    state = init_state(cfg, opt, jax.random.PRNGKey(0))
    ref = jax.tree.map(np.asarray, jax.device_get(state.params))

    state, m = step_fn(state, batch_fn(0))          # clean: commits
    assert bool(m["all_finite"])
    assert int(state.step) == 1
    after_one = jax.tree.map(np.asarray, jax.device_get(state.params))
    assert any(not np.array_equal(a, b) for a, b in
               zip(jax.tree.leaves(ref), jax.tree.leaves(after_one)))

    state, m = step_fn(state, batch_fn(1))          # poisoned: refused
    assert not bool(m["all_finite"])
    assert int(state.step) == 1                     # step did not advance
    for a, b in zip(jax.tree.leaves(after_one),
                    jax.tree.leaves(jax.device_get(state.params))):
        np.testing.assert_array_equal(a, np.asarray(b))
    assert bool(all_finite_tree(state.params))

    state, m = step_fn(state, batch_fn(2))          # recovers
    assert bool(m["all_finite"]) and int(state.step) == 2


def test_scale_hyperparam_surgery():
    opt = get_optimizer("adamw", lr=1e-2, lr_scale=True)
    params = {"w": jnp.ones((4, 4))}
    st = opt.init(params)
    st2, hits = scale_hyperparam(st, "lr_scale", 0.25)
    assert hits == 1
    # same treedef/shapes/dtypes: no retrace when fed to a compiled step
    assert jax.tree.structure(st) == jax.tree.structure(st2)
    _, hits = scale_hyperparam(st, "nonexistent", 0.5)
    assert hits == 0


# ---------------------------------------------------------------------------
# escalation ladder policy
# ---------------------------------------------------------------------------
def _mgr(**kw):
    return ResilienceManager(ResilienceConfig(**kw), log_fn=lambda s: None)


def test_ladder_skip_then_rollback_then_halt():
    m = _mgr(max_skips=2, max_rollbacks=2, lr_cut=0.5)
    assert m.observe(1, 1.0, True).kind == "ok"
    assert m.observe(2, float("nan"), False).kind == "skip"
    assert m.observe(2, float("nan"), False).kind == "skip"
    a = m.observe(2, float("nan"), False)           # skips exhausted
    assert a.kind == "rollback" and a.lr_factor == 1.0
    assert m.lr_scale == 1.0
    a = m.observe(2, float("nan"), False)
    assert a.kind == "skip"                         # counter reset post-roll
    assert m.observe(2, float("nan"), False).kind == "skip"
    a = m.observe(2, float("nan"), False)
    assert a.kind == "rollback" and a.lr_factor == 0.5
    assert m.lr_scale == 0.5                        # cumulative cut armed
    for _ in range(2):
        assert m.observe(2, float("nan"), False).kind == "skip"
    a = m.observe(2, float("nan"), False)
    assert a.kind == "halt" and m.halted
    with pytest.raises(TrainingHalted):
        raise TrainingHalted(a.reason)


def test_ladder_divergence_spike():
    m = _mgr(spike_factor=2.0, ema_warmup=3, spike_patience=2)
    for i in range(5):
        assert m.observe(i, 1.0, True).kind == "ok"
    a = m.observe(5, 10.0, True)                    # spike 1: tolerated
    assert a.kind == "ok" and "spike" in a.reason
    a = m.observe(6, 10.0, True)                    # spike 2: tolerated
    assert a.kind == "ok"
    a = m.observe(7, 10.0, True)                    # patience exhausted
    assert a.kind == "rollback" and "diverged" in a.reason
    # healthy steps reset the spike counter
    m2 = _mgr(spike_factor=2.0, ema_warmup=3, spike_patience=2)
    for i in range(5):
        m2.observe(i, 1.0, True)
    m2.observe(5, 10.0, True)
    m2.observe(6, 1.0, True)                        # recovers
    assert m2.observe(7, 10.0, True).kind == "ok"   # patience refilled


def test_ladder_heals_and_data_offset():
    m = _mgr(max_skips=0, max_rollbacks=2, heal_steps=3)
    assert m.observe(1, float("nan"), False).kind == "rollback"
    m.rolled_back(from_step=5, to_step=2)
    assert m.data_offset == 4                       # skips the bad window
    m.skipped()
    assert m.data_offset == 5
    assert m.n_rollbacks == 1
    for i in range(3):
        m.observe(10 + i, 1.0, True)
    assert m.n_rollbacks == 0                       # budget healed
    # persistence round-trip
    d = m.state_dict()
    m2 = _mgr()
    m2.load_state_dict(d)
    assert m2.data_offset == 5 and m2.lr_scale == m.lr_scale


def test_halt_dump(tmp_path):
    m = _mgr(max_skips=0, max_rollbacks=0)
    a = m.observe(3, float("nan"), False)
    assert a.kind == "halt"
    p = m.dump(str(tmp_path / "halt.json"), context={"trainer_step": 3})
    rec = json.loads(open(p).read())
    assert rec["halted"] and rec["recent_steps"][-1]["step"] == 3
    assert rec["trainer_step"] == 3


# ---------------------------------------------------------------------------
# checkpoint integrity: CRC verify, fallback, quarantine
# ---------------------------------------------------------------------------
def test_checkpoint_crc_detects_silent_corruption(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=4, log=lambda s: None)
    state = {"w": jnp.arange(64.0).reshape(8, 8), "b": jnp.ones((4,))}
    cm.save(1, state)
    cm.save(2, state)
    # rot the newest state.npz *behind* its OK marker
    corrupt_file(str(tmp_path / "step_2" / "state.npz"), mode="bitflip")
    with pytest.raises(CheckpointCorruptError):
        cm.verify(2)
    cm.verify(1)                                    # older one is fine
    # restore_latest falls back to 1 and quarantines 2
    target = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    step, restored = cm.restore_latest(target)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
    assert not (tmp_path / "step_2").exists()
    assert (tmp_path / "step_2.corrupt").exists()
    assert cm.all_steps() == [1]


def test_checkpoint_truncation_and_manifest_shape_mismatch(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=4, log=lambda s: None)
    state = {"w": jnp.ones((16, 16))}
    cm.save(1, state)
    corrupt_file(str(tmp_path / "step_1" / "state.npz"), mode="truncate")
    with pytest.raises(CheckpointCorruptError):
        cm.verify(1)
    assert cm.latest_verified_step() is None        # nothing survives
    assert (tmp_path / "step_1.corrupt").exists()

    cm.save(2, state)
    man = json.loads(open(tmp_path / "step_2" / "manifest.json").read())
    man["leaves"]["w"]["shape"] = [8, 8]
    with open(tmp_path / "step_2" / "manifest.json", "w") as f:
        json.dump(man, f)
    with pytest.raises(CheckpointCorruptError, match="manifest says"):
        cm.verify(2)


def test_checkpoint_preformat_loads_unverified(tmp_path):
    """Checkpoints written before the integrity format (no 'leaves'
    record) still restore — backward compatible."""
    cm = CheckpointManager(str(tmp_path), log=lambda s: None)
    state = {"w": jnp.ones((4,))}
    cm.save(3, state)
    man_path = tmp_path / "step_3" / "manifest.json"
    man = json.loads(open(man_path).read())
    del man["leaves"]
    with open(man_path, "w") as f:
        json.dump(man, f)
    assert cm.latest_verified_step() == 3
    cm.restore(3, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state))


def test_async_writer_killed_midwrite(tmp_path):
    """An aborted async writer leaves only a torn .tmp behind: the latest
    published checkpoint still loads, and a restarted manager sweeps the
    orphan."""
    plan = ChaosPlan([Fault(step=2, site="checkpoint", mode="abort",
                            arg="mid_write")], log_fn=lambda s: None)
    cm = CheckpointManager(str(tmp_path), keep=3, log=lambda s: None,
                           fault_hook=plan.bind_checkpoint_dir(
                               str(tmp_path)))
    state = {"w": jnp.ones((8, 8))}
    cm.async_save(1, state)
    cm.wait()
    cm.async_save(2, state)                         # writer dies mid-write
    cm.wait()
    assert cm.latest_verified_step() == 1           # publish never happened
    assert (tmp_path / "step_2.tmp").exists()       # torn dir left behind
    # a fresh manager (restarted process) sweeps the orphan on startup
    cm2 = CheckpointManager(str(tmp_path), log=lambda s: None)
    assert not (tmp_path / "step_2.tmp").exists()
    assert cm2.latest_verified_step() == 1


def test_save_drains_pending_writer(tmp_path):
    """The sync/async save race: save() must drain the pending writer
    before writing (two writers GC'ing the same dir tear keep-k)."""
    import threading
    import time

    release = threading.Event()

    def slow_hook(stage, step):
        if stage == "pre_publish" and step == 1:
            release.wait(5.0)

    cm = CheckpointManager(str(tmp_path), keep=2, log=lambda s: None,
                           fault_hook=slow_hook)
    state = {"w": jnp.ones((4,))}
    cm.async_save(1, state)
    time.sleep(0.05)                                # writer parked pre-publish
    t = threading.Thread(target=lambda: (time.sleep(0.05), release.set()))
    t.start()
    cm.save(2, state)                               # must drain 1 first
    t.join()
    assert cm.all_steps() == [1, 2]
    for s in (1, 2):
        cm.verify(s)


# ---------------------------------------------------------------------------
# chaos plan schema
# ---------------------------------------------------------------------------
def test_chaos_plan_spec_roundtrip(tmp_path):
    spec = [{"step": [3, 4], "site": "grads", "mode": "nan"},
            {"step": 6, "site": "checkpoint", "mode": "bitflip"},
            {"step": 2, "site": "data", "mode": "delay", "arg": 0.01}]
    p = tmp_path / "plan.json"
    p.write_text(json.dumps(spec))
    plan = ChaosPlan.load(str(p), log_fn=lambda s: None)
    assert len(plan.faults) == 4                    # step list expanded
    assert {f.step for f in plan.at("grads")} == {3, 4}
    assert plan.to_spec()[2]["mode"] == "bitflip"
    with pytest.raises(ValueError, match="unknown fault site"):
        Fault(step=1, site="nope", mode="nan")
    with pytest.raises(ValueError, match="has no mode"):
        Fault(step=1, site="grads", mode="sigkill")
    with pytest.raises(ValueError, match="stage"):
        Fault(step=1, site="checkpoint", mode="abort", arg="nope")


def test_chaos_batch_stamp_stripped_from_model():
    from repro.train.chaos import strip_chaos_key
    plan = ChaosPlan([], log_fn=lambda s: None)
    fn = plan.wrap_batch_fn(lambda s: {"tokens": jnp.zeros((2, 4))})
    b = fn(7)
    assert int(b["_chaos_step"]) == 7
    clean, cs = strip_chaos_key(b)
    assert "_chaos_step" not in clean and int(cs) == 7
    clean2, cs2 = strip_chaos_key({"tokens": jnp.zeros((2, 4))})
    assert cs2 is None


# ---------------------------------------------------------------------------
# end-to-end: NaN window + silently-corrupted checkpoint -> skip, quarantine,
# rollback to an older verified checkpoint, finish at target step
# ---------------------------------------------------------------------------
def test_chaos_e2e_rollback_past_corrupt_checkpoint(tmp_path):
    cfg = _tiny()
    opt = get_optimizer("dct_adamw", lr=1e-3, rank=8, lr_scale=True)
    plan = ChaosPlan([
        Fault(step=5, site="grads", mode="nan"),
        Fault(step=6, site="grads", mode="nan"),
        Fault(step=7, site="grads", mode="nan"),
        Fault(step=4, site="checkpoint", mode="bitflip"),
    ], log_fn=lambda s: None)
    step_fn = jax.jit(make_train_step(cfg, opt, guard=True, chaos=plan),
                      donate_argnums=0)
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, global_batch=2)
    res = ResilienceManager(ResilienceConfig(max_skips=2, max_rollbacks=3),
                            log_fn=lambda s: None)
    lines = []
    trainer = Trainer(
        train_step=step_fn,
        init_state_fn=lambda: init_state(cfg, opt, jax.random.PRNGKey(0)),
        batch_fn=plan.wrap_batch_fn(lambda s: ds.batch(jnp.int32(s))),
        ckpt_dir=str(tmp_path), ckpt_every=2, keep=4, log_every=100,
        log_fn=lines.append, resilience=res,
        ckpt_fault_hook=plan.bind_checkpoint_dir(str(tmp_path)))
    state = trainer.run(total_steps=12)

    assert int(state.step) == 12                    # reached the target
    assert bool(all_finite_tree(state.params))      # with finite params
    assert np.isfinite(float(trainer.metrics_history[-1]["loss"]))
    assert any("rollback: step 5 -> 2" in ln for ln in lines), lines
    # the bitflipped step-4 checkpoint was quarantined on the way down
    assert (tmp_path / "step_4.corrupt").exists()
    assert res.n_rollbacks == 1 and res.n_skips == 2
    # ladder state rode the manifests of post-recovery checkpoints
    cm = CheckpointManager(str(tmp_path), log=lambda s: None)
    saved = cm.manifest(cm.latest_step())["resilience"]
    assert saved["data_offset"] == res.data_offset > 0


def test_resilient_trainer_halts_on_exhausted_ladder(tmp_path):
    cfg = _tiny()
    opt = get_optimizer("dct_adamw", lr=1e-3, rank=8, lr_scale=True)
    # NaN on every batch: skips and rollbacks can never escape
    plan = ChaosPlan([Fault(step=s, site="grads", mode="nan")
                      for s in range(40)], log_fn=lambda s: None)
    step_fn = jax.jit(make_train_step(cfg, opt, guard=True, chaos=plan),
                      donate_argnums=0)
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, global_batch=2)
    res = ResilienceManager(ResilienceConfig(max_skips=1, max_rollbacks=2,
                                             lr_cut=0.5),
                            log_fn=lambda s: None)
    trainer = Trainer(
        train_step=step_fn,
        init_state_fn=lambda: init_state(cfg, opt, jax.random.PRNGKey(0)),
        batch_fn=plan.wrap_batch_fn(lambda s: ds.batch(jnp.int32(s))),
        ckpt_dir=str(tmp_path), ckpt_every=2, log_every=100,
        log_fn=lambda s: None, resilience=res)
    with pytest.raises(TrainingHalted):
        trainer.run(total_steps=10)
    assert res.lr_scale == 0.5                      # cut applied from roll 2
    rec = json.loads(open(tmp_path / "halt.json").read())
    assert rec["halted"] and rec["ladder"]["n_rollbacks"] == 3


# ---------------------------------------------------------------------------
# data pipeline error propagation
# ---------------------------------------------------------------------------
def test_pipeline_retries_transient_errors():
    calls = []

    def flaky(step):
        calls.append(step)
        if step == 1 and calls.count(1) < 3:
            raise OSError("transient storage blip")
        return {"step": step}

    p = DataPipeline(flaky, depth=2, timeout_s=5.0, retries=3,
                     retry_backoff_s=0.01)
    try:
        for s in range(3):
            assert p.get(s)["step"] == s
    finally:
        p.close()
    assert calls.count(1) == 3                      # healed on 3rd attempt


def test_pipeline_raises_persistent_error():
    def broken(step):
        if step >= 1:
            raise ValueError("bad shard")
        return {"step": step}

    p = DataPipeline(broken, depth=2, timeout_s=10.0, retries=1,
                     retry_backoff_s=0.01)
    try:
        assert p.get(0)["step"] == 0
        with pytest.raises(RuntimeError, match="failed permanently"):
            p.get(1)
    finally:
        p.close()


# ---------------------------------------------------------------------------
# supervisor: progress-aware restarts
# ---------------------------------------------------------------------------
def _child_script(tmp_path, fail_until: int, progress: bool) -> list[str]:
    """A scripted child: increments a run counter, optionally 'writes a
    checkpoint' (bumps a progress file), exits 1 until run >= fail_until."""
    script = textwrap.dedent(f"""
        import os, sys
        d = {str(tmp_path)!r}
        cp = os.path.join(d, "count")
        n = int(open(cp).read()) + 1 if os.path.exists(cp) else 1
        open(cp, "w").write(str(n))
        if {progress!r}:
            open(os.path.join(d, "progress"), "w").write(str(n))
        sys.exit(0 if n >= {fail_until} else 1)
    """)
    return [sys.executable, "-c", script]


def _progress_fn(tmp_path):
    def fn():
        p = os.path.join(str(tmp_path), "progress")
        return int(open(p).read()) if os.path.exists(p) else None
    return fn


def test_supervise_restarts_until_success(tmp_path):
    from repro.train.supervisor import supervise
    lines = []
    rc = supervise(_child_script(tmp_path, 3, progress=True),
                   max_restarts=5, backoff_s=0.01, log=lines.append,
                   progress_fn=_progress_fn(tmp_path))
    assert rc == 0
    assert open(tmp_path / "count").read() == "3"   # failed twice, then ok
    assert any("resume context" in ln for ln in lines)
    assert any("budget reset" in ln for ln in lines)


def test_supervise_budget_resets_on_progress(tmp_path):
    """With max_restarts=1 a child that fails 3 times would exhaust the
    budget — unless every attempt makes checkpoint progress."""
    from repro.train.supervisor import supervise
    rc = supervise(_child_script(tmp_path, 4, progress=True),
                   max_restarts=1, backoff_s=0.01, log=lambda s: None,
                   progress_fn=_progress_fn(tmp_path))
    assert rc == 0


def test_supervise_halts_on_crash_loop(tmp_path):
    from repro.train.supervisor import supervise
    lines = []
    rc = supervise(_child_script(tmp_path, 99, progress=False),
                   max_restarts=10, backoff_s=0.01, log=lines.append,
                   progress_fn=_progress_fn(tmp_path), crash_loop_limit=3)
    assert rc == 1
    assert open(tmp_path / "count").read() == "3"   # stopped at the limit
    assert any("crash loop" in ln for ln in lines)


def test_supervise_never_restarts_deliberate_halt(tmp_path):
    from repro.train.supervisor import supervise
    script = textwrap.dedent(f"""
        import os, sys
        d = {str(tmp_path)!r}
        cp = os.path.join(d, "count")
        n = int(open(cp).read()) + 1 if os.path.exists(cp) else 1
        open(cp, "w").write(str(n))
        sys.exit({HALT_EXIT_CODE})
    """)
    lines = []
    rc = supervise([sys.executable, "-c", script], max_restarts=5,
                   backoff_s=0.01, log=lines.append)
    assert rc == HALT_EXIT_CODE
    assert open(tmp_path / "count").read() == "1"   # exactly one attempt
    assert any("halted deliberately" in ln for ln in lines)


# ---------------------------------------------------------------------------
# guard + rollback under ZeRO-1 sharding (8 forced host devices)
# ---------------------------------------------------------------------------
_ZERO_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import contextlib, io, json, tempfile

    import numpy as np

    from repro.launch.train import main

    plan = [{"step": [4, 5, 6], "site": "grads", "mode": "nan"}]
    pp = os.path.join(tempfile.mkdtemp(prefix="chaos_"), "plan.json")
    with open(pp, "w") as f:
        json.dump(plan, f)

    def run(extra):
        ck = tempfile.mkdtemp(prefix="rck_")
        argv = ["--arch", "phi3-mini-3.8b", "--smoke",
                "--optimizer", "dct_adamw", "--rank", "8",
                "--steps", "8", "--seq-len", "16", "--batch", "8",
                "--ckpt-every", "3", "--ckpt-dir", ck, "--log-every", "1",
                "--resilient", "--chaos", pp] + extra
        buf = io.StringIO()
        with contextlib.redirect_stdout(buf):
            rc = main(argv)
        out = buf.getvalue()
        assert rc == 0, out
        assert "rollback: step 4 -> 3" in out, out
        loss = float(out.rsplit("loss ", 1)[1].split()[0])
        assert np.isfinite(loss), out
        return loss

    l_rep = run([])
    l_zero = run(["--zero", "1"])
    print(f"replicated loss {l_rep:.6f}  zero loss {l_zero:.6f}")
    assert abs(l_rep - l_zero) < 1e-4, (l_rep, l_zero)
    print("zero resilient parity OK")
""")


def test_zero_guard_rollback_parity():
    """The guard + ladder recover identically under ZeRO-1 sharding and on
    the replicated path (8 forced host devices, fresh process)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _ZERO_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "zero resilient parity OK" in proc.stdout
