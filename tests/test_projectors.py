"""Tests for the pluggable projector family (DCT drop-in for SVD/QR)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.projectors import Projector, rotation_matrix, shared_basis_for

M, N, R = 24, 16, 6


def _g(seed=0, batch=()):
    return jnp.asarray(
        np.random.default_rng(seed).standard_normal((*batch, M, N)).astype(np.float32)
    )


@pytest.mark.parametrize("kind", ["dct", "svd", "power", "random", "randperm"])
def test_projector_roundtrip_shapes(kind):
    p = Projector(kind=kind, r=R)
    g = _g()
    q = shared_basis_for(kind, N)
    state = p.init(g.shape)
    key = jax.random.PRNGKey(0)
    state = p.update(g, state, shared_q=q, key=key)
    low = p.project(g, state, shared_q=q)
    assert low.shape == (M, R)
    rec = p.backproject(low, state, shared_q=q, n=N)
    assert rec.shape == (M, N)
    # projection of reconstruction is idempotent (P^2 = P)
    low2 = p.project(rec, state, shared_q=q)
    np.testing.assert_allclose(np.asarray(low2), np.asarray(low), atol=1e-4)


def test_svd_is_best_dct_close():
    """SVD gives minimal reconstruction error; DCT should be within a modest
    factor (it approximates the eigenbasis, paper §4.2)."""
    g = _g(1)

    def err(kind):
        p = Projector(kind=kind, r=R)
        q = shared_basis_for(kind, N)
        state = p.update(g, p.init(g.shape), shared_q=q, key=jax.random.PRNGKey(1))
        rec = p.backproject(p.project(g, state, shared_q=q), state, shared_q=q, n=N)
        return float(jnp.linalg.norm(g - rec))

    e_svd, e_dct, e_randperm = err("svd"), err("dct"), err("randperm")
    assert e_svd <= e_dct + 1e-5
    # dct (adaptive) should beat identity-column sampling on gaussian data
    assert e_dct <= e_randperm * 1.2


def test_dct_state_is_indices_only():
    """The paper's memory claim: per-layer state is r int32 indices."""
    p = Projector(kind="dct", r=R)
    g = _g(2)
    q = shared_basis_for("dct", N)
    state = p.update(g, p.init(g.shape), shared_q=q)
    assert state.dtype == jnp.int32 and state.shape == (R,)


def test_rotation_permutation_equals_matmul():
    """R = Q_prev^T Q_crt computed as 0/1 permutation == paper-literal matmul."""
    p = Projector(kind="dct", r=R)
    q = shared_basis_for("dct", N)
    s1 = p.update(_g(3), p.init((M, N)), shared_q=q)
    s2 = p.update(_g(4), p.init((M, N)), shared_q=q)
    r_fast = np.asarray(rotation_matrix(s1, s2, p, N, shared_q=q))
    r_exact = np.asarray(rotation_matrix(s1, s2, p, N, shared_q=q, exact_matmul=True))
    np.testing.assert_allclose(r_fast, r_exact, atol=1e-4)


def test_stacked_layers_broadcast():
    p = Projector(kind="dct", r=R)
    g = _g(5, batch=(3, 2))
    q = shared_basis_for("dct", N)
    state = p.update(g, p.init(g.shape), shared_q=q)
    assert state.shape == (3, 2, R)
    low = p.project(g, state, shared_q=q)
    assert low.shape == (3, 2, M, R)
    rec = p.backproject(low, state, shared_q=q, n=N)
    assert rec.shape == g.shape
